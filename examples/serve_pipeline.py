"""Serving example: the same declarative pipeline run two ways — as an
offline Experiment, then as a long-lived online service through
``PipelineServer`` (continuous micro-batching over the compiled pipeline)
configured with ``ServeConfig`` builders, multiplexing a second tenant
pipeline over the same engine/scheduler/stage-cache (WFQ lanes, shared
prefix hits), plus an LM generation stage behind the decode batcher.

    PYTHONPATH=src python examples/serve_pipeline.py
"""
import time

import numpy as np
import jax

from repro.core import DenseRerank, Experiment, JaxBackend, Retrieve, format_table
from repro.core.data import make_queries
from repro.index import build_index, synthesize_corpus, synthesize_topics
from repro.models import transformer_lm as tlm
from repro.serve import PipelineServer, ServeConfig
from repro.serve.batching import ContinuousBatcher, Request


def main():
    # --- retrieval side -----------------------------------------------------
    corpus = synthesize_corpus(n_docs=10_000, vocab=30_000, mean_len=120)
    topics = synthesize_topics(corpus, n_topics=12, q_len=3)
    index = build_index(corpus)
    backend = JaxBackend(index, default_k=50)
    Q = make_queries(np.asarray(topics.terms), np.asarray(topics.weights),
                     np.asarray(topics.qids))

    pipe = (Retrieve("BM25") % 20) >> DenseRerank(alpha=0.3)
    res = Experiment([Retrieve("BM25") % 20, pipe], Q, topics.qrels,
                     ["map", "ndcg_cut_10"], backend=backend,
                     names=["bm25@20", "bm25>>dense"], measure_time=True)
    print(format_table(res["table"]))

    # --- the same pipeline as a multi-tenant online service -----------------
    cfg = (ServeConfig.default()
           .with_batching(max_wait_ms=4.0)
           .with_lanes(("interactive", 4.0), ("background", 1.0),
                       default="interactive"))
    server = PipelineServer(pipe, backend, cfg, name="dense")
    server.add_pipeline(Retrieve("BM25") % 20, name="bm25")  # second tenant:
    server.warmup(Q)       # compile every (stage, bucket) pair, per tenant
    server.start()         # shares the dense tenant's BM25 prefix via cache
    reqs = []
    for i in range(24):                  # queries arrive one at a time
        row = {k: np.asarray(v)[i % 12:i % 12 + 1] for k, v in Q.items()}
        tenant = "dense" if i < 12 else "bm25"
        reqs.append(server.submit_one(
            row, pipeline=tenant,
            lane="interactive" if tenant == "dense" else "background"))
        time.sleep(0.002)
    results = [r.wait(timeout=30) for r in reqs]
    server.stop()
    s = server.stats()
    print(f"\nserved {s['served']} queries in {s['batches']} micro-batches "
          f"(mean batch {s['mean_batch_size']}); "
          f"p50={s['latency_ms']['p50_ms']}ms "
          f"p95={s['latency_ms']['p95_ms']}ms; "
          f"cache hit depths {s['cache_hit_depths']}; "
          f"cross-pipeline prefix hits: {s['cross_pipeline_hits']}; "
          f"lane slots {s['lane_served']}; "
          f"recompiles after warmup: {s['recompiles_since_warmup']}")
    top = np.asarray(results[0]["docids"])[0, :5]
    print(f"rid=1 top-5 docids: {top}")

    # --- serving side: LM behind the continuous batcher ---------------------
    cfg = tlm.LMConfig(name="serve-demo", n_layers=2, d_model=64, n_q=4,
                       n_kv=2, d_head=16, d_ff=128, vocab=512)
    params = tlm.init_params(cfg, jax.random.key(0))
    batcher = ContinuousBatcher(cfg, params, slots=4, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(6):
        batcher.submit(Request(
            rid=rid, prompt=rng.integers(0, 512, 8, dtype=np.int32),
            max_new_tokens=6))
    done = batcher.run_to_completion()
    print(f"\nserved {len(done)} generation requests through the batcher; "
          f"e.g. rid=0 -> {done[0].generated}")


if __name__ == "__main__":
    main()
