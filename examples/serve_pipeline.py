"""Serving example: a declarative retrieval pipeline whose re-rank stage is
an LM served through the continuous-batching scheduler — the paper's
"neural re-ranker in the pipeline" (CEDR slot) with production serving.

    PYTHONPATH=src python examples/serve_pipeline.py
"""
import numpy as np
import jax

from repro.core import DenseRerank, Experiment, JaxBackend, Retrieve, format_table
from repro.core.data import make_queries
from repro.index import build_index, synthesize_corpus, synthesize_topics
from repro.models import transformer_lm as tlm
from repro.serve.batching import ContinuousBatcher, Request


def main():
    # --- retrieval side -----------------------------------------------------
    corpus = synthesize_corpus(n_docs=10_000, vocab=30_000, mean_len=120)
    topics = synthesize_topics(corpus, n_topics=12, q_len=3)
    index = build_index(corpus)
    backend = JaxBackend(index, default_k=50)
    Q = make_queries(np.asarray(topics.terms), np.asarray(topics.weights),
                     np.asarray(topics.qids))

    pipe = (Retrieve("BM25") % 20) >> DenseRerank(alpha=0.3)
    res = Experiment([Retrieve("BM25") % 20, pipe], Q, topics.qrels,
                     ["map", "ndcg_cut_10"], backend=backend,
                     names=["bm25@20", "bm25>>dense"], measure_time=True)
    print(format_table(res["table"]))

    # --- serving side: LM behind the continuous batcher ---------------------
    cfg = tlm.LMConfig(name="serve-demo", n_layers=2, d_model=64, n_q=4,
                       n_kv=2, d_head=16, d_ff=128, vocab=512)
    params = tlm.init_params(cfg, jax.random.key(0))
    batcher = ContinuousBatcher(cfg, params, slots=4, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(6):
        batcher.submit(Request(
            rid=rid, prompt=rng.integers(0, 512, 8, dtype=np.int32),
            max_new_tokens=6))
    done = batcher.run_to_completion()
    print(f"\nserved {len(done)} generation requests through the batcher; "
          f"e.g. rid=0 -> {done[0].generated}")


if __name__ == "__main__":
    main()
