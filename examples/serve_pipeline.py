"""Serving example: the same declarative pipeline run two ways — as an
offline Experiment, then as a long-lived online service through
``PipelineServer`` (continuous micro-batching over the compiled pipeline)
configured with ``ServeConfig`` builders, multiplexing a second tenant
pipeline over the same engine/scheduler/stage-cache (WFQ lanes, shared
prefix hits), plus a full RAG chain — ``retrieve >> rerank % k >>
generate`` — served with token-level continuous batching.

    PYTHONPATH=src python examples/serve_pipeline.py
"""
import time

import numpy as np

from repro import (DenseRerank, Experiment, Generate, JaxBackend,
                   PipelineServer, Retrieve, ServeConfig, format_table,
                   make_queries)
from repro.index import build_index, synthesize_corpus, synthesize_topics
from repro.models import transformer_lm as tlm


def main():
    # --- retrieval side -----------------------------------------------------
    corpus = synthesize_corpus(n_docs=10_000, vocab=30_000, mean_len=120)
    topics = synthesize_topics(corpus, n_topics=12, q_len=3)
    index = build_index(corpus)
    backend = JaxBackend(index, default_k=50)
    Q = make_queries(np.asarray(topics.terms), np.asarray(topics.weights),
                     np.asarray(topics.qids))

    pipe = (Retrieve("BM25") % 20) >> DenseRerank(alpha=0.3)
    res = Experiment([Retrieve("BM25") % 20, pipe], Q, topics.qrels,
                     ["map", "ndcg_cut_10"], backend=backend,
                     names=["bm25@20", "bm25>>dense"], measure_time=True)
    print(format_table(res["table"]))

    # --- the same pipeline as a multi-tenant online service -----------------
    cfg = (ServeConfig.default()
           .with_batching(max_wait_ms=4.0)
           .with_lanes(("interactive", 4.0), ("background", 1.0),
                       default="interactive"))
    server = PipelineServer(pipe, backend, cfg, name="dense")
    server.add_pipeline(Retrieve("BM25") % 20, name="bm25")  # second tenant:
    server.warmup(Q)       # compile every (stage, bucket) pair, per tenant
    server.start()         # shares the dense tenant's BM25 prefix via cache
    reqs = []
    for i in range(24):                  # queries arrive one at a time
        row = {k: np.asarray(v)[i % 12:i % 12 + 1] for k, v in Q.items()}
        tenant = "dense" if i < 12 else "bm25"
        reqs.append(server.submit_one(
            row, pipeline=tenant,
            lane="interactive" if tenant == "dense" else "background"))
        time.sleep(0.002)
    results = [r.wait(timeout=30) for r in reqs]
    server.stop()
    s = server.stats()
    print(f"\nserved {s['served']} queries in {s['batches']} micro-batches "
          f"(mean batch {s['mean_batch_size']}); "
          f"p50={s['latency_ms']['p50_ms']}ms "
          f"p95={s['latency_ms']['p95_ms']}ms; "
          f"cache hit depths {s['cache_hit_depths']}; "
          f"cross-pipeline prefix hits: {s['cross_pipeline_hits']}; "
          f"lane slots {s['lane_served']}; "
          f"recompiles after warmup: {s['recompiles_since_warmup']}")
    top = np.asarray(results[0]["docids"])[0, :5]
    print(f"rid=1 top-5 docids: {top}")

    # --- RAG: the same retrieval prefix feeding a generate leaf -------------
    # Generate is a typed IR stage (R -> A, terminal): the retrieval prefix
    # rides the bucketed micro-batches above while prompts decode in a
    # continuous-batched slot pool, new requests admitted between decode
    # steps.  All decode shapes are pinned in the engine's jit cache, so
    # the zero-recompile invariant covers generation too.
    lm_cfg = tlm.LMConfig(name="serve-demo", n_layers=2, d_model=64, n_q=4,
                          n_kv=2, d_head=16, d_ff=128, vocab=512)
    backend.register_lm(lm_cfg.name, lm_cfg)
    rag = (pipe % 8 >> Generate(lm_cfg.name, max_new_tokens=8,
                                max_prompt_len=48, prompt_docs=3))
    rag_server = PipelineServer(
        rag, backend, ServeConfig.default().with_decode(4))
    rag_server.warmup(Q)
    rag_reqs = [rag_server.submit_one(
        {k: np.asarray(v)[i:i + 1] for k, v in Q.items()})
        for i in range(12)]
    rag_server.pump()
    answers = [r.wait(30) for r in rag_reqs]
    s = rag_server.stats()
    print(f"\nserved {s['decode']['requests']} RAG requests "
          f"({s['decode']['tokens']} tokens) through "
          f"{s['decode_pools']['default']['slots']} decode slots in "
          f"{s['decode_pools']['default']['decode_steps']} decode steps; "
          f"ttft p95={s['decode']['ttft_ms']['p95_ms']}ms, "
          f"per-token p95={s['decode']['per_token_ms']['p95_ms']}ms; "
          f"recompiles after warmup: {s['recompiles_since_warmup']}")
    print(f"rid=0 answer tokens: {np.asarray(answers[0]['tokens'])[0].tolist()}")


if __name__ == "__main__":
    main()
