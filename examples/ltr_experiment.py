"""Listing 1 of the paper, end to end: PRF candidates + multi-model features
+ a trained LTR re-ranker, evaluated against the first-pass baseline.

    PYTHONPATH=src python examples/ltr_experiment.py
"""
import numpy as np

from repro.core import (Experiment, Extract, JaxBackend, LTRRerank, Retrieve,
                        RM3Expand, SDMRewrite, format_table)
from repro.core.data import make_queries
from repro.index import build_index, synthesize_corpus, synthesize_topics


def main():
    corpus = synthesize_corpus(n_docs=15_000, vocab=40_000, mean_len=150)
    train_topics = synthesize_topics(corpus, n_topics=24, q_len=3, seed=1)
    test_topics = synthesize_topics(corpus, n_topics=24, q_len=3, seed=2)
    index = build_index(corpus)
    backend = JaxBackend(index, default_k=50)

    Qtr = make_queries(np.asarray(train_topics.terms),
                       np.asarray(train_topics.weights),
                       np.asarray(train_topics.qids))
    Qte = make_queries(np.asarray(test_topics.terms),
                       np.asarray(test_topics.weights),
                       np.asarray(test_topics.qids))

    # Listing 1 structure (adapted): first pass, PRF, sdm, features -> LTR
    first_pass = Retrieve("BM25", k=50)
    prf = first_pass >> RM3Expand(fb_docs=5, fb_terms=8) >> Retrieve("BM25", k=50)
    sdm = SDMRewrite() >> Retrieve("BM25", k=50)
    features = prf >> (Extract("QL") ** Extract("TF_IDF") ** Extract("DPH"))
    full_pipeline = features >> LTRRerank(n_features=3, epochs=40)

    # train the pipeline (fit propagates to the LTR stage, paper eq. 9)
    full_pipeline.fit(Qtr, train_topics.qrels, backend=backend)

    res = Experiment(
        [first_pass, prf, sdm, full_pipeline],
        Qte, test_topics.qrels, ["map", "ndcg_cut_10", "P_10"],
        backend=backend,
        names=["bm25", "bm25+rm3", "sdm>>bm25", "full (ltr)"],
        measure_time=True)
    print(format_table(res["table"]))


if __name__ == "__main__":
    main()
