"""Quickstart: declarative IR pipelines, rewriting, and evaluation.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the paper's core flow: declare pipelines with operators, let the
compiler rewrite them against the backend's capabilities, evaluate
side-by-side with Experiment.
"""
import numpy as np

from repro.core import (Experiment, Extract, JaxBackend, Retrieve, RM3Expand,
                        compile_pipeline, format_table, raise_ir)
from repro.core.data import make_queries
from repro.index import build_index, synthesize_corpus, synthesize_topics


def main():
    # 1. a (synthetic) test collection + JAX-native inverted index
    corpus = synthesize_corpus(n_docs=20_000, vocab=50_000, mean_len=150)
    topics = synthesize_topics(corpus, n_topics=25, q_len=3)
    index = build_index(corpus)
    backend = JaxBackend(index, default_k=100)
    Q = make_queries(np.asarray(topics.terms), np.asarray(topics.weights),
                     np.asarray(topics.qids))

    # 2. declare pipelines with the operator algebra (paper Table 2)
    bm25 = Retrieve("BM25")
    top10 = bm25 % 10                                   # rank cutoff
    fusion = 0.7 * Retrieve("BM25", k=100) + 0.3 * Retrieve("QL", k=100)
    prf = Retrieve("BM25", k=100) >> RM3Expand() >> Retrieve("BM25", k=100)
    fat = Retrieve("BM25", k=100) >> (Extract("QL") ** Extract("TF_IDF"))

    # 3. the compiler rewrites them against backend capabilities
    for name, pipe in [("cutoff", top10), ("fusion", fusion), ("fat", fat)]:
        trace = []
        opt = raise_ir(compile_pipeline(pipe, backend, trace=trace))
        print(f"{name:8s} {pipe!r}\n     -->  {opt!r}"
              f"   (rules: {[t[0] for t in trace]})")

    # 3b. or inspect the full compiler pipeline: typed IR before/after
    # each pass (schemas, rewrites, the cost-gated kernel lowering)
    print()
    print(top10.explain(backend))

    # 4. evaluate side-by-side (common topics/qrels, shared prefix cache)
    res = Experiment(
        [bm25 % 100, fusion, prf],
        Q, topics.qrels, ["map", "ndcg_cut_10", "P_10"],
        backend=backend, names=["bm25", "fusion", "bm25+rm3"],
        measure_time=True)
    print()
    print(format_table(res["table"]))


if __name__ == "__main__":
    main()
