"""End-to-end LM training driver (deliverable b): trains a ~100M-param
decoder-only LM with the full substrate — deterministic data pipeline,
AdamW + cosine schedule, grad accumulation, async checkpointing, fault-
tolerant StepGuard.

    PYTHONPATH=src python examples/train_lm.py --preset 10m --steps 300
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

(The 100m preset is the deliverable configuration; 10m runs a quick
same-code demonstration on slow hosts.)
"""
import argparse
import functools
import time

import jax

from repro.models import transformer_lm as tlm
from repro.train import data as data_lib
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts
from repro.train.fault import StepGuard

PRESETS = {
    # ~110M params: 12L x 768, ff 2048, 32k vocab (tied)
    "100m": dict(n_layers=12, d_model=768, n_q=12, n_kv=4, d_head=64,
                 d_ff=2048, vocab=32768, batch=8, seq=256),
    # ~13M params: fast smoke-scale
    "10m": dict(n_layers=6, d_model=256, n_q=8, n_kv=4, d_head=32,
                d_ff=1024, vocab=8192, batch=8, seq=128),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--attn-impl", default="flash")
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = tlm.LMConfig(
        name=f"lm-{args.preset}", n_layers=p["n_layers"], d_model=p["d_model"],
        n_q=p["n_q"], n_kv=p["n_kv"], d_head=p["d_head"], d_ff=p["d_ff"],
        vocab=p["vocab"], tie_embeddings=True, attn_impl=args.attn_impl)
    print(f"{cfg.name}: {cfg.params_total/1e6:.1f}M params")

    params = tlm.init_params(cfg, jax.random.key(0))
    state = ts.init_state(params)
    opt_cfg = opt_lib.AdamWConfig(lr=3e-3, warmup_steps=args.steps // 10,
                                  total_steps=args.steps)
    step_fn = jax.jit(ts.make_train_step(
        functools.partial(tlm.loss_fn, cfg), opt_cfg, n_micro=2),
        donate_argnums=0)

    pipeline = data_lib.DataPipeline(
        data_lib.lm_batch_fn(cfg.vocab, p["batch"], p["seq"]))
    guard = StepGuard(args.ckpt_dir, ckpt_every=50)

    hist = []
    t0 = time.time()

    def logged(state, batch):
        s, m = step_fn(state, batch)
        hist.append(float(m["ce"]))
        if len(hist) % 20 == 0:
            print(f"step {len(hist):4d}  ce={hist[-1]:.4f}  "
                  f"({(time.time()-t0)/len(hist)*1000:.0f} ms/step)")
        return s, m

    state, _, step = guard.run(state, pipeline.iter_from, logged, args.steps)
    print(f"finished {step} steps: ce {hist[0]:.3f} -> {hist[-1]:.3f} "
          f"(min {min(hist):.3f})")


if __name__ == "__main__":
    main()
