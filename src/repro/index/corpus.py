"""Synthetic TREC-scale corpora (Zipf term distribution) + topics + qrels.

The paper evaluates on TREC Disks 4&5 (528,155 docs) and ClueWeb09 (50.2M).
We synthesise corpora with matched statistics: Zipf-1.07 unigram distribution,
log-normal document lengths (mean ≈ 300 terms for Robust, ≈ 800 for web), and
topics of configurable length (T / TD / TDN ≈ 3 / 10 / 30 terms).

Relevance is *planted*: each topic selects a set of relevant documents whose
term distributions are tilted toward the topic terms (with noise), so
effectiveness metrics are non-degenerate without making any single weighting
model trivially perfect.
"""
from __future__ import annotations

import dataclasses

import numpy as np

ROBUST_DOCS = 528_155
CLUEWEB_DOCS = 50_220_423   # descriptor scale; materialised only in dry-runs


@dataclasses.dataclass
class Corpus:
    doc_terms: np.ndarray      # [total_tokens] int32 term ids, doc-major
    doc_start: np.ndarray      # [D+1] int64 CSR offsets
    vocab: int

    @property
    def n_docs(self) -> int:
        return len(self.doc_start) - 1


def synthesize_corpus(n_docs: int = 20_000, vocab: int = 50_000,
                      mean_len: int = 300, seed: int = 0,
                      zipf_s: float = 1.07) -> Corpus:
    rng = np.random.default_rng(seed)
    lens = np.maximum(
        rng.lognormal(np.log(mean_len), 0.5, n_docs).astype(np.int64), 8)
    total = int(lens.sum())
    # Zipf sampling via inverse-CDF over precomputed weights
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    w = ranks ** -zipf_s
    cdf = np.cumsum(w / w.sum())
    u = rng.random(total)
    terms = np.searchsorted(cdf, u).astype(np.int32)
    doc_start = np.zeros(n_docs + 1, np.int64)
    np.cumsum(lens, out=doc_start[1:])
    return Corpus(terms, doc_start, vocab)


@dataclasses.dataclass
class Topics:
    qids: np.ndarray          # [NQ] int32
    terms: np.ndarray         # [NQ, MAXQ] int32, padded with -1
    weights: np.ndarray       # [NQ, MAXQ] float32 (0 where padded)
    qrels: dict[int, dict[int, int]]   # qid -> {docid: grade}


def synthesize_topics(corpus: Corpus, n_topics: int = 50, q_len: int = 3,
                      max_q_len: int = 32, rels_per_topic: int = 30,
                      seed: int = 1) -> Topics:
    """Sample mid-frequency query terms; plant graded relevant docs by
    injecting topic terms into their token streams."""
    rng = np.random.default_rng(seed)
    lo, hi = corpus.vocab // 200, corpus.vocab // 4  # mid-frequency band
    terms = np.full((n_topics, max_q_len), -1, np.int32)
    weights = np.zeros((n_topics, max_q_len), np.float32)
    qrels: dict[int, dict[int, int]] = {}
    for q in range(n_topics):
        qt = rng.choice(np.arange(lo, hi), size=q_len, replace=False).astype(np.int32)
        terms[q, :q_len] = qt
        weights[q, :q_len] = 1.0
        # relevant docs: mild, graded term injection (noisy — some rel docs
        # receive few topic terms and will be missed by lexical rankers)
        picked = rng.choice(corpus.n_docs, size=4 * rels_per_topic, replace=False)
        rel_docs, distractors = picked[:rels_per_topic], picked[rels_per_topic:]
        grades = {}
        for j, d in enumerate(rel_docs):
            grade = 2 if j < rels_per_topic // 5 else 1
            s, e = corpus.doc_start[d], corpus.doc_start[d + 1]
            n_inject = min(int(rng.poisson(1 + grade * q_len / 2)) + 1, e - s)
            pos = rng.integers(s, e, n_inject)
            corpus.doc_terms[pos] = rng.choice(qt, n_inject)
            grades[int(d)] = grade
        # distractors: topically-matching but NOT relevant documents
        for d in distractors:
            s, e = corpus.doc_start[d], corpus.doc_start[d + 1]
            n_inject = min(int(rng.poisson(0.8)) + 1, e - s)
            pos = rng.integers(s, e, n_inject)
            corpus.doc_terms[pos] = rng.choice(qt, n_inject)
        qrels[q] = grades
    return Topics(np.arange(n_topics, dtype=np.int32), terms, weights, qrels)


def expand_topics(topics: Topics, q_len: int, seed: int = 2) -> Topics:
    """Lengthen topics (T -> TD -> TDN formulations) by sampling extra terms
    correlated with the originals (hash-derived neighbours + noise)."""
    rng = np.random.default_rng(seed)
    terms = topics.terms.copy()
    weights = topics.weights.copy()
    for q in range(terms.shape[0]):
        base = terms[q][terms[q] >= 0]
        have = len(base)
        vocab_hi = int(base.max() * 2 + 7)
        extra = []
        while have + len(extra) < q_len:
            t = int(base[rng.integers(0, len(base))])
            extra.append((t * 31 + 7 + int(rng.integers(0, 64))) % vocab_hi)
        terms[q, have:have + len(extra)] = np.array(extra, np.int32)
        weights[q, have:have + len(extra)] = 0.5   # description terms weigh less
    return Topics(topics.qids, terms, weights, topics.qrels)
