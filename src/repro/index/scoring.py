"""Weighting models over gathered postings — BM25, TF.IDF, QL-Dirichlet, DPH,
CoordMatch — each with a block-level score upper bound for block-max pruning.

All functions are pure jnp over arrays shaped [..] of (tf, doc_len) with
per-term (df, cf) broadcast alongside; collection stats enter as scalars.
The multi-model single-pass evaluation used by the fused "fat" pipeline is
:func:`score_all` (one gather, F model scores) — the paper's RQ2 insight.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import Registry

WEIGHTING_MODELS = Registry("weighting model")

# Default parameters (Terrier/Anserini defaults)
BM25_K1, BM25_B = 1.2, 0.75
QL_MU = 2500.0


def _idf(df, n_docs):
    return jnp.log1p((n_docs - df + 0.5) / (df + 0.5))


@WEIGHTING_MODELS.register("BM25")
def bm25(tf, doc_len, df, cf, stats):
    tf = tf.astype(jnp.float32)
    dl = doc_len.astype(jnp.float32)
    idf = _idf(df.astype(jnp.float32), stats["n_docs"])
    denom = tf + BM25_K1 * (1 - BM25_B + BM25_B * dl / stats["avg_doclen"])
    return idf * tf * (BM25_K1 + 1.0) / jnp.maximum(denom, 1e-9)


@WEIGHTING_MODELS.register("TF_IDF")
def tf_idf(tf, doc_len, df, cf, stats):
    tf = tf.astype(jnp.float32)
    idf = jnp.log(stats["n_docs"] / jnp.maximum(df.astype(jnp.float32), 1.0))
    # Robertson's TF with length normalisation
    k = 1.2 * (0.25 + 0.75 * doc_len.astype(jnp.float32) / stats["avg_doclen"])
    return idf * tf / (tf + k)


@WEIGHTING_MODELS.register("QL")
def ql_dirichlet(tf, doc_len, df, cf, stats):
    """Query likelihood w/ Dirichlet smoothing (log-space, shifted so that
    tf=0 contributes 0 — rank-equivalent and sparse-friendly)."""
    tf = tf.astype(jnp.float32)
    dl = doc_len.astype(jnp.float32)
    p_c = cf.astype(jnp.float32) / stats["total_terms"]
    num = tf + QL_MU * p_c
    den = dl + QL_MU
    base = QL_MU * p_c / jnp.maximum(den, 1.0)
    return jnp.log(jnp.maximum(num, 1e-20) / jnp.maximum(den, 1.0)) - \
        jnp.log(jnp.maximum(base, 1e-20))


@WEIGHTING_MODELS.register("DPH")
def dph(tf, doc_len, df, cf, stats):
    tf = tf.astype(jnp.float32)
    dl = jnp.maximum(doc_len.astype(jnp.float32), 1.0)
    f = jnp.clip(tf / dl, 1e-9, 1.0 - 1e-9)
    norm = (1.0 - f) ** 2 / (tf + 1.0)
    avg = stats["total_terms"] / stats["n_docs"]
    info = tf * jnp.log2(jnp.maximum(
        tf * avg / dl * stats["n_docs"] / jnp.maximum(cf.astype(jnp.float32), 1.0),
        1e-9))
    bonus = 0.5 * jnp.log2(2.0 * jnp.pi * tf * (1.0 - f) + 1e-9)
    return jnp.maximum(norm * (info + bonus), 0.0)


@WEIGHTING_MODELS.register("Coord")
def coord(tf, doc_len, df, cf, stats):
    """Coordination level match (# matching terms)."""
    return (tf > 0).astype(jnp.float32)


def upper_bound(model: str, block_max_tf, block_min_dl, df, cf, stats):
    """Per-block score upper bound: evaluate the model at the block's most
    favourable (tf, dl) corner.  Monotone in tf and anti-monotone in dl for
    all registered models."""
    fn = WEIGHTING_MODELS[model]
    return fn(block_max_tf, block_min_dl, df, cf, stats)


def score_all(models: list[str], tf, doc_len, df, cf, stats) -> jax.Array:
    """Single-pass multi-model scoring: [..] inputs -> [.., F] scores.

    This is the fused *fat* evaluation: the postings gather is shared and
    every weighting model reads the same registers/VMEM-resident tiles.
    """
    outs = [WEIGHTING_MODELS[m](tf, doc_len, df, cf, stats) for m in models]
    return jnp.stack(outs, axis=-1)
