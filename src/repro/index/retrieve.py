"""Retrieval engines over the JAX inverted index.

Three evaluation strategies — the backend capabilities the pipeline
compiler's rewrite rules target (cf. paper §4):

* ``score_exhaustive``  — term-at-a-time over all postings, dense [D] scores,
                          full sort. The unoptimised ``Retrieve() % K`` path.
* ``retrieve_pruned``   — TPU-adapted BlockMaxWAND: per-block score upper
                          bounds, top-``n_blocks`` block selection (budget is
                          a function of K), sparse aggregation, k-dependent
                          work end-to-end.  The target of the RQ1 rewrite.
* ``retrieve_fat``      — single-pass *multi-model* retrieval: one postings
                          gather scores the ranking model AND every feature
                          model (fat postings [Macdonald et al.]).  The
                          target of the RQ2 rewrite.

Plus the unoptimised counterpart of fat: ``extract_features_docvectors``
(per-feature passes over the direct index, Asadi & Lin's doc-vectors).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.common import cdiv
from repro.index.inverted import BLOCK, InvertedIndex, gather_postings
from repro.index import scoring


def _posting_scores(index, post, weights, model):
    """Per-posting weighted scores [MAXQ, L] for one weighting model."""
    dl = index.doc_len[post["doc_ids"]]
    s = scoring.WEIGHTING_MODELS[model](
        post["tfs"], dl, post["df"][:, None], post["cf"][:, None], index.stats)
    return s * weights[:, None] * post["mask"]


@partial(jax.jit, static_argnames=("model", "max_postings"))
def score_exhaustive(index: InvertedIndex, terms, weights, *,
                     model: str = "BM25", max_postings: int) -> jax.Array:
    """Dense scores [n_docs] for one query (terms [MAXQ])."""
    post = gather_postings(index, terms, max_postings)
    s = _posting_scores(index, post, weights, model)
    return jnp.zeros((index.n_docs,), jnp.float32).at[
        post["doc_ids"].reshape(-1)].add(s.reshape(-1))


@partial(jax.jit, static_argnames=("model", "max_postings", "k"))
def retrieve_topk(index: InvertedIndex, terms, weights, *, model: str,
                  k: int, max_postings: int):
    scores = score_exhaustive(index, terms, weights, model=model,
                              max_postings=max_postings)
    top_s, top_d = jax.lax.top_k(scores, k)
    return top_d.astype(jnp.int32), top_s


# ---------------------------------------------------------------------------
# block-max pruned retrieval
# ---------------------------------------------------------------------------

def block_budget(k: int, n_terms: int) -> int:
    """Block budget as a function of K — the dynamic-pruning dial that the
    RQ1 rewrite turns.  ~4x oversampling plus a floor per query term."""
    return max(4 * n_terms, 4 * cdiv(4 * k, BLOCK) * n_terms)


def _aggregate_sparse(doc_ids, scores, k):
    """Combine duplicate doc ids (sort + boundary segment-sum) then top-k."""
    n = doc_ids.shape[0]
    order = jnp.argsort(doc_ids)
    d = doc_ids[order]
    s = scores[order]
    seg = jnp.cumsum(jnp.concatenate([jnp.zeros(1, jnp.int32),
                                      (d[1:] != d[:-1]).astype(jnp.int32)]))
    agg = jax.ops.segment_sum(s, seg, num_segments=n)
    first = jnp.concatenate([jnp.ones(1, bool), d[1:] != d[:-1]])
    rep = jnp.where(first, agg[seg], -jnp.inf)
    rep = jnp.where(d >= 0, rep, -jnp.inf)     # drop padding docs
    top_s, idx = jax.lax.top_k(rep, k)
    return d[idx].astype(jnp.int32), top_s


@partial(jax.jit, static_argnames=("model", "k", "n_blocks", "max_blocks_per_term"))
def retrieve_pruned(index: InvertedIndex, terms, weights, *, model: str,
                    k: int, n_blocks: int, max_blocks_per_term: int):
    """Approximate top-k via block-max pruning (TPU-adapted BMW).

    1. per (term, block): score upper bound from (block_max_tf, block_min_dl)
    2. global top-``n_blocks`` blocks by UB        (the block skip)
    3. gather + score ONLY those blocks' postings  (k-dependent work)
    4. sparse aggregate + top-k
    """
    MAXQ = terms.shape[0]
    t = jnp.maximum(terms, 0)
    start_blk = (index.term_start[t] // BLOCK).astype(jnp.int32)
    n_blk = ((index.term_start[t + 1] - index.term_start[t]) // BLOCK).astype(jnp.int32)
    blk_idx = start_blk[:, None] + jnp.arange(max_blocks_per_term)[None, :]
    blk_valid = (jnp.arange(max_blocks_per_term)[None, :] < n_blk[:, None]) & \
        (terms >= 0)[:, None]
    blk_idx = jnp.minimum(blk_idx, index.block_max_tf.shape[0] - 1)

    ub = scoring.upper_bound(
        model, index.block_max_tf[blk_idx], index.block_min_dl[blk_idx],
        index.df[t][:, None], index.cf[t][:, None], index.stats)
    ub = jnp.where(blk_valid, ub * weights[:, None], -jnp.inf)

    flat_ub = ub.reshape(-1)
    _, sel = jax.lax.top_k(flat_ub, n_blocks)          # block selection
    sel_term = sel // max_blocks_per_term               # term providing df/cf
    sel_blk = blk_idx.reshape(-1)[sel]
    sel_valid = jnp.isfinite(flat_ub[sel])

    pos = sel_blk[:, None].astype(jnp.int64) * BLOCK + jnp.arange(BLOCK)[None, :]
    docs = index.doc_ids[pos]
    tfs = index.tfs[pos]
    mask = sel_valid[:, None] & (docs >= 0)
    dl = index.doc_len[jnp.maximum(docs, 0)]
    df = index.df[t][sel_term][:, None]
    cf = index.cf[t][sel_term][:, None]
    s = scoring.WEIGHTING_MODELS[model](tfs, dl, df, cf, index.stats)
    s = s * weights[sel_term][:, None] * mask
    flat_docs = jnp.where(mask, docs, -1).reshape(-1)
    return _aggregate_sparse(flat_docs, s.reshape(-1), k)


# ---------------------------------------------------------------------------
# fat (single-pass multi-model) retrieval — RQ2 optimised path
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("rank_model", "feature_models",
                                   "max_postings", "k"))
def retrieve_fat(index: InvertedIndex, terms, weights, *, rank_model: str,
                 feature_models: tuple[str, ...], k: int, max_postings: int):
    """One postings pass -> candidate top-k under ``rank_model`` PLUS all
    ``feature_models`` scores for the candidates.  Returns (docids [k],
    scores [k], features [k, F])."""
    post = gather_postings(index, terms, max_postings)
    dl = index.doc_len[post["doc_ids"]]
    models = (rank_model,) + tuple(feature_models)
    all_s = scoring.score_all(list(models), post["tfs"], dl,
                              post["df"][:, None], post["cf"][:, None],
                              index.stats)
    all_s = all_s * (weights[:, None, None] *
                     post["mask"][..., None].astype(jnp.float32))
    flat_docs = post["doc_ids"].reshape(-1)
    dense = jnp.zeros((index.n_docs, len(models)), jnp.float32).at[
        flat_docs].add(all_s.reshape(-1, len(models)))
    top_s, top_d = jax.lax.top_k(dense[:, 0], k)
    feats = dense[top_d, 1:]
    return top_d.astype(jnp.int32), top_s, feats


@partial(jax.jit, static_argnames=("models", "max_postings", "k"))
def retrieve_multi(index: InvertedIndex, terms, weights, model_weights, *,
                   models: tuple[str, ...], k: int, max_postings: int):
    """Weighted multi-model retrieval in ONE postings pass — the target of
    the LinearFusion rewrite (w1·Retrieve(m1) + w2·Retrieve(m2) fused)."""
    post = gather_postings(index, terms, max_postings)
    dl = index.doc_len[post["doc_ids"]]
    all_s = scoring.score_all(list(models), post["tfs"], dl,
                              post["df"][:, None], post["cf"][:, None],
                              index.stats)
    s = jnp.einsum("qpf,f->qp", all_s, model_weights)
    s = s * weights[:, None] * post["mask"]
    dense = jnp.zeros((index.n_docs,), jnp.float32).at[
        post["doc_ids"].reshape(-1)].add(s.reshape(-1))
    top_s, top_d = jax.lax.top_k(dense, k)
    return top_d.astype(jnp.int32), top_s


# ---------------------------------------------------------------------------
# kernel-fused retrieval — targets of the IR lowering pass (core/passes.py)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("model", "max_postings", "k"))
def retrieve_topk_fused(index: InvertedIndex, terms, weights, *, model: str,
                        k: int, max_postings: int):
    """``Retrieve >> … % K`` lowered through the streaming top-k kernel:
    exhaustive scoring feeds ``kernels/topk`` (block-max skipping on TPU,
    ``lax.top_k`` oracle elsewhere) at the *cutoff* depth K, so the dense
    [n_docs] score vector is never sorted to the retriever's full k."""
    from repro.kernels.topk.ops import streaming_topk
    scores = score_exhaustive(index, terms, weights, model=model,
                              max_postings=max_postings)
    vals, idxs = streaming_topk(scores, k=k)
    return idxs.astype(jnp.int32), vals


@partial(jax.jit, static_argnames=("rank_model", "feature_models",
                                   "max_postings", "k"))
def retrieve_fat_fused(index: InvertedIndex, terms, weights, *,
                       rank_model: str, feature_models: tuple[str, ...],
                       k: int, max_postings: int):
    """``Retrieve >> (Extract ** …) % K`` lowered through the fused-scoring
    kernel: one postings gather, every weighting model's math on the same
    VMEM tile (``kernels/fused_scoring``), candidates cut to K directly."""
    from repro.kernels.fused_scoring.ops import fused_scoring
    post = gather_postings(index, terms, max_postings)
    dl = index.doc_len[post["doc_ids"]]
    models = (rank_model,) + tuple(feature_models)
    MAXQ, L = post["tfs"].shape
    df = jnp.broadcast_to(post["df"][:, None], (MAXQ, L))
    cf = jnp.broadcast_to(post["cf"][:, None], (MAXQ, L))
    flat = lambda x: x.reshape(-1)
    all_s = fused_scoring(flat(post["tfs"]), flat(dl), flat(df), flat(cf),
                          models=models, stats=index.stats)
    all_s = all_s.reshape(MAXQ, L, len(models))
    all_s = all_s * (weights[:, None, None] *
                     post["mask"][..., None].astype(jnp.float32))
    dense = jnp.zeros((index.n_docs, len(models)), jnp.float32).at[
        post["doc_ids"].reshape(-1)].add(all_s.reshape(-1, len(models)))
    top_s, top_d = jax.lax.top_k(dense[:, 0], k)
    feats = dense[top_d, 1:]
    return top_d.astype(jnp.int32), top_s, feats


@partial(jax.jit, static_argnames=("model", "k_in", "k", "alpha",
                                   "max_postings"))
def retrieve_dense_rerank(index: InvertedIndex, emb, terms, weights, qvec, *,
                          model: str, k_in: int, k: int, alpha: float,
                          max_postings: int):
    """The unfused ``Retrieve >> DenseRerank % K`` chain as one per-query
    program: sparse top-k_in candidates, dense re-scoring
    (``alpha * sparse + emb @ qvec``), full sort, slice to K.  The fusion
    gate's unfused pricing candidate — and the semantics the fused form
    below must reproduce exactly."""
    docs, scores = retrieve_topk(index, terms, weights, model=model, k=k_in,
                                 max_postings=max_postings)
    ds = jnp.where(docs >= 0,
                   alpha * scores + emb[jnp.maximum(docs, 0)] @ qvec,
                   -jnp.inf)
    order = jnp.argsort(-ds)
    return docs[order][:k].astype(jnp.int32), ds[order][:k]


@partial(jax.jit, static_argnames=("model", "k_in", "k", "alpha",
                                   "max_postings"))
def retrieve_dense_rerank_fused(index: InvertedIndex, emb, terms, weights,
                                qvec, *, model: str, k_in: int, k: int,
                                alpha: float, max_postings: int):
    """``Retrieve >> DenseRerank % K`` lowered through the dense-scoring
    kernel: the sparse contribution rides in as the kernel's ``base`` score
    and the streaming top-k runs at the *cutoff* depth K, so the candidate
    list is never fully sorted (``kernels/dense_scoring``)."""
    from repro.index.dense import NEG
    from repro.kernels.dense_scoring.ops import streaming_dense_topk
    docs, scores = retrieve_topk(index, terms, weights, model=model, k=k_in,
                                 max_postings=max_postings)
    base = jnp.where(docs >= 0, alpha * scores, NEG)
    vals, idxs = streaming_dense_topk(emb[jnp.maximum(docs, 0)], qvec, base,
                                      k=k)
    ok = vals > NEG / 2
    out_docs = jnp.where(ok, docs[idxs], -1)
    return out_docs.astype(jnp.int32), jnp.where(ok, vals, -jnp.inf)


# ---------------------------------------------------------------------------
# doc-vectors feature extraction — the unoptimised per-feature pass
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("model", "max_fwd"))
def extract_feature_docvectors(index: InvertedIndex, terms, weights,
                               docids, *, model: str, max_fwd: int):
    """Score ``docids`` [K] under one weighting model via the direct index
    (one full pass over each candidate's doc vector per feature)."""
    d = jnp.maximum(docids, 0)
    start = index.fwd_start[d]
    length = index.fwd_start[d + 1] - start
    pos = start[:, None] + jnp.arange(max_fwd)[None, :]
    in_rng = jnp.arange(max_fwd)[None, :] < length[:, None]
    pos = jnp.minimum(pos, index.fwd_terms.shape[0] - 1)
    dterms = jnp.where(in_rng, index.fwd_terms[pos], -1)    # [K, L]
    dtfs = jnp.where(in_rng, index.fwd_tfs[pos], 0)

    # match doc terms against query terms: [K, L, MAXQ]
    eq = (dterms[:, :, None] == terms[None, None, :]) & (terms >= 0)[None, None, :]
    tf_q = jnp.einsum("klq,kl->kq", eq.astype(jnp.float32),
                      dtfs.astype(jnp.float32))             # [K, MAXQ]
    dl = index.doc_len[d][:, None]
    t = jnp.maximum(terms, 0)
    s = scoring.WEIGHTING_MODELS[model](
        tf_q, dl, index.df[t][None, :], index.cf[t][None, :], index.stats)
    s = s * weights[None, :] * (terms >= 0)[None, :]
    s = jnp.where((docids >= 0)[:, None], s, 0.0)
    return jnp.sum(s, axis=1)                               # [K]


# ---------------------------------------------------------------------------
# RM3 query expansion via the direct index
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("fb_terms", "max_fwd"))
def rm3_expand(index: InvertedIndex, terms, weights, docids, scores, *,
               fb_terms: int = 10, alpha: float = 0.5, max_fwd: int):
    """Relevance-model expansion from the top feedback docs.

    Returns (new_terms [MAXQ], new_weights [MAXQ]) where expansion terms are
    appended after the original query terms.
    """
    MAXQ = terms.shape[0]
    d = jnp.maximum(docids, 0)
    start = index.fwd_start[d]
    length = index.fwd_start[d + 1] - start
    pos = start[:, None] + jnp.arange(max_fwd)[None, :]
    in_rng = jnp.arange(max_fwd)[None, :] < length[:, None]
    pos = jnp.minimum(pos, index.fwd_terms.shape[0] - 1)
    dterms = jnp.where(in_rng, index.fwd_terms[pos], 0)
    dtfs = jnp.where(in_rng, index.fwd_tfs[pos].astype(jnp.float32), 0.0)

    p_rel = jax.nn.softmax(jnp.where(docids >= 0, scores, -jnp.inf))
    p_t_d = dtfs / jnp.maximum(index.doc_len[d][:, None].astype(jnp.float32), 1.0)
    w_contrib = (p_rel[:, None] * p_t_d).reshape(-1)
    rm = jnp.zeros((index.vocab,), jnp.float32).at[dterms.reshape(-1)].add(w_contrib)
    # don't re-select original terms
    rm = rm.at[jnp.maximum(terms, 0)].set(
        jnp.where(terms >= 0, 0.0, rm[jnp.maximum(terms, 0)]))
    exp_w, exp_t = jax.lax.top_k(rm, fb_terms)
    exp_w = exp_w / jnp.maximum(exp_w.sum(), 1e-9)

    n_orig = jnp.sum(terms >= 0)
    slots = jnp.arange(MAXQ)
    exp_slot = slots[None, :] == (n_orig + jnp.arange(fb_terms))[:, None]
    new_terms = jnp.where(terms >= 0, terms,
                          (exp_slot * (exp_t[:, None] + 1)).sum(0) - 1)
    w_norm = weights / jnp.maximum(jnp.sum(weights * (terms >= 0)), 1e-9)
    new_weights = jnp.where(terms >= 0, alpha * w_norm,
                            (1 - alpha) * (exp_slot * exp_w[:, None]).sum(0))
    return new_terms.astype(jnp.int32), new_weights
