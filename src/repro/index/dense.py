"""Dense (embedding) index: brute-force chunked-matmul scoring + top-k,
the IVF-flat ANN layout, and the IVF-PQ compressed layout for
memory-scale dense candidate generation.

Used by neural re-rank stages and dense-retrieval transformers.  Document
embeddings come either from a trained encoder or, for infrastructure tests,
from deterministic random-projection of term-count vectors (fast, content-
correlated, no training required).

The IVF-flat index (:class:`IVFDenseIndex`) groups documents by a coarse
quantiser (spherical k-means over the doc embeddings); a query probes its
``nprobe`` closest lists and scores only those lists' embeddings — the
k-dependent-work analogue of block-max pruning for the dense stage.  Search
comes in two strategies, mirroring ``index/retrieve.py``:

* ``*_topk``        — gather candidates, score with one matmul, oracle
                      ``lax.top_k``.  The unfused interpreter path.
* ``*_topk_fused``  — same candidates through the blocked matmul +
                      streaming top-k Pallas kernel
                      (``kernels/dense_scoring``) at the *cutoff* depth.
                      The target of the cost-gated IR lowering.

Both score candidates with the same expression (``emb @ qvec + base``), so
the fusion gate's HLO proxies tie exactly when nothing is saved.

The IVF-PQ index (:class:`IVFPQIndex`) replaces the float list store with
per-subspace product-quantised uint8 codes behind the same CSR
``list_start`` layout (``dim * 4 / m`` compression of the scoring store).
Search is two-level: candidates are scored with an asymmetric-distance
(ADC) table built once per query, the top ``refine * k`` shortlist is
re-scored with exact float dot products against the (shared, not
duplicated) flat embedding store, and the final top-k is taken from the
exact scores.  The ADC stage again has a ref and a fused kernel strategy
(``kernels/pq_scoring``), both bit-identical under ties.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.inverted import InvertedIndex

#: mask score for padded / invalid candidate rows — same constant the
#: streaming kernels use, so fused and unfused paths rank identically
NEG = -3.0e38


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DenseIndex:
    emb: jax.Array       # [D, dim] unit-normalised
    dim: int

    def tree_flatten(self):
        return (self.emb,), (self.dim,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


def build_dense_index(index: InvertedIndex, dim: int = 64, seed: int = 0,
                      chunk: int = 1 << 21) -> DenseIndex:
    """Random-projection doc embeddings from the forward file (host loop
    over doc chunks to bound memory)."""
    rng = np.random.default_rng(seed)
    proj = rng.standard_normal((index.vocab, dim)).astype(np.float32) / np.sqrt(dim)
    fwd_start = np.asarray(index.fwd_start)
    fwd_terms = np.asarray(index.fwd_terms)
    fwd_tfs = np.asarray(index.fwd_tfs).astype(np.float32)
    D = index.n_docs
    emb = np.zeros((D, dim), np.float32)
    doc_of = np.repeat(np.arange(D), np.diff(fwd_start))
    # chunk the scatter: proj[fwd_terms] would otherwise materialise an
    # [nnz, dim] buffer (tens of GB at Robust scale)
    F = fwd_terms.shape[0]
    for s in range(0, F, chunk):
        e = min(s + chunk, F)
        np.add.at(emb, doc_of[s:e],
                  proj[fwd_terms[s:e]] * np.log1p(fwd_tfs[s:e])[:, None])
    emb /= np.maximum(np.linalg.norm(emb, axis=1, keepdims=True), 1e-6)
    return DenseIndex(jnp.asarray(emb), dim)


def embed_query(dense: DenseIndex, index: InvertedIndex, terms, weights,
                proj_seed: int = 0):
    """Project a sparse query into the dense space (same projection)."""
    rng = np.random.default_rng(proj_seed)
    proj = jnp.asarray(rng.standard_normal((index.vocab, dense.dim))
                       .astype(np.float32) / np.sqrt(dense.dim))
    t = jnp.maximum(terms, 0)
    vec = jnp.sum(proj[t] * (weights * (terms >= 0))[:, None], axis=0)
    return vec / jnp.maximum(jnp.linalg.norm(vec), 1e-6)


@partial(jax.jit, static_argnames=("k",))
def dense_topk(dense: DenseIndex, qvec: jax.Array, *, k: int):
    scores = dense.emb @ qvec
    top_s, top_d = jax.lax.top_k(scores, k)
    return top_d.astype(jnp.int32), top_s


@jax.jit
def dense_score(dense: DenseIndex, qvec: jax.Array, docids: jax.Array):
    return jnp.where(docids >= 0, dense.emb[jnp.maximum(docids, 0)] @ qvec, 0.0)


# ---------------------------------------------------------------------------
# IVF-flat ANN index (coarse k-means quantiser + list-ordered flat store)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class IVFDenseIndex:
    """IVF-flat layout over a :class:`DenseIndex`.

    ``emb`` holds the document embeddings *reordered by list* so a probed
    list is one contiguous gather; ``doc_ids[i]`` maps row ``i`` of the
    reordered store back to the original document id.  ``list_start`` is the
    CSR offset array (``[n_lists + 1]``); ``max_list_len`` bounds every
    list, giving probes a static gather shape.

    ``emb`` may be ``None`` (``build_ivf_index(..., keep_flat=False)``):
    the index then carries only the coarse-quantiser skeleton — enough to
    back an :class:`IVFPQIndex`, whose exact final-K re-scoring is served
    by the flat :class:`DenseIndex` store — without duplicating the full
    float embedding array in list order.
    """
    centroids: jax.Array            # [n_lists, dim] unit-normalised
    emb: jax.Array | None           # [D, dim] embeddings in list order
    doc_ids: jax.Array              # [D] row -> original doc id
    list_start: jax.Array           # [n_lists + 1] CSR offsets
    dim: int
    n_lists: int
    max_list_len: int

    def tree_flatten(self):
        return ((self.centroids, self.emb, self.doc_ids, self.list_start),
                (self.dim, self.n_lists, self.max_list_len))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def default_n_lists(n_docs: int) -> int:
    """sqrt(D) coarse lists (the usual IVF operating point), capped so tiny
    corpora still get multi-document lists."""
    return int(max(1, min(4096, round(n_docs ** 0.5))))


def _coarse_quantise(emb: np.ndarray, n_lists: int, iters: int, seed: int,
                     chunk: int):
    """Spherical k-means skeleton shared by the IVF-flat and IVF-PQ builds:
    centroids, the stable list-order permutation, and the CSR offsets."""
    D = emb.shape[0]
    rng = np.random.default_rng(seed)
    cent = emb[rng.choice(D, size=n_lists, replace=False)].copy()
    assign = np.zeros(D, np.int64)
    for it in range(max(1, iters)):
        for s in range(0, D, chunk):
            e = min(s + chunk, D)
            assign[s:e] = np.argmax(emb[s:e] @ cent.T, axis=1)
        # per-dim bincount scatter: np.add.at is an unbuffered per-element
        # loop and would dominate the build at Robust scale
        sums = np.stack([np.bincount(assign, weights=emb[:, d],
                                     minlength=n_lists)
                         for d in range(emb.shape[1])], axis=1)
        sums = sums.astype(np.float32)
        norms = np.linalg.norm(sums, axis=1, keepdims=True)
        # an emptied list keeps its previous centroid (stays probeable)
        cent = np.where(norms > 1e-9, sums / np.maximum(norms, 1e-9), cent)
    for s in range(0, D, chunk):
        e = min(s + chunk, D)
        assign[s:e] = np.argmax(emb[s:e] @ cent.T, axis=1)
    order = np.argsort(assign, kind="stable").astype(np.int32)
    counts = np.bincount(assign, minlength=n_lists)
    list_start = np.zeros(n_lists + 1, np.int32)
    list_start[1:] = np.cumsum(counts, dtype=np.int64)
    return cent.astype(np.float32), order, list_start, counts


def build_ivf_index(dense: DenseIndex, *, n_lists: int | None = None,
                    iters: int = 6, seed: int = 0, chunk: int = 1 << 16,
                    keep_flat: bool = True) -> IVFDenseIndex:
    """Spherical k-means over the doc embeddings -> IVF-flat index.

    Pure function of (embeddings, config): rebuilding from the same dense
    index and params yields identical arrays, which is what lets the plan
    cache digest the IVF by its config instead of its contents.  Host-side
    numpy with the [D, n_lists] assignment matmul chunked over docs to
    bound memory at Robust scale.

    ``keep_flat=False`` skips materialising the list-ordered float copy of
    the embeddings (``emb=None``) — the skeleton for a PQ-only deployment
    where flat-IVF search is never run and the exact final-K pass is served
    by PQ re-scoring against the original flat store.
    """
    emb = np.asarray(dense.emb)
    D = emb.shape[0]
    n_lists = default_n_lists(D) if n_lists is None else int(n_lists)
    n_lists = max(1, min(n_lists, D))
    cent, order, list_start, counts = _coarse_quantise(
        emb, n_lists, iters, seed, chunk)
    return IVFDenseIndex(
        centroids=jnp.asarray(cent),
        emb=jnp.asarray(emb[order]) if keep_flat else None,
        doc_ids=jnp.asarray(order),
        list_start=jnp.asarray(list_start),
        dim=dense.dim, n_lists=int(n_lists),
        max_list_len=int(counts.max()))


def _ivf_probe(index, qvec, *, nprobe: int):
    """Fixed-shape probe shared by the flat and PQ layouts: each candidate
    row's position into the list-ordered store [nprobe * L] and a
    NEG-masked base score [nprobe * L]."""
    c_scores = index.centroids @ qvec
    _, lists = jax.lax.top_k(c_scores, nprobe)
    L = index.max_list_len
    start = index.list_start[lists]
    length = index.list_start[lists + 1] - start
    slot = jnp.arange(L, dtype=jnp.int32)
    valid = slot[None, :] < length[:, None]
    pos = jnp.minimum(start[:, None] + slot[None, :],
                      index.doc_ids.shape[0] - 1).reshape(-1)
    base = jnp.where(valid.reshape(-1), 0.0, NEG)
    return pos, base


def _ivf_candidates(ivf: IVFDenseIndex, qvec, *, nprobe: int):
    """Fixed-shape candidate block for one query: the ``nprobe`` best lists'
    embeddings [nprobe * L, dim], a NEG-masked base score [nprobe * L], and
    each row's position into the list-ordered store."""
    if ivf.emb is None:
        raise ValueError(
            "IVF-flat search needs the list-ordered float store; this index "
            "was built with keep_flat=False (PQ-only skeleton)")
    pos, base = _ivf_probe(ivf, qvec, nprobe=nprobe)
    return ivf.emb[pos], base, pos


def _pad_candidates(emb_c, base, pos, k: int):
    """Guarantee at least ``k`` candidate rows (tiny nprobe x short lists):
    padded rows score NEG and surface as docid -1 / -inf."""
    n = base.shape[0]
    if n >= k:
        return emb_c, base, pos
    pad = k - n
    return (jnp.pad(emb_c, ((0, pad), (0, 0))),
            jnp.pad(base, (0, pad), constant_values=NEG),
            jnp.pad(pos, (0, pad)))


def _finish_search(ivf: IVFDenseIndex, pos, vals, idxs):
    ok = vals > NEG / 2
    docs = jnp.where(ok, ivf.doc_ids[pos[idxs]], -1)
    return docs.astype(jnp.int32), jnp.where(ok, vals, -jnp.inf)


@partial(jax.jit, static_argnames=("k", "nprobe"))
def ivf_retrieve_topk(ivf: IVFDenseIndex, qvec, *, k: int, nprobe: int):
    """IVF probe + matmul scoring + oracle top-k (the unfused path)."""
    from repro.kernels.dense_scoring.ref import dense_topk_ref
    emb_c, base, pos = _ivf_candidates(ivf, qvec, nprobe=nprobe)
    emb_c, base, pos = _pad_candidates(emb_c, base, pos, k)
    vals, idxs = dense_topk_ref(emb_c, qvec, base, k=k)
    return _finish_search(ivf, pos, vals, idxs)


@partial(jax.jit, static_argnames=("k", "nprobe"))
def ivf_retrieve_topk_fused(ivf: IVFDenseIndex, qvec, *, k: int, nprobe: int):
    """IVF probe through the blocked-matmul + streaming-top-k kernel at the
    cutoff depth (``dense_retrieve % K`` lowered by the fusion pass)."""
    from repro.kernels.dense_scoring.ops import streaming_dense_topk
    emb_c, base, pos = _ivf_candidates(ivf, qvec, nprobe=nprobe)
    emb_c, base, pos = _pad_candidates(emb_c, base, pos, k)
    vals, idxs = streaming_dense_topk(emb_c, qvec, base, k=k)
    return _finish_search(ivf, pos, vals, idxs)


@partial(jax.jit, static_argnames=("k",))
def dense_retrieve_exact(dense: DenseIndex, qvec, *, k: int):
    """Brute-force dense top-k over every document (nprobe=0 mode)."""
    from repro.kernels.dense_scoring.ref import dense_topk_ref
    vals, idxs = dense_topk_ref(dense.emb, qvec, None, k=k)
    return idxs.astype(jnp.int32), vals


@partial(jax.jit, static_argnames=("k",))
def dense_retrieve_exact_fused(dense: DenseIndex, qvec, *, k: int):
    """Brute-force dense top-k through the streaming kernel."""
    from repro.kernels.dense_scoring.ops import streaming_dense_topk
    vals, idxs = streaming_dense_topk(dense.emb, qvec, None, k=k)
    return idxs.astype(jnp.int32), vals


# ---------------------------------------------------------------------------
# Product quantisation (PQ): per-subspace codebooks + uint8 codes
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PQCodebook:
    """Per-subspace k-means codebooks: the embedding space is split into
    ``m`` contiguous subspaces of ``dsub = dim // m`` dims, each quantised
    independently against ``n_codes`` (<= 256, so codes fit uint8)
    centroids."""
    codebooks: jax.Array        # [m, n_codes, dsub] float32
    m: int
    dsub: int
    n_codes: int

    def tree_flatten(self):
        return (self.codebooks,), (self.m, self.dsub, self.n_codes)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


def build_pq_codebook(emb, *, m: int = 8, iters: int = 10, seed: int = 0,
                      sample: int = 1 << 17,
                      chunk: int = 1 << 16) -> PQCodebook:
    """Train per-subspace k-means codebooks host-side (chunked, like the
    coarse quantiser).  L2 k-means on the subvectors minimises the
    reconstruction MSE, which bounds the inner-product ADC error by
    Cauchy-Schwarz (|x.q - x_hat.q| <= ||x - x_hat|| for unit queries)."""
    emb = np.asarray(emb)
    D, dim = emb.shape
    m = int(m)
    if m < 1 or dim % m != 0:
        raise ValueError(f"m={m} must divide dim={dim}")
    dsub = dim // m
    n_codes = int(min(256, D))
    rng = np.random.default_rng(seed)
    train = emb if D <= sample else emb[rng.choice(D, size=sample,
                                                   replace=False)]
    T = train.shape[0]
    books = np.zeros((m, n_codes, dsub), np.float32)
    for s in range(m):
        X = np.ascontiguousarray(train[:, s * dsub:(s + 1) * dsub])
        cent = X[rng.choice(T, size=n_codes, replace=False)].copy()
        assign = np.zeros(T, np.int64)
        for _ in range(max(1, iters)):
            c2 = np.sum(cent * cent, axis=1)
            for lo in range(0, T, chunk):
                hi = min(lo + chunk, T)
                # argmin ||x - c||^2 == argmin (||c||^2 - 2 x.c)
                assign[lo:hi] = np.argmin(c2[None, :] - 2.0 * (X[lo:hi]
                                                               @ cent.T),
                                          axis=1)
            counts = np.bincount(assign, minlength=n_codes)
            sums = np.stack([np.bincount(assign, weights=X[:, d],
                                         minlength=n_codes)
                             for d in range(dsub)], axis=1).astype(np.float32)
            # an emptied code keeps its previous centroid
            nz = counts > 0
            cent[nz] = sums[nz] / counts[nz, None]
        books[s] = cent
    return PQCodebook(jnp.asarray(books), m, dsub, n_codes)


def pq_encode(cb: PQCodebook, emb, chunk: int = 1 << 16) -> np.ndarray:
    """Quantise embeddings to uint8 codes [D, m] (host-side, chunked)."""
    emb = np.asarray(emb)
    books = np.asarray(cb.codebooks)
    D = emb.shape[0]
    codes = np.zeros((D, cb.m), np.uint8)
    for s in range(cb.m):
        X = emb[:, s * cb.dsub:(s + 1) * cb.dsub]
        cent = books[s]
        c2 = np.sum(cent * cent, axis=1)
        for lo in range(0, D, chunk):
            hi = min(lo + chunk, D)
            codes[lo:hi, s] = np.argmin(c2[None, :] - 2.0 * (X[lo:hi]
                                                             @ cent.T),
                                        axis=1).astype(np.uint8)
    return codes


def pq_decode(cb: PQCodebook, codes: jax.Array) -> jax.Array:
    """Reconstruct approximate embeddings [N, dim] from codes [N, m]."""
    idx = codes.astype(jnp.int32)
    parts = [cb.codebooks[s][idx[:, s]] for s in range(cb.m)]
    return jnp.concatenate(parts, axis=1)


def adc_table(cb: PQCodebook, qvec: jax.Array) -> jax.Array:
    """Per-query asymmetric-distance lookup table [m, n_codes]: entry
    ``(s, c)`` is the inner product of the query's s-th subvector with
    code ``c`` of subspace ``s``; an ADC score is the sum of ``m`` table
    lookups."""
    q = qvec.reshape(cb.m, cb.dsub)
    return jnp.einsum("mcd,md->mc", cb.codebooks, q)


# ---------------------------------------------------------------------------
# IVF-PQ: uint8 codes in list order behind the same CSR layout
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class IVFPQIndex:
    """IVF-PQ layout: the float list store of :class:`IVFDenseIndex` is
    replaced by product-quantised uint8 ``codes`` (list order, same CSR
    ``list_start`` offsets).  ``emb`` is the *flat* (doc-id-ordered) float
    store shared with the source :class:`DenseIndex` — it backs the exact
    re-scoring of the final-K shortlist and is a reference, not a copy.
    ``emb=None`` drops exact re-scoring: search returns ADC-approximate
    scores (codes-only memory footprint)."""
    centroids: jax.Array            # [n_lists, dim]
    codes: jax.Array                # [D, m] uint8, list order
    doc_ids: jax.Array              # [D] row -> original doc id
    list_start: jax.Array           # [n_lists + 1] CSR offsets
    codebook: PQCodebook
    emb: jax.Array | None           # [D, dim] float32, DOC-ID order
    dim: int
    n_lists: int
    max_list_len: int

    def tree_flatten(self):
        return ((self.centroids, self.codes, self.doc_ids, self.list_start,
                 self.codebook, self.emb),
                (self.dim, self.n_lists, self.max_list_len))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def m(self) -> int:
        return self.codebook.m


def pq_store_bytes(pq: IVFPQIndex) -> int:
    """Bytes of the PQ scoring store: codes + codebooks + coarse centroids
    (the flat re-score store is shared with the DenseIndex, not owned)."""
    return int(pq.codes.size * pq.codes.dtype.itemsize
               + pq.codebook.codebooks.size * 4
               + pq.centroids.size * 4)


def build_ivfpq_index(dense: DenseIndex, *, n_lists: int | None = None,
                      iters: int = 6, seed: int = 0, m: int = 8,
                      pq_iters: int = 10, chunk: int = 1 << 16,
                      keep_flat: bool = True,
                      ivf: IVFDenseIndex | None = None) -> IVFPQIndex:
    """Build an IVF-PQ index over a dense index.

    Reuses an existing IVF skeleton when given (sharing the coarse
    quantiser with a flat index built from the same config); otherwise
    builds one with ``keep_flat=False`` so no list-ordered float copy is
    ever materialised.  ``keep_flat`` here controls the exact re-score
    store: ``True`` shares the flat ``dense.emb`` reference, ``False``
    stores no float embeddings at all (ADC-only search).
    """
    if ivf is None:
        ivf = build_ivf_index(dense, n_lists=n_lists, iters=iters, seed=seed,
                              chunk=chunk, keep_flat=False)
    cb = build_pq_codebook(dense.emb, m=m, iters=pq_iters, seed=seed,
                           chunk=chunk)
    codes = pq_encode(cb, dense.emb, chunk=chunk)
    order = np.asarray(ivf.doc_ids)
    return IVFPQIndex(
        centroids=ivf.centroids,
        codes=jnp.asarray(codes[order]),
        doc_ids=ivf.doc_ids,
        list_start=ivf.list_start,
        codebook=cb,
        emb=dense.emb if keep_flat else None,
        dim=dense.dim, n_lists=ivf.n_lists,
        max_list_len=ivf.max_list_len)


def _pq_finish(pq: IVFPQIndex, qvec, pos_r, vals_a, *, k: int):
    """Exact float re-scoring of the ADC shortlist + final top-k.  With no
    float store the ADC scores stand (already sorted desc by the shortlist
    stage, so the top-k is a prefix selection)."""
    ok = vals_a > NEG / 2
    docs = pq.doc_ids[pos_r]
    if pq.emb is not None:
        vals = jnp.where(ok, pq.emb[docs] @ qvec, NEG)
    else:
        vals = jnp.where(ok, vals_a, NEG)
    top_v, sel = jax.lax.top_k(vals, k)
    ok_k = top_v > NEG / 2
    docs_k = jnp.where(ok_k, docs[sel], -1)
    return docs_k.astype(jnp.int32), jnp.where(ok_k, top_v, -jnp.inf)


def _pq_shortlist_depth(k: int, refine: int, n_cand: int) -> int:
    return max(k, min(int(refine) * k, n_cand))


def _pq_resolve_depth(k: int, refine: int, n_cand: int,
                      shortlist: int | None) -> int:
    """An explicit ``shortlist`` overrides the refine*k default — the
    fusion gate uses it to replicate the *unfused* chain's shortlist depth
    (computed from the pre-cutoff k) so ``fused(K) == cutoff(unfused(k_in),
    K)`` holds exactly; clamped to [k, n_cand] for top-k legality."""
    if shortlist is None:
        return _pq_shortlist_depth(k, refine, n_cand)
    return max(k, min(int(shortlist), n_cand))


@partial(jax.jit, static_argnames=("k", "nprobe", "refine", "shortlist"))
def ivfpq_retrieve_topk(pq: IVFPQIndex, qvec, *, k: int, nprobe: int,
                        refine: int = 4, shortlist: int | None = None):
    """Two-level IVF-PQ search, unfused ADC stage: probe + code gather +
    table-lookup scoring + oracle top-(refine*k) shortlist, then exact
    float re-scoring of the shortlist."""
    from repro.kernels.pq_scoring.ref import pq_topk_ref
    pos, base = _ivf_probe(pq, qvec, nprobe=nprobe)
    r = _pq_resolve_depth(k, refine, pos.shape[0], shortlist)
    table = adc_table(pq.codebook, qvec)
    codes_c, base, pos = _pad_candidates(pq.codes[pos], base, pos, r)
    vals_a, idxs = pq_topk_ref(codes_c, table, base, k=r)
    return _pq_finish(pq, qvec, pos[idxs], vals_a, k=k)


@partial(jax.jit, static_argnames=("k", "nprobe", "refine", "block",
                                   "shortlist"))
def ivfpq_retrieve_topk_fused(pq: IVFPQIndex, qvec, *, k: int, nprobe: int,
                              refine: int = 4, block: int | None = None,
                              shortlist: int | None = None):
    """Two-level IVF-PQ search with the ADC stage through the fused
    code-gather + table-add + streaming-top-k kernel."""
    from repro.kernels.pq_scoring.ops import streaming_pq_topk
    pos, base = _ivf_probe(pq, qvec, nprobe=nprobe)
    r = _pq_resolve_depth(k, refine, pos.shape[0], shortlist)
    table = adc_table(pq.codebook, qvec)
    codes_c, base, pos = _pad_candidates(pq.codes[pos], base, pos, r)
    kw = {} if block is None else {"block": int(block)}
    vals_a, idxs = streaming_pq_topk(codes_c, table, base, k=r, **kw)
    return _pq_finish(pq, qvec, pos[idxs], vals_a, k=k)


# ---------------------------------------------------------------------------
# Doc-axis sharding: per-shard top-k + cross-shard merge
# ---------------------------------------------------------------------------

def shard_dense_index(dense: DenseIndex,
                      n_shards: int) -> list[tuple[DenseIndex, int]]:
    """Partition the document axis into ``n_shards`` contiguous slices.
    Returns ``(shard, offset)`` pairs; ``offset`` maps shard-local row ids
    back to global doc ids.  Contiguity is what makes the cross-shard merge
    tie-break identically to the single-index oracle (lower global id
    wins in both)."""
    D = int(dense.emb.shape[0])
    n_shards = int(n_shards)
    if n_shards < 1 or n_shards > D:
        raise ValueError(f"n_shards={n_shards} outside [1, {D}]")
    cuts = [round(i * D / n_shards) for i in range(n_shards + 1)]
    return [(DenseIndex(dense.emb[lo:hi], dense.dim), lo)
            for lo, hi in zip(cuts[:-1], cuts[1:])]


def sharded_dense_topk(shards, qvec, *, k: int):
    """Per-shard exact top-k + ``lax`` gather-merge (one query).

    Bit-identical to ``dense_retrieve_exact`` on the unsharded index:
    per-row dot products don't depend on the other rows, per-shard
    ``lax.top_k`` keeps ties in ascending local (= global, shards are
    contiguous) id order, and the merge's ``lax.top_k`` over the
    shard-ordered concatenation therefore resolves ties to the lowest
    global doc id — exactly the oracle's rule.  Traceable: wrap in
    jit/vmap at the call site.
    """
    docs_parts, vals_parts = [], []
    for shard, offset in shards:
        ks = min(k, int(shard.emb.shape[0]))
        d, v = dense_retrieve_exact(shard, qvec, k=ks)
        docs_parts.append(d + jnp.int32(offset))
        vals_parts.append(v)
    vals = jnp.concatenate(vals_parts)
    docs = jnp.concatenate(docs_parts)
    top_v, sel = jax.lax.top_k(vals, k)
    return docs[sel].astype(jnp.int32), top_v
