"""Dense (embedding) index: brute-force chunked-matmul scoring + top-k,
plus the IVF-flat ANN layout for dense candidate generation.

Used by neural re-rank stages and dense-retrieval transformers.  Document
embeddings come either from a trained encoder or, for infrastructure tests,
from deterministic random-projection of term-count vectors (fast, content-
correlated, no training required).

The IVF-flat index (:class:`IVFDenseIndex`) groups documents by a coarse
quantiser (spherical k-means over the doc embeddings); a query probes its
``nprobe`` closest lists and scores only those lists' embeddings — the
k-dependent-work analogue of block-max pruning for the dense stage.  Search
comes in two strategies, mirroring ``index/retrieve.py``:

* ``*_topk``        — gather candidates, score with one matmul, oracle
                      ``lax.top_k``.  The unfused interpreter path.
* ``*_topk_fused``  — same candidates through the blocked matmul +
                      streaming top-k Pallas kernel
                      (``kernels/dense_scoring``) at the *cutoff* depth.
                      The target of the cost-gated IR lowering.

Both score candidates with the same expression (``emb @ qvec + base``), so
the fusion gate's HLO proxies tie exactly when nothing is saved.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.inverted import InvertedIndex

#: mask score for padded / invalid candidate rows — same constant the
#: streaming kernels use, so fused and unfused paths rank identically
NEG = -3.0e38


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DenseIndex:
    emb: jax.Array       # [D, dim] unit-normalised
    dim: int

    def tree_flatten(self):
        return (self.emb,), (self.dim,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


def build_dense_index(index: InvertedIndex, dim: int = 64, seed: int = 0,
                      chunk: int = 1 << 21) -> DenseIndex:
    """Random-projection doc embeddings from the forward file (host loop
    over doc chunks to bound memory)."""
    rng = np.random.default_rng(seed)
    proj = rng.standard_normal((index.vocab, dim)).astype(np.float32) / np.sqrt(dim)
    fwd_start = np.asarray(index.fwd_start)
    fwd_terms = np.asarray(index.fwd_terms)
    fwd_tfs = np.asarray(index.fwd_tfs).astype(np.float32)
    D = index.n_docs
    emb = np.zeros((D, dim), np.float32)
    doc_of = np.repeat(np.arange(D), np.diff(fwd_start))
    # chunk the scatter: proj[fwd_terms] would otherwise materialise an
    # [nnz, dim] buffer (tens of GB at Robust scale)
    F = fwd_terms.shape[0]
    for s in range(0, F, chunk):
        e = min(s + chunk, F)
        np.add.at(emb, doc_of[s:e],
                  proj[fwd_terms[s:e]] * np.log1p(fwd_tfs[s:e])[:, None])
    emb /= np.maximum(np.linalg.norm(emb, axis=1, keepdims=True), 1e-6)
    return DenseIndex(jnp.asarray(emb), dim)


def embed_query(dense: DenseIndex, index: InvertedIndex, terms, weights,
                proj_seed: int = 0):
    """Project a sparse query into the dense space (same projection)."""
    rng = np.random.default_rng(proj_seed)
    proj = jnp.asarray(rng.standard_normal((index.vocab, dense.dim))
                       .astype(np.float32) / np.sqrt(dense.dim))
    t = jnp.maximum(terms, 0)
    vec = jnp.sum(proj[t] * (weights * (terms >= 0))[:, None], axis=0)
    return vec / jnp.maximum(jnp.linalg.norm(vec), 1e-6)


@partial(jax.jit, static_argnames=("k",))
def dense_topk(dense: DenseIndex, qvec: jax.Array, *, k: int):
    scores = dense.emb @ qvec
    top_s, top_d = jax.lax.top_k(scores, k)
    return top_d.astype(jnp.int32), top_s


@jax.jit
def dense_score(dense: DenseIndex, qvec: jax.Array, docids: jax.Array):
    return jnp.where(docids >= 0, dense.emb[jnp.maximum(docids, 0)] @ qvec, 0.0)


# ---------------------------------------------------------------------------
# IVF-flat ANN index (coarse k-means quantiser + list-ordered flat store)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class IVFDenseIndex:
    """IVF-flat layout over a :class:`DenseIndex`.

    ``emb`` holds the document embeddings *reordered by list* so a probed
    list is one contiguous gather; ``doc_ids[i]`` maps row ``i`` of the
    reordered store back to the original document id.  ``list_start`` is the
    CSR offset array (``[n_lists + 1]``); ``max_list_len`` bounds every
    list, giving probes a static gather shape.
    """
    centroids: jax.Array     # [n_lists, dim] unit-normalised
    emb: jax.Array           # [D, dim] embeddings in list order
    doc_ids: jax.Array       # [D] row -> original doc id
    list_start: jax.Array    # [n_lists + 1] CSR offsets
    dim: int
    n_lists: int
    max_list_len: int

    def tree_flatten(self):
        return ((self.centroids, self.emb, self.doc_ids, self.list_start),
                (self.dim, self.n_lists, self.max_list_len))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def default_n_lists(n_docs: int) -> int:
    """sqrt(D) coarse lists (the usual IVF operating point), capped so tiny
    corpora still get multi-document lists."""
    return int(max(1, min(4096, round(n_docs ** 0.5))))


def build_ivf_index(dense: DenseIndex, *, n_lists: int | None = None,
                    iters: int = 6, seed: int = 0,
                    chunk: int = 1 << 16) -> IVFDenseIndex:
    """Spherical k-means over the doc embeddings -> IVF-flat index.

    Pure function of (embeddings, config): rebuilding from the same dense
    index and params yields identical arrays, which is what lets the plan
    cache digest the IVF by its config instead of its contents.  Host-side
    numpy with the [D, n_lists] assignment matmul chunked over docs to
    bound memory at Robust scale.
    """
    emb = np.asarray(dense.emb)
    D = emb.shape[0]
    n_lists = default_n_lists(D) if n_lists is None else int(n_lists)
    n_lists = max(1, min(n_lists, D))
    rng = np.random.default_rng(seed)
    cent = emb[rng.choice(D, size=n_lists, replace=False)].copy()
    assign = np.zeros(D, np.int64)
    for it in range(max(1, iters)):
        for s in range(0, D, chunk):
            e = min(s + chunk, D)
            assign[s:e] = np.argmax(emb[s:e] @ cent.T, axis=1)
        # per-dim bincount scatter: np.add.at is an unbuffered per-element
        # loop and would dominate the build at Robust scale
        sums = np.stack([np.bincount(assign, weights=emb[:, d],
                                     minlength=n_lists)
                         for d in range(emb.shape[1])], axis=1)
        sums = sums.astype(np.float32)
        norms = np.linalg.norm(sums, axis=1, keepdims=True)
        # an emptied list keeps its previous centroid (stays probeable)
        cent = np.where(norms > 1e-9, sums / np.maximum(norms, 1e-9), cent)
    for s in range(0, D, chunk):
        e = min(s + chunk, D)
        assign[s:e] = np.argmax(emb[s:e] @ cent.T, axis=1)
    order = np.argsort(assign, kind="stable").astype(np.int32)
    counts = np.bincount(assign, minlength=n_lists)
    list_start = np.zeros(n_lists + 1, np.int32)
    list_start[1:] = np.cumsum(counts, dtype=np.int64)
    return IVFDenseIndex(
        centroids=jnp.asarray(cent.astype(np.float32)),
        emb=jnp.asarray(emb[order]),
        doc_ids=jnp.asarray(order),
        list_start=jnp.asarray(list_start),
        dim=dense.dim, n_lists=int(n_lists),
        max_list_len=int(counts.max()))


def _ivf_candidates(ivf: IVFDenseIndex, qvec, *, nprobe: int):
    """Fixed-shape candidate block for one query: the ``nprobe`` best lists'
    embeddings [nprobe * L, dim], a NEG-masked base score [nprobe * L], and
    each row's position into the list-ordered store."""
    c_scores = ivf.centroids @ qvec
    _, lists = jax.lax.top_k(c_scores, nprobe)
    L = ivf.max_list_len
    start = ivf.list_start[lists]
    length = ivf.list_start[lists + 1] - start
    slot = jnp.arange(L, dtype=jnp.int32)
    valid = slot[None, :] < length[:, None]
    pos = jnp.minimum(start[:, None] + slot[None, :],
                      ivf.doc_ids.shape[0] - 1).reshape(-1)
    base = jnp.where(valid.reshape(-1), 0.0, NEG)
    return ivf.emb[pos], base, pos


def _pad_candidates(emb_c, base, pos, k: int):
    """Guarantee at least ``k`` candidate rows (tiny nprobe x short lists):
    padded rows score NEG and surface as docid -1 / -inf."""
    n = base.shape[0]
    if n >= k:
        return emb_c, base, pos
    pad = k - n
    return (jnp.pad(emb_c, ((0, pad), (0, 0))),
            jnp.pad(base, (0, pad), constant_values=NEG),
            jnp.pad(pos, (0, pad)))


def _finish_search(ivf: IVFDenseIndex, pos, vals, idxs):
    ok = vals > NEG / 2
    docs = jnp.where(ok, ivf.doc_ids[pos[idxs]], -1)
    return docs.astype(jnp.int32), jnp.where(ok, vals, -jnp.inf)


@partial(jax.jit, static_argnames=("k", "nprobe"))
def ivf_retrieve_topk(ivf: IVFDenseIndex, qvec, *, k: int, nprobe: int):
    """IVF probe + matmul scoring + oracle top-k (the unfused path)."""
    from repro.kernels.dense_scoring.ref import dense_topk_ref
    emb_c, base, pos = _ivf_candidates(ivf, qvec, nprobe=nprobe)
    emb_c, base, pos = _pad_candidates(emb_c, base, pos, k)
    vals, idxs = dense_topk_ref(emb_c, qvec, base, k=k)
    return _finish_search(ivf, pos, vals, idxs)


@partial(jax.jit, static_argnames=("k", "nprobe"))
def ivf_retrieve_topk_fused(ivf: IVFDenseIndex, qvec, *, k: int, nprobe: int):
    """IVF probe through the blocked-matmul + streaming-top-k kernel at the
    cutoff depth (``dense_retrieve % K`` lowered by the fusion pass)."""
    from repro.kernels.dense_scoring.ops import streaming_dense_topk
    emb_c, base, pos = _ivf_candidates(ivf, qvec, nprobe=nprobe)
    emb_c, base, pos = _pad_candidates(emb_c, base, pos, k)
    vals, idxs = streaming_dense_topk(emb_c, qvec, base, k=k)
    return _finish_search(ivf, pos, vals, idxs)


@partial(jax.jit, static_argnames=("k",))
def dense_retrieve_exact(dense: DenseIndex, qvec, *, k: int):
    """Brute-force dense top-k over every document (nprobe=0 mode)."""
    from repro.kernels.dense_scoring.ref import dense_topk_ref
    vals, idxs = dense_topk_ref(dense.emb, qvec, None, k=k)
    return idxs.astype(jnp.int32), vals


@partial(jax.jit, static_argnames=("k",))
def dense_retrieve_exact_fused(dense: DenseIndex, qvec, *, k: int):
    """Brute-force dense top-k through the streaming kernel."""
    from repro.kernels.dense_scoring.ops import streaming_dense_topk
    vals, idxs = streaming_dense_topk(dense.emb, qvec, None, k=k)
    return idxs.astype(jnp.int32), vals
