"""Dense (embedding) index: brute-force chunked-matmul scoring + top-k.

Used by neural re-rank stages and dense-retrieval transformers.  Document
embeddings come either from a trained encoder or, for infrastructure tests,
from deterministic random-projection of term-count vectors (fast, content-
correlated, no training required).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.inverted import InvertedIndex


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DenseIndex:
    emb: jax.Array       # [D, dim] unit-normalised
    dim: int

    def tree_flatten(self):
        return (self.emb,), (self.dim,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


def build_dense_index(index: InvertedIndex, dim: int = 64, seed: int = 0,
                      chunk: int = 1 << 21) -> DenseIndex:
    """Random-projection doc embeddings from the forward file (host loop
    over doc chunks to bound memory)."""
    rng = np.random.default_rng(seed)
    proj = rng.standard_normal((index.vocab, dim)).astype(np.float32) / np.sqrt(dim)
    fwd_start = np.asarray(index.fwd_start)
    fwd_terms = np.asarray(index.fwd_terms)
    fwd_tfs = np.asarray(index.fwd_tfs).astype(np.float32)
    D = index.n_docs
    emb = np.zeros((D, dim), np.float32)
    doc_of = np.repeat(np.arange(D), np.diff(fwd_start))
    # chunk the scatter: proj[fwd_terms] would otherwise materialise an
    # [nnz, dim] buffer (tens of GB at Robust scale)
    F = fwd_terms.shape[0]
    for s in range(0, F, chunk):
        e = min(s + chunk, F)
        np.add.at(emb, doc_of[s:e],
                  proj[fwd_terms[s:e]] * np.log1p(fwd_tfs[s:e])[:, None])
    emb /= np.maximum(np.linalg.norm(emb, axis=1, keepdims=True), 1e-6)
    return DenseIndex(jnp.asarray(emb), dim)


def embed_query(dense: DenseIndex, index: InvertedIndex, terms, weights,
                proj_seed: int = 0):
    """Project a sparse query into the dense space (same projection)."""
    rng = np.random.default_rng(proj_seed)
    proj = jnp.asarray(rng.standard_normal((index.vocab, dense.dim))
                       .astype(np.float32) / np.sqrt(dense.dim))
    t = jnp.maximum(terms, 0)
    vec = jnp.sum(proj[t] * (weights * (terms >= 0))[:, None], axis=0)
    return vec / jnp.maximum(jnp.linalg.norm(vec), 1e-6)


@partial(jax.jit, static_argnames=("k",))
def dense_topk(dense: DenseIndex, qvec: jax.Array, *, k: int):
    scores = dense.emb @ qvec
    top_s, top_d = jax.lax.top_k(scores, k)
    return top_d.astype(jnp.int32), top_s


@jax.jit
def dense_score(dense: DenseIndex, qvec: jax.Array, docids: jax.Array):
    return jnp.where(docids >= 0, dense.emb[jnp.maximum(docids, 0)] @ qvec, 0.0)
