"""JAX-native inverted + direct index (padded-CSR pytree, shardable).

The inverted file stores postings term-major in flat arrays (CSR); posting
lists are additionally blocked at ``BLOCK`` granularity with per-block
maximum term frequency / minimum document length so the retriever can do
TPU-style *block-max* pruning (dense block sweeps with block-granular
skipping — the WAND adaptation described in DESIGN.md).

The direct (forward) index is the transpose, used by the doc-vectors
feature-extraction path [Asadi & Lin] and by query expansion (RM3).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.corpus import Corpus

BLOCK = 128


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class InvertedIndex:
    # inverted file (term-major CSR, postings sorted by docid)
    term_start: jax.Array    # [V+1] int64
    doc_ids: jax.Array       # [P] int32
    tfs: jax.Array           # [P] int32
    # per-block metadata (block b covers postings [b*BLOCK, (b+1)*BLOCK))
    block_max_tf: jax.Array    # [P/BLOCK] int32
    block_min_dl: jax.Array    # [P/BLOCK] int32
    # document statistics
    doc_len: jax.Array       # [D] int32
    df: jax.Array            # [V] int32
    cf: jax.Array            # [V] int64 collection frequency
    # direct (forward) file
    fwd_start: jax.Array     # [D+1] int64
    fwd_terms: jax.Array     # [F] int32 unique terms per doc
    fwd_tfs: jax.Array       # [F] int32
    # static metadata
    n_docs: int
    vocab: int
    avg_doclen: float
    total_terms: int
    max_fwd_len: int         # max unique terms in any doc

    def tree_flatten(self):
        children = (self.term_start, self.doc_ids, self.tfs, self.block_max_tf,
                    self.block_min_dl, self.doc_len, self.df, self.cf,
                    self.fwd_start, self.fwd_terms, self.fwd_tfs)
        aux = (self.n_docs, self.vocab, self.avg_doclen, self.total_terms,
               self.max_fwd_len)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def stats(self) -> dict:
        return {"n_docs": self.n_docs, "avg_doclen": self.avg_doclen,
                "total_terms": self.total_terms, "vocab": self.vocab}


def build_index(corpus: Corpus, *, stop_df_fraction: float = 0.1) -> InvertedIndex:
    """Host-side index construction (numpy), then device arrays.

    Terms with df > ``stop_df_fraction``·D are stopwords and are removed at
    index time (standard Terrier/Anserini practice) — this also bounds the
    static postings-gather width of the jitted retrievers.
    """
    D = corpus.n_docs
    doc_of_token = np.repeat(np.arange(D, dtype=np.int64),
                             np.diff(corpus.doc_start))
    terms = corpus.doc_terms.astype(np.int64)
    doc_len = np.diff(corpus.doc_start).astype(np.int32)

    # unique (term, doc) pairs with counts == postings
    keys = terms * D + doc_of_token
    uniq, counts = np.unique(keys, return_counts=True)
    p_term = (uniq // D).astype(np.int64)
    p_doc = (uniq % D).astype(np.int32)
    p_tf = counts.astype(np.int32)

    V = corpus.vocab
    df = np.bincount(p_term, minlength=V).astype(np.int32)
    cf = np.bincount(terms, minlength=V).astype(np.int64)

    # stopword removal (index-time): drop postings of ubiquitous terms
    stop = df > stop_df_fraction * D
    if stop.any():
        keep = ~stop[p_term]
        p_term, p_doc, p_tf = p_term[keep], p_doc[keep], p_tf[keep]
        df = np.where(stop, 0, df)

    # pad each posting list to a BLOCK multiple so block metadata is aligned
    padded_len = np.maximum((df + BLOCK - 1) // BLOCK, 0) * BLOCK
    term_start = np.zeros(V + 1, np.int64)
    np.cumsum(padded_len, out=term_start[1:])
    P = int(term_start[-1])
    doc_ids = np.full(P, -1, np.int32)
    tfs = np.zeros(P, np.int32)
    # scatter postings into padded layout
    src_start = np.zeros(V + 1, np.int64)
    np.cumsum(df, out=src_start[1:])
    offsets = np.arange(len(p_term), dtype=np.int64) - src_start[p_term]
    dst = term_start[p_term] + offsets
    doc_ids[dst] = p_doc
    tfs[dst] = p_tf

    # block metadata (padding rows: tf=0, dl=max -> upper bound 0)
    nb = P // BLOCK
    b_tf = tfs.reshape(nb, BLOCK)
    b_dl = np.where(doc_ids.reshape(nb, BLOCK) >= 0,
                    doc_len[np.maximum(doc_ids.reshape(nb, BLOCK), 0)],
                    np.iinfo(np.int32).max)
    block_max_tf = b_tf.max(axis=1).astype(np.int32)
    block_min_dl = b_dl.min(axis=1).astype(np.int32)

    # forward file from the same pairs (doc-major)
    order = np.argsort(p_doc, kind="stable")
    f_doc = p_doc[order]
    fwd_terms = p_term[order].astype(np.int32)
    fwd_tfs = p_tf[order]
    fwd_counts = np.bincount(f_doc, minlength=D)
    fwd_start = np.zeros(D + 1, np.int64)
    np.cumsum(fwd_counts, out=fwd_start[1:])

    return InvertedIndex(
        term_start=jnp.asarray(term_start), doc_ids=jnp.asarray(doc_ids),
        tfs=jnp.asarray(tfs), block_max_tf=jnp.asarray(block_max_tf),
        block_min_dl=jnp.asarray(block_min_dl), doc_len=jnp.asarray(doc_len),
        df=jnp.asarray(df), cf=jnp.asarray(cf),
        fwd_start=jnp.asarray(fwd_start), fwd_terms=jnp.asarray(fwd_terms),
        fwd_tfs=jnp.asarray(fwd_tfs),
        n_docs=D, vocab=V, avg_doclen=float(doc_len.mean()),
        total_terms=int(doc_len.sum()), max_fwd_len=int(fwd_counts.max()),
    )


@partial(jax.jit, static_argnames=("max_postings",))
def gather_postings(index: InvertedIndex, terms: jax.Array, max_postings: int):
    """Gather padded postings for query ``terms`` [MAXQ].

    Returns dict with [MAXQ, max_postings] doc_ids/tfs/mask and per-term df.
    """
    t = jnp.maximum(terms, 0)
    start = index.term_start[t]
    length = index.term_start[t + 1] - start
    pos = start[:, None] + jnp.arange(max_postings)[None, :]
    in_range = (jnp.arange(max_postings)[None, :] < length[:, None]) & \
        (terms >= 0)[:, None]
    pos = jnp.minimum(pos, index.doc_ids.shape[0] - 1)
    docs = jnp.where(in_range, index.doc_ids[pos], -1)
    tf = jnp.where(in_range, index.tfs[pos], 0)
    mask = in_range & (docs >= 0)
    return {"doc_ids": jnp.maximum(docs, 0), "tfs": tf, "mask": mask,
            "df": index.df[t], "cf": index.cf[t]}
