from repro.index.inverted import InvertedIndex, build_index  # noqa: F401
from repro.index.corpus import synthesize_corpus, synthesize_topics  # noqa: F401
from repro.index.dense import (DenseIndex, IVFDenseIndex,  # noqa: F401
                               build_dense_index, build_ivf_index)
