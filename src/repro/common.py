"""Shared utilities: dtype policy, tree helpers, simple registries."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# dtype policy
# ---------------------------------------------------------------------------

#: Default parameter / activation dtype for large-scale runs. fp32 is used for
#: softmax, layernorm statistics, router logits and the optimizer state.
DEFAULT_DTYPE = jnp.bfloat16


def cast_tree(tree: Any, dtype) -> Any:
    """Cast every floating leaf of ``tree`` to ``dtype``."""

    def _cast(x):
        if isinstance(x, (jax.Array, np.ndarray)) and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(_cast, tree)


def tree_size_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree) if hasattr(x, "size"))


def tree_param_count(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree) if hasattr(x, "shape"))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class Registry:
    """Minimal name → factory registry (used for archs, weighting models, ...)."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict[str, Any] = {}

    def register(self, name: str, obj: Any = None):
        if obj is not None:
            self._entries[name] = obj
            return obj

        def deco(fn):
            self._entries[name] = fn
            return fn

        return deco

    def __getitem__(self, name: str) -> Any:
        if name not in self._entries:
            raise KeyError(
                f"unknown {self.kind} {name!r}; known: {sorted(self._entries)}")
        return self._entries[name]

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def names(self) -> list[str]:
        return sorted(self._entries)


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def frozen(cls):
    """Shorthand for a frozen dataclass with keyword-only fields."""
    return dataclasses.dataclass(frozen=True, kw_only=True)(cls)
