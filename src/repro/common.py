"""Shared utilities: dtype policy, tree helpers, simple registries."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# dtype policy
# ---------------------------------------------------------------------------

#: Default parameter / activation dtype for large-scale runs. fp32 is used for
#: softmax, layernorm statistics, router logits and the optimizer state.
DEFAULT_DTYPE = jnp.bfloat16


def cast_tree(tree: Any, dtype) -> Any:
    """Cast every floating leaf of ``tree`` to ``dtype``."""

    def _cast(x):
        if isinstance(x, (jax.Array, np.ndarray)) and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(_cast, tree)


def tree_size_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree) if hasattr(x, "size"))


def tree_param_count(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree) if hasattr(x, "shape"))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class Registry:
    """Minimal name → factory registry (used for archs, weighting models, ...)."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict[str, Any] = {}

    def register(self, name: str, obj: Any = None):
        if obj is not None:
            self._entries[name] = obj
            return obj

        def deco(fn):
            self._entries[name] = fn
            return fn

        return deco

    def __getitem__(self, name: str) -> Any:
        if name not in self._entries:
            raise KeyError(
                f"unknown {self.kind} {name!r}; known: {sorted(self._entries)}")
        return self._entries[name]

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def names(self) -> list[str]:
        return sorted(self._entries)


# ---------------------------------------------------------------------------
# bounded LRU mapping (engine jit/chunk caches, serve-layer stage cache)
# ---------------------------------------------------------------------------


class LRU:
    """Bounded insertion/access-ordered mapping with eviction + hit counters.

    ``maxsize=None`` disables the bound (plain dict semantics).  A long-lived
    server touches arbitrarily many (stage, bucket, signature) cache keys, so
    every cache on that path must be bounded or it leaks; the counters feed
    ``cache_info()``-style accessors.

    Thread-safe: the serving layer explicitly supports one cache shared by
    several running servers, and both ``get`` (pop + re-insert) and ``put``
    (insert + evict-oldest) are compound — two racing evictions would pop
    the same oldest key and the loser would KeyError without the lock.
    The lock is reentrant because weakref death callbacks (the engine's
    chunk cache evicts entries when their source array dies) may fire from
    GC triggered *inside* a locked method on the same thread.
    """

    def __init__(self, maxsize: int | None = None):
        import threading
        self.maxsize = maxsize
        self._d: dict = {}
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key, default=None):
        with self._lock:
            try:
                v = self._d.pop(key)
            except KeyError:
                self.misses += 1
                return default
            self._d[key] = v      # re-insert = move to most-recent
            self.hits += 1
            return v

    def put(self, key, value) -> None:
        with self._lock:
            self._d.pop(key, None)
            self._d[key] = value
            if self.maxsize is not None:
                while len(self._d) > self.maxsize:
                    self._d.pop(next(iter(self._d)), None)
                    self.evictions += 1

    def pop(self, key, default=None):
        with self._lock:
            return self._d.pop(key, default)

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def __contains__(self, key) -> bool:   # no LRU touch, no counter bump
        with self._lock:
            return key in self._d

    def values(self) -> list:
        """Snapshot copy — a live dict view would raise if another thread
        inserts mid-iteration (stats readers race the serving thread)."""
        with self._lock:
            return list(self._d.values())

    def clear(self) -> None:
        with self._lock:
            self._d.clear()

    def info(self) -> dict:
        with self._lock:
            return {"size": len(self._d), "maxsize": self.maxsize,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions}


# ---------------------------------------------------------------------------
# bucket ladder policy (shared by the engine and the serving scheduler)
# ---------------------------------------------------------------------------


def select_ladder_bucket(ladder, n: int, *, clamp: bool = False) -> int:
    """Smallest rung of a sorted bucket ``ladder`` covering an ``n``-query
    micro-batch.  This is THE ladder policy — the engine's padding rule and
    the serving scheduler's batch-closure rule are the same function, so
    the two can never drift.  ``clamp=True`` returns the largest rung for
    oversized ``n`` (schedulers report a bucket for any batch they could
    close); ``clamp=False`` raises (the engine chunk-plans big batches
    instead of silently truncating them)."""
    if n <= 0:
        raise ValueError("empty query batch")
    for b in ladder:
        if b >= n:
            return int(b)
    if clamp:
        return int(ladder[-1])
    raise ValueError(
        f"micro-batch of {n} exceeds largest bucket {ladder[-1]}; "
        f"split it (run() chunk-plans big batches automatically)")


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def frozen(cls):
    """Shorthand for a frozen dataclass with keyword-only fields."""
    return dataclasses.dataclass(frozen=True, kw_only=True)(cls)
