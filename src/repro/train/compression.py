"""Gradient compression for slow interconnects (cross-pod DCN).

Two standard schemes, both with error feedback (the residual is carried so
compression error doesn't bias the optimizer — Karimireddy et al.):

* int8 quantisation — per-tensor scale, 4x over fp32 (2x over bf16)
* top-k sparsification — keep the largest |g| entries (indices+values)

Usage in the multi-pod layout: compress BEFORE the cross-pod ('pod' axis)
all-reduce, keep the intra-pod ICI all-reduce uncompressed.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def topk_sparsify(g: jax.Array, k_frac: float = 0.01):
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * k_frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx, g.shape


def topk_densify(vals, idx, shape):
    flat = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), vals.dtype)
    return flat.at[idx].set(vals).reshape(shape)


class ErrorFeedback:
    """Carry compression residuals across steps: g_t' = g_t + e_{t-1};
    e_t = g_t' - decompress(compress(g_t'))."""

    def __init__(self, scheme: str = "int8", k_frac: float = 0.01):
        assert scheme in ("int8", "topk")
        self.scheme = scheme
        self.k_frac = k_frac

    def init(self, grads: Any) -> Any:
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def compress_decompress(self, grads: Any, residual: Any):
        """Returns (decompressed grads as seen after the wire, new residual).
        jit-safe; the 'wire format' is materialised so cross-pod traffic is
        genuinely the compressed payload."""

        def one(g, e):
            gf = g.astype(jnp.float32) + e
            if self.scheme == "int8":
                q, s = quantize_int8(gf)
                out = dequantize_int8(q, s)
            else:
                vals, idx, shape = topk_sparsify(gf, self.k_frac)
                out = topk_densify(vals, idx, shape)
            return out, gf - out

        flat, treedef = jax.tree.flatten(grads)
        res = treedef.flatten_up_to(residual)
        outs = [one(g, e) for g, e in zip(flat, res)]
        return (treedef.unflatten([o[0] for o in outs]),
                treedef.unflatten([o[1] for o in outs]))

    def wire_bytes(self, grads: Any) -> tuple[int, int]:
        """(compressed, uncompressed fp32) bytes per step — for EXPERIMENTS."""
        total = sum(int(x.size) for x in jax.tree.leaves(grads))
        if self.scheme == "int8":
            comp = total + 4 * len(jax.tree.leaves(grads))
        else:
            comp = int(total * self.k_frac) * 8
        return comp, total * 4
