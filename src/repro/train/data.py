"""Deterministic sharded data pipeline.

Determinism contract (required by StepGuard replay): batch ``t`` depends only
on (seed, step t, host shard) — a restored run re-reads exactly the batches
it would have seen.  Per-family synthetic generators with double-buffered
host prefetch.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator

import numpy as np


def lm_batch_fn(vocab: int, batch: int, seq: int):
    def make(step: int, shard: int = 0, n_shards: int = 1) -> dict[str, np.ndarray]:
        b = batch // n_shards
        rng = np.random.default_rng((step * 1_000_003 + shard) & 0x7FFFFFFF)
        # zipf-ish tokens: realistic id skew for embedding-gather benches
        toks = (rng.zipf(1.3, (b, seq + 1)) - 1) % vocab
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    return make


def recsys_batch_fn(make_inputs: Callable[[int, np.random.Generator], dict]):
    def make(step: int, shard: int = 0, n_shards: int = 1):
        rng = np.random.default_rng((step * 999_983 + shard) & 0x7FFFFFFF)
        return make_inputs(step, rng)
    return make


class DataPipeline:
    """Deterministic, replayable, prefetched iterator."""

    def __init__(self, batch_fn: Callable[..., dict], *, shard: int = 0,
                 n_shards: int = 1, prefetch: int = 2):
        self.batch_fn = batch_fn
        self.shard = shard
        self.n_shards = n_shards
        self.prefetch = prefetch

    def iter_from(self, step: int) -> Iterator[dict[str, np.ndarray]]:
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def producer():
            s = step
            while not stop.is_set():
                b = self.batch_fn(s, self.shard, self.n_shards)
                q.put((s, b))
                s += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                _, b = q.get()
                yield b
        finally:
            stop.set()
            try:
                q.get_nowait()
            except queue.Empty:
                pass
