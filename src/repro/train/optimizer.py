"""Hand-rolled AdamW (+schedules) — optax is not available offline.

State layout (a pytree parallel to params):
  {"m": fp32 tree, "v": fp32 tree, "step": scalar int32}

Moments are fp32 regardless of param dtype; under TP profiles they are
additionally ZeRO-1 sharded over the data axis (see sharding.zero1_spec).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True, kw_only=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # "cosine" | "linear" | "constant"


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = jnp.float32(1.0)
    return cfg.lr * warm * decay


def init(params: Any) -> dict[str, Any]:
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(cfg: AdamWConfig, grads: Any, state: dict[str, Any], params: Any):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    lr = schedule_lr(cfg, step)
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1.0 - cfg.b1) * g
        v_new = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        u = (m_new / c1) / (jnp.sqrt(v_new / c2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        if cfg.weight_decay and p.ndim >= 2:  # decay matrices, not norms/bias
            u = u + cfg.weight_decay * p32
        return (p32 - lr * u).astype(p.dtype), m_new, v_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
