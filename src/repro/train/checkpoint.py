"""Sharded, integrity-manifested checkpointing with async host writes.

Orbax is not available offline — this implements the essentials a 1000-node
run needs:

* **sharded layout**: every leaf is written as its own ``.npy`` under a step
  directory, keyed by its pytree path; on restore, leaves are placed back
  onto the target shardings (device_put), so mesh shape may CHANGE between
  save and restore (elastic re-scale).
* **integrity manifest**: per-leaf SHA-256 + dtype/shape; restore verifies
  before the optimizer ever sees the data (detects torn writes).
* **atomicity**: writes go to ``<step>.tmp`` and are renamed only after the
  manifest is fsynced — a crashed save can never shadow the latest good one.
* **async**: ``save_async`` snapshots leaves to host memory synchronously
  (cheap) and does hashing+IO on a background thread, overlapping the next
  training steps.
"""
from __future__ import annotations

import hashlib
import json
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str | Path, step: int, tree: Any) -> Path:
    """Synchronous atomic sharded save. Returns the final directory."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest = {"step": step, "leaves": {}}
    for key, arr in _flatten(tree).items():
        fname = key.replace("/", "__") + ".npy"
        np.save(tmp / fname, arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
        }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


class AsyncCheckpointer:
    """Snapshot-on-call, write-in-background. One outstanding save at a time
    (the next save waits — bounded memory)."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save_async(self, step: int, tree: Any):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # device->host snapshot

        def work():
            try:
                save(self.ckpt_dir, step, host_tree)
                self._gc()
            except Exception as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self):
        steps = sorted(self.ckpt_dir.glob("step_????????"))
        for old in steps[:-self.keep]:
            shutil.rmtree(old, ignore_errors=True)


def latest_step(ckpt_dir: str | Path) -> int | None:
    steps = sorted(Path(ckpt_dir).glob("step_????????"))
    return int(steps[-1].name.split("_")[1]) if steps else None


def restore(ckpt_dir: str | Path, step: int, target: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target`` (abstract or concrete tree),
    verifying integrity, placing leaves onto ``shardings`` if given."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    with open(d / "manifest.json") as f:
        manifest = json.load(f)
    leaves = manifest["leaves"]
    flat_paths = jax.tree_util.tree_flatten_with_path(target)[0]
    shard_list = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(flat_paths))
    out = []
    for (path, leaf), sh in zip(flat_paths, shard_list):
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        meta = leaves[key]
        arr = np.load(d / meta["file"])
        digest = hashlib.sha256(arr.tobytes()).hexdigest()
        if digest != meta["sha256"]:
            raise IOError(f"checkpoint corruption in leaf {key!r}")
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs "
                             f"{leaf.shape}")
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    treedef = jax.tree.structure(target)
    return jax.tree.unflatten(treedef, out)
