"""Generic distributed train step: grad accumulation + AdamW + metrics.

``make_train_step(loss_fn, opt_cfg, n_micro)`` builds a pure
``train_step(state, batch) -> (state, metrics)`` suitable for
``jax.jit(..., in_shardings=..., out_shardings=..., donate_argnums=0)``.

Gradient accumulation reshapes the global batch leading dim into
``[n_micro, B/n_micro, ...]`` and scans, accumulating fp32 grads — the
standard activation-memory lever for the 1M-token train cells.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt_lib


def _split_micro(batch: dict[str, jax.Array], n_micro: int):
    def r(x):
        assert x.shape[0] % n_micro == 0, (x.shape, n_micro)
        return x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(
    loss_fn: Callable[..., tuple[jax.Array, dict]],
    opt_cfg: opt_lib.AdamWConfig,
    *,
    n_micro: int = 1,
) -> Callable[[dict, dict], tuple[dict, dict]]:

    def train_step(state: dict[str, Any], batch: dict[str, jax.Array]):
        params = state["params"]
        grad_fn = jax.grad(loss_fn, has_aux=True)

        if n_micro == 1:
            grads, metrics = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            micro = _split_micro(batch, n_micro)
            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mb):
                g, m = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), acc, g)
                return acc, m

            grads, metrics_seq = jax.lax.scan(body, acc0, micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), metrics_seq)

        new_params, new_opt, opt_metrics = opt_lib.update(
            opt_cfg, grads, state["opt"], params)
        metrics = {**metrics, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_state(params: Any) -> dict[str, Any]:
    return {"params": params, "opt": opt_lib.init(params)}
