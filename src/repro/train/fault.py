"""Fault tolerance for 1000+-node runs: elastic re-meshing, retry-with-
restore, and straggler mitigation.

On a real multi-pod deployment these hooks bind to the cluster manager
(GKE/Borg health signals); here the policy logic is real and unit-tested,
with device liveness injected as a probe function.

* :class:`ElasticMesh` — rebuilds the largest feasible (data, model) mesh
  from surviving devices (model degree is preserved: TP groups are intact or
  dropped whole; DP degree shrinks), and re-places a checkpointed state onto
  the new mesh. Shrinking DP keeps the global batch via more grad-accum
  microbatches.
* :class:`StepGuard` — wraps a train step: on exception (device loss,
  pre-emption) it restores the last good checkpoint, optionally re-meshes,
  and replays. Deterministic data order makes replay exact (see data.py).
* :class:`StragglerMonitor` — EMA of per-step host times; hosts slower than
  ``threshold``× the fleet median are flagged for re-dispatch/eviction (the
  scheduler hook), with hysteresis.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.train import checkpoint as ckpt_lib


# ---------------------------------------------------------------------------
# elastic re-meshing
# ---------------------------------------------------------------------------

def feasible_mesh_shape(n_alive: int, model_degree: int,
                        min_data: int = 1) -> tuple[int, int]:
    """Largest (data, model) grid from ``n_alive`` devices keeping the model
    (TP) degree fixed — TP groups must stay whole."""
    data = n_alive // model_degree
    if data < min_data:
        raise RuntimeError(
            f"only {n_alive} devices alive; cannot keep model degree "
            f"{model_degree}")
    return (data, model_degree)


@dataclasses.dataclass
class ElasticMesh:
    model_degree: int
    axis_names: tuple[str, str] = ("data", "model")

    def build(self, devices: list | None = None):
        devices = devices if devices is not None else jax.devices()
        shape = feasible_mesh_shape(len(devices), self.model_degree)
        n = shape[0] * shape[1]
        dev_grid = np.asarray(devices[:n]).reshape(shape)
        return jax.sharding.Mesh(dev_grid, self.axis_names)

    def rescale_plan(self, old_data_degree: int, new_data_degree: int,
                     global_batch: int, n_micro: int) -> dict:
        """Preserve the global batch (up to rounding) when DP shrinks by
        raising grad-accum; per-shard batch is padded to a microbatch
        multiple and the achieved batch reported."""
        scale = old_data_degree / new_data_degree
        new_micro = max(1, int(np.ceil(n_micro * scale)))
        per_shard = -(-global_batch // new_data_degree)      # ceil div
        per_shard = -(-per_shard // new_micro) * new_micro   # micro multiple
        return {"n_micro": new_micro,
                "per_shard_batch": per_shard,
                "achieved_global_batch": per_shard * new_data_degree}


# ---------------------------------------------------------------------------
# retry / restore guard
# ---------------------------------------------------------------------------

class StepGuard:
    """train loop wrapper: checkpoint every ``ckpt_every`` steps; on failure
    restore last good state and replay."""

    def __init__(self, ckpt_dir, *, ckpt_every: int = 50, max_retries: int = 3,
                 on_failure: Callable[[Exception], None] | None = None):
        self.ckpt = ckpt_lib.AsyncCheckpointer(ckpt_dir)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.on_failure = on_failure
        self.retries = 0
        self.replays = 0

    def run(self, state, data_iter_factory, step_fn, n_steps: int,
            start_step: int = 0):
        """``data_iter_factory(step)`` -> iterator from that step (replay)."""
        step = start_step
        data_iter = data_iter_factory(step)
        metrics = None
        while step < n_steps:
            try:
                batch = next(data_iter)
                state, metrics = step_fn(state, batch)
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save_async(step, state)
            except Exception as e:  # noqa: BLE001 — node loss, OOM, ...
                self.retries += 1
                if self.on_failure:
                    self.on_failure(e)
                if self.retries > self.max_retries:
                    raise
                self.ckpt.wait()
                last = ckpt_lib.latest_step(self.ckpt_dir)
                if last is not None:
                    state = ckpt_lib.restore(self.ckpt_dir, last, state)
                    step = last
                data_iter = data_iter_factory(step)   # deterministic replay
                self.replays += 1
        self.ckpt.wait()
        return state, metrics, step


# ---------------------------------------------------------------------------
# straggler mitigation
# ---------------------------------------------------------------------------

class StragglerMonitor:
    def __init__(self, n_hosts: int, *, threshold: float = 1.5,
                 ema: float = 0.9, grace_steps: int = 5):
        self.times = np.zeros(n_hosts)
        self.strikes = np.zeros(n_hosts, np.int32)
        self.threshold = threshold
        self.ema = ema
        self.grace = grace_steps

    def record(self, host_times: np.ndarray) -> list[int]:
        """Feed per-host step durations; returns hosts to re-dispatch."""
        self.times = np.where(self.times == 0, host_times,
                              self.ema * self.times + (1 - self.ema) * host_times)
        med = np.median(self.times)
        slow = self.times > self.threshold * med
        self.strikes = np.where(slow, self.strikes + 1, 0)
        return np.nonzero(self.strikes >= self.grace)[0].tolist()
