"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Params and activations are annotated with *logical* axis names; per-arch
profiles map logical axes onto mesh axes.  Rules whose dimension does not
divide the mesh axis size are dropped at resolve time (falling back to
replication) so one profile works across mesh shapes.
"""
from __future__ import annotations

from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Canonical logical axis names used throughout the model zoo.
BATCH = "batch"          # global batch / token dim of activations
SEQ = "seq"              # sequence dim of activations
KV_SEQ = "kv_seq"        # sequence dim of a KV cache (SP for long decode)
EMBED = "embed"          # d_model
VOCAB = "vocab"          # vocabulary
Q_HEADS = "q_heads"
KV_HEADS = "kv_heads"
HEAD_DIM = "head_dim"
MLP = "mlp"              # FFN hidden
EXPERTS = "experts"      # MoE expert dim
EXPERT_CAP = "expert_cap"
LAYERS = "layers"        # stacked-layer leading dim (never sharded)
NODES = "nodes"          # GNN node dim
EDGES = "edges"          # GNN edge dim
TABLE_ROWS = "table_rows"  # recsys embedding-table vocab rows
FEATURES = "features"    # generic trailing feature dim
CANDIDATES = "candidates"  # retrieval candidate dim


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel mesh axes ('pod' folded in when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# profiles
# ---------------------------------------------------------------------------

def tp_profile(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    """Megatron-style tensor parallelism over the 'model' axis + DP batch."""
    dp = dp_axes(mesh)
    return {
        BATCH: dp,
        Q_HEADS: ("model",),
        KV_HEADS: ("model",),
        MLP: ("model",),
        VOCAB: ("model",),
        EXPERTS: ("model",),
        KV_SEQ: dp + ("model",),  # KV seq sharded over whatever batch leaves free
        TABLE_ROWS: ("model",),
        EDGES: dp,
        NODES: dp,
        CANDIDATES: dp + ("model",),
    }


def fsdp_profile(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    """ZeRO-3 style: parameter storage sharded over BOTH 'data' (EMBED dim)
    and 'model' (output dims); weights are all-gathered at use.  Used by
    archs whose head counts don't divide the TP degree (qwen2-1.5b,
    llama4-scout) and wherever param+optimizer memory dominates."""
    dp = dp_axes(mesh)
    return {
        BATCH: dp,
        EMBED: ("data",),      # ZeRO shard of the d_model dim of every weight
        Q_HEADS: ("model",),   # auto-dropped when not divisible
        HEAD_DIM: ("model",),  # picks up 'model' when q_heads dropped
        MLP: ("model",),
        VOCAB: ("model",),
        EXPERTS: ("model",),
        KV_SEQ: dp + ("model",),
        TABLE_ROWS: ("model",),
        EDGES: dp,
        NODES: dp,
        CANDIDATES: dp + ("model",),
    }


def zero3_profile(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    """Pure storage sharding (§Perf iteration 2): attention weights shard
    ONLY on their d_model (EMBED) dim over 'data' — compute-local attention
    after the FSDP gather — while FFN/vocab keep 'model' TP.  Removes the
    cross-shard QK^T/PV contractions the fsdp profile's HEAD_DIM rule
    induces (measured: those dominated the all-reduce volume)."""
    dp = dp_axes(mesh)
    return {
        BATCH: dp,
        EMBED: ("data",),
        MLP: ("model",),
        VOCAB: ("model",),
        EXPERTS: ("model",),
        KV_SEQ: dp + ("model",),
        TABLE_ROWS: ("model",),
        EDGES: dp,
        NODES: dp,
        CANDIDATES: dp + ("model",),
    }


def light_profile(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    """§Perf iteration 3: attention weights fully replicated (no gathers,
    no cross-shard contractions — the zero3 EMBED-over-data gathers
    triggered XLA involuntary rematerialisation inside scan loops); FFN and
    vocab keep 'model' TP; optimizer moments are still ZeRO-1 over data.
    Right for ≤2B-param archs whose attention weights fit replicated."""
    dp = dp_axes(mesh)
    return {
        BATCH: dp,
        MLP: ("model",),
        VOCAB: ("model",),
        EXPERTS: ("model",),
        KV_SEQ: dp + ("model",),
        TABLE_ROWS: ("model",),
        EDGES: dp,
        NODES: dp,
        CANDIDATES: dp + ("model",),
    }


def dp_profile(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    """§Perf iteration 4: pure data parallelism over EVERY mesh axis
    (batch 256-way), weights replicated, optimizer ZeRO-1 over data.
    The right answer for ≤2B dense models: no TP collectives at all, the
    only traffic is one gradient all-reduce per step."""
    dp = dp_axes(mesh) + ("model",)
    return {
        BATCH: dp,
        KV_SEQ: dp,
        TABLE_ROWS: ("model",),
        EDGES: dp,
        NODES: dp,
        CANDIDATES: dp,
    }


def dp_ep_profile(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    """Pure-DP activations + expert weights sharded (EP over 'model', expert
    ff additionally over 'data') — for MoE archs whose dense parts fit
    replicated but whose expert bank doesn't (llama4-scout)."""
    dp = dp_axes(mesh) + ("model",)
    return {
        BATCH: dp,
        EXPERTS: ("model",),
        MLP: ("data",),        # expert ff dim ZeRO-sharded over data
        VOCAB: ("model",),
        EMBED: ("data",),      # embedding/unembed d-shard (vocab is huge)
        KV_SEQ: dp,
        TABLE_ROWS: ("model",),
        EDGES: dp,
        NODES: dp,
        CANDIDATES: dp,
    }


PROFILES = {"tp": tp_profile, "fsdp": fsdp_profile, "zero3": zero3_profile,
            "light": light_profile, "dp": dp_profile, "dp_ep": dp_ep_profile}


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------

def _axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def resolve_spec(
    logical: Sequence[str | None],
    dims: Sequence[int],
    mesh: Mesh,
    profile: Mapping[str, tuple[str, ...]],
) -> P:
    """Map logical axes of one array to a PartitionSpec, dropping rules whose
    mesh-axis product does not divide the dim (uneven shards are legal in
    GSPMD but we avoid them for predictable layouts)."""
    assert len(logical) == len(dims), (logical, dims)
    spec, used = [], set()
    for name, dim in zip(logical, dims):
        axes = tuple(profile.get(name, ())) if name else ()
        axes = tuple(a for a in axes if a in mesh.axis_names and a not in used)
        # longest prefix of the requested axes whose product divides the dim
        while axes and dim % _axes_size(mesh, axes) != 0:
            axes = axes[:-1]
        if axes:
            used.update(axes)
            spec.append(axes if len(axes) > 1 else axes[0])
        else:
            spec.append(None)
    return P(*spec)


def named_sharding(mesh, logical, dims, profile) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(logical, dims, mesh, profile))


def constrain(x, logical: Sequence[str | None], mesh: Mesh, profile) -> jax.Array:
    """with_sharding_constraint via logical names (no-op outside jit/mesh)."""
    spec = resolve_spec(logical, x.shape, mesh, profile)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


class Ax:
    """Pytree *leaf* wrapper holding the logical axis names of one param.

    (A plain tuple would be flattened as a pytree node, breaking tree.map
    against the param tree — hence the wrapper.)
    """

    __slots__ = ("names",)

    def __init__(self, *names: str | None):
        self.names = names

    def __repr__(self):
        return f"Ax{self.names}"


def spec_tree(abstract_params, logical_tree, mesh, profile):
    """Build a NamedSharding tree parallel to an abstract param tree.

    ``logical_tree`` mirrors the param tree with ``Ax(...)`` leaves.
    """
    return jax.tree.map(
        lambda a, ax: named_sharding(mesh, ax.names, a.shape, profile),
        abstract_params,
        logical_tree,
    )


def pspec_tree(abstract_params, logical_tree, mesh, profile):
    """Same as spec_tree but returning raw PartitionSpecs."""
    return jax.tree.map(
        lambda a, ax: resolve_spec(ax.names, a.shape, mesh, profile),
        abstract_params,
        logical_tree,
    )


def zero1_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO-1: extend a param spec with 'data' sharding on the first free,
    divisible dim — used for optimizer moments so they never replicate
    across the data axis even under pure-TP profiles."""
    if "data" not in mesh.axis_names:
        return spec
    used = set()
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a:
                used.add(a)
    if "data" in used:
        return spec
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % mesh.shape["data"] == 0 and dim > 1:
            entries[i] = "data"
            return P(*entries)
    return spec


def zero1_sharding_tree(abstract_tree, spec_tree_, mesh) -> Any:
    return jax.tree.map(
        lambda a, s: NamedSharding(mesh, zero1_spec(
            s.spec if isinstance(s, NamedSharding) else s, a.shape, mesh)),
        abstract_tree, spec_tree_)
