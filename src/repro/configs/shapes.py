"""Canonical shape cells per family (assigned-architecture input shapes)."""
from __future__ import annotations

# — LM-family transformers: seq_len × global_batch —
LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", kv_len=32768, batch=128),
    # long-context decode: 1 new token vs a 512k KV cache (linear per step;
    # KV is sequence-sharded — see DESIGN.md §Shape-cell notes)
    "long_500k": dict(kind="decode", kv_len=524288, batch=1),
}

# — gat-cora: dataset-sized graph cells —
GNN_SHAPES = {
    "full_graph_sm": dict(kind="train", n_nodes=2708, n_edges=10556,
                          d_feat=1433, n_classes=7),
    # Reddit, fanout 15-10 from 1024 seeds -> padded subgraph:
    # nodes = 1024 + 1024*15 + 15360*10 ; edges = 15360 + 153600
    "minibatch_lg": dict(kind="train", n_nodes=169984, n_edges=168960,
                         d_feat=602, n_classes=41, sampled=True,
                         base_nodes=232965, base_edges=114615892,
                         batch_nodes=1024, fanouts=(15, 10)),
    "ogb_products": dict(kind="train", n_nodes=2449029, n_edges=61859140,
                         d_feat=100, n_classes=47),
    "molecule": dict(kind="train", n_graphs=128, nodes_per_graph=30,
                     edges_per_graph=64, d_feat=9, n_classes=2,
                     readout="mean"),
}

# — recsys —
RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, candidates=1000000),
}
