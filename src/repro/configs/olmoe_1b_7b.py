"""olmoe-1b-7b [arXiv:2409.02060]: 16L d=2048 16H (kv=16) d_ff=1024,
MoE 64e top-8, vocab=50304.  16 heads divide 16 -> TP attention + EP experts
(64/16 = 4 experts per shard).
"""
from __future__ import annotations

import numpy as np

from repro.configs import shapes
from repro.configs.registry import ArchDef, register
from repro.models.moe import MoEConfig
from repro.models.transformer_lm import LMConfig


def model_cfg(shape: str | None = None) -> LMConfig:
    return LMConfig(
        name="olmoe-1b-7b", n_layers=16, d_model=2048, n_q=16, n_kv=16,
        d_head=128, d_ff=1024, vocab=50304, rope_theta=1e4,
        moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024,
                      router_act="softmax", normalize_gates=True,
                      dispatch="scatter"),
        sharding_profile="tp",
    )


def reduced():
    cfg = LMConfig(
        name="olmoe-smoke", n_layers=2, d_model=64, n_q=4, n_kv=4, d_head=16,
        d_ff=64, vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64),
    )

    def batch():
        rng = np.random.default_rng(4)
        t = rng.integers(0, cfg.vocab, (2, 32), dtype=np.int32)
        return {"tokens": t, "targets": t}

    return cfg, batch


register(ArchDef(
    arch_id="olmoe-1b-7b", family="lm", shapes=shapes.LM_SHAPES,
    model_cfg=model_cfg, reduced=reduced, train_microbatches=4,
    notes="64 experts top-8 [arXiv:2409.02060; hf]",
))
