"""dcn-v2 [arXiv:2008.13535]: 13 dense + 26 sparse (Criteo vocabs), dim-16
embeds, 3 cross layers, MLP 1024-1024-512.
"""
from __future__ import annotations

import numpy as np

from repro.configs import shapes
from repro.configs.registry import ArchDef, register
from repro.models.recsys.dcn import DCNConfig


def model_cfg(shape: str | None = None) -> DCNConfig:
    return DCNConfig()


def reduced():
    cfg = DCNConfig(vocabs=(50,) * 26, mlp=(64, 64, 32))

    def batch():
        rng = np.random.default_rng(6)
        return {
            "dense": rng.standard_normal((16, 13), dtype=np.float32),
            "cat": rng.integers(0, 50, (16, 26), dtype=np.int32),
            "label": rng.integers(0, 2, 16, dtype=np.int32),
        }

    return cfg, batch


register(ArchDef(
    arch_id="dcn-v2", family="recsys", shapes=shapes.RECSYS_SHAPES,
    model_cfg=model_cfg, reduced=reduced,
    notes="cross interaction [arXiv:2008.13535; paper]",
))
