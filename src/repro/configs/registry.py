"""Architecture registry: ``--arch <id>`` resolution for all 10 assigned archs."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

from repro.common import Registry

ARCHS = Registry("architecture")


@dataclasses.dataclass(frozen=True, kw_only=True)
class ArchDef:
    """One selectable architecture with its shape cells.

    ``model_cfg(shape_name)`` may specialise the config per shape (the GNN
    cells carry their own feature/class counts); ``reduced()`` returns a
    small same-family config + a host-side batch factory for smoke tests.
    """

    arch_id: str
    family: str                                   # "lm" | "gnn" | "recsys"
    shapes: dict[str, dict]
    model_cfg: Callable[[str], Any]
    reduced: Callable[[], tuple[Any, Callable[[], dict]]]
    train_microbatches: int = 1                    # grad-accum for train cells
    notes: str = ""

    @property
    def module(self):
        mod = {
            "lm": "repro.models.transformer_lm",
            "gnn": "repro.models.gnn",
        }.get(self.family)
        if mod is None:  # recsys: per-arch module (dcn-v2 -> dcn, ...)
            mod = f"repro.models.recsys.{self.arch_id.split('-')[0]}"
        return importlib.import_module(mod)


def register(arch: ArchDef) -> ArchDef:
    ARCHS.register(arch.arch_id, arch)
    return arch


def get_arch(arch_id: str) -> ArchDef:
    _ensure_loaded()
    return ARCHS[arch_id]


def all_arch_ids() -> list[str]:
    _ensure_loaded()
    return ARCHS.names()


_LOADED = False

_CONFIG_MODULES = [
    "repro.configs.qwen2_1_5b",
    "repro.configs.glm4_9b",
    "repro.configs.internlm2_1_8b",
    "repro.configs.llama4_scout_17b_a16e",
    "repro.configs.olmoe_1b_7b",
    "repro.configs.gat_cora",
    "repro.configs.dcn_v2",
    "repro.configs.dien",
    "repro.configs.mind",
    "repro.configs.autoint",
]


def _ensure_loaded():
    global _LOADED
    if not _LOADED:
        for m in _CONFIG_MODULES:
            importlib.import_module(m)
        _LOADED = True
