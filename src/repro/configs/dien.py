"""dien [arXiv:1809.03672]: embed_dim=18, seq_len=100, GRU 108, MLP 200-80,
AUGRU interest evolution (Amazon-Electronics-sized vocabularies).
"""
from __future__ import annotations

import numpy as np

from repro.configs import shapes
from repro.configs.registry import ArchDef, register
from repro.models.recsys.dien import DIENConfig


def model_cfg(shape: str | None = None) -> DIENConfig:
    return DIENConfig()


def reduced():
    cfg = DIENConfig(item_vocab=200, cate_vocab=20, seq_len=12, mlp=(32, 16))

    def batch():
        rng = np.random.default_rng(7)
        return {
            "hist_items": rng.integers(0, 200, (8, 12), dtype=np.int32),
            "hist_cates": rng.integers(0, 20, (8, 12), dtype=np.int32),
            "hist_mask": (rng.random((8, 12)) < 0.8).astype(np.float32),
            "target_item": rng.integers(0, 200, 8, dtype=np.int32),
            "target_cate": rng.integers(0, 20, 8, dtype=np.int32),
            "label": rng.integers(0, 2, 8, dtype=np.int32),
        }

    return cfg, batch


register(ArchDef(
    arch_id="dien", family="recsys", shapes=shapes.RECSYS_SHAPES,
    model_cfg=model_cfg, reduced=reduced,
    notes="AUGRU interest evolution [arXiv:1809.03672; unverified]",
))
