"""autoint [arXiv:1810.11921]: 39 sparse fields, dim-16 embeds, 3 self-attn
interacting layers, 2 heads, d_attn=32.
"""
from __future__ import annotations

import numpy as np

from repro.configs import shapes
from repro.configs.registry import ArchDef, register
from repro.models.recsys.autoint import AutoIntConfig


def model_cfg(shape: str | None = None) -> AutoIntConfig:
    return AutoIntConfig()


def reduced():
    cfg = AutoIntConfig(vocabs=(50,) * 39)

    def batch():
        rng = np.random.default_rng(9)
        return {
            "cat": rng.integers(0, 50, (16, 39), dtype=np.int32),
            "label": rng.integers(0, 2, 16, dtype=np.int32),
        }

    return cfg, batch


register(ArchDef(
    arch_id="autoint", family="recsys", shapes=shapes.RECSYS_SHAPES,
    model_cfg=model_cfg, reduced=reduced,
    notes="self-attention feature interaction [arXiv:1810.11921; paper]",
))
