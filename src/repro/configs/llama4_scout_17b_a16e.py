"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E]: 48L d=5120
40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1 + 1 shared expert,
chunked local attention (8192) on 3/4 layers (iRoPE-style).

40 heads don't divide 16 -> FSDP attention (params ZeRO-sharded over
data×model) + expert parallelism over 'model' (16 experts / 16-way = 1
expert per TP group).  ~109B total / ~17B active params.
"""
from __future__ import annotations

import numpy as np

from repro.configs import shapes
from repro.configs.registry import ArchDef, register
from repro.models.moe import MoEConfig
from repro.models.transformer_lm import LMConfig


def model_cfg(shape: str | None = None) -> LMConfig:
    return LMConfig(
        name="llama4-scout-17b-a16e", n_layers=48, d_model=5120, n_q=40,
        n_kv=8, d_head=128, d_ff=8192, vocab=202048, rope_theta=5e5,
        attn_chunk=8192, attn_chunk_every=4,
        moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, n_shared=1,
                      d_ff_shared=8192, router_act="sigmoid",
                      normalize_gates=False, dispatch="scatter"),
        sharding_profile="fsdp",
    )


def reduced():
    cfg = LMConfig(
        name="llama4-smoke", n_layers=2, d_model=64, n_q=4, n_kv=2, d_head=16,
        d_ff=128, vocab=512, attn_chunk=16, attn_chunk_every=2,
        moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=64, n_shared=1,
                      d_ff_shared=64, router_act="sigmoid",
                      normalize_gates=False),
    )

    def batch():
        rng = np.random.default_rng(3)
        t = rng.integers(0, cfg.vocab, (2, 32), dtype=np.int32)
        return {"tokens": t, "targets": t}

    return cfg, batch


register(ArchDef(
    arch_id="llama4-scout-17b-a16e", family="lm", shapes=shapes.LM_SHAPES,
    model_cfg=model_cfg, reduced=reduced, train_microbatches=8,
    notes="MoE 16e top-1, early fusion (modality frontend stubbed per brief) "
          "[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
))
