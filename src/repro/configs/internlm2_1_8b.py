"""internlm2-1.8b [arXiv:2403.17297]: 24L d=2048 16H (GQA kv=8) d_ff=8192
vocab=92544 — GQA.  16 q-heads divide 16 -> TP profile.
"""
from __future__ import annotations

import numpy as np

from repro.configs import shapes
from repro.configs.registry import ArchDef, register
from repro.models.transformer_lm import LMConfig


def model_cfg(shape: str | None = None) -> LMConfig:
    return LMConfig(
        name="internlm2-1.8b", n_layers=24, d_model=2048, n_q=16, n_kv=8,
        d_head=128, d_ff=8192, vocab=92544, rope_theta=1e6,
        sharding_profile="tp",
    )


def reduced():
    cfg = LMConfig(
        name="internlm2-smoke", n_layers=2, d_model=64, n_q=4, n_kv=2,
        d_head=16, d_ff=128, vocab=512,
    )

    def batch():
        rng = np.random.default_rng(2)
        t = rng.integers(0, cfg.vocab, (2, 32), dtype=np.int32)
        return {"tokens": t, "targets": t}

    return cfg, batch


register(ArchDef(
    arch_id="internlm2-1.8b", family="lm", shapes=shapes.LM_SHAPES,
    model_cfg=model_cfg, reduced=reduced, train_microbatches=4,
    notes="GQA [arXiv:2403.17297; hf]",
))
