"""mind [arXiv:1904.08030]: embed_dim=64, 4 interests, 3 capsule routing
iterations, multi-interest retrieval.
"""
from __future__ import annotations

import numpy as np

from repro.configs import shapes
from repro.configs.registry import ArchDef, register
from repro.models.recsys.mind import MINDConfig


def model_cfg(shape: str | None = None) -> MINDConfig:
    return MINDConfig()


def reduced():
    cfg = MINDConfig(item_vocab=500, seq_len=10)

    def batch():
        rng = np.random.default_rng(8)
        return {
            "hist_items": rng.integers(0, 500, (8, 10), dtype=np.int32),
            "hist_mask": (rng.random((8, 10)) < 0.9).astype(np.float32),
            "target_item": rng.integers(0, 500, 8, dtype=np.int32),
        }

    return cfg, batch


register(ArchDef(
    arch_id="mind", family="recsys", shapes=shapes.RECSYS_SHAPES,
    model_cfg=model_cfg, reduced=reduced,
    notes="multi-interest capsule routing [arXiv:1904.08030; unverified]",
))
