"""glm4-9b [hf:THUDM/glm-4-9b]: 40L d=4096 32H (GQA kv=2) d_ff=13696
vocab=151552 — RoPE, GQA.  32 q-heads divide 16 -> TP profile (+ZeRO-1 opt).
"""
from __future__ import annotations

import numpy as np

from repro.configs import shapes
from repro.configs.registry import ArchDef, register
from repro.models.transformer_lm import LMConfig


def model_cfg(shape: str | None = None) -> LMConfig:
    return LMConfig(
        name="glm4-9b", n_layers=40, d_model=4096, n_q=32, n_kv=2,
        d_head=128, d_ff=13696, vocab=151552, rope_theta=1e6,
        sharding_profile="tp", seq_parallel=True,
    )


def reduced():
    cfg = LMConfig(
        name="glm4-smoke", n_layers=2, d_model=64, n_q=8, n_kv=2, d_head=16,
        d_ff=160, vocab=512,
    )

    def batch():
        rng = np.random.default_rng(1)
        t = rng.integers(0, cfg.vocab, (2, 32), dtype=np.int32)
        return {"tokens": t, "targets": t}

    return cfg, batch


register(ArchDef(
    arch_id="glm4-9b", family="lm", shapes=shapes.LM_SHAPES,
    model_cfg=model_cfg, reduced=reduced, train_microbatches=8,
    notes="RoPE, GQA [hf:THUDM/glm-4-9b; hf]",
))
