"""qwen2-1.5b [arXiv:2407.10671]: 28L d=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — GQA with QKV bias, tied embeddings.

12 query heads don't divide the 16-way model axis -> FSDP (ZeRO-3) profile.
"""
from __future__ import annotations

import numpy as np

from repro.configs import shapes
from repro.configs.registry import ArchDef, register
from repro.models.transformer_lm import LMConfig


def model_cfg(shape: str | None = None) -> LMConfig:
    return LMConfig(
        name="qwen2-1.5b", n_layers=28, d_model=1536, n_q=12, n_kv=2,
        d_head=128, d_ff=8960, vocab=151936, qkv_bias=True,
        tie_embeddings=True, rope_theta=1e6,
        sharding_profile="fsdp",
    )


def reduced():
    cfg = LMConfig(
        name="qwen2-smoke", n_layers=2, d_model=64, n_q=4, n_kv=2, d_head=16,
        d_ff=128, vocab=512, qkv_bias=True, tie_embeddings=True,
    )

    def batch():
        rng = np.random.default_rng(0)
        t = rng.integers(0, cfg.vocab, (2, 32), dtype=np.int32)
        return {"tokens": t, "targets": t}

    return cfg, batch


register(ArchDef(
    arch_id="qwen2-1.5b", family="lm", shapes=shapes.LM_SHAPES,
    model_cfg=model_cfg, reduced=reduced, train_microbatches=4,
    notes="GQA, QKV bias [arXiv:2407.10671; hf]",
))
