"""gat-cora [arXiv:1710.10903]: 2-layer GAT, 8 hidden per head, 8 heads,
attention aggregator.  Feature/class dims follow the dataset of each shape
cell (Cora / Reddit / ogbn-products / molhiv-like molecules).
"""
from __future__ import annotations

import numpy as np

from repro.configs import shapes
from repro.configs.registry import ArchDef, register
from repro.models.gnn import GATConfig


def model_cfg(shape: str | None = None) -> GATConfig:
    cell = shapes.GNN_SHAPES.get(shape or "full_graph_sm",
                                 shapes.GNN_SHAPES["full_graph_sm"])
    return GATConfig(
        name="gat-cora", n_layers=2, d_hidden=8, n_heads=8,
        d_feat=cell["d_feat"], n_classes=cell["n_classes"],
        readout=cell.get("readout"),
    )


def reduced():
    cfg = GATConfig(name="gat-smoke", n_layers=2, d_hidden=8, n_heads=4,
                    d_feat=16, n_classes=5)

    def batch():
        rng = np.random.default_rng(5)
        return {
            "x": rng.standard_normal((64, 16), dtype=np.float32),
            "src": rng.integers(0, 64, 256, dtype=np.int32),
            "dst": rng.integers(0, 64, 256, dtype=np.int32),
            "labels": rng.integers(0, 5, 64, dtype=np.int32),
            "label_mask": np.ones(64, bool),
        }

    return cfg, batch


register(ArchDef(
    arch_id="gat-cora", family="gnn", shapes=shapes.GNN_SHAPES,
    model_cfg=model_cfg, reduced=reduced,
    notes="SpMM/SDDMM regime via segment ops [arXiv:1710.10903; paper]",
))
