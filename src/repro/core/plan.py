"""Experiment planner: trie-based shared-prefix scheduling + artifact cache.

The paper's ``Experiment`` promises that pipelines sharing a common prefix
execute that prefix once.  This module makes the promise *structural*
instead of accidental: the planner compiles every pipeline through the IR
pass manager (``core/passes.py``, with one CSE table spanning all
pipelines), flattens the resulting IR into its chain of top-level stage
ops, inserts the chains into a **prefix trie** keyed by the ops' stable
content keys, and schedules a depth-first
traversal in which every trie node — i.e. every distinct shared
sub-pipeline — executes **exactly once** per query set.  (Cf. MacAvaney &
Macdonald on precomputation/caching in pipeline architectures, and Anu &
Macdonald's trie-based experiment plans.)

Per trie node the planner records wall-clock for a cold pass (includes JIT
compilation) and a steady-state pass, so an Experiment's MRT decomposes
into ``compile`` / ``execute`` / ``shared-amortised`` components instead of
conflating compilation with retrieval.

Stage outputs can additionally be spilled to an on-disk :class:`ArtifactCache`
keyed by ``(prefix key, query-set digest, index digest)`` — all
content-derived, so a cache directory is valid across processes.  Stages
whose structural key embeds process-local state (``("obj", id)`` params or
stateful uid/version markers) are never persisted.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from pathlib import Path
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ir
from repro.core.compiler import (Context, JaxBackend, _execute, content_token,
                                 derive_token)
from repro.core.passes import compile_pipeline
from repro.core.transformer import Transformer
from repro.obs.tracing import NOOP_TRACER, get_tracer


# ---------------------------------------------------------------------------
# canonical chains + persistent keys
# ---------------------------------------------------------------------------

def stage_chain(node: Transformer | ir.Op) -> list:
    """A (compiled) pipeline as its linear chain of top-level stages.
    Nested combinators stay atomic trie entries; sharing inside them is
    handled by the content-addressed memo.  The planner operates on IR ops;
    ``Transformer`` trees are accepted for compatibility."""
    if isinstance(node, Transformer):
        node = ir.lower(node)
    return ir.chain(node)


def _key_is_persistent(key) -> bool:
    kind, items, state, children = key
    if state:                       # stateful: (uid, version), process-local
        return False
    for _, v in items:
        if isinstance(v, tuple) and len(v) == 2 and v[0] == "obj":
            return False            # param keyed by object identity
    return all(_key_is_persistent(c) for c in children)


def persistent_key(node) -> str | None:
    """Cross-process digest of a stage's structural key (IR op or
    Transformer), or None if the key references process-local state and
    must not be written to disk."""
    key = node.key()
    if not _key_is_persistent(key):
        return None
    return hashlib.sha256(repr(key).encode()).hexdigest()


def chain_prefix_digests(chain: Sequence, *, scope: str = "") -> list[str]:
    """Cumulative digests of a stage chain's prefixes: ``out[i]`` covers
    stages ``0..i``.  This is the serving layer's stage-cache key family —
    the online counterpart of the plan trie's per-node ``persist`` digests,
    chained the same way but over the *full* structural key, so
    process-local stages (object-identity params, stateful version markers)
    participate too.  Only valid in-process while the caller pins the ops
    (id-bearing keys may alias once the objects die); anything written to
    disk must go through :func:`persistent_key` instead.  A stateful
    stage's ``fit()`` bumps its version marker, which changes every digest
    from that stage onward — built-in invalidation."""
    out: list[str] = []
    acc = hashlib.sha256(scope.encode()).hexdigest()
    for stage in chain:
        acc = hashlib.sha256(
            (acc + repr(stage.key())).encode()).hexdigest()
        out.append(acc)
    return out


def backend_digest(backend: JaxBackend) -> str:
    """Content digest of the backend's result-affecting state: the index
    arrays plus the execution config stages resolve at run time (default_k
    for Retrieve(k=None), the dense embeddings and query projection for
    DenseRerank / embed_queries, the IVF quantiser config for
    DenseRetrieve).  A lazily built IVF is a pure function of
    (dense.emb, ivf_* config), so its *config* digests it; an externally
    supplied IVF is digested by its full contents (centroids alone would
    alias two hand-built IVFs sharing centroids but not list assignment).
    Cached — all of it is immutable once the backend is built."""
    dig = getattr(backend, "_content_digest", None)
    if dig is None:
        ivf_part = (backend.ivf if backend._ivf_external
                    else (-1 if backend.ivf_lists is None
                          else backend.ivf_lists,
                          backend.ivf_iters, backend.ivf_seed,
                          bool(getattr(backend, "ivf_keep_flat", True))))
        pq_part = (backend.ivfpq if getattr(backend, "_ivfpq_external",
                                            False)
                   else (getattr(backend, "pq_m", 8),
                         getattr(backend, "pq_iters", 10),
                         getattr(backend, "pq_refine", 4)))
        dig = content_token((backend.index, backend.default_k,
                             backend.dense.emb, backend._qproj, ivf_part,
                             pq_part))
        backend._content_digest = dig
    return dig


# ---------------------------------------------------------------------------
# on-disk artifact cache
# ---------------------------------------------------------------------------

class ArtifactCache:
    """Stage-output store: one ``.npz`` per (prefix, query set, index) key,
    holding the stage's (Q, R) output arrays."""

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self.path.mkdir(parents=True, exist_ok=True)
        self.hits = 0
        self.misses = 0

    def _file(self, key: str) -> Path:
        return self.path / f"{key}.npz"

    def load(self, key: str):
        f = self._file(key)
        if not f.exists():
            self.misses += 1
            return None
        try:
            with np.load(f) as z:
                meta = json.loads(z["__meta__"].item())
                out = []
                for part in ("Q", "R"):
                    if meta[part] is None:
                        out.append(None)
                    else:
                        out.append({k: jnp.asarray(z[f"{part}.{k}"])
                                    for k in meta[part]})
        except Exception:
            # corrupt / truncated / foreign file: a cache must degrade to
            # recompute, never take the experiment down
            f.unlink(missing_ok=True)
            self.misses += 1
            return None
        self.hits += 1
        return tuple(out)

    def store(self, key: str, Q, R) -> None:
        arrays, meta = {}, {}
        for part, d in (("Q", Q), ("R", R)):
            meta[part] = None if d is None else sorted(d)
            if d is not None:
                for k, v in d.items():
                    arrays[f"{part}.{k}"] = np.asarray(v)
        # per-writer tmp name (concurrent processes may store the same key),
        # .npz suffix so savez keeps the name; then atomic publish
        tmp = self.path / f"{key}.{os.getpid()}.tmp.npz"
        np.savez(tmp, __meta__=json.dumps(meta), **arrays)
        tmp.replace(self._file(key))


# ---------------------------------------------------------------------------
# the plan trie
# ---------------------------------------------------------------------------

class PlanNode:
    """One trie node = one stage execution (an IR op), shared by every
    pipeline whose chain passes through this prefix."""

    __slots__ = ("stage", "parent", "children", "pipelines", "persist",
                 "cold_s", "warm_s", "cache_hit")

    def __init__(self, stage: "ir.Op | None", parent: "PlanNode | None"):
        self.stage = stage
        self.parent = parent
        self.children: dict = {}        # stage.key() -> PlanNode
        self.pipelines: list[int] = []  # pipeline indices sharing this prefix
        self.persist: str | None = None # cross-process prefix digest
        self.cold_s: float | None = None
        self.warm_s: float | None = None
        self.cache_hit = False

    @property
    def n_shared(self) -> int:
        return len(self.pipelines)

    @property
    def depth(self) -> int:
        d, n = 0, self
        while n.parent is not None:
            d, n = d + 1, n.parent
        return d

    def label(self) -> str:
        return self.stage.label() if self.stage is not None else "<root>"


class ExperimentPlan:
    """Shared-prefix execution plan over a set of pipelines.

    ``execute`` runs every trie node exactly once per call (depth-first, so
    intermediate results die as soon as the last sibling consumed them) and
    returns the per-pipeline final results in input order.
    """

    def __init__(self, pipelines: Sequence[Transformer], backend: JaxBackend,
                 *, optimize: bool = True):
        self.backend = backend
        self.pipelines = list(pipelines)
        #: per-pipeline rewrite traces [(rule, before_op, after_op), ...]
        self.traces: list[list] = [[] for _ in self.pipelines]
        #: one CSE interning table across all pipelines: shared prefixes
        #: compile to literally shared IR ops, which is what the trie keys on
        cse_table: dict = {}
        self.ops = [compile_pipeline(p, backend, optimize=optimize,
                                     trace=self.traces[i],
                                     cse_table=cse_table)
                    for i, p in enumerate(self.pipelines)]
        # publish any fresh autotune/gate decisions now, so a second
        # Experiment (or another process) compiles this plan profile-warm
        prof = getattr(backend, "descriptor", None) and backend.descriptor.profile
        if prof:
            prof.save()
        self.chains = [ir.chain(op) for op in self.ops]
        self.root = PlanNode(None, None)
        self.root.persist = "root"
        self._leaves: list[PlanNode] = []
        for i, chain in enumerate(self.chains):
            cur = self.root
            cur.pipelines.append(i)
            for stage in chain:
                nxt = cur.children.get(stage.key())
                if nxt is None:
                    nxt = PlanNode(stage, cur)
                    pk = persistent_key(stage)
                    if pk is not None and cur.persist is not None:
                        nxt.persist = hashlib.sha256(
                            (cur.persist + pk).encode()).hexdigest()
                    cur.children[stage.key()] = nxt
                nxt.pipelines.append(i)
                cur = nxt
            self._leaves.append(cur)

    # -- structure ----------------------------------------------------------
    def nodes(self) -> list[PlanNode]:
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            if n.stage is not None:
                out.append(n)
            stack.extend(n.children.values())
        return out

    @property
    def n_stage_executions(self) -> int:
        """Stages the plan will execute (vs sum(len(chain)) without sharing)."""
        return len(self.nodes())

    @property
    def n_stage_requests(self) -> int:
        return sum(len(c) for c in self.chains)

    # -- execution ----------------------------------------------------------
    def execute(self, Q, *, ctx: Context | None = None,
                cache: ArtifactCache | None = None,
                record: str | None = "cold") -> list:
        ctx = ctx or Context(self.backend)
        desc = getattr(self.backend, "descriptor", None)
        tracer = (get_tracer() if getattr(desc, "observability", False)
                  else NOOP_TRACER)
        qtok = ctx.source_token(Q, None)
        idx_dig = backend_digest(self.backend) if cache is not None else None
        results: list = [None] * len(self._leaves)
        leaf_index: dict[int, list[int]] = {}
        for i, leaf in enumerate(self._leaves):   # duplicates share one leaf
            leaf_index.setdefault(id(leaf), []).append(i)

        def run_stage(child: PlanNode, Qi, Ri, toki):
            ck = loaded = None
            if cache is not None and child.persist is not None:
                ck = hashlib.sha256(
                    f"{child.persist}:{qtok}:{idx_dig}".encode()).hexdigest()
                loaded = cache.load(ck)
            t0 = time.perf_counter()
            if loaded is not None:
                Qo, Ro = loaded
                toko = derive_token(child.stage.key(), toki)
                # seed the memo so non-plan users of this ctx share too
                ctx.memo[(child.stage.key(), toki)] = (Qo, Ro, toko)
                child.cache_hit = True
            else:
                Qo, Ro, toko = _execute(child.stage, ctx, Qi, Ri, toki)
                # barrier only at stage boundaries the caller needs timed
                # (or persisted); untimed runs stay fully async so chunk
                # dispatch pipelines across stage and pipeline boundaries
                if record is not None or ck is not None:
                    jax.block_until_ready((Qo, Ro))
                child.cache_hit = False
                if ck is not None:
                    cache.store(ck, Qo, Ro)
            dt = time.perf_counter() - t0
            if record == "warm":
                child.warm_s = dt
            elif record == "cold":
                child.cold_s = dt
            return Qo, Ro, toko

        def visit(node: PlanNode, Qi, Ri, toki) -> None:
            for i in leaf_index.get(id(node), ()):
                results[i] = Ri if Ri is not None else Qi
            for child in node.children.values():
                # span covers the child's whole subtree, so the exported
                # trace nests exactly like the trie (children inside their
                # shared prefix); cache_hit lands on the span after run
                with tracer.span("plan.stage", "plan",
                                 stage=child.stage.label(),
                                 depth=child.depth,
                                 n_pipelines=child.n_shared) as sp:
                    out = run_stage(child, Qi, Ri, toki)
                    sp.set(cache_hit=child.cache_hit)
                    visit(child, *out)

        with tracer.span("plan.execute", "plan",
                         n_stage_executions=self.n_stage_executions,
                         n_stage_requests=self.n_stage_requests):
            visit(self.root, Q, None, qtok)
        return results

    # -- timing attribution --------------------------------------------------
    def pipeline_times(self, i: int) -> dict:
        """Decomposed wall-clock for pipeline ``i``: steady execution,
        compile (cold - steady), and the sharing-amortised steady time in
        which each stage's cost is split across the pipelines using it."""
        steady = compile_ = amortised = 0.0
        node = self._leaves[i]
        while node is not None and node.stage is not None:
            warm = node.warm_s if node.warm_s is not None else (node.cold_s or 0.0)
            cold = node.cold_s if node.cold_s is not None else warm
            steady += warm
            compile_ += max(0.0, cold - warm)
            amortised += warm / max(node.n_shared, 1)
            node = node.parent
        return {"steady_s": steady, "compile_s": compile_,
                "amortised_s": amortised}

    def stage_stats(self) -> list[dict]:
        """Per-trie-node report (one row per *executed* stage)."""
        rows = []
        for n in sorted(self.nodes(), key=lambda n: (n.depth, n.label())):
            warm = n.warm_s if n.warm_s is not None else n.cold_s
            row = {"stage": n.label(), "depth": n.depth,
                   "n_pipelines": n.n_shared, "cache_hit": n.cache_hit,
                   "cold_ms": None if n.cold_s is None else 1000 * n.cold_s,
                   "steady_ms": None if warm is None else 1000 * warm}
            if n.cold_s is not None and n.warm_s is not None:
                row["compile_ms"] = 1000 * max(0.0, n.cold_s - n.warm_s)
            rows.append(row)
        return rows
