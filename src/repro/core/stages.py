"""Concrete IR transformers (paper Table 1) over the JAX backend.

Leaf stages close over *static* config only; array state (learned weights)
lives in ``self.state`` and is trained through ``fit()``.  Execution is
vmapped over the query axis and chunked by the backend (the DP dimension of
the multi-pod deployment).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import data as D
from repro.core.transformer import Transformer
from repro.index import retrieve as RT
from repro.index import scoring
from repro.index.inverted import BLOCK


# ---------------------------------------------------------------------------
# retrieval stages
# ---------------------------------------------------------------------------

class Retrieve(Transformer):
    """Exhaustive top-k retrieval under one weighting model (Q -> R)."""
    kind = "retrieve"
    reads_results = False

    def __init__(self, model: str = "BM25", k: int | None = None):
        super().__init__(model=model, k=k)

    def execute(self, ctx, Q, R):
        # clamp to corpus size like the dense stages: lax.top_k cannot take
        # more entries than exist, and parity across engines requires every
        # path to clamp identically
        k = min(self.params["k"] or ctx.backend.default_k,
                ctx.backend.index.n_docs)
        model = self.params["model"]

        def one(terms, weights):
            return RT.retrieve_topk(ctx.backend.index, terms, weights,
                                    model=model, k=k,
                                    max_postings=ctx.backend.max_postings)

        docs, scores = ctx.backend.vmap_queries(one, Q, key=self.key())
        return Q, {"qid": Q["qid"], "docids": docs, "scores": scores}


class PrunedRetrieve(Transformer):
    """Block-max pruned top-k — the RQ1-optimised Retrieve (created by the
    CutoffPushdown rewrite; can also be used directly)."""
    kind = "pruned_retrieve"
    reads_results = False

    def __init__(self, model: str = "BM25", k: int = 10, n_terms: int = 8):
        super().__init__(model=model, k=k, n_terms=n_terms)

    def execute(self, ctx, Q, R):
        k = min(self.params["k"], ctx.backend.index.n_docs)
        model = self.params["model"]
        budget = RT.block_budget(k, self.params["n_terms"])
        budget = min(budget, ctx.backend.total_blocks)
        mbt = ctx.backend.max_blocks_per_term

        def one(terms, weights):
            return RT.retrieve_pruned(ctx.backend.index, terms, weights,
                                      model=model, k=k, n_blocks=budget,
                                      max_blocks_per_term=mbt)

        docs, scores = ctx.backend.vmap_queries(one, Q, key=self.key())
        return Q, {"qid": Q["qid"], "docids": docs, "scores": scores}


class MultiRetrieve(Transformer):
    """Single-pass weighted multi-model retrieval (created by the
    LinearFusion rewrite — beyond-paper optimisation)."""
    kind = "multi_retrieve"
    reads_results = False

    def __init__(self, models: tuple[str, ...], weights: tuple[float, ...],
                 k: int | None = None):
        super().__init__(models=tuple(models), weights=tuple(weights), k=k)

    def execute(self, ctx, Q, R):
        k = min(self.params["k"] or ctx.backend.default_k,
                ctx.backend.index.n_docs)
        models = self.params["models"]
        mw = jnp.asarray(self.params["weights"], jnp.float32)

        def one(terms, weights):
            return RT.retrieve_multi(ctx.backend.index, terms, weights, mw,
                                     models=models, k=k,
                                     max_postings=ctx.backend.max_postings)

        docs, scores = ctx.backend.vmap_queries(one, Q, key=self.key())
        return Q, {"qid": Q["qid"], "docids": docs, "scores": scores}


class FatRetrieve(Transformer):
    """Single-pass retrieval + multi-model feature extraction (fat postings —
    the RQ2-optimised form of Retrieve >> (Extract ** ... ** Extract))."""
    kind = "fat_retrieve"
    reads_results = False

    def __init__(self, model: str = "BM25",
                 features: tuple[str, ...] = (), k: int | None = None):
        super().__init__(model=model, features=tuple(features), k=k)

    def execute(self, ctx, Q, R):
        k = min(self.params["k"] or ctx.backend.default_k,
                ctx.backend.index.n_docs)

        def one(terms, weights):
            return RT.retrieve_fat(
                ctx.backend.index, terms, weights,
                rank_model=self.params["model"],
                feature_models=self.params["features"], k=k,
                max_postings=ctx.backend.max_postings)

        docs, scores, feats = ctx.backend.vmap_queries(one, Q, key=self.key())
        return Q, {"qid": Q["qid"], "docids": docs, "scores": scores,
                   "features": feats}


class FusedTopKRetrieve(Transformer):
    """``Retrieve >> … % K`` lowered to the streaming top-k kernel path
    (``kernels/topk``), created by the cost-gated IR lowering pass
    (core/passes.py).  Exact — same scores as Retrieve, the top-k is just
    taken at the cutoff depth instead of sort-at-full-k-then-slice."""
    kind = "fused_topk_retrieve"
    reads_results = False

    def __init__(self, model: str = "BM25", k: int = 10):
        super().__init__(model=model, k=int(k))

    def execute(self, ctx, Q, R):
        k = min(self.params["k"], ctx.backend.index.n_docs)
        model = self.params["model"]

        def one(terms, weights):
            return RT.retrieve_topk_fused(ctx.backend.index, terms, weights,
                                          model=model, k=k,
                                          max_postings=ctx.backend.max_postings)

        docs, scores = ctx.backend.vmap_queries(one, Q, key=self.key())
        return Q, {"qid": Q["qid"], "docids": docs, "scores": scores}


class FusedFatRetrieve(Transformer):
    """``Retrieve >> (Extract ** …) % K`` lowered to the fused-scoring
    kernel path (``kernels/fused_scoring``) at the cutoff depth — the
    cost-gated kernel form of FatRetrieve % K."""
    kind = "fused_fat_retrieve"
    reads_results = False

    def __init__(self, model: str = "BM25",
                 features: tuple[str, ...] = (), k: int = 10):
        super().__init__(model=model, features=tuple(features), k=int(k))

    def execute(self, ctx, Q, R):
        k = min(self.params["k"], ctx.backend.index.n_docs)

        def one(terms, weights):
            return RT.retrieve_fat_fused(
                ctx.backend.index, terms, weights,
                rank_model=self.params["model"],
                feature_models=self.params["features"], k=k,
                max_postings=ctx.backend.max_postings)

        docs, scores, feats = ctx.backend.vmap_queries(one, Q, key=self.key())
        return Q, {"qid": Q["qid"], "docids": docs, "scores": scores,
                   "features": feats}


class DenseRetrieve(Transformer):
    """ANN-style dense candidate generation over the IVF dense index
    (Q -> R): embed the query, probe the ``nprobe`` closest coarse lists,
    score only those lists' documents.  ``nprobe=0`` scores every document
    (exact brute force) — the mode dense equivalence tests pin against.
    ``pq=True`` scores candidates against the compressed IVF-PQ store
    (ADC table lookups + exact float re-scoring of the final-K shortlist)
    instead of the float list store."""
    kind = "dense_retrieve"
    reads_results = False

    def __init__(self, k: int | None = None, nprobe: int = 8,
                 pq: bool = False):
        super().__init__(k=k, nprobe=int(nprobe), pq=bool(pq))

    def execute(self, ctx, Q, R):
        from repro.index import dense as DN
        be = ctx.backend
        k = min(self.params["k"] or be.default_k, be.index.n_docs)
        nprobe = self.params["nprobe"]
        qvecs = be.embed_queries(Q)
        if nprobe and self.params["pq"]:
            pq = be.ivfpq
            npb = min(nprobe, pq.n_lists)
            refine = be.pq_refine
            one = lambda qv: DN.ivfpq_retrieve_topk(pq, qv, k=k, nprobe=npb,
                                                    refine=refine)
        elif nprobe:
            ivf = be.ivf
            npb = min(nprobe, ivf.n_lists)
            one = lambda qv: DN.ivf_retrieve_topk(ivf, qv, k=k, nprobe=npb)
        else:
            dense = be.dense
            one = lambda qv: DN.dense_retrieve_exact(dense, qv, k=k)
        docs, scores = be.vmap_queries(one, None, qvecs, key=self.key())
        return Q, {"qid": Q["qid"], "docids": docs, "scores": scores}


class FusedDenseRetrieve(Transformer):
    """``DenseRetrieve % K`` lowered to the blocked-matmul + streaming-top-k
    kernel path (``kernels/dense_scoring``, or ``kernels/pq_scoring`` when
    ``pq=True``) at the cutoff depth, created by the cost-gated IR lowering
    pass (core/passes.py).  ``pq_block`` pins the PQ kernel's candidate
    block size (autotuned; ``None`` = package default); ``pq_shortlist``
    pins the ADC shortlist depth (the gate sets it to the *unfused*
    chain's depth so fusion is an exact rewrite; ``None`` = refine*k)."""
    kind = "fused_dense_retrieve"
    reads_results = False

    def __init__(self, k: int = 10, nprobe: int = 8, pq: bool = False,
                 pq_block: int | None = None,
                 pq_shortlist: int | None = None):
        super().__init__(
            k=int(k), nprobe=int(nprobe), pq=bool(pq),
            pq_block=None if pq_block is None else int(pq_block),
            pq_shortlist=None if pq_shortlist is None else int(pq_shortlist))

    def execute(self, ctx, Q, R):
        from repro.index import dense as DN
        be = ctx.backend
        k = min(self.params["k"], be.index.n_docs)
        nprobe = self.params["nprobe"]
        qvecs = be.embed_queries(Q)
        if nprobe and self.params["pq"]:
            pq = be.ivfpq
            npb = min(nprobe, pq.n_lists)
            refine = be.pq_refine
            block = self.params["pq_block"]
            shortlist = self.params["pq_shortlist"]
            one = lambda qv: DN.ivfpq_retrieve_topk_fused(
                pq, qv, k=k, nprobe=npb, refine=refine, block=block,
                shortlist=shortlist)
        elif nprobe:
            ivf = be.ivf
            npb = min(nprobe, ivf.n_lists)
            one = lambda qv: DN.ivf_retrieve_topk_fused(ivf, qv, k=k,
                                                        nprobe=npb)
        else:
            dense = be.dense
            one = lambda qv: DN.dense_retrieve_exact_fused(dense, qv, k=k)
        docs, scores = be.vmap_queries(one, None, qvecs, key=self.key())
        return Q, {"qid": Q["qid"], "docids": docs, "scores": scores}


class FusedDenseRerank(Transformer):
    """``Retrieve >> DenseRerank % K`` lowered to one fused per-query
    program: sparse candidates at depth ``k_in``, dense re-scoring on the
    kernel with the sparse score as the additive base, streaming top-k at
    the cutoff depth ``k`` — the cost-gated kernel form of the dense second
    stage (core/passes.py)."""
    kind = "fused_dense_rerank"
    reads_results = False

    def __init__(self, model: str = "BM25", k_in: int = 1000, k: int = 10,
                 alpha: float = 0.0):
        super().__init__(model=model, k_in=int(k_in), k=int(k),
                         alpha=float(alpha))

    def execute(self, ctx, Q, R):
        be = ctx.backend
        p = self.params
        k_in = min(p["k_in"], be.index.n_docs)
        k = min(p["k"], be.index.n_docs)
        qvecs = be.embed_queries(Q)
        emb = be.dense.emb

        def one(terms, weights, qv):
            return RT.retrieve_dense_rerank_fused(
                be.index, emb, terms, weights, qv, model=p["model"],
                k_in=k_in, k=k, alpha=p["alpha"],
                max_postings=be.max_postings)

        docs, scores = be.vmap_queries(one, Q, qvecs, key=self.key())
        return Q, {"qid": Q["qid"], "docids": docs, "scores": scores}


# ---------------------------------------------------------------------------
# query rewriting / expansion
# ---------------------------------------------------------------------------

class SDMRewrite(Transformer):
    """Sequential-dependence-style rewrite (Q -> Q).

    Positions are not stored in the index, so the proximity operators (#1,
    #uw8) are adapted as weight redistribution over the original terms
    (unigram 0.85 emphasis) plus duplicated high-weight lead terms — a
    rank-affecting, semantics-documented analogue (DESIGN.md §2).
    """
    kind = "sdm_rewrite"
    out_kind = "Q"
    reads_results = False

    def __init__(self, unigram: float = 0.85):
        super().__init__(unigram=unigram)

    def execute(self, ctx, Q, R):
        w = Q["weights"]
        u = self.params["unigram"]
        n = jnp.maximum(jnp.sum(Q["terms"] >= 0, 1, keepdims=True), 1)
        lead = (jnp.arange(w.shape[1])[None, :] < jnp.maximum(n // 2, 1))
        w2 = w * (u + (1 - u) * 2 * lead)
        return {**Q, "weights": w2}, R


class StemRewrite(Transformer):
    """Context-sensitive-stemming analogue: adds a same-frequency-band
    variant term (synthetic stem class neighbour) at reduced weight."""
    kind = "stem_rewrite"
    out_kind = "Q"
    reads_results = False

    def __init__(self, weight: float = 0.4):
        super().__init__(weight=weight)

    def execute(self, ctx, Q, R):
        t, w = Q["terms"], Q["weights"]
        n = jnp.sum(t >= 0, 1, keepdims=True)
        L = t.shape[1]
        variant = jnp.where(t >= 0, t ^ 1, -1)          # stem-class sibling
        idx = jnp.arange(L)[None, :]
        shifted = idx - n
        take = (shifted >= 0) & (shifted < n)
        sh = jnp.clip(shifted, 0, L - 1)
        t2 = jnp.where(t >= 0, t,
                       jnp.where(take, jnp.take_along_axis(variant, sh, 1), -1))
        w2 = jnp.where(t >= 0, w,
                       jnp.where(take,
                                 jnp.take_along_axis(w, sh, 1) * self.params["weight"],
                                 0.0))
        return {**Q, "terms": t2, "weights": w2}, R


class RM3Expand(Transformer):
    """Pseudo-relevance-feedback expansion (Q × R -> Q'), paper eq. (5)."""
    kind = "rm3"
    out_kind = "Q"          # R passes through untouched
    reads_results = True    # ... but fb_docs are read from it

    def __init__(self, fb_terms: int = 10, fb_docs: int = 10, alpha: float = 0.5):
        super().__init__(fb_terms=fb_terms, fb_docs=fb_docs, alpha=alpha)

    def execute(self, ctx, Q, R):
        assert R is not None, "RM3 needs retrieved results (use after Retrieve)"
        fb_docs = self.params["fb_docs"]

        def one(terms, weights, docids, scores):
            return RT.rm3_expand(ctx.backend.index, terms, weights,
                                 docids[:fb_docs], scores[:fb_docs],
                                 fb_terms=self.params["fb_terms"],
                                 alpha=self.params["alpha"],
                                 max_fwd=ctx.backend.index.max_fwd_len)

        t2, w2 = ctx.backend.vmap_queries(one, Q, R["docids"], R["scores"],
                                          key=self.key())
        return {**Q, "terms": t2, "weights": w2}, R


# ---------------------------------------------------------------------------
# feature extraction / re-ranking
# ---------------------------------------------------------------------------

class Extract(Transformer):
    """Per-feature doc-vectors pass (Q × R -> R+feature) — the unoptimised
    feature extractor the RQ2 rewrite replaces."""
    kind = "extract"

    def __init__(self, model: str):
        super().__init__(model=model)

    def execute(self, ctx, Q, R):
        def one(terms, weights, docids):
            return RT.extract_feature_docvectors(
                ctx.backend.index, terms, weights, docids,
                model=self.params["model"], max_fwd=ctx.backend.index.max_fwd_len)

        f = ctx.backend.vmap_queries(one, Q, R["docids"],      # [NQ, K]
                                     key=self.key())
        feats = R.get("features")
        f = f[..., None]
        feats = f if feats is None else jnp.concatenate([feats, f], -1)
        return Q, {**R, "features": feats}


def _sort_by_scores(R, new_scores):
    order = jnp.argsort(-new_scores, axis=1)
    out = {**R, "docids": jnp.take_along_axis(R["docids"], order, 1),
           "scores": jnp.take_along_axis(new_scores, order, 1)}
    if "features" in R:
        out["features"] = jnp.take_along_axis(R["features"], order[..., None], 1)
    return out


class LTRRerank(Transformer):
    """Learning-to-rank stage over feature columns (LambdaMART slot).

    A pairwise-logistic MLP trained with the framework optimizer — the
    xgBoost stage of Listing 1 realised JAX-natively.
    """
    kind = "ltr"
    stateful = True

    def __init__(self, n_features: int, hidden: int = 32, lr: float = 0.05,
                 epochs: int = 30, seed: int = 0):
        super().__init__(n_features=n_features, hidden=hidden, lr=lr,
                         epochs=epochs, seed=seed)
        k1, k2 = jax.random.split(jax.random.key(seed))
        F, H = n_features, hidden
        self.state = {
            "w1": jax.random.normal(k1, (F, H), jnp.float32) / np.sqrt(F),
            "b1": jnp.zeros((H,), jnp.float32),
            "w2": jax.random.normal(k2, (H, 1), jnp.float32) / np.sqrt(H),
        }

    def _score(self, state, feats):
        h = jnp.tanh(feats @ state["w1"] + state["b1"])
        return (h @ state["w2"])[..., 0]

    def execute(self, ctx, Q, R):
        assert "features" in R, "LTRRerank needs feature columns (use ** / Extract)"
        s = self._score(self.state, R["features"])
        s = jnp.where(R["docids"] >= 0, s, -jnp.inf)
        return Q, _sort_by_scores(R, s)

    def _fit_local(self, ctx, Q, R, qrels, Q_valid, R_valid, qrels_valid):
        feats = R["features"]
        labels = ctx.backend.label_results(Q, R, qrels)      # [NQ, K] float
        valid = (R["docids"] >= 0)

        def loss_fn(state):
            s = self._score(state, feats)
            # pairwise logistic over intra-query pairs
            ds = s[:, :, None] - s[:, None, :]
            dl = labels[:, :, None] - labels[:, None, :]
            pair = (dl > 0) & valid[:, :, None] & valid[:, None, :]
            losses = jnp.logaddexp(0.0, -ds) * pair
            return jnp.sum(losses) / jnp.maximum(jnp.sum(pair), 1.0)

        lr = self.params["lr"]
        grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        state = self.state
        for _ in range(self.params["epochs"]):
            _, g = grad_fn(state)
            state = jax.tree.map(lambda p, gg: p - lr * gg, state, g)
        self.state = state
        self.version += 1


# ---------------------------------------------------------------------------
# generation (RAG answer stage)
# ---------------------------------------------------------------------------

def assemble_prompt_fn(index, *, vocab: int, max_prompt_len: int,
                       prompt_docs: int):
    """Per-query prompt assembler ``(terms, weights, docids) -> [P] int32``.

    Deterministic static-shape assembly: the query's terms followed by the
    forward-index terms of the top ``prompt_docs`` documents, mapped into
    the LM vocab (ids 0/1 reserved for pad/bos), compacted to the front and
    *cyclically repeated* to fill exactly ``max_prompt_len`` positions — a
    fixed prompt length means one prefill shape per decode batch size, so
    the bucket ladder keeps generation recompile-free."""
    fwd_start = index.fwd_start
    fwd_terms = index.fwd_terms
    max_fwd = int(index.max_fwd_len)
    n_terms = int(fwd_terms.shape[0])
    P = int(max_prompt_len)

    def one(terms, weights, docids):
        d = docids[:prompt_docs]
        d0 = jnp.maximum(d, 0)
        start = fwd_start[d0]
        count = fwd_start[d0 + 1] - start
        win = jnp.arange(max_fwd)
        idx = start[:, None] + win[None, :]
        dterm = fwd_terms[jnp.clip(idx, 0, n_terms - 1)]
        dvalid = (win[None, :] < count[:, None]) & (d >= 0)[:, None]
        dterm = jnp.where(dvalid, dterm, -1)
        cand = jnp.concatenate([terms.astype(jnp.int32),
                                dterm.reshape(-1).astype(jnp.int32)])
        valid = cand >= 0
        tok = (2 + jnp.maximum(cand, 0) % (vocab - 2)).astype(jnp.int32)
        pos = jnp.cumsum(valid) - 1
        slot = jnp.where(valid & (pos < P), pos, P)
        prompt = jnp.zeros((P + 1,), jnp.int32).at[slot].set(tok)[:P]
        n = jnp.clip(jnp.sum(valid), 1, P)
        fill = jnp.arange(P)
        return jnp.where(fill < n, prompt, prompt[fill % n])

    return one


def greedy_generate_fn(cfg, *, max_prompt_len: int, max_new_tokens: int):
    """Batched oracle decode ``(params, prompts [B, P]) -> tokens [B, T]``:
    one prefill over the prompt block, then a ``lax.scan`` of greedy
    decode steps against a [B, P+T] KV cache.  Same argmax/cache math as
    the serving-side ragged decode (``serve/batching.py``), so served
    output is comparable token-for-token."""
    from repro.models import transformer_lm as tlm
    P, T = int(max_prompt_len), int(max_new_tokens)

    def gen(params, prompts):
        B = prompts.shape[0]
        cache = tlm.init_kv_cache(cfg, B, P + T)
        logits, cache = tlm.prefill(cfg, params, prompts, cache)
        first = jnp.argmax(logits, -1).astype(jnp.int32)

        def body(carry, t):
            tok, cache = carry
            logits, cache = tlm.decode_step(cfg, params, tok[:, None],
                                            cache, t)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            return (nxt, cache), nxt

        (_, _), rest = jax.lax.scan(
            body, (first, cache), P + jnp.arange(T - 1, dtype=jnp.int32))
        return jnp.concatenate([first[:, None], rest.T], axis=1)

    return gen


class Generate(Transformer):
    """RAG answer stage (R -> A): assemble the top-``prompt_docs`` documents
    into a fixed-length prompt and decode ``max_new_tokens`` greedy tokens
    with the named backend-registered LM (``backend.register_lm``).

    All params are scalar statics — model *name*, prompt/decode lengths —
    so the op stays content-addressable (CSE, serving digests, engine jit
    keys) and every compiled shape is fixed at compile time.  The output is
    the answer-bearing A relation: the incoming ranking plus a
    ``tokens [NQ, max_new_tokens]`` column block; A is terminal, no ranking
    stage may consume it (core/passes.py schema rules)."""
    kind = "generate"
    out_kind = "A"
    reads_results = True

    def __init__(self, model: str, max_new_tokens: int = 16,
                 max_prompt_len: int = 64, prompt_docs: int = 4):
        super().__init__(model=model, max_new_tokens=int(max_new_tokens),
                         max_prompt_len=int(max_prompt_len),
                         prompt_docs=int(prompt_docs))

    def assemble(self, ctx, Q, R):
        """Prompts [NQ, max_prompt_len] for the incoming ranking (shared by
        the offline path below and the server's decode pool)."""
        be = ctx.backend
        cfg, _ = be.lm(self.params["model"])
        one = assemble_prompt_fn(
            be.index, vocab=cfg.vocab,
            max_prompt_len=self.params["max_prompt_len"],
            prompt_docs=self.params["prompt_docs"])
        return be.vmap_queries(one, Q, R["docids"], key=self.key())

    def execute(self, ctx, Q, R):
        assert R is not None, "Generate needs retrieved results"
        be = ctx.backend
        cfg, params = be.lm(self.params["model"])
        prompts = self.assemble(ctx, Q, R)
        gen = greedy_generate_fn(
            cfg, max_prompt_len=self.params["max_prompt_len"],
            max_new_tokens=self.params["max_new_tokens"])
        if be.engine is not None:
            from repro.core.engine import StageProgram
            prog = StageProgram(key=(be.uid, self.key(), "generate"), fn=gen)
            tokens = be.engine.run_pinned(prog, params, prompts)
        else:
            tokens = gen(params, prompts)
        return Q, {"qid": Q["qid"], "docids": R["docids"],
                   "scores": R["scores"], "tokens": tokens}


class DenseRerank(Transformer):
    """Dense (embedding) re-scoring of the candidate set — the neural
    re-ranker slot (CEDR/BERT in Listing 1), backed by the dense index."""
    kind = "dense_rerank"

    def __init__(self, alpha: float = 0.0):
        super().__init__(alpha=alpha)

    def execute(self, ctx, Q, R):
        qvecs = ctx.backend.embed_queries(Q)                  # [NQ, dim]
        emb = ctx.backend.dense.emb

        def one(qv, docids, scores):
            d = emb[jnp.maximum(docids, 0)] @ qv
            return jnp.where(docids >= 0,
                             self.params["alpha"] * scores + d, -jnp.inf)

        s = ctx.backend.vmap_queries(one, None, qvecs, R["docids"],
                                     R["scores"], key=self.key())
        return Q, _sort_by_scores(R, s)
