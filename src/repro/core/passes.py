"""Pass-manager compiler over the typed pipeline IR (paper §4).

Replaces the ad-hoc fixpoint rewriter (the late ``core/rewrite.py``) with
an explicit ordered pipeline of IR-to-IR passes:

  canonicalise        — re-establish the canonical variadic forms (flatten
                        Then-of-Then / FeatureUnion nests, inline Scale and
                        Linear children into Linear weights)
  schema_inference    — infer per-op :class:`~repro.core.ir.Schema` (Q/R/F
                        stream, static k, feature width) and validate the
                        typing rules (a rank cutoff must attach to an
                        R-producing expression)
  rewrite             — the equivalence rules (cutoff merge/into-then/
                        scale-swap/pushdown, fat/extract/linear fusion,
                        scale folding) re-expressed over IR ops, applied
                        bottom-up to fixpoint against the backend
                        capability descriptor
  cse                 — hash-cons structurally identical subgraphs into
                        shared op instances; the interning table can span
                        pipelines, so ``ExperimentPlan`` feeds the plan trie
                        with literally shared prefix ops
  fusion              — cost-gated lowering to the Pallas kernel paths:
                        ``cutoff(retrieve)`` -> FusedTopKRetrieve
                        (kernels/topk), ``cutoff(fat_retrieve)`` ->
                        FusedFatRetrieve (kernels/fused_scoring),
                        ``cutoff(dense_retrieve)`` -> FusedDenseRetrieve
                        and ``retrieve >> cutoff(dense_rerank)`` ->
                        FusedDenseRerank (kernels/dense_scoring, behind the
                        ``dense_topk`` / ``fused_dense`` capabilities),
                        accepted only when the HLO cost model
                        (:func:`repro.analysis.hlo_cost.estimate_callable`)
                        prices the fused form strictly cheaper; otherwise
                        the unfused interpreter path is kept
  schema_check        — re-infer/validate schemas on the final graph

``compile_pipeline`` is the single optimization entry point — the executor
(``compiler.run_pipeline``), the planner (``plan.ExperimentPlan``), the
experiment/tuning drivers and the serving layer all go through it;
``explain_pipeline`` renders the IR before/after each pass for
``pipeline.explain()``.
"""
from __future__ import annotations

import time
from typing import Callable

from repro.core import stages as S
from repro.core.descriptor import BackendDescriptor, as_descriptor
from repro.obs.metrics import CounterMap, MetricsRegistry
from repro.obs.tracing import NOOP_TRACER, get_tracer
from repro.core.ir import (COMBINATOR_KINDS, Op, Schema, SchemaError, chain,
                           leaf, lower, pretty)
from repro.core.transformer import Transformer

#: query-term width used for cost-gate lowering AND probe measurement (only
#: cost *ratios* gate decisions, and they are monotone in the query width);
#: doubles as the tuning profile's bucket key
GATE_MAXQ = 8


# ---------------------------------------------------------------------------
# schema inference
# ---------------------------------------------------------------------------

_RETRIEVER_KINDS = frozenset({"retrieve", "pruned_retrieve", "multi_retrieve",
                              "fused_topk_retrieve", "dense_retrieve",
                              "fused_dense_retrieve", "fused_dense_rerank"})
_FAT_KINDS = frozenset({"fat_retrieve", "fused_fat_retrieve"})


def _carry(s_in: Schema | None):
    return (None, None) if s_in is None else (s_in.k, s_in.width)


def _reject_answer(st: Schema, where: str, child: Op) -> None:
    """A is terminal: no ranking combinator may consume an answer stream."""
    if st.out == "A":
        raise SchemaError(
            f"{where} typed against an answer-bearing (A) expression "
            f"({child.label()}): generate is terminal — no ranking stage "
            f"may consume its output")


def _stage_schema(op: Op, s_in: Schema | None, backend,
                  annot: dict | None) -> Schema:
    """Schema of ``op``'s output stream given the schema of the incoming R
    stream (None = statically unknown / absent)."""
    kind = op.kind
    k_in, w_in = _carry(s_in)
    if s_in is not None and s_in.out == "A":
        raise SchemaError(
            f"stage {op.label()} typed against an answer-bearing (A) "
            f"stream: generate is terminal — no stage may consume its "
            f"output")
    if kind in _RETRIEVER_KINDS:
        k = op.params.get("k") or (backend.default_k if backend else None)
        out = Schema("R", k, None, False)
    elif kind in _FAT_KINDS:
        k = op.params.get("k") or (backend.default_k if backend else None)
        out = Schema("F", k, len(op.params["features"]), False)
    elif kind == "extract":
        out = Schema("F", k_in, None if s_in is None else (w_in or 0) + 1,
                     True)
    elif kind in ("sdm_rewrite", "stem_rewrite"):
        out = Schema("Q", k_in, w_in, False)
    elif kind == "rm3":
        out = Schema("Q", k_in, w_in, True)
    elif kind == "ltr":
        out = Schema("F", k_in, w_in, True)
    elif kind == "dense_rerank":
        out = Schema("F" if s_in is not None and s_in.out == "F" else "R",
                     k_in, w_in, True)
    elif kind == "generate":
        if s_in is None:
            raise SchemaError(
                f"generate ({op.label()}) typed against a pure Q -> Q "
                f"expression: prompt assembly reads ranked results, so "
                f"generate may only follow an R-producing expression")
        # A: answer-bearing results.  k carries the (static) result depth
        # the prompt reads; width carries the static decode length — both
        # fixed at compile time so the bucket ladder stays recompile-free.
        out = Schema("A", k_in, op.params["max_new_tokens"], True)
    elif kind == "then":
        r_sch = s_in
        child_outs = []
        for c in op.inputs:
            st = _stage_schema(c, r_sch, backend, annot)
            child_outs.append(st)
            if st.out != "Q":
                r_sch = st
        if all(st.out == "Q" for st in child_outs):
            out = Schema("Q", *_carry(r_sch),
                         any(st.reads_results for st in child_outs))
        else:
            out = Schema(r_sch.out, r_sch.k, r_sch.width,
                         any(st.reads_results for st in child_outs))
    elif kind == "cutoff":
        st = _stage_schema(op.inputs[0], s_in, backend, annot)
        if st.out == "Q":
            raise SchemaError(
                f"rank cutoff %{op.params['k']} typed against a pure "
                f"Q -> Q expression ({op.inputs[0].label()}): a cutoff may "
                f"only attach to an R-producing expression")
        if st.out == "A":
            raise SchemaError(
                f"rank cutoff %{op.params['k']} typed against an "
                f"answer-bearing (A) expression ({op.inputs[0].label()}): "
                f"generate is terminal — apply the cutoff before it")
        K = op.params["k"]
        out = Schema(st.out, K if st.k is None else min(K, st.k), st.width,
                     st.reads_results)
    elif kind == "scale":
        st = _stage_schema(op.inputs[0], s_in, backend, annot)
        _reject_answer(st, "score scale", op.inputs[0])
        out = Schema(st.out, st.k, st.width, st.reads_results)
    elif kind == "linear":
        sts = [_stage_schema(c, s_in, backend, annot) for c in op.inputs]
        for st, c in zip(sts, op.inputs):
            _reject_answer(st, "linear combination", c)
        ks = [st.k for st in sts]
        out = Schema("R", None if any(k is None for k in ks) else max(ks),
                     None, any(st.reads_results for st in sts))
    elif kind in ("setop", "concat"):
        s1 = _stage_schema(op.inputs[0], s_in, backend, annot)
        s2 = _stage_schema(op.inputs[1], s_in, backend, annot)
        _reject_answer(s1, f"{kind} operand", op.inputs[0])
        _reject_answer(s2, f"{kind} operand", op.inputs[1])
        if kind == "setop" and op.params.get("op") == "intersect":
            k = s1.k
        else:
            k = None if s1.k is None or s2.k is None else s1.k + s2.k
        out = Schema("R", k, None, s1.reads_results or s2.reads_results)
    elif kind == "feature_union":
        sts = [_stage_schema(c, s_in, backend, annot) for c in op.inputs]
        for st, c in zip(sts, op.inputs):
            _reject_answer(st, "feature union", c)
        widths = [st.width if st.width else 1 for st in sts]
        out = Schema("F", sts[0].k,
                     None if any(st.out == "F" and st.width is None
                                 for st in sts) else sum(widths),
                     any(st.reads_results for st in sts))
    else:
        # unknown leaf (Generic, user extensions): class attrs, no statics
        ref = op.ref
        out = Schema(ref.out_kind if ref is not None else "R", None, None,
                     ref.reads_results if ref is not None else True)
    if annot is not None:
        annot[id(op)] = out
    return out


def annotate(root: Op, backend=None) -> dict[int, Schema]:
    """id(op) -> Schema for every op in ``root`` (validates as it goes)."""
    annot: dict[int, Schema] = {}
    _stage_schema(root, None, backend, annot)
    return annot


def expr_schema(op: Op, backend=None) -> Schema:
    """Schema of an expression evaluated against an unknown input stream
    (``out == "Q"`` = pure query rewrite) — the bits rewrite rules guard
    on."""
    return _stage_schema(op, None, backend, None)


# ---------------------------------------------------------------------------
# pass infrastructure
# ---------------------------------------------------------------------------

class PassContext:
    """Shared state for one compile: backend + its descriptor, rewrite
    trace, fusion-gate decisions and tuning counters, optional
    cross-pipeline CSE table, per-pass IR snapshots."""

    def __init__(self, backend, *, trace: list | None = None,
                 cse_table: dict | None = None, keep_snapshots: bool = False,
                 descriptor: BackendDescriptor | None = None):
        self.backend = backend
        self.descriptor = descriptor if descriptor is not None \
            else as_descriptor(backend)
        self.trace = trace if trace is not None else []
        self.cse_table = cse_table if cse_table is not None else {}
        self.decisions: list[dict] = []
        self.snapshots: list[tuple[str, Op]] = []
        self.keep_snapshots = keep_snapshots
        self.timings: list[tuple[str, float]] = []
        #: per-compile metrics registry; ``pipeline.explain()`` and the
        #: compile report read tuning counts through it (one source of
        #: truth with the serving-side registries)
        self.metrics = MetricsRegistry()
        #: spans route to the process-global tracer only when the
        #: descriptor opted in — the default is the shared no-op
        self.tracer = (get_tracer()
                       if getattr(self.descriptor, "observability", False)
                       else NOOP_TRACER)
        #: the acceptance counters for the warm-reuse property: a compile
        #: served entirely from a persisted TuningProfile must show zero
        #: gate_estimates (candidate compiles) and zero probe_measurements.
        #: Dict-shaped view over the registry's ``compile_tuning_total``.
        self.counters = CounterMap(
            self.metrics.counter(
                "compile_tuning_total",
                "fusion-gate and autotune work per compile", ("counter",)),
            ("gate_estimates", "probe_measurements",
             "profile_hits", "profile_misses"))


class Pass:
    name = "pass"

    def run(self, op: Op, pctx: PassContext) -> Op:
        raise NotImplementedError


class PassManager:
    def __init__(self, passes: list[Pass]):
        self.passes = list(passes)

    def run(self, op: Op, pctx: PassContext) -> Op:
        if pctx.keep_snapshots:
            pctx.snapshots.append(("lower", op))
        with pctx.tracer.span("compile.pipeline", "compile",
                              n_passes=len(self.passes)):
            for p in self.passes:
                t0 = time.perf_counter()
                with pctx.tracer.span(f"compile.pass.{p.name}", "compile"):
                    op = p.run(op, pctx)
                pctx.timings.append((p.name, time.perf_counter() - t0))
                if pctx.keep_snapshots:
                    pctx.snapshots.append((p.name, op))
        return op


def _rebuild(op: Op, new_inputs: list[Op]) -> Op:
    if len(new_inputs) == len(op.inputs) and \
            all(a is b for a, b in zip(new_inputs, op.inputs)):
        return op
    return op.with_inputs(new_inputs)


# ---------------------------------------------------------------------------
# canonicalise
# ---------------------------------------------------------------------------

class CanonicalizePass(Pass):
    """Re-establish the canonical variadic node forms on IR (the operator
    constructors guarantee them at build time; rewrites re-run this)."""
    name = "canonicalise"

    def run(self, op: Op, pctx: PassContext) -> Op:
        return self._walk(op)

    def _walk(self, op: Op) -> Op:
        op = _rebuild(op, [self._walk(i) for i in op.inputs])
        if op.kind == "then" and any(i.kind == "then" for i in op.inputs):
            flat: list[Op] = []
            for i in op.inputs:
                flat.extend(i.inputs if i.kind == "then" else [i])
            return Op("then", {}, flat)
        if op.kind == "feature_union" and \
                any(i.kind == "feature_union" for i in op.inputs):
            flat = []
            for i in op.inputs:
                flat.extend(i.inputs if i.kind == "feature_union" else [i])
            return Op("feature_union", {}, flat)
        if op.kind == "linear" and \
                any(i.kind in ("linear", "scale") for i in op.inputs):
            ws, cs = [], []
            for w, c in zip(op.params["weights"], op.inputs):
                if c.kind == "linear":
                    ws.extend(w * wi for wi in c.params["weights"])
                    cs.extend(c.inputs)
                elif c.kind == "scale":
                    ws.append(w * c.params["alpha"])
                    cs.append(c.inputs[0])
                else:
                    ws.append(w)
                    cs.append(c)
            return Op("linear", {"weights": tuple(ws)}, cs)
        return op


# ---------------------------------------------------------------------------
# schema inference / validation
# ---------------------------------------------------------------------------

class SchemaPass(Pass):
    """Infer + validate schemas over the whole graph (raises SchemaError on
    ill-typed pipelines; the inferred annotations drive explain())."""

    def __init__(self, name: str = "schema_inference"):
        self.name = name

    def run(self, op: Op, pctx: PassContext) -> Op:
        annotate(op, pctx.backend)
        return op


# ---------------------------------------------------------------------------
# rewrite rules over IR
# ---------------------------------------------------------------------------

IRRule = Callable[[Op, PassContext], "Op | None"]
#: (name, rule, required capability or None) — capability-gated rules are
#: filtered once at pass construction against the backend descriptor, not
#: string-probed per match (the descriptor refactor)
IR_RULES: list[tuple[str, IRRule, str | None]] = []


def ir_rule(name: str, requires: str | None = None):
    def deco(fn):
        IR_RULES.append((name, fn, requires))
        return fn
    return deco


@ir_rule("cutoff_merge")
def cutoff_merge(op, pctx):
    if op.kind == "cutoff" and op.inputs[0].kind == "cutoff":
        inner = op.inputs[0]
        k = min(op.params["k"], inner.params["k"])
        return Op("cutoff", {"k": k}, (inner.inputs[0],))
    return None


@ir_rule("cutoff_into_then")
def cutoff_into_then(op, pctx):
    """(A >> B) % K -> A >> (B % K), guarded on B's schema: a rank cutoff is
    only typed for R-producing expressions.  Trailing Q -> Q rewrites that
    never read R (SDM, stemming) are hopped over — sound, they cannot
    observe the truncation — so the cutoff lands on the last R-producing
    stage and stays eligible for the RQ1 pushdown / kernel lowering.  An
    R-*reading* query rewrite (RM3 reads fb_docs from R) blocks the push."""
    if not (op.kind == "cutoff" and op.inputs[0].kind == "then"):
        return None
    kids = list(op.inputs[0].inputs)
    be = pctx.backend
    i, st = len(kids) - 1, None
    while i >= 0:
        st = expr_schema(kids[i], be)
        if not (st.out == "Q" and not st.reads_results):
            break
        i -= 1
    if i < 0 or st is None or st.out == "Q":
        return None
    last = Op("cutoff", {"k": op.params["k"]}, (kids[i],))
    return Op("then", {}, (*kids[:i], last, *kids[i + 1:]))


@ir_rule("cutoff_scale_swap")
def cutoff_scale_swap(op, pctx):
    if op.kind == "cutoff" and op.inputs[0].kind == "scale":
        sc = op.inputs[0]
        if sc.params["alpha"] > 0:
            inner = Op("cutoff", {"k": op.params["k"]}, (sc.inputs[0],))
            return Op("scale", {"alpha": sc.params["alpha"]}, (inner,))
    return None


@ir_rule("cutoff_pushdown", requires="pruned_topk")
def cutoff_pushdown(op, pctx):
    """Retrieve % K -> PrunedRetrieve(K): the RQ1 dynamic-pruning rewrite."""
    if op.kind == "cutoff" and op.inputs[0].kind == "retrieve":
        ret = op.inputs[0]
        K = op.params["k"]
        if ret.params["k"] is None or ret.params["k"] >= K:
            return leaf(S.PrunedRetrieve(model=ret.params["model"], k=K))
    return None


def _as_extract_models(inputs) -> tuple[str, ...] | None:
    models = []
    for c in inputs:
        if c.kind != "extract":
            return None
        models.append(c.params["model"])
    return tuple(models)


@ir_rule("fat_fusion", requires="fat")
def fat_fusion(op, pctx):
    """Retrieve >> (Extract ** ... ** Extract) -> FatRetrieve: RQ2 (a single
    Extract is the degenerate one-feature case)."""
    if op.kind != "then":
        return None
    kids = list(op.inputs)
    for i in range(len(kids) - 1):
        a, b = kids[i], kids[i + 1]
        if a.kind != "retrieve":
            continue
        if b.kind == "feature_union":
            models = _as_extract_models(b.inputs)
        elif b.kind == "extract":
            models = (b.params["model"],)
        else:
            continue
        if models is None:
            continue
        fat = leaf(S.FatRetrieve(model=a.params["model"], features=models,
                                 k=a.params["k"]))
        new_kids = kids[:i] + [fat] + kids[i + 2:]
        return new_kids[0] if len(new_kids) == 1 else Op("then", {}, new_kids)
    return None


@ir_rule("linear_fusion", requires="multi_model")
def linear_fusion(op, pctx):
    """Σ wᵢ·Retrieve(mᵢ, k) on one index -> MultiRetrieve (one postings
    pass instead of N — beyond-paper rewrite enabled by score_all).  The
    uniform-k guard is the equivalence boundary; mixed-k fusion is handled
    by the AutotunePass, which only takes it when *measured* faster."""
    if op.kind != "linear":
        return None
    ks = set()
    models = []
    for c in op.inputs:
        if c.kind != "retrieve":
            return None
        ks.add(c.params["k"])
        models.append(c.params["model"])
    if len(ks) != 1 or len(models) < 2:
        return None
    return leaf(S.MultiRetrieve(models=tuple(models),
                                weights=tuple(op.params["weights"]),
                                k=ks.pop()))


@ir_rule("scale_fold")
def scale_fold(op, pctx):
    if op.kind != "scale":
        return None
    inner = op.inputs[0]
    a = op.params["alpha"]
    if a == 1.0:
        return inner
    if inner.kind == "scale":
        return Op("scale", {"alpha": a * inner.params["alpha"]},
                  (inner.inputs[0],))
    if inner.kind == "linear":
        return Op("linear",
                  {"weights": tuple(a * w for w in inner.params["weights"])},
                  inner.inputs)
    return None


class RewritePass(Pass):
    """Bottom-up application of the equivalence rules to a fixpoint — the
    IR re-expression of the old ad-hoc rewriter loop.

    Capability-gated rules are filtered ONCE against the backend descriptor
    at pass construction; the match loop never probes the backend."""
    name = "rewrite"

    def __init__(self, descriptor: BackendDescriptor | None = None,
                 max_iters: int = 20):
        self.max_iters = max_iters
        self.descriptor = descriptor
        self._rules: list[tuple[str, IRRule]] | None = (
            None if descriptor is None else _eligible_rules(descriptor))

    def run(self, op: Op, pctx: PassContext) -> Op:
        # a pass built without a descriptor (legacy direct construction)
        # resolves its rule set from the context's descriptor per run
        rules = self._rules if self._rules is not None \
            else _eligible_rules(pctx.descriptor)
        for _ in range(self.max_iters):
            new = self._walk(op, pctx, rules)
            if new.key() == op.key():
                return new
            op = new
        return op

    def _walk(self, op: Op, pctx: PassContext, rules) -> Op:
        op = _rebuild(op, [self._walk(i, pctx, rules) for i in op.inputs])
        for name, rule in rules:
            out = rule(op, pctx)
            if out is not None and out.key() != op.key():
                pctx.trace.append((name, op, out))
                return self._walk(out, pctx, rules)
        return op


def _eligible_rules(desc: BackendDescriptor) -> list[tuple[str, IRRule]]:
    return [(name, rule) for name, rule, req in IR_RULES
            if req is None or desc.supports(req)]


# ---------------------------------------------------------------------------
# common-subexpression elimination
# ---------------------------------------------------------------------------

class CSEPass(Pass):
    """Hash-cons structurally identical subgraphs into shared op instances.

    Keys are content keys, so two pipelines building ``Retrieve("BM25")``
    separately intern to ONE op; with a cross-pipeline table
    (``PassContext.cse_table`` shared by ``ExperimentPlan``) the plan trie
    receives literally shared prefix ops.  Stateful stages and
    object-identity params embed uid/id in their key, so distinct live
    objects never merge."""
    name = "cse"

    def run(self, op: Op, pctx: PassContext) -> Op:
        return self._intern(op, pctx.cse_table)

    def _intern(self, op: Op, table: dict) -> Op:
        op = _rebuild(op, [self._intern(i, table) for i in op.inputs])
        hit = table.get(op.key())
        if hit is None:
            table[op.key()] = op
            return op
        return hit


# ---------------------------------------------------------------------------
# cost-gated fusion / kernel lowering
# ---------------------------------------------------------------------------

def _abstract_sds(tree):
    import jax
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def _abstract_args(backend):
    import jax
    import jax.numpy as jnp
    idx = _abstract_sds(backend.index)
    t = jax.ShapeDtypeStruct((GATE_MAXQ,), jnp.int32)
    w = jax.ShapeDtypeStruct((GATE_MAXQ,), jnp.float32)
    return idx, t, w


def _abstract_qvec(backend):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct((backend.dense.dim,), jnp.float32)


def _abstract_dense_rerank_args(backend):
    """(index, doc embeddings, terms, weights, query vector) — the per-query
    signature of the fused/unfused dense-rerank candidates."""
    idx, t, w = _abstract_args(backend)
    emb = _abstract_sds(backend.dense.emb)
    return idx, emb, t, w, _abstract_qvec(backend)


def _estimate(backend, desc: BackendDescriptor, key, build, args,
              counters: dict | None = None):
    """Cost estimate for one candidate per-query program, cached on the
    backend by content key (compilation dominates; estimates are pure
    functions of backend + static params + the descriptor's peaks).

    The cache is scoped by the descriptor's host/peak digest: an estimate
    priced under one set of peak constants (or computed on another host and
    carried over in a deserialised profile) must never answer for a
    differently calibrated descriptor."""
    scope = backend.__dict__.setdefault("_cost_estimates", {})
    cache = scope.setdefault(desc.peak_digest, {})
    if key in cache:
        return cache[key]
    from repro.analysis.hlo_cost import estimate_callable
    if counters is not None:
        counters["gate_estimates"] += 1
    try:
        fn = build()
        est = estimate_callable(
            fn, *args, peaks=(desc.peak_flops_per_s, desc.peak_bytes_per_s))
    except Exception:          # lowering unavailable: never fuse blind
        est = None
    cache[key] = est
    return est


def _backend_gate_digest(backend) -> str:
    """Content digest keying this backend's tuning-profile entries (lazy
    import: plan imports this module at load time)."""
    from repro.core.plan import backend_digest
    try:
        return backend_digest(backend)
    except Exception:
        # duck-typed test backends without index arrays: scope by uid so
        # entries at least never cross live backends
        return f"uid:{getattr(backend, 'uid', id(backend))}"


def _probe_queries(backend, n: int):
    """Concrete synthetic (terms, weights) probe batch [n, GATE_MAXQ] —
    deterministic, so probe timings are comparable across candidates."""
    import jax.numpy as jnp
    import numpy as np
    rng = np.random.default_rng(0)
    vocab = backend.index.vocab
    terms = rng.integers(0, vocab, (n, GATE_MAXQ)).astype(np.int32)
    weights = np.ones((n, GATE_MAXQ), np.float32)
    return jnp.asarray(terms), jnp.asarray(weights)


def _probe_qvecs(backend, n: int):
    import jax.numpy as jnp
    import numpy as np
    rng = np.random.default_rng(0)
    qv = rng.standard_normal((n, backend.dense.dim)).astype(np.float32)
    qv /= np.maximum(np.linalg.norm(qv, axis=-1, keepdims=True), 1e-6)
    return jnp.asarray(qv)


def _measure_callable(fn, static_args, batched_args, repeats: int) -> float:
    """Wall-clock one candidate on a concrete probe batch: jit(vmap(fn)),
    one warm-up call (compile excluded), then min-of-repeats seconds."""
    import jax
    in_axes = (None,) * len(static_args) + (0,) * len(batched_args)
    vf = jax.jit(jax.vmap(fn, in_axes=in_axes))
    args = (*static_args, *batched_args)
    jax.block_until_ready(vf(*args))
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(vf(*args))
        best = min(best, time.perf_counter() - t0)
    return best


class FusionPass(Pass):
    """Lower ``cutoff(retrieve)`` / ``cutoff(fat_retrieve)`` /
    ``cutoff(dense_retrieve)`` chains — and the two-stage
    ``retrieve >> cutoff(dense_rerank)`` pattern — onto the Pallas kernel
    paths, gated by the HLO cost model: the fused candidate must price
    *strictly* cheaper than the unfused chain it replaces, else the unfused
    interpreter path is kept.  Every decision (either way) is recorded in
    ``PassContext.decisions`` and, when the descriptor carries a
    :class:`~repro.core.descriptor.TuningProfile`, persisted so the next
    compile against the same backend replays it with zero candidate
    compiles.  Enablement and kernel-native limits come from the backend
    descriptor, received at pass construction."""
    name = "fusion"

    def __init__(self, descriptor: BackendDescriptor | None = None):
        self.descriptor = descriptor

    def _desc(self, pctx: PassContext) -> BackendDescriptor:
        return self.descriptor if self.descriptor is not None \
            else pctx.descriptor

    def run(self, op: Op, pctx: PassContext) -> Op:
        out = self._walk(op, pctx)
        prof = self._desc(pctx).profile
        if prof is not None:
            prof.save()           # no-op unless dirty (or in-memory)
        return out

    def _walk(self, op: Op, pctx: PassContext) -> Op:
        op = _rebuild(op, [self._walk(i, pctx) for i in op.inputs])
        desc = self._desc(pctx)
        if op.kind == "then":
            return self._fuse_dense_rerank_pairs(op, pctx)
        if op.kind == "linear":
            return self._tune_mixed_linear(op, pctx)
        if op.kind != "cutoff" or not op.inputs[0].is_leaf:
            return op
        inner = op.inputs[0]
        be = pctx.backend
        K = op.params["k"]
        k_in = inner.params.get("k") or be.default_k
        if K > k_in:
            return op
        # gate candidates must lower with legal shapes: clamp to the corpus
        # size exactly like the stage executors do (top-k cannot return more
        # entries than documents exist)
        K = min(K, be.index.n_docs)
        k_in = min(k_in, be.index.n_docs)
        from repro.index import retrieve as RT
        mp = be.max_postings
        if inner.kind == "dense_retrieve":
            need = "pq_topk" if (inner.params.get("pq")
                                 and inner.params.get("nprobe")) \
                else "dense_topk"
            if desc.supports(need):
                return self._fuse_dense_retrieve(op, inner, K, k_in, pctx)
            return op
        if inner.kind == "retrieve" and desc.supports("fused_topk"):
            model = inner.params["model"]
            fused = leaf(S.FusedTopKRetrieve(model=model, k=K))
            if self._gate(pctx, "topk",
                          kernel_native=desc.kernel_native("topk", K),
                          args=_abstract_args(be),
                          unfused=("topk_unfused", model, k_in, mp),
                          fused=("topk_fused", model, K, mp),
                          build_unfused=lambda: (
                              lambda ix, t, w: RT.retrieve_topk(
                                  ix, t, w, model=model, k=k_in,
                                  max_postings=mp)),
                          build_fused=lambda: (
                              lambda ix, t, w: RT.retrieve_topk_fused(
                                  ix, t, w, model=model, k=K,
                                  max_postings=mp)),
                          probe=lambda n: ((be.index,),
                                           _probe_queries(be, n))):
                pctx.trace.append(("fuse_topk", op, fused))
                return fused
        elif inner.kind == "fat_retrieve" and desc.supports("fused_scoring"):
            from repro.kernels.fused_scoring.ops import models_supported
            model = inner.params["model"]
            feats = tuple(inner.params["features"])
            if not models_supported((model,) + feats):
                return op
            fused = leaf(S.FusedFatRetrieve(model=model, features=feats, k=K))
            if self._gate(pctx, "fat",
                          kernel_native=desc.kernel_native("fat", K),
                          args=_abstract_args(be),
                          unfused=("fat_unfused", model, feats, k_in, mp),
                          fused=("fat_fused", model, feats, K, mp),
                          build_unfused=lambda: (
                              lambda ix, t, w: RT.retrieve_fat(
                                  ix, t, w, rank_model=model,
                                  feature_models=feats, k=k_in,
                                  max_postings=mp)),
                          build_fused=lambda: (
                              lambda ix, t, w: RT.retrieve_fat_fused(
                                  ix, t, w, rank_model=model,
                                  feature_models=feats, k=K,
                                  max_postings=mp)),
                          probe=lambda n: ((be.index,),
                                           _probe_queries(be, n))):
                pctx.trace.append(("fuse_fat", op, fused))
                return fused
        return op

    # -- mixed-k linear fusion: measured-only (AutotunePass) ----------------
    def _tune_mixed_linear(self, op: Op, pctx: PassContext) -> Op:
        """Hook for the AutotunePass's mixed-k ``linear()`` fusion.  The
        static pass never takes it (uniform-k is the equivalence-rule
        boundary; mixed-k changes the per-model truncation depths, so it is
        only acceptable when *measured* faster)."""
        return op

    # -- dense candidate generation: cutoff(dense_retrieve) -----------------
    def _fuse_dense_retrieve(self, op: Op, inner: Op, K: int, k_in: int,
                             pctx: PassContext) -> Op:
        from repro.index import dense as DN
        be = pctx.backend
        desc = self._desc(pctx)
        nprobe = inner.params["nprobe"]
        qv = _abstract_qvec(be)
        if nprobe and inner.params.get("pq"):
            # two-level IVF-PQ: the fused candidate replicates the *unfused*
            # chain's ADC shortlist depth (computed from the pre-cutoff
            # k_in) so fusion stays an exact rewrite — cutoff(topK) of the
            # re-scored shortlist commutes with selecting K directly.  The
            # kernel-native predicate is evaluated at that depth: it is the
            # k the streaming kernel must carry.
            pqi = be.ivfpq
            npb = min(nprobe, pqi.n_lists)
            refine = be.pq_refine
            r = DN._pq_shortlist_depth(k_in, refine, npb * pqi.max_list_len)
            fused = leaf(S.FusedDenseRetrieve(k=K, nprobe=nprobe, pq=True,
                                              pq_shortlist=r))
            if self._gate(pctx, "pq_topk",
                          kernel_native=desc.kernel_native("pq_topk", r),
                          args=(_abstract_sds(pqi), qv),
                          unfused=("pq_topk_unfused", k_in, nprobe, refine),
                          fused=("pq_topk_fused", K, nprobe, refine, r),
                          build_unfused=lambda: (
                              lambda ix, q: DN.ivfpq_retrieve_topk(
                                  ix, q, k=k_in, nprobe=npb, refine=refine)),
                          build_fused=lambda: (
                              lambda ix, q: DN.ivfpq_retrieve_topk_fused(
                                  ix, q, k=K, nprobe=npb, refine=refine,
                                  shortlist=r)),
                          probe=lambda n: ((pqi,), (_probe_qvecs(be, n),))):
                pctx.trace.append(("fuse_pq_topk", op, fused))
                return self._tune_dense_knobs(fused, pctx)
            return op
        fused = leaf(S.FusedDenseRetrieve(k=K, nprobe=nprobe))
        if nprobe:
            npb = min(nprobe, be.ivf.n_lists)
            args = (_abstract_sds(be.ivf), qv)
            build_u = lambda: (lambda ivf, q: DN.ivf_retrieve_topk(
                ivf, q, k=k_in, nprobe=npb))
            build_f = lambda: (lambda ivf, q: DN.ivf_retrieve_topk_fused(
                ivf, q, k=K, nprobe=npb))
            probe = lambda n: ((be.ivf,), (_probe_qvecs(be, n),))
        else:
            args = (_abstract_sds(be.dense), qv)
            build_u = lambda: (lambda dn, q: DN.dense_retrieve_exact(
                dn, q, k=k_in))
            build_f = lambda: (lambda dn, q: DN.dense_retrieve_exact_fused(
                dn, q, k=K))
            probe = lambda n: ((be.dense,), (_probe_qvecs(be, n),))
        if self._gate(pctx, "dense_topk",
                      kernel_native=desc.kernel_native("dense_topk", K),
                      args=args,
                      unfused=("dense_topk_unfused", k_in, nprobe),
                      fused=("dense_topk_fused", K, nprobe),
                      build_unfused=build_u, build_fused=build_f,
                      probe=probe):
            pctx.trace.append(("fuse_dense_topk", op, fused))
            return self._tune_dense_knobs(fused, pctx) if nprobe else fused
        return op

    def _tune_dense_knobs(self, op: Op, pctx: PassContext) -> Op:
        """Hook for the AutotunePass's IVF knob search (``nprobe``, PQ
        candidate block).  The static pass keeps the configured knobs: a
        different ``nprobe`` changes which lists are scanned, so it is only
        acceptable when *measured* both faster and within the descriptor's
        result-overlap band."""
        return op

    # -- dense second stage: retrieve >> cutoff(dense_rerank) --------------
    def _fuse_dense_rerank_pairs(self, op: Op, pctx: PassContext) -> Op:
        """Within a ``then`` chain, lower each adjacent
        ``retrieve, cutoff(dense_rerank)`` pair to one FusedDenseRerank
        stage (the rewrite pass has already pushed the pipeline-level cutoff
        onto the last R-producer, so the paper's ``bm25 >> neural % K``
        arrives here in exactly this shape)."""
        if not self._desc(pctx).supports("fused_dense"):
            return op
        kids = list(op.inputs)
        changed = False
        i = 0
        while i < len(kids) - 1:
            fused = self._try_dense_rerank_pair(kids[i], kids[i + 1], pctx)
            if fused is not None:
                kids[i:i + 2] = [fused]
                changed = True
            else:
                i += 1
        if not changed:
            return op
        return kids[0] if len(kids) == 1 else Op("then", {}, kids)

    def _try_dense_rerank_pair(self, a: Op, b: Op,
                               pctx: PassContext) -> Op | None:
        if not (a.kind == "retrieve" and b.kind == "cutoff"
                and b.inputs[0].kind == "dense_rerank"):
            return None
        from repro.index import retrieve as RT
        be = pctx.backend
        desc = self._desc(pctx)
        K = b.params["k"]
        k_in = a.params.get("k") or be.default_k
        if K > k_in:
            return None
        K = min(K, be.index.n_docs)
        k_in = min(k_in, be.index.n_docs)
        model = a.params["model"]
        alpha = b.inputs[0].params["alpha"]
        mp = be.max_postings
        fused = leaf(S.FusedDenseRerank(model=model, k_in=k_in, k=K,
                                        alpha=alpha))
        if self._gate(pctx, "dense_rerank",
                      kernel_native=desc.kernel_native("dense_rerank", K),
                      args=_abstract_dense_rerank_args(be),
                      unfused=("dense_rerank_unfused", model, k_in, K,
                               alpha, mp),
                      fused=("dense_rerank_fused", model, k_in, K,
                             alpha, mp),
                      build_unfused=lambda: (
                          lambda ix, emb, t, w, q: RT.retrieve_dense_rerank(
                              ix, emb, t, w, q, model=model, k_in=k_in, k=K,
                              alpha=alpha, max_postings=mp)),
                      build_fused=lambda: (
                          lambda ix, emb, t, w, q:
                          RT.retrieve_dense_rerank_fused(
                              ix, emb, t, w, q, model=model, k_in=k_in, k=K,
                              alpha=alpha, max_postings=mp)),
                      probe=lambda n: (
                          (be.index, be.dense.emb),
                          (*_probe_queries(be, n), _probe_qvecs(be, n)))):
            pctx.trace.append(("fuse_dense_rerank", Op("then", {}, (a, b)),
                               fused))
            return fused
        return None

    def _gate(self, pctx, pattern, *, unfused, fused, build_unfused,
              build_fused, args, kernel_native: bool = True,
              probe=None, require_measured: bool = False) -> bool:
        """One gate decision.  Resolution order: persisted TuningProfile hit
        (zero candidate compiles, zero probes) -> cost estimates -> the
        subclass ``_decide`` policy (base: estimate-only strict-less-than;
        AutotunePass: probe-measure inside the uncertainty band).  Fresh
        decisions are recorded back into the profile."""
        be = pctx.backend
        desc = self._desc(pctx)
        prof = desc.profile
        opk = (pattern, fused, unfused)
        bd = None
        if prof is not None:
            bd = _backend_gate_digest(be)
            hit = prof.lookup(bd, opk, GATE_MAXQ)
            if hit is not None:
                pctx.counters["profile_hits"] += 1
                d = dict(hit)
                d["source"] = "profile"
                pctx.decisions.append(d)
                return bool(d["accepted"])
            pctx.counters["profile_misses"] += 1
        est_u = _estimate(be, desc, unfused, build_unfused, args,
                          counters=pctx.counters)
        est_f = _estimate(be, desc, fused, build_fused, args,
                          counters=pctx.counters)
        d = self._decide(pctx, desc, est_u, est_f, build_unfused,
                         build_fused, probe, require_measured)
        d.update({
            "pattern": pattern, "kernel_native": kernel_native,
            "unfused_key": unfused, "fused_key": fused,
            "unfused_proxy_s": None if est_u is None else est_u["time_proxy_s"],
            "fused_proxy_s": None if est_f is None else est_f["time_proxy_s"],
            "unfused_flops": None if est_u is None else est_u["flops_per_chip"],
            "unfused_bytes": None if est_u is None else est_u["bytes_per_chip"],
            "fused_flops": None if est_f is None else est_f["flops_per_chip"],
            "fused_bytes": None if est_f is None else est_f["bytes_per_chip"],
        })
        pctx.decisions.append(d)
        if prof is not None:
            prof.record(bd, opk, GATE_MAXQ, d)
        return d["accepted"]

    def _decide(self, pctx, desc, est_u, est_f, build_unfused, build_fused,
                probe, require_measured: bool = False) -> dict:
        """Static policy: accept iff the fused estimate prices *strictly*
        cheaper (lowering failure on either side -> never fuse blind).
        Semantics-affecting candidates (``require_measured``) are never
        taken on estimates alone, so the static gate rejects them."""
        accepted = (not require_measured
                    and est_u is not None and est_f is not None
                    and est_f["time_proxy_s"] < est_u["time_proxy_s"])
        return {"accepted": accepted, "source": "estimate",
                "unfused_measured_s": None, "fused_measured_s": None}


class AutotunePass(FusionPass):
    """Measurement-driven fusion gate (opt-in: ``descriptor.autotune``).

    Two extensions over the static gate.  (1) When the estimated margin
    between the candidates, ``|fused - unfused| / unfused`` over the proxy
    times, is within ``descriptor.autotune_band`` — the regime where the
    static roofline is least trustworthy — both lowerings are wall-clock
    measured on a small concrete probe batch and the *measured* winner is
    recorded.  (2) Mixed-k ``linear()`` combinations, which the equivalence
    rewriter must skip (per-model truncation depths differ), are lowered to
    a single MultiRetrieve when — and only when — measured faster.  Either
    way the decision lands in the TuningProfile exactly like the static
    gate's, so the next compile replays it with zero probes."""
    name = "autotune"

    def _decide(self, pctx, desc, est_u, est_f, build_unfused, build_fused,
                probe, require_measured: bool = False) -> dict:
        d = super()._decide(pctx, desc, est_u, est_f, build_unfused,
                            build_fused, probe, require_measured)
        measure = require_measured
        if not measure and est_u is not None and est_f is not None:
            pu, pf = est_u["time_proxy_s"], est_f["time_proxy_s"]
            measure = pu > 0 and abs(pf - pu) / pu <= desc.autotune_band
        if not measure or probe is None:
            return d
        try:
            static_args, batched_args = probe(desc.probe_queries)
            m_u = _measure_callable(build_unfused(), static_args,
                                    batched_args, desc.probe_repeats)
            m_f = _measure_callable(build_fused(), static_args,
                                    batched_args, desc.probe_repeats)
        except Exception:
            return d               # probe failure: fall back to the estimate
        pctx.counters["probe_measurements"] += 2
        d.update({"accepted": bool(m_f < m_u), "source": "measured",
                  "unfused_measured_s": m_u, "fused_measured_s": m_f})
        return d

    # -- IVF knob search: nprobe (and PQ candidate block on TPU) ------------
    def _tune_dense_knobs(self, op: Op, pctx: PassContext) -> Op:
        """Measured ``nprobe`` search around the configured value, on an
        already accepted fused dense stage.  Speed alone would always shrink
        ``nprobe`` (fewer lists scanned is strictly less work) and silently
        trash recall, so a candidate is eligible only if its top-K overlap
        against the *widest* candidate stays within the descriptor's
        ``autotune_band``; the fastest eligible candidate wins.  For PQ on a
        TPU backend the candidate-block size of the streaming ADC kernel is
        probed the same way (on CPU the reference path ignores it)."""
        import jax
        desc = self._desc(pctx)
        be = pctx.backend
        params = dict(op.params)
        nprobe = params.get("nprobe")
        if not nprobe:
            return op
        from repro.index import dense as DN
        pq = bool(params.get("pq"))
        K = params["k"]
        if pq:
            index = be.ivfpq
            refine = be.pq_refine
            sl = params.get("pq_shortlist")
            fn_for = lambda c: (lambda ix, q: DN.ivfpq_retrieve_topk_fused(
                ix, q, k=K, nprobe=c, refine=refine, shortlist=sl))
        else:
            index = be.ivf
            refine = None
            fn_for = lambda c: (lambda ix, q: DN.ivf_retrieve_topk_fused(
                ix, q, k=K, nprobe=c))
        npb = min(int(nprobe), index.n_lists)
        cands = sorted({max(1, npb // 2), npb,
                        min(2 * npb, index.n_lists)})
        chosen = self._probe_knob(
            pctx, pattern="nprobe_tune", knob="nprobe", configured=npb,
            cands=cands, index=index, fn_for=fn_for,
            extra_key=(pq, K, refine))
        if chosen is not None and chosen != params["nprobe"]:
            params["nprobe"] = chosen
            op = leaf(S.FusedDenseRetrieve(**params))
        if pq and jax.default_backend() == "tpu":
            from repro.kernels.pq_scoring.pq_scoring import BLOCK_C
            npb = min(int(params["nprobe"]), index.n_lists)
            sl = params.get("pq_shortlist")
            blk_for = lambda c: (
                lambda ix, q: DN.ivfpq_retrieve_topk_fused(
                    ix, q, k=K, nprobe=npb, refine=refine, block=c,
                    shortlist=sl))
            chosen_b = self._probe_knob(
                pctx, pattern="pq_block_tune", knob="pq_block",
                configured=params.get("pq_block") or BLOCK_C,
                cands=[BLOCK_C // 2, BLOCK_C, BLOCK_C * 2],
                index=index, fn_for=blk_for,
                extra_key=(params["nprobe"], K, refine))
            if chosen_b is not None and chosen_b != params.get("pq_block"):
                params["pq_block"] = chosen_b
                op = leaf(S.FusedDenseRetrieve(**params))
        return op

    def _probe_knob(self, pctx, *, pattern, knob, configured, cands,
                    index, fn_for, extra_key):
        """Measure each knob candidate on the concrete probe batch; return
        the fastest whose top-K doc overlap vs the widest candidate is >=
        1 - autotune_band (None = keep the configured value).  Decisions are
        persisted in the TuningProfile and replayed like gate decisions."""
        import numpy as np
        desc = self._desc(pctx)
        be = pctx.backend
        prof = desc.profile
        opk = (pattern, knob, tuple(cands), extra_key)
        bd = None
        if prof is not None:
            bd = _backend_gate_digest(be)
            hit = prof.lookup(bd, opk, GATE_MAXQ)
            if hit is not None:
                pctx.counters["profile_hits"] += 1
                d = dict(hit)
                d["source"] = "profile"
                pctx.decisions.append(d)
                return d.get("chosen")
            pctx.counters["profile_misses"] += 1
        if len(cands) < 2:
            return None
        import jax
        try:
            qvecs = _probe_qvecs(be, desc.probe_queries)
            times, docs = {}, {}
            for c in cands:
                vf = jax.jit(jax.vmap(fn_for(c), in_axes=(None, 0)))
                out = vf(index, qvecs)
                jax.block_until_ready(out)
                docs[c] = np.asarray(out[0])
                best = float("inf")
                for _ in range(max(desc.probe_repeats, 1)):
                    t0 = time.perf_counter()
                    jax.block_until_ready(vf(index, qvecs))
                    best = min(best, time.perf_counter() - t0)
                times[c] = best
        except Exception:
            return None            # probe failure: keep the configured knob
        pctx.counters["probe_measurements"] += len(cands)
        ref = docs[cands[-1]]

        def overlap(a):
            tot = 0.0
            for i in range(ref.shape[0]):
                want = {int(x) for x in ref[i] if x >= 0}
                got = {int(x) for x in a[i] if x >= 0}
                tot += len(want & got) / max(len(want), 1)
            return tot / max(ref.shape[0], 1)

        ovl = {c: overlap(docs[c]) for c in cands}
        floor = 1.0 - desc.autotune_band
        eligible = [c for c in cands if ovl[c] >= floor]
        chosen = min(eligible, key=lambda c: times[c]) if eligible \
            else cands[-1]
        d = {"pattern": pattern, "knob": knob, "configured": configured,
             "candidates": list(cands), "chosen": chosen,
             "accepted": bool(chosen != configured), "source": "measured",
             "measured_knob_s": {str(c): times[c] for c in cands},
             "overlap_at_k": {str(c): ovl[c] for c in cands},
             "kernel_native": True,
             "unfused_proxy_s": None, "fused_proxy_s": None,
             "unfused_measured_s": None, "fused_measured_s": None}
        pctx.decisions.append(d)
        if prof is not None:
            prof.record(bd, opk, GATE_MAXQ, d)
        return chosen

    def _tune_mixed_linear(self, op: Op, pctx: PassContext) -> Op:
        """Σ wᵢ·Retrieve(mᵢ, kᵢ) with *differing* kᵢ -> MultiRetrieve at
        max(kᵢ) when measured faster.  ``retrieve_multi`` combines the full
        dense score vectors before the final top-k (no per-model
        truncation), so the fused program is identical whatever the
        children's ks — but it is NOT equivalent to the truncating unfused
        sum, hence measured-only."""
        desc = self._desc(pctx)
        be = pctx.backend
        if not desc.supports("multi_model"):
            return op
        ks, models = [], []
        for c in op.inputs:
            if c.kind != "retrieve":
                return op
            ks.append(min(c.params["k"] or be.default_k, be.index.n_docs))
            models.append(c.params["model"])
        if len(models) < 2 or len(set(ks)) == 1:
            return op
        import jax.numpy as jnp

        from repro.index import retrieve as RT
        mtuple = tuple(models)
        weights = tuple(op.params["weights"])
        kmax = max(ks)
        mp = be.max_postings
        mw = jnp.asarray(weights, jnp.float32)

        def build_fused():
            def f(ix, t, w):
                return RT.retrieve_multi(ix, t, w, mw, models=mtuple,
                                         k=kmax, max_postings=mp)
            return f

        def build_unfused():
            def f(ix, t, w):
                return tuple(
                    RT.retrieve_topk(ix, t, w, model=m, k=kc,
                                     max_postings=mp)
                    for m, kc in zip(mtuple, ks))
            return f

        fused = leaf(S.MultiRetrieve(models=mtuple, weights=weights, k=kmax))
        if self._gate(pctx, "multi_mixed", kernel_native=True,
                      args=_abstract_args(be),
                      unfused=("multi_mixed_unfused", mtuple, tuple(ks), mp),
                      fused=("multi_mixed_fused", mtuple, weights, kmax, mp),
                      build_unfused=build_unfused, build_fused=build_fused,
                      probe=lambda n: ((be.index,), _probe_queries(be, n)),
                      require_measured=True):
            pctx.trace.append(("tune_multi_mixed", op, fused))
            return fused
        return op


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def default_passes(descriptor: BackendDescriptor | None = None,
                   max_rewrite_iters: int = 20) -> list[Pass]:
    """The standard pass pipeline, parameterised by the backend descriptor
    (None = resolve from the PassContext per run, the legacy behaviour).
    ``descriptor.autotune`` selects the measurement-driven fusion gate."""
    fusion_cls = AutotunePass if (descriptor is not None
                                  and descriptor.autotune) else FusionPass
    return [CanonicalizePass(), SchemaPass("schema_inference"),
            RewritePass(descriptor, max_iters=max_rewrite_iters), CSEPass(),
            fusion_cls(descriptor), SchemaPass("schema_check")]


def compile_pipeline(node: Transformer | Op, backend, *,
                     optimize: bool = True, trace: list | None = None,
                     cse_table: dict | None = None,
                     report: dict | None = None,
                     keep_snapshots: bool = False,
                     max_rewrite_iters: int = 20,
                     pctx: PassContext | None = None) -> Op:
    """Lower a pipeline to IR and (optionally) run the pass pipeline.

    ``optimize=False`` lowers only — exactly the seed's unoptimised
    semantics.  ``report`` (a dict, filled in place) receives per-pass
    timings and the fusion gate's decisions; ``cse_table`` may be shared
    across calls to intern ops across pipelines.
    """
    op = node if isinstance(node, Op) else lower(node)
    if not optimize:
        return op
    pctx = pctx or PassContext(backend, trace=trace, cse_table=cse_table,
                               keep_snapshots=keep_snapshots)
    passes = default_passes(pctx.descriptor,
                            max_rewrite_iters=max_rewrite_iters)
    op = PassManager(passes).run(op, pctx)
    if report is not None:
        report["pass_timings_s"] = list(pctx.timings)
        report["fusion_decisions"] = list(pctx.decisions)
        report["snapshots"] = list(pctx.snapshots)
        report["tuning"] = dict(pctx.counters)
    return op


def explain_pipeline(node: Transformer, backend=None, *,
                     optimize: bool = True) -> str:
    """Render the IR before/after each pass (``pipeline.explain()``)."""
    op = lower(node)
    if backend is None or not optimize:
        return "== lowered IR ==\n" + pretty(op, _safe_annotate(op, backend))
    pctx = PassContext(backend, keep_snapshots=True)
    compile_pipeline(op, backend, pctx=pctx, keep_snapshots=True)
    out = []
    prev_key = None
    for name, snap in pctx.snapshots:
        if prev_key is not None and snap.key() == prev_key:
            out.append(f"== after {name}: (unchanged)")
            continue
        prev_key = snap.key()
        head = "lowered IR" if name == "lower" else f"after {name}"
        out.append(f"== {head} ==\n" + pretty(snap, _safe_annotate(snap,
                                                                   backend)))
    for d in pctx.decisions:
        fmt = lambda v: "n/a" if v is None else f"{v:.4e}s"
        if d.get("knob"):
            out.append(
                f"-- autotune knob [{d['pattern']}]: "
                f"{d['knob']}={d['chosen']} "
                f"(configured {d['configured']}, "
                f"candidates {d['candidates']}, "
                f"{d.get('source', 'measured')})")
            continue
        line = (f"-- fusion gate [{d['pattern']}]: "
                f"{'fused' if d['accepted'] else 'kept unfused'} "
                f"(predicted fused {fmt(d['fused_proxy_s'])} vs "
                f"unfused {fmt(d['unfused_proxy_s'])}")
        if d.get("fused_measured_s") is not None:
            line += (f"; measured fused {fmt(d['fused_measured_s'])} vs "
                     f"unfused {fmt(d['unfused_measured_s'])}")
        line += f", {d.get('source', 'estimate')})"
        out.append(line)
    return "\n".join(out)


def _safe_annotate(op: Op, backend):
    try:
        return annotate(op, backend)
    except SchemaError:
        return None
