"""Graph rewriting: semantics-preserving pipeline optimisation (paper §4).

The rewriter applies *equivalence rules* bottom-up to a fixpoint.  Rules
consult the backend capability descriptor, mirroring how PyTerrier compiles
``Retrieve % 10`` into an Anserini BlockMaxWAND call and
``Retrieve >> (Extract ** Extract)`` into a Terrier fat-postings pass.
Associativity/commutativity is handled by the canonical variadic node forms
(see transformer.py) — structural matching replaces MatchPy.

Rules (★ = beyond-paper):
  cutoff_merge       %K1 %K2                    -> %min(K1,K2)
  cutoff_into_then   (A >> B) % K               -> A >> (B % K)
  cutoff_scale_swap  (α·T) % K                  -> α·(T % K)
  cutoff_pushdown    Retrieve % K               -> PrunedRetrieve(k=K)   [RQ1]
  fat_fusion         Retrieve >> (Extract ** …) -> FatRetrieve           [RQ2]
  extract_fusion     Retrieve >> Extract        -> FatRetrieve(1 feat)
  linear_fusion ★    Σ wᵢ·Retrieve(mᵢ)          -> MultiRetrieve (1 pass)
  scale_fold         α(βT) -> (αβ)T ; weights folded into Linear
"""
from __future__ import annotations

from typing import Callable

from repro.core import stages as S
from repro.core.transformer import (Concat, Cutoff, FeatureUnion, Linear,
                                    Scale, SetOp, Then, Transformer)

Rule = Callable[[Transformer, "JaxBackend"], Transformer | None]
RULES: list[tuple[str, Rule]] = []


def rule(name: str):
    def deco(fn):
        RULES.append((name, fn))
        return fn
    return deco


def _clone(node: Transformer, children) -> Transformer:
    new = object.__new__(type(node))
    new.__dict__.update(node.__dict__)
    new.children = tuple(children)
    return new


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------

@rule("cutoff_merge")
def cutoff_merge(node, backend):
    if isinstance(node, Cutoff) and isinstance(node.children[0], Cutoff):
        inner = node.children[0]
        k = min(node.params["k"], inner.params["k"])
        return Cutoff(children=[inner.children[0]], k=k)
    return None


def _out_kind(node: Transformer) -> str:
    """Primary output stream of an expression.  A Then of pure query
    rewrites is itself Q -> Q; any R-producing child makes it "R"."""
    if isinstance(node, Then):
        return ("Q" if all(_out_kind(c) == "Q" for c in node.children)
                else "R")
    return node.out_kind


def _reads_results(node: Transformer) -> bool:
    if isinstance(node, Then):
        return any(_reads_results(c) for c in node.children)
    return node.reads_results


@rule("cutoff_into_then")
def cutoff_into_then(node, backend):
    """(A >> B) % K -> A >> (B % K), guarded on B's output kind: a rank
    cutoff is only typed for R-producing expressions.  Trailing Q -> Q
    rewrites that never read R (SDM, stemming) are hopped over — sound,
    they cannot observe the truncation — so the cutoff lands on the last
    R-producing stage and stays eligible for the RQ1 pushdown.  An
    R-*reading* query rewrite (RM3 reads fb_docs from R) blocks the push:
    it must see the untruncated result list, and wrapping it in a Cutoff
    would type a % K against a Q -> Q stage (the unsound pre-fix form)."""
    if not (isinstance(node, Cutoff) and isinstance(node.children[0], Then)):
        return None
    kids = list(node.children[0].children)
    i = len(kids) - 1
    while i >= 0 and _out_kind(kids[i]) == "Q" and not _reads_results(kids[i]):
        i -= 1
    if i < 0 or _out_kind(kids[i]) != "R":
        return None
    last = Cutoff(children=[kids[i]], k=node.params["k"])
    return Then(children=[*kids[:i], last, *kids[i + 1:]])


@rule("cutoff_scale_swap")
def cutoff_scale_swap(node, backend):
    if isinstance(node, Cutoff) and isinstance(node.children[0], Scale):
        sc = node.children[0]
        if sc.params["alpha"] > 0:
            inner = Cutoff(children=[sc.children[0]], k=node.params["k"])
            return Scale(children=[inner], alpha=sc.params["alpha"])
    return None


@rule("cutoff_pushdown")
def cutoff_pushdown(node, backend):
    """Retrieve % K -> PrunedRetrieve(K): the RQ1 dynamic-pruning rewrite."""
    if "pruned_topk" not in backend.capabilities:
        return None
    if isinstance(node, Cutoff) and isinstance(node.children[0], S.Retrieve):
        ret = node.children[0]
        K = node.params["k"]
        if ret.params["k"] is None or ret.params["k"] >= K:
            return S.PrunedRetrieve(model=ret.params["model"], k=K)
    return None


def _as_extract_models(children) -> tuple[str, ...] | None:
    models = []
    for c in children:
        if isinstance(c, S.Extract):
            models.append(c.params["model"])
        else:
            return None
    return tuple(models)


@rule("fat_fusion")
def fat_fusion(node, backend):
    """Retrieve >> (Extract ** ... ** Extract) -> FatRetrieve: RQ2."""
    if "fat" not in backend.capabilities or not isinstance(node, Then):
        return None
    kids = list(node.children)
    for i in range(len(kids) - 1):
        a, b = kids[i], kids[i + 1]
        if not isinstance(a, S.Retrieve):
            continue
        if isinstance(b, FeatureUnion):
            models = _as_extract_models(b.children)
        elif isinstance(b, S.Extract):
            models = (b.params["model"],)
        else:
            continue
        if models is None:
            continue
        fat = S.FatRetrieve(model=a.params["model"], features=models,
                            k=a.params["k"])
        new_kids = kids[:i] + [fat] + kids[i + 2:]
        return new_kids[0] if len(new_kids) == 1 else Then(children=new_kids)
    return None


@rule("linear_fusion")
def linear_fusion(node, backend):
    """★ Σ wᵢ·Retrieve(mᵢ, k) on one index -> MultiRetrieve: one postings
    pass instead of N (beyond-paper rewrite enabled by score_all)."""
    if "multi_model" not in backend.capabilities or not isinstance(node, Linear):
        return None
    ks = set()
    models = []
    for c in node.children:
        if not isinstance(c, S.Retrieve):
            return None
        ks.add(c.params["k"])
        models.append(c.params["model"])
    if len(ks) != 1 or len(models) < 2:
        return None
    return S.MultiRetrieve(models=tuple(models),
                           weights=tuple(node.params["weights"]),
                           k=ks.pop())


@rule("scale_fold")
def scale_fold(node, backend):
    if isinstance(node, Scale):
        inner = node.children[0]
        a = node.params["alpha"]
        if a == 1.0:
            return inner
        if isinstance(inner, (Scale, Linear)):
            return Scale.of(a, inner)   # re-canonicalise
    return None


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def optimize_pipeline(root: Transformer, backend, *, max_iters: int = 20,
                      trace: list | None = None) -> Transformer:
    """Bottom-up rewrite to fixpoint."""

    def walk(node: Transformer) -> Transformer:
        new_children = [walk(c) for c in node.children]
        if any(n is not o for n, o in zip(new_children, node.children)):
            node = _clone(node, new_children)
        for name, r in RULES:
            out = r(node, backend)
            if out is not None and out.key() != node.key():
                if trace is not None:
                    trace.append((name, node, out))
                return walk(out)
        return node

    for _ in range(max_iters):
        new = walk(root)
        if new.key() == root.key():
            return new
        root = new
    return root
