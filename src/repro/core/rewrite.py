"""Pipeline optimisation entry point — thin shim over the IR pass manager.

The bottom-up fixpoint rewriter that used to live here has been re-expressed
as typed-IR passes in ``core/passes.py`` (rules: cutoff_merge /
cutoff_into_then / cutoff_scale_swap / cutoff_pushdown, fat / extract /
linear fusion, scale_fold — same names, same semantics, now with schema
inference and a cost-gated kernel-lowering stage behind them).
``optimize_pipeline`` is kept for external callers and returns a
``Transformer`` tree as before: it lowers to IR, runs the pass pipeline,
and raises the result back.
"""
from __future__ import annotations

from repro.core.transformer import Transformer


def _clone(node: Transformer, children) -> Transformer:
    """Shallow-clone ``node`` with new children.

    The clone gets its *own* params dict: ``object.__new__`` +
    ``__dict__.update`` alone would share the original's ``params`` mapping,
    so a later in-place mutation of either node's params would silently
    rewrite the other (and corrupt every structural key derived from it).
    """
    new = object.__new__(type(node))
    new.__dict__.update(node.__dict__)
    new.params = dict(node.params)
    new.children = tuple(children)
    return new


def optimize_pipeline(root: Transformer, backend, *, max_iters: int = 20,
                      trace: list | None = None) -> Transformer:
    """Optimise a pipeline against ``backend``'s
    :class:`~repro.core.descriptor.BackendDescriptor` (capability flags,
    kernel limits, calibrated roofline peaks, optional tuning profile /
    autotune policy — ``as_descriptor`` adapts legacy flat-``capabilities``
    backends).

    Shim over the pass-manager compiler: ``lower -> canonicalise -> schema
    inference -> rewrite rules -> CSE -> cost-gated fusion -> raise``.
    ``trace`` (if given) collects ``(rule_name, before_op, after_op)``
    entries from the rewrite and fusion passes.
    """
    from repro.core.ir import raise_ir
    from repro.core.passes import compile_pipeline
    return raise_ir(compile_pipeline(root, backend, optimize=True,
                                     trace=trace,
                                     max_rewrite_iters=max_iters))
