"""Experiment variants the paper sketches in §3.4: grid search with
stage-output caching, and k-fold cross-validation.

"Due to the compositional nature of a retrieval pipeline, the grid search
would be able to cache the outcomes of earlier stages, such that later
retrieval components could be varied without re-execution of all pipeline
stages."  — implemented literally: all candidate pipelines share one
``Context`` memo, so common prefixes (hash-consed by structural key)
execute once across the whole grid.
"""
from __future__ import annotations

import itertools
from typing import Any, Callable, Sequence

import numpy as np

from repro.core import measures as M
from repro.core.compiler import Context, JaxBackend, run_pipeline
from repro.core.data import make_queries
from repro.core.passes import compile_pipeline
from repro.core.transformer import Transformer


def GridSearch(build: Callable[..., Transformer], grid: dict[str, Sequence],
               topics, qrels, *, metric: str = "map", backend: JaxBackend,
               optimize: bool = True) -> dict:
    """Evaluate ``build(**params)`` over the cartesian grid; returns
    {"best_params", "best_score", "table"}.  Shared-prefix stage caching
    happens automatically via the common Context.
    """
    ctx = Context(backend)
    names = list(grid)
    rows = []
    best = (None, -np.inf)
    for values in itertools.product(*grid.values()):
        params = dict(zip(names, values))
        pipe = build(**params)
        node = compile_pipeline(pipe, backend) if optimize else pipe
        R = run_pipeline(node, topics, backend=backend, optimize=False,
                         ctx=ctx)
        score = M.compute_measures(R, qrels, [metric])[metric]
        rows.append({**params, metric: score})
        if score > best[1]:
            best = (params, score)
    return {"best_params": best[0], "best_score": best[1], "table": rows}


def kfold_splits(qids: np.ndarray, k: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(qids))
    folds = np.array_split(order, k)
    for i in range(k):
        test = folds[i]
        train = np.concatenate([folds[j] for j in range(k) if j != i])
        yield train, test


def _subset(Q, idx):
    return {k: v[np.asarray(idx)] for k, v in Q.items()}


def _subset_qrels(qrels, Q):
    qids = set(int(q) for q in np.asarray(Q["qid"]))
    return {q: g for q, g in qrels.items() if q in qids}


def CrossValidate(build: Callable[..., Transformer], topics, qrels, *,
                  k: int = 5, metrics: Sequence[str] = ("map",),
                  backend: JaxBackend, fit: bool = True, seed: int = 0) -> dict:
    """k-fold CV: for each fold, ``build()`` a fresh pipeline, fit it on the
    train queries (if it has stateful stages), evaluate on the held-out
    fold; returns per-fold and mean metrics."""
    qids = np.asarray(topics["qid"])
    folds = []
    for train_idx, test_idx in kfold_splits(qids, k, seed):
        pipe = build()
        Qtr, Qte = _subset(topics, train_idx), _subset(topics, test_idx)
        if fit:
            pipe.fit(Qtr, _subset_qrels(qrels, Qtr), backend=backend)
        R = pipe.transform(Qte, backend=backend)
        folds.append(M.compute_measures(R, _subset_qrels(qrels, Qte),
                                        list(metrics)))
    mean = {m: float(np.mean([f[m] for f in folds])) for m in metrics}
    return {"folds": folds, "mean": mean}
