"""The IR data model (paper §3.1): queries Q, result lists R, qrels RA.

Q and R are relations realised as dict-of-array pytrees so that entire
pipelines lower into single XLA programs and shard over the query axis (DP)
and index axis (MP):

  Q:  {"qid" [NQ], "terms" [NQ, MAXQ] (-1 padded), "weights" [NQ, MAXQ]}
  R:  {"qid" [NQ], "docids" [NQ, K] (-1 padded), "scores" [NQ, K],
       optional "features" [NQ, K, F]}

Primary keys: q.id for Q; (q.id, d.id) for R — mirrored from the paper's
object-relational model.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

MAXQ = 48   # padded query length (original + expansion terms)

Queries = dict[str, Any]
Results = dict[str, Any]


def make_queries(terms: np.ndarray, weights: np.ndarray | None = None,
                 qids: np.ndarray | None = None, maxq: int = MAXQ) -> Queries:
    terms = np.asarray(terms, np.int32)
    nq, L = terms.shape
    if L < maxq:
        terms = np.pad(terms, ((0, 0), (0, maxq - L)), constant_values=-1)
        if weights is not None:
            weights = np.pad(np.asarray(weights, np.float32),
                             ((0, 0), (0, maxq - L)))
    if weights is None:
        weights = (terms >= 0).astype(np.float32)
    if qids is None:
        qids = np.arange(nq, dtype=np.int32)
    return {"qid": jnp.asarray(qids), "terms": jnp.asarray(terms),
            "weights": jnp.asarray(weights, jnp.float32)}


def empty_results(nq: int, k: int) -> Results:
    return {"qid": jnp.arange(nq, dtype=jnp.int32),
            "docids": jnp.full((nq, k), -1, jnp.int32),
            "scores": jnp.full((nq, k), -jnp.inf, jnp.float32)}


def n_queries(Q: Queries) -> int:
    return int(Q["qid"].shape[0])


def results_depth(R: Results) -> int:
    return int(R["docids"].shape[1])


def to_host(R: Results) -> dict[str, np.ndarray]:
    return {k: np.asarray(v) for k, v in R.items()}
