"""IR evaluation measures (trec_eval semantics) — metric math in JAX.

The (qid, docid) -> grade join happens host-side (as trec_eval does); the
measure computations are vectorised jnp over the dense [NQ, K] grade matrix.
Supported: map, ndcg_cut_K, P_K, recip_rank, recall_K, num_rel_ret.
"""
from __future__ import annotations

import re

import jax.numpy as jnp
import numpy as np


def label_matrix(R, qrels: dict[int, dict[int, int]]) -> tuple[np.ndarray, np.ndarray]:
    """Returns (grades [NQ, K], n_rel [NQ])."""
    qids = np.asarray(R["qid"])
    docids = np.asarray(R["docids"])
    grades = np.zeros(docids.shape, np.float32)
    n_rel = np.zeros(len(qids), np.float32)
    for i, q in enumerate(qids):
        g = qrels.get(int(q), {})
        n_rel[i] = sum(1 for v in g.values() if v > 0)
        if g:
            row = docids[i]
            grades[i] = [g.get(int(d), 0) if d >= 0 else 0 for d in row]
    return grades, n_rel


def average_precision(grades, n_rel):
    rel = (grades > 0).astype(jnp.float32)
    cum = jnp.cumsum(rel, axis=1)
    ranks = jnp.arange(1, grades.shape[1] + 1, dtype=jnp.float32)
    prec = cum / ranks
    ap = jnp.sum(prec * rel, axis=1) / jnp.maximum(n_rel, 1.0)
    return jnp.where(n_rel > 0, ap, 0.0)


def ndcg_at(grades, n_rel, k: int):
    g = grades[:, :k]
    discounts = 1.0 / jnp.log2(jnp.arange(2, k + 2, dtype=jnp.float32))
    dcg = jnp.sum((2.0 ** g - 1.0) * discounts, axis=1)
    ideal = jnp.sort(grades, axis=1)[:, ::-1][:, :k]
    idcg = jnp.sum((2.0 ** ideal - 1.0) * discounts, axis=1)
    return jnp.where(idcg > 0, dcg / jnp.maximum(idcg, 1e-9), 0.0)


def precision_at(grades, n_rel, k: int):
    return jnp.mean((grades[:, :k] > 0).astype(jnp.float32), axis=1)


def recip_rank(grades, n_rel):
    rel = grades > 0
    first = jnp.argmax(rel, axis=1)
    has = jnp.any(rel, axis=1)
    return jnp.where(has, 1.0 / (first + 1.0), 0.0)


def recall_at(grades, n_rel, k: int):
    hits = jnp.sum((grades[:, :k] > 0).astype(jnp.float32), axis=1)
    return jnp.where(n_rel > 0, hits / jnp.maximum(n_rel, 1.0), 0.0)


def compute_measures(R, qrels, metrics: list[str]) -> dict[str, float]:
    grades_np, n_rel_np = label_matrix(R, qrels)
    grades, n_rel = jnp.asarray(grades_np), jnp.asarray(n_rel_np)
    out = {}
    for m in metrics:
        if m == "map":
            v = average_precision(grades, n_rel)
        elif m == "recip_rank":
            v = recip_rank(grades, n_rel)
        elif m == "num_rel_ret":
            v = jnp.sum(grades > 0, axis=1).astype(jnp.float32)
        elif (mm := re.fullmatch(r"ndcg_cut_(\d+)", m)):
            v = ndcg_at(grades, n_rel, int(mm.group(1)))
        elif (mm := re.fullmatch(r"P_(\d+)", m)):
            v = precision_at(grades, n_rel, int(mm.group(1)))
        elif (mm := re.fullmatch(r"recall_(\d+)", m)):
            v = recall_at(grades, n_rel, int(mm.group(1)))
        else:
            raise ValueError(f"unknown metric {m}")
        out[m] = float(jnp.mean(v))
    return out
