"""Typed dataflow IR for declarative retrieval pipelines (paper §4).

The operator algebra (``core/transformer.py``) is a *surface syntax*: users
compose ``Transformer`` nodes with the eight operators and nothing carries
types, static shapes, or a stable identity the optimiser / planner / engine
all agree on.  This module is the single representation they share:

* :class:`Op` — one dataflow node: ``kind`` + static ``params`` + ``inputs``
  (operand ops) + an optional ``ref`` back to the executable stage object.
  Ops are *structurally immutable*: rewrites build new ops (``with_inputs``)
  instead of mutating, so schema/key caches stay sound and CSE can share
  instances freely.
* :class:`Schema` — the type of an op's output stream: ``Q`` (query
  rewrite, the R stream passes through), ``R`` (ranked results), ``F``
  (ranked results carrying feature columns), or ``A`` (answer-bearing
  results: ranked results plus generated token columns), plus the *static*
  result depth ``k`` and feature width where they are known at compile time.
* ``lower`` / ``raise_ir`` — convert a ``Transformer`` tree to IR and back.
  The round trip preserves ``key()`` exactly: ``Op.key()`` is computed with
  the same canonicalisation as ``Transformer.key()``
  (:func:`repro.core.transformer.canon_param_items`), so result-memo
  entries, plan-trie nodes and engine jit-cache entries written against one
  representation are valid against the other.
* ``pretty`` — human-readable rendering, used by ``pipeline.explain()``.

The pass manager that operates on this IR lives in ``core/passes.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.transformer import (Concat, Cutoff, FeatureUnion, Linear,
                                    Scale, SetOp, Then, Transformer,
                                    canon_param_items)


class SchemaError(TypeError):
    """A pipeline violates the IR typing rules (e.g. a rank cutoff applied
    to a pure query-rewrite expression)."""


@dataclasses.dataclass(frozen=True)
class Schema:
    """Static type of an op's output stream.

    ``out``  — "Q" (no result stream produced; R passes through), "R"
               (ranked results), "F" (results + feature columns), "A"
               (answer-bearing results: R plus generated token columns —
               terminal; no ranking stage may consume it).
    ``k``    — static result depth, or None where unknown at compile time.
    ``width``— static feature-column count, or None where unknown.
    ``reads_results`` — whether executing the op observes the incoming R
               (the cutoff-hop soundness bit: a % K may hop a Q -> Q stage
               only if that stage never looks at R).
    """
    out: str = "R"
    k: int | None = None
    width: int | None = None
    reads_results: bool = True

    def annotate(self) -> str:
        bits = [self.out]
        if self.k is not None:
            bits.append(f"k={self.k}")
        if self.width:
            bits.append(f"w={self.width}")
        if self.reads_results:
            bits.append("readsR")
        return "[" + ", ".join(bits) + "]"


#: combinator kinds executed structurally by the compiler (inputs + params
#: fully define them); every other kind is a leaf stage executed via ``ref``
#: (retrieve / fat_retrieve / dense_retrieve / dense_rerank / ... plus the
#: fused_* kinds the cost-gated fusion pass lowers chains onto)
COMBINATOR_KINDS = frozenset({
    "then", "linear", "scale", "cutoff", "setop", "concat", "feature_union",
})

_COMBINATOR_TYPES = {
    "then": Then, "linear": Linear, "scale": Scale, "cutoff": Cutoff,
    "setop": SetOp, "concat": Concat, "feature_union": FeatureUnion,
}


class Op:
    """One typed-IR node.  Treat as immutable once constructed."""

    __slots__ = ("kind", "params", "inputs", "ref", "_key", "_stateful")

    def __init__(self, kind: str, params: dict | None = None,
                 inputs: Sequence["Op"] = (), ref: Transformer | None = None):
        self.kind = kind
        self.params = dict(params or {})
        self.inputs = tuple(inputs)
        self.ref = ref
        self._key = None
        self._stateful = None
        if kind not in COMBINATOR_KINDS and ref is None:
            raise ValueError(f"leaf op {kind!r} needs an executable ref")

    # -- identity -----------------------------------------------------------
    def _state(self) -> tuple:
        r = self.ref
        if r is not None and r.stateful:
            return (r.uid, r.version)
        return ()

    def stateful_subtree(self) -> bool:
        """Whether any op in this subtree wraps a stateful stage (whose key
        embeds a live version marker)."""
        if self._stateful is None:
            self._stateful = (self.ref is not None and self.ref.stateful) \
                or any(i.stateful_subtree() for i in self.inputs)
        return self._stateful

    def key(self) -> tuple:
        """Stable content key, bit-identical to the key of the raised
        ``Transformer`` tree.  Subtrees containing a stateful leaf embed a
        live (uid, version) marker, so their keys are recomputed on every
        call (fit() bumps the version — a cached key anywhere on the path
        would serve pre-training memo entries); fully stateless keys are
        cached."""
        if self._key is not None:
            return self._key
        k = (self.kind, canon_param_items(self.params), self._state(),
             tuple(i.key() for i in self.inputs))
        if not self.stateful_subtree():
            self._key = k
        return k

    def with_inputs(self, inputs: Sequence["Op"]) -> "Op":
        return Op(self.kind, self.params, inputs, ref=self.ref)

    def with_params(self, **params) -> "Op":
        return Op(self.kind, {**self.params, **params}, self.inputs,
                  ref=self.ref)

    @property
    def is_leaf(self) -> bool:
        return self.kind not in COMBINATOR_KINDS

    def label(self) -> str:
        if self.ref is not None:
            return type(self.ref).__name__
        return self.kind

    def __repr__(self):
        inner = ", ".join(
            [f"{k}={v!r}" for k, v in self.params.items()
             if not hasattr(v, "shape") and k != "index"])
        tail = f" x{len(self.inputs)}" if self.inputs else ""
        return f"Op({self.kind}{'(' + inner + ')' if inner else ''}{tail})"


# ---------------------------------------------------------------------------
# lowering / raising
# ---------------------------------------------------------------------------

def lower(node: Transformer) -> Op:
    """Transformer tree -> IR graph.  Every op keeps a ``ref`` to the node
    it was lowered from: leaves execute through it, and an unchanged subtree
    raises back to the identical object (key/state preserved for free)."""
    return Op(node.kind, node.params,
              tuple(lower(c) for c in node.children), ref=node)


def leaf(stage: Transformer) -> Op:
    """Wrap a freshly built leaf stage (rewrite/fusion product) as an op."""
    assert not stage.children, "leaf() is for childless stages"
    return Op(stage.kind, stage.params, (), ref=stage)


def raise_ir(op: Op) -> Transformer:
    """IR graph -> Transformer tree (inverse of :func:`lower`).

    Leaves return their ``ref`` (the executable payload *is* the node);
    combinators are rebuilt from the registry unless the op still matches
    its ref's children, in which case the original node is returned — so
    ``raise_ir(lower(t))`` is ``t`` and trivially preserves ``key()``.
    """
    if op.is_leaf:
        return op.ref
    kids = [raise_ir(i) for i in op.inputs]
    r = op.ref
    if (r is not None and len(kids) == len(r.children)
            and all(a is b for a, b in zip(kids, r.children))
            and canon_param_items(r.params) == canon_param_items(op.params)):
        return r
    return _COMBINATOR_TYPES[op.kind](children=kids, **op.params)


def chain(op: Op) -> list[Op]:
    """A pipeline as its linear chain of top-level stages (the planner's
    trie rows).  Nested combinators stay atomic entries."""
    return list(op.inputs) if op.kind == "then" else [op]


# ---------------------------------------------------------------------------
# rendering
# ---------------------------------------------------------------------------

def pretty(op: Op, schemas: dict[int, Schema] | None = None,
           indent: int = 0) -> str:
    """Indented tree rendering; ``schemas`` (id(op) -> Schema, as produced
    by the schema-inference pass) adds type annotations."""
    pad = "  " * indent
    inner = ", ".join(f"{k}={v!r}" for k, v in sorted(op.params.items())
                      if not hasattr(v, "shape"))
    line = f"{pad}{op.label()}({inner})" if inner else f"{pad}{op.label()}"
    if schemas is not None and id(op) in schemas:
        line += f"  {schemas[id(op)].annotate()}"
    lines = [line]
    for i in op.inputs:
        lines.append(pretty(i, schemas, indent + 1))
    return "\n".join(lines)
