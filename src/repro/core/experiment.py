"""The Experiment abstraction (paper §3.4).

``Experiment(pipelines, topics, qrels, metrics)`` applies each pipeline to a
common query set and evaluates the results side-by-side, sharing a result
cache so common pipeline prefixes execute once (the paper's grid-search
caching).  Optionally times each pipeline (MRT — mean response time), which
is how the RQ1/RQ2 tables are produced.
"""
from __future__ import annotations

import time
from typing import Sequence

import jax

from repro.core import measures as M
from repro.core.compiler import Context, JaxBackend, run_pipeline
from repro.core.rewrite import optimize_pipeline
from repro.core.transformer import Transformer


def Experiment(pipelines: Sequence[Transformer], topics, qrels,
               metrics: Sequence[str] = ("map", "ndcg_cut_10"),
               *, backend: JaxBackend, names: Sequence[str] | None = None,
               optimize: bool = True, measure_time: bool = False,
               share_cache: bool = True) -> dict:
    """Returns {"table": [row dicts], "results": [R per pipeline]}."""
    names = list(names) if names else [repr(p)[:60] for p in pipelines]
    ctx = Context(backend) if share_cache else None
    rows, results = [], []
    for name, pipe in zip(names, pipelines):
        node = optimize_pipeline(pipe, backend) if optimize else pipe
        t0 = time.perf_counter()
        R = run_pipeline(node, topics, backend=backend, optimize=False,
                         ctx=ctx if share_cache else Context(backend))
        jax.block_until_ready(R["scores"])
        elapsed = time.perf_counter() - t0
        row = {"name": name, **M.compute_measures(R, qrels, list(metrics))}
        if measure_time:
            nq = int(R["qid"].shape[0])
            row["mrt_ms"] = 1000.0 * elapsed / nq
        rows.append(row)
        results.append(R)
    return {"table": rows, "results": results}


def format_table(rows: list[dict]) -> str:
    if not rows:
        return "(empty)"
    cols = list(rows[0].keys())
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows)) for c in cols}
    lines = ["  ".join(c.ljust(widths[c]) for c in cols)]
    for r in rows:
        lines.append("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))
    return "\n".join(lines)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4f}"
    return str(v)
