"""The Experiment abstraction (paper §3.4).

``Experiment(pipelines, topics, qrels, metrics)`` applies each pipeline to a
common query set and evaluates the results side-by-side.  By default the
pipelines are compiled into an :class:`~repro.core.plan.ExperimentPlan` — a
shared-prefix trie that executes every common sub-pipeline exactly once and
attributes per-stage wall-clock, so MRT (mean response time, the RQ1/RQ2
tables) decomposes into compile / steady-state / shared-amortised
components.  ``plan=False`` preserves the old sequential path (one
``run_pipeline`` per pipeline over a shared memo).

Timing semantics: with ``measure_time=True`` the plan runs twice — a cold
pass (JIT compilation happens here) and a steady-state pass with a fresh
memo — and ``mrt_ms`` reports the steady pass, matching the paper's
mean-response-time definition (compilation is reported separately as
``compile_ms``; ``mrt_shared_ms`` amortises each stage over the pipelines
sharing it).
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Sequence

import jax

from repro.core import measures as M
from repro.core.compiler import Context, JaxBackend, run_pipeline
from repro.core.passes import compile_pipeline
from repro.core.plan import ArtifactCache, ExperimentPlan
from repro.core.transformer import Transformer


def Experiment(pipelines: Sequence[Transformer], topics, qrels,
               metrics: Sequence[str] = ("map", "ndcg_cut_10"),
               *, backend: JaxBackend, names: Sequence[str] | None = None,
               optimize: bool = True, measure_time: bool = False,
               share_cache: bool = True, plan: bool = True,
               artifact_cache: ArtifactCache | str | Path | None = None) -> dict:
    """Returns {"table": [row dicts], "results": [R per pipeline]}; planned
    runs also carry "plan" (the ExperimentPlan) and "stage_table"
    (per-stage timing/sharing attribution)."""
    names = list(names) if names else [repr(p)[:60] for p in pipelines]
    if isinstance(artifact_cache, (str, Path)):
        artifact_cache = ArtifactCache(artifact_cache)
    if plan:
        return _experiment_planned(pipelines, topics, qrels, metrics,
                                   backend, names, optimize, measure_time,
                                   artifact_cache)
    return _experiment_sequential(pipelines, topics, qrels, metrics, backend,
                                  names, optimize, measure_time, share_cache)


def _experiment_planned(pipelines, topics, qrels, metrics, backend, names,
                        optimize, measure_time, cache) -> dict:
    eplan = ExperimentPlan(pipelines, backend, optimize=optimize)
    results = eplan.execute(topics, ctx=Context(backend), cache=cache,
                            record="cold")
    if measure_time:
        if cache is not None and cache.hits:
            # artifacts served from disk mean the cold pass compiled
            # nothing — pay JIT compilation in an unrecorded pass so the
            # timed steady pass below stays compile-free (compile_ms then
            # reflects what the *cold pass* paid, i.e. ~0 on a warm cache)
            eplan.execute(topics, ctx=Context(backend), record=None)
        # steady-state pass: fresh memo, but the backend/JIT caches are warm,
        # so per-stage wall-clock now excludes compilation.  No artifact
        # cache here — MRT must measure execution, not disk reads.
        results = eplan.execute(topics, ctx=Context(backend), record="warm")
    nq = int(topics["qid"].shape[0])
    rows = []
    for i, (name, R) in enumerate(zip(names, results)):
        row = {"name": name, **M.compute_measures(R, qrels, list(metrics))}
        if measure_time:
            t = eplan.pipeline_times(i)
            row["mrt_ms"] = 1000.0 * t["steady_s"] / nq
            row["compile_ms"] = 1000.0 * t["compile_s"]
            row["mrt_shared_ms"] = 1000.0 * t["amortised_s"] / nq
        rows.append(row)
    return {"table": rows, "results": results, "plan": eplan,
            "stage_table": eplan.stage_stats()}


def _experiment_sequential(pipelines, topics, qrels, metrics, backend, names,
                           optimize, measure_time, share_cache) -> dict:
    """The pre-planner path (``plan=False`` escape hatch)."""
    ctx = Context(backend) if share_cache else None
    rows, results = [], []
    for name, pipe in zip(names, pipelines):
        node = compile_pipeline(pipe, backend) if optimize else pipe
        if measure_time:
            # warm-up with a throwaway memo so the timed region below
            # measures steady-state retrieval, not JIT compilation
            Rw = run_pipeline(node, topics, backend=backend, optimize=False,
                              ctx=Context(backend))
            jax.block_until_ready(Rw["scores"])
        t0 = time.perf_counter()
        R = run_pipeline(node, topics, backend=backend, optimize=False,
                         ctx=ctx if share_cache else Context(backend))
        jax.block_until_ready(R["scores"])
        elapsed = time.perf_counter() - t0
        row = {"name": name, **M.compute_measures(R, qrels, list(metrics))}
        if measure_time:
            nq = int(R["qid"].shape[0])
            row["mrt_ms"] = 1000.0 * elapsed / nq
        rows.append(row)
        results.append(R)
    return {"table": rows, "results": results}


def format_table(rows: list[dict]) -> str:
    if not rows:
        return "(empty)"
    cols = list(rows[0].keys())
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows)) for c in cols}
    lines = ["  ".join(c.ljust(widths[c]) for c in cols)]
    for r in rows:
        lines.append("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))
    return "\n".join(lines)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4f}"
    return str(v)
