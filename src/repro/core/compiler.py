"""Pipeline compiler + executor (paper §4).

``run_pipeline`` = lower to the typed IR -> run the pass-manager compiler
(canonicalise, schema inference, rewrite rules, CSE, cost-gated kernel
fusion — ``core/passes.py``) -> execute the IR with hash-consed result
caching (identical sub-pipelines run once per query set — the paper's
grid-search/common-prefix caching).  Combinator ops are interpreted here;
leaf ops delegate to their stage payload, which calls jitted index ops with
the op's content key naming the engine's jit-cache entry.

Result identity is *content-addressed*: the memo key for a node is
``(node.key(), token)`` where ``token`` digests the actual input arrays at
the pipeline source and is then derived structurally
(``token' = H(node.key(), token)``) as data flows through the DAG.  See
DESIGN.md §Planner for why ``id()``-based tokens are unsound (ids are
recycled once arrays are garbage-collected, so a long-lived shared Context
could serve stale results).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import os
import weakref

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.descriptor import BackendDescriptor
from repro.core.engine import ShardedQueryEngine, StageProgram
from repro.core.ir import Op, lower
from repro.core.transformer import Transformer
from repro.index.dense import DenseIndex, build_dense_index
from repro.index.inverted import BLOCK, InvertedIndex


# ---------------------------------------------------------------------------
# backend
# ---------------------------------------------------------------------------

#: monotonic backend ids for engine jit-cache scoping (id() would recycle)
_BACKEND_UID = itertools.count()


class JaxBackend:
    """Execution backend over the JAX-native index (capability descriptor +
    sharded bucketed query execution + query embedding + registered LMs
    for the generate stage).

    The optimisation surface consulted by the rewrite/fusion passes (paper
    §4: BMW cutoff on Anserini; fat postings on Terrier — our backend
    supports all, plus the Pallas kernel lowerings the fusion pass
    cost-gates) lives on ``self.descriptor`` (a
    :class:`~repro.core.descriptor.BackendDescriptor`); pass
    ``descriptor=BackendDescriptor.default(capability_set)`` to restrict
    it.  The pre-descriptor ``capabilities=`` constructor kwarg was removed
    after its deprecation cycle."""

    def __init__(self, index: InvertedIndex, dense: DenseIndex | None = None,
                 *, default_k: int = 1000, query_chunk: int = 16,
                 stop_df_fraction: float = 0.1, seed: int = 0,
                 descriptor: BackendDescriptor | None = None,
                 sharded: bool | None = None,
                 engine: ShardedQueryEngine | None = None,
                 bucket_ladder=None, ivf=None, ivf_lists: int | None = None,
                 ivf_iters: int = 6, ivf_seed: int = 0,
                 ivf_keep_flat: bool = True, ivfpq=None, pq_m: int = 8,
                 pq_iters: int = 10, pq_refine: int = 4):
        self.index = index
        self.uid = next(_BACKEND_UID)
        self.default_k = min(default_k, index.n_docs)
        self.query_chunk = query_chunk
        self.descriptor = (descriptor if descriptor is not None
                           else BackendDescriptor.default())
        #: name -> (LMConfig, params): decoder LMs the generate stage
        #: resolves by name.  Registration keeps Generate's params scalar
        #: (the model *name* is the content key, not the weight arrays), so
        #: CSE / serving digests / engine jit keys stay stable.
        self._lms: dict = {}
        # stopwords are removed at index time (build_index), so the global
        # max posting-list length is the safe static gather width
        lens = np.diff(np.asarray(index.term_start))
        self.max_postings = int(lens.max())
        self.max_blocks_per_term = self.max_postings // BLOCK
        self.total_blocks = int(index.doc_ids.shape[0]) // BLOCK
        self.dense = dense if dense is not None else build_dense_index(index)
        # IVF-flat config: the index itself is built lazily on first dense
        # retrieval (a pure function of dense.emb + these statics, which is
        # what lets plan.backend_digest key it by config, not contents)
        self._ivf = ivf
        #: an externally supplied IVF is digested by content (its arrays are
        #: not derivable from the backend's own config)
        self._ivf_external = ivf is not None
        self.ivf_lists = ivf_lists
        self.ivf_iters = ivf_iters
        self.ivf_seed = ivf_seed
        #: keep_flat=False drops the list-ordered float duplicate from the
        #: lazily built IVF (PQ-only deployments; flat-IVF search then
        #: raises).  Digest-relevant: it changes which paths can execute.
        self.ivf_keep_flat = ivf_keep_flat
        # IVF-PQ config: same lazy-build/config-digest story as the IVF
        self._ivfpq = ivfpq
        self._ivfpq_external = ivfpq is not None
        self.pq_m = int(pq_m)
        self.pq_iters = int(pq_iters)
        self.pq_refine = int(pq_refine)
        rng = np.random.default_rng(seed)
        self._qproj = jnp.asarray(
            rng.standard_normal((index.vocab, self.dense.dim)).astype(np.float32)
            / np.sqrt(self.dense.dim))
        # sharded engine is the default execution path; REPRO_ENGINE=sequential
        # (or sharded=False) preserves the seed's single-device chunked loop
        if sharded is None:
            sharded = os.environ.get("REPRO_ENGINE", "sharded") != "sequential"
        self.engine = (engine if engine is not None
                       else ShardedQueryEngine(ladder=bucket_ladder)
                       if sharded else None)

    @property
    def capabilities(self) -> frozenset:
        """Read-only alias for ``self.descriptor.capabilities`` (the flat
        capability set the rewrite passes probe)."""
        return self.descriptor.capabilities

    # -- generate-stage LMs --------------------------------------------------
    def register_lm(self, name: str, cfg, params=None, *, seed: int = 0):
        """Register a decoder LM under ``name`` for the generate stage.

        ``cfg`` is a :class:`repro.models.transformer_lm.LMConfig`;
        ``params`` defaults to a fresh :func:`init_params` draw from
        ``seed``.  The generate stage refers to the model by name only, so
        its IR params stay scalar and content-addressable."""
        from repro.models import transformer_lm as tlm
        if params is None:
            params = tlm.init_params(cfg, jax.random.key(seed))
        self._lms[name] = (cfg, params)
        return self

    def lm(self, name: str):
        """(cfg, params) of a registered LM; KeyError names the gap."""
        try:
            return self._lms[name]
        except KeyError:
            raise KeyError(
                f"no LM registered as {name!r} on this backend "
                f"(have {sorted(self._lms)}); call "
                f"backend.register_lm(name, cfg) first") from None

    @property
    def ivf(self):
        """IVF-flat dense index (``repro.index.dense.IVFDenseIndex``),
        built on first use from the dense embeddings + the backend's
        ``ivf_*`` config."""
        if self._ivf is None:
            from repro.index.dense import build_ivf_index
            self._ivf = build_ivf_index(self.dense, n_lists=self.ivf_lists,
                                        iters=self.ivf_iters,
                                        seed=self.ivf_seed,
                                        keep_flat=self.ivf_keep_flat)
        return self._ivf

    @property
    def ivfpq(self):
        """IVF-PQ compressed dense index
        (``repro.index.dense.IVFPQIndex``), built on first use.  Shares the
        coarse quantiser with ``self.ivf`` when that is already built (or
        external); otherwise builds a ``keep_flat=False`` skeleton so no
        list-ordered float copy is ever materialised."""
        if self._ivfpq is None:
            from repro.index.dense import build_ivfpq_index
            self._ivfpq = build_ivfpq_index(
                self.dense, n_lists=self.ivf_lists, iters=self.ivf_iters,
                seed=self.ivf_seed, m=self.pq_m, pq_iters=self.pq_iters,
                ivf=self._ivf)
        return self._ivfpq

    # -- query-axis execution ----------------------------------------------
    def vmap_queries(self, fn, Q, *extra, key=None):
        """vmap ``fn(terms, weights, *extra_i)`` over queries.  If Q is None,
        ``fn(*extra_i)`` is mapped over the extra arrays.  Routed through the
        sharded bucketed engine when one is attached (the default); ``key``
        (a stage's structural key) names the engine's persistent jit-cache
        entry, scoped by this backend's uid — stage keys do not embed index
        contents, so on an engine shared across backends an unscoped key
        would serve one backend's closure-captured index/embeddings to the
        other.  Falls back to the sequential single-device chunked loop."""
        if self.engine is not None:
            scoped = None if key is None else (self.uid, key)
            return self.engine.run(StageProgram(key=scoped, fn=fn), Q, *extra)
        return self.vmap_queries_sequential(fn, Q, *extra)

    def vmap_queries_sequential(self, fn, Q, *extra):
        """The seed's single-device chunked-vmap loop, kept as the engine's
        baseline (benchmarks) and escape hatch (REPRO_ENGINE=sequential)."""
        args = ((Q["terms"], Q["weights"]) if Q is not None else ()) + extra
        nq = args[0].shape[0]
        if nq == 0:
            # parity with the engine path (chunk_plan raises the same):
            # nothing downstream can infer output shapes from zero queries
            raise ValueError("empty query batch")
        c = min(self.query_chunk, nq)
        vf = jax.vmap(fn)
        outs = []
        for s in range(0, nq, c):
            chunk = tuple(a[s:s + c] for a in args)
            if chunk[0].shape[0] < c:  # pad tail chunk to keep shapes static
                pad = c - chunk[0].shape[0]
                chunk = tuple(jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
                              for a in chunk)
                out = vf(*chunk)
                out = jax.tree.map(lambda x: x[:-pad], out)
            else:
                out = vf(*chunk)
            outs.append(out)
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *outs)

    def embed_queries(self, Q):
        t = jnp.maximum(Q["terms"], 0)
        w = Q["weights"] * (Q["terms"] >= 0)
        vec = jnp.einsum("qld,ql->qd", self._qproj[t], w)
        return vec / jnp.maximum(
            jnp.linalg.norm(vec, axis=-1, keepdims=True), 1e-6)

    def label_results(self, Q, R, qrels: dict[int, dict[int, int]]):
        """Join a result list with qrels -> dense grade matrix [NQ, K]."""
        qids = np.asarray(Q["qid"])
        docids = np.asarray(R["docids"])
        labels = np.zeros(docids.shape, np.float32)
        for i, q in enumerate(qids):
            g = qrels.get(int(q), {})
            if g:
                labels[i] = [g.get(int(d), 0) if d >= 0 else 0 for d in docids[i]]
        return jnp.asarray(labels)


# ---------------------------------------------------------------------------
# combinator semantics (paper Table 2 relational definitions)
# ---------------------------------------------------------------------------

def _aggregate_rows(docs, scores, k_out):
    """Per-query CombSUM: sum scores of duplicate docids, top-k_out."""
    order = jnp.argsort(docs)
    d, s = docs[order], scores[order]
    seg = jnp.cumsum(jnp.concatenate(
        [jnp.zeros(1, jnp.int32), (d[1:] != d[:-1]).astype(jnp.int32)]))
    agg = jax.ops.segment_sum(s, seg, num_segments=d.shape[0])
    first = jnp.concatenate([jnp.ones(1, bool), d[1:] != d[:-1]])
    rep = jnp.where(first & (d >= 0), agg[seg], -jnp.inf)
    top_s, idx = jax.lax.top_k(rep, k_out)
    return jnp.where(jnp.isfinite(top_s), d[idx], -1).astype(jnp.int32), \
        jnp.where(jnp.isfinite(top_s), top_s, -jnp.inf)


@jax.jit
def _combine_linear(all_docs, all_scores, weights):
    """all_docs [NQ, C, K]; weights [C] -> CombSUM over the union."""
    NQ, C, K = all_docs.shape
    w = weights[None, :, None]
    s = jnp.where(all_docs >= 0, all_scores * w, 0.0)
    flat_d = all_docs.reshape(NQ, C * K)
    flat_s = s.reshape(NQ, C * K)
    return jax.vmap(lambda d, sc: _aggregate_rows(d, sc, K))(flat_d, flat_s)


@jax.jit
def _setop_union(d1, s1, d2, s2):
    """Union of two result lists; scores are ⊥ (=0, to be re-ranked)."""
    docs = jnp.concatenate([d1, d2], 1)
    order = jnp.argsort(docs, 1)
    d = jnp.take_along_axis(docs, order, 1)
    first = jnp.concatenate([jnp.ones_like(d[:, :1], bool),
                             d[:, 1:] != d[:, :-1]], 1) & (d >= 0)
    key = jnp.where(first, d, jnp.iinfo(jnp.int32).max)
    order2 = jnp.argsort(key, 1)
    d = jnp.where(jnp.take_along_axis(first, order2, 1),
                  jnp.take_along_axis(d, order2, 1), -1)
    return d, jnp.where(d >= 0, 0.0, -jnp.inf)


@jax.jit
def _setop_intersect(d1, s1, d2, s2):
    member = ((d1[:, :, None] == d2[:, None, :]) & (d1 >= 0)[:, :, None]).any(2)
    key = jnp.where(member, d1, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(key, 1)
    d = jnp.where(jnp.take_along_axis(member, order, 1),
                  jnp.take_along_axis(d1, order, 1), -1)
    return d, jnp.where(d >= 0, 0.0, -jnp.inf)


@jax.jit
def _concat_rankings(d1, s1, d2, s2, eps=1e-3):
    """Paper ^: append R2\\R1 below R1 with shifted scores."""
    dup = ((d2[:, :, None] == d1[:, None, :]) & (d2 >= 0)[:, :, None]).any(2)
    v1 = d1 >= 0
    v2 = (d2 >= 0) & ~dup
    min1 = jnp.min(jnp.where(v1, s1, jnp.inf), 1, keepdims=True)
    max2 = jnp.max(jnp.where(v2, s2, -jnp.inf), 1, keepdims=True)
    min1 = jnp.where(jnp.isfinite(min1), min1, 0.0)
    max2 = jnp.where(jnp.isfinite(max2), max2, 0.0)
    s2n = s2 - max2 + min1 - eps
    docs = jnp.concatenate([jnp.where(v1, d1, -1), jnp.where(v2, d2, -1)], 1)
    scores = jnp.concatenate([jnp.where(v1, s1, -jnp.inf),
                              jnp.where(v2, s2n, -jnp.inf)], 1)
    order = jnp.argsort(-scores, 1)
    return (jnp.take_along_axis(docs, order, 1),
            jnp.take_along_axis(scores, order, 1))


def _feature_columns(R):
    if "features" in R:
        return R["features"]
    return R["scores"][..., None]


@jax.jit
def _align_features(base_docs, child_docs, child_feats):
    """Align child feature rows onto base docids ((qid,docid) join)."""
    eq = (base_docs[:, :, None] == child_docs[:, None, :]) & \
        (base_docs >= 0)[:, :, None]
    aligned = jnp.einsum("qbc,qcf->qbf", eq.astype(child_feats.dtype),
                         child_feats)
    return aligned


# op-kind -> executor for combinator IR ops; each receives the content token
# of its input so sub-pipeline results can be memoised soundly
def _exec_then(op, ctx, Q, R, tok):
    for child in op.inputs:
        Q, R, tok = _execute(child, ctx, Q, R, tok)
    return Q, R


def _exec_linear(op, ctx, Q, R, tok):
    outs = [_execute(c, ctx, Q, R, tok)[1] for c in op.inputs]
    K = max(o["docids"].shape[1] for o in outs)
    pad = lambda o: jnp.pad(o["docids"], ((0, 0), (0, K - o["docids"].shape[1])),
                            constant_values=-1)
    pads = lambda o: jnp.pad(o["scores"], ((0, 0), (0, K - o["scores"].shape[1])),
                             constant_values=-jnp.inf)
    docs = jnp.stack([pad(o) for o in outs], 1)
    scores = jnp.stack([pads(o) for o in outs], 1)
    w = jnp.asarray(op.params["weights"], jnp.float32)
    d, s = _combine_linear(docs, scores, w)
    return Q, {"qid": Q["qid"], "docids": d, "scores": s}


def _exec_scale(op, ctx, Q, R, tok):
    Q, R1, _ = _execute(op.inputs[0], ctx, Q, R, tok)
    a = op.params["alpha"]
    return Q, {**R1, "scores": jnp.where(R1["docids"] >= 0,
                                         R1["scores"] * a, -jnp.inf)}


def _exec_cutoff(op, ctx, Q, R, tok):
    Q, R1, _ = _execute(op.inputs[0], ctx, Q, R, tok)
    k = op.params["k"]
    out = {**R1, "docids": R1["docids"][:, :k], "scores": R1["scores"][:, :k]}
    if "features" in R1:
        out["features"] = R1["features"][:, :k]
    return Q, out


def _exec_setop(op, ctx, Q, R, tok):
    _, R1, _ = _execute(op.inputs[0], ctx, Q, R, tok)
    _, R2, _ = _execute(op.inputs[1], ctx, Q, R, tok)
    fn = _setop_union if op.params["op"] == "union" else _setop_intersect
    d, s = fn(R1["docids"], R1["scores"], R2["docids"], R2["scores"])
    return Q, {"qid": Q["qid"], "docids": d, "scores": s}


def _exec_concat(op, ctx, Q, R, tok):
    _, R1, _ = _execute(op.inputs[0], ctx, Q, R, tok)
    _, R2, _ = _execute(op.inputs[1], ctx, Q, R, tok)
    d, s = _concat_rankings(R1["docids"], R1["scores"],
                            R2["docids"], R2["scores"])
    return Q, {"qid": Q["qid"], "docids": d, "scores": s}


def _exec_feature_union(op, ctx, Q, R, tok):
    outs = [_execute(c, ctx, Q, R, tok)[1] for c in op.inputs]
    base = outs[0]
    cols = [_feature_columns(base)]
    for o in outs[1:]:
        cols.append(_align_features(base["docids"], o["docids"],
                                    _feature_columns(o)))
    feats = jnp.concatenate(cols, -1)
    return Q, {**base, "features": feats}


_COMBINATORS = {
    "then": _exec_then, "linear": _exec_linear, "scale": _exec_scale,
    "cutoff": _exec_cutoff, "setop": _exec_setop, "concat": _exec_concat,
    "feature_union": _exec_feature_union,
}


# ---------------------------------------------------------------------------
# execution engine with content-addressed result caching
# ---------------------------------------------------------------------------

def content_token(tree) -> str:
    """Digest of the actual array contents of a (Q, R)-like pytree.

    This is the *source* token of a pipeline run: unlike ``id()``-keyed
    tokens it cannot alias after garbage collection (CPython recycles object
    ids), so a long-lived shared Context stays sound.
    """
    leaves, treedef = jax.tree.flatten(tree)
    h = hashlib.sha256(repr(treedef).encode())
    for leaf in leaves:
        a = np.asarray(leaf)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def derive_token(node_key, token_in: str) -> str:
    """Token of a node's output: H(producing node key, input token).  Pure
    function of pipeline structure + source content, so identical
    sub-pipelines over the same query set share one token across pipelines,
    Experiments, and grid-search candidates."""
    h = hashlib.sha256(repr(node_key).encode())
    h.update(token_in.encode())
    return h.hexdigest()


@dataclasses.dataclass
class Context:
    """Shared execution state: result memo keyed by (node key, input token),
    plus per-node execution counters (used by the planner's exactly-once
    tests and the benchmark's sharing report)."""
    backend: JaxBackend
    memo: dict = dataclasses.field(default_factory=dict)
    exec_counts: dict = dataclasses.field(default_factory=dict)
    #: strong refs to executed nodes — node keys embed id()s of non-scalar
    #: params (e.g. Generic fns), which stay unique only while alive
    _pins: dict = dataclasses.field(default_factory=dict)
    #: id -> (weakref, digest): avoids re-hashing the same live arrays on
    #: every run (grid search presents the same topics per candidate)
    _leaf_tokens: dict = dataclasses.field(default_factory=dict)

    def pin(self, node: Transformer) -> None:
        self._pins[id(node)] = node

    def _leaf_token(self, leaf) -> str:
        ent = self._leaf_tokens.get(id(leaf))
        if ent is not None and ent[0]() is leaf:
            # identity check makes the id-keyed cache sound: a dead ref can
            # never vouch for a recycled id
            return ent[1]
        a = np.asarray(leaf)
        h = hashlib.sha256(str((a.dtype, a.shape)).encode())
        h.update(a.tobytes())
        tok = h.hexdigest()
        try:
            self._leaf_tokens[id(leaf)] = (weakref.ref(leaf), tok)
        except TypeError:
            pass                      # non-weakrefable leaf: just rehash
        return tok

    def source_token(self, Q, R) -> str:
        leaves, treedef = jax.tree.flatten((Q, R))
        h = hashlib.sha256(repr(treedef).encode())
        for leaf in leaves:
            h.update(self._leaf_token(leaf).encode())
        return h.hexdigest()


def _execute(op, ctx: Context, Q, R, tok: str | None = None):
    """Execute an IR op on (Q, R); returns ``(Q', R', token')`` where
    ``token'`` content-addresses the output.  A ``Transformer`` is accepted
    for compatibility and lowered on the fly (keys are representation-
    independent, so the memo stays shared either way)."""
    if isinstance(op, Transformer):
        op = lower(op)
    if tok is None:
        tok = ctx.source_token(Q, R)
    ctx.pin(op)
    if op.ref is not None:
        ctx.pin(op.ref)
    key = op.key()
    memo_key = (key, tok)
    hit = ctx.memo.get(memo_key)
    if hit is not None:
        return hit
    fn = _COMBINATORS.get(op.kind)
    if fn is not None:
        Q2, R2 = fn(op, ctx, Q, R, tok)
    else:
        ctx.exec_counts[key] = ctx.exec_counts.get(key, 0) + 1
        Q2, R2 = op.ref.execute(ctx, Q, R)
    out = (Q2, R2, derive_token(key, tok))
    ctx.memo[memo_key] = out
    return out


def run_pipeline(node: Transformer | Op, Q, R=None, *, backend: JaxBackend,
                 optimize: bool = True, ctx: Context | None = None):
    from repro.core.passes import compile_pipeline
    # Op inputs go through the same compile path (the passes are idempotent
    # on already-compiled IR): skipping it would silently drop optimisation
    # AND schema validation exactly when the caller hands over raw IR
    op = compile_pipeline(node, backend, optimize=optimize)
    ctx = ctx or Context(backend)
    Q2, R2, _ = _execute(op, ctx, Q, R)
    return R2 if R2 is not None else Q2


def fit_pipeline(root: Transformer, Q_train, qrels_train, Q_valid,
                 qrels_valid, *, backend: JaxBackend):
    """Depth-first fit: run the pipeline; each stateful node receives the
    (Q, R) flowing into it plus qrels (paper eq. 9 semantics)."""
    ctx = Context(backend)

    def walk(node, st, sv):
        # st / sv: (Q, R, token) train / validation streams
        if node.kind == "then":
            for child in node.children:
                st, sv = walk(child, st, sv)
            return st, sv
        # fit children first (they feed this node)
        for child in node.children:
            walk(child, st, sv)
        return _execute_prefit(node, st), \
            (_execute_prefit(node, sv) if sv is not None else None)

    def _execute_prefit(node, state):
        Q, R, tok = state
        if node.stateful:
            # must fit BEFORE executing (execute needs trained state)
            node._fit_local(ctx, Q, R, qrels_train, None, None, qrels_valid)
        return _execute(node, ctx, Q, R, tok)

    sv0 = None
    if Q_valid is not None:
        sv0 = (Q_valid, None, ctx.source_token(Q_valid, None))
    walk(root, (Q_train, None, ctx.source_token(Q_train, None)), sv0)
    return root
