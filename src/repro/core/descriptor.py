"""Backend capability descriptors + persisted tuning profiles.

The rewrite/fusion passes used to string-probe a flat ``frozenset`` on the
backend (``"fused_topk" in be.capabilities``) at match time.  This module
replaces that convention with a :class:`BackendDescriptor` — a frozen
config object carrying everything the compiler needs to know about a
backend *as data*:

* capability flags            (which rewrites/lowerings are legal),
* kernel limits               (the native-k ceilings of the Pallas kernels,
                               so "will this K hit the kernel fast path" is
                               a descriptor lookup, not an import),
* per-host peak constants     (the roofline peaks the HLO cost gate prices
                               with — calibratable from measured bench
                               ratios via ``analysis.hlo_cost.fit_peaks``),
* a tuning-profile handle     (persisted gate decisions keyed by
                               ``(backend digest, op key, bucket)``), and
* autotune policy             (opt-in probe measurement of gate candidates
                               whose estimated margin is within a band).

Passes receive the descriptor at build time (``default_passes(desc)``);
``JaxBackend`` exposes one as ``backend.descriptor`` (the flat
``capabilities=`` ctor kwarg is gone; ``backend.capabilities`` survives
only as a read-only alias of ``descriptor.capabilities``).

:class:`TuningProfile` is the persistence layer: an on-disk JSON store of
fusion-gate decisions, hardened the same way ``plan.ArtifactCache`` is —
pid-suffixed tmp file + atomic replace on write, corrupt/truncated files
degrade to an empty profile instead of taking the compile down.  A profile
hit replays the stored decision with ZERO gate-candidate compiles and ZERO
probe measurements, which is what lets repeated Experiments and server
restarts skip the expensive half of compilation.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from pathlib import Path

#: the full capability set of the JAX backend (paper §4 engines support
#: subsets; ``JaxBackend.CAPABILITIES`` aliases this for compatibility)
DEFAULT_CAPABILITIES = frozenset({
    "pruned_topk", "fat", "multi_model", "fused_topk", "fused_scoring",
    "dense_topk", "fused_dense", "pq_topk",
})


# ---------------------------------------------------------------------------
# tuning profile — persisted fusion-gate decisions
# ---------------------------------------------------------------------------

class TuningProfile:
    """On-disk store of fusion-gate decisions keyed by
    ``(backend digest, op key, bucket)``.

    The key is fully content-derived: the backend digest covers the index
    arrays + execution config (``plan.backend_digest``), the op key names
    the candidate pair the gate compared, and the bucket is the query-term
    width the candidates were priced/probed at.  A profile written on one
    backend therefore can never serve decisions to a different index — the
    digest misses and the gate re-derives.

    ``path=None`` keeps the profile in memory (tests, throwaway tuning).
    """

    VERSION = 1

    def __init__(self, path: str | Path | None = None):
        self.path = None if path is None else Path(path)
        self.entries: dict[str, dict] = {}
        self.calibration: dict | None = None
        self.hits = 0
        self.misses = 0
        self.dirty = False
        self._load()

    # -- persistence --------------------------------------------------------
    def _load(self) -> None:
        if self.path is None or not self.path.exists():
            return
        try:
            doc = json.loads(self.path.read_text())
            if doc.get("version") != self.VERSION:
                raise ValueError(f"profile version {doc.get('version')!r}")
            entries = doc["entries"]
            if not isinstance(entries, dict):
                raise TypeError("entries must be a mapping")
            self.entries = entries
            cal = doc.get("calibration")
            self.calibration = cal if isinstance(cal, dict) else None
        except Exception:
            # corrupt / truncated / foreign / old-version file: a tuning
            # store must degrade to re-tuning, never take the compile down
            self.path.unlink(missing_ok=True)
            self.entries = {}
            self.calibration = None

    def save(self) -> None:
        """Atomic publish (pid-suffixed tmp + replace — the ArtifactCache
        hardening pattern; concurrent writers race benignly)."""
        if self.path is None or not self.dirty:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        doc = {"version": self.VERSION, "entries": self.entries}
        if self.calibration is not None:
            doc["calibration"] = self.calibration
        tmp = self.path.with_name(f"{self.path.name}.{os.getpid()}.tmp")
        tmp.write_text(json.dumps(doc, indent=1))
        tmp.replace(self.path)
        self.dirty = False

    # -- keying -------------------------------------------------------------
    @staticmethod
    def key(backend_digest: str, op_key, bucket: int) -> str:
        return hashlib.sha256(
            f"{backend_digest}:{op_key!r}:{bucket}".encode()).hexdigest()

    # -- access -------------------------------------------------------------
    def lookup(self, backend_digest: str, op_key, bucket: int) -> dict | None:
        ent = self.entries.get(self.key(backend_digest, op_key, bucket))
        if ent is None:
            self.misses += 1
            return None
        self.hits += 1
        return ent["decision"]

    def record(self, backend_digest: str, op_key, bucket: int,
               decision: dict) -> None:
        k = self.key(backend_digest, op_key, bucket)
        ent = {"decision": _jsonable(decision), "bucket": bucket,
               "op": repr(op_key)}
        if self.entries.get(k) != ent:
            self.entries[k] = ent
            self.dirty = True

    # -- roofline auto-refit ------------------------------------------------
    def note_calibration(self, fit: dict | None) -> None:
        """Record the bench trajectory's latest roofline fit
        (``hlo_cost.fit_peaks`` output).  Descriptors attaching this
        profile via ``with_profile`` auto-apply a noted fit that is newer
        than their current ``peak_digest`` — no explicit
        ``descriptor.calibrated(fit)`` call needed."""
        if not isinstance(fit, dict) or \
                "peak_flops_per_s" not in fit or "peak_bytes_per_s" not in fit:
            return
        ent = {"fit": _jsonable(fit), "applied_digest": None}
        if (self.calibration or {}).get("fit") != ent["fit"]:
            self.calibration = ent
            self.dirty = True

    def refresh_from_summary(self, summary: dict) -> None:
        """Pull the ``calibration_fit`` block out of a bench-trajectory
        summary (the autotune section emits it) into this profile."""
        self.note_calibration((summary.get("autotune") or
                               {}).get("calibration_fit"))

    def pending_fit(self, peak_digest: str) -> dict | None:
        """The noted fit, if it has not yet been applied to a descriptor
        with this ``peak_digest`` (i.e. the trajectory is newer than the
        profile's recorded calibration state)."""
        cal = self.calibration
        if not cal or not isinstance(cal.get("fit"), dict):
            return None
        if cal.get("applied_digest") == peak_digest:
            return None
        return cal["fit"]

    def mark_calibrated(self, peak_digest: str) -> None:
        if self.calibration is not None and \
                self.calibration.get("applied_digest") != peak_digest:
            self.calibration["applied_digest"] = peak_digest
            self.dirty = True

    def info(self) -> dict:
        return {"path": None if self.path is None else str(self.path),
                "entries": len(self.entries), "hits": self.hits,
                "misses": self.misses, "dirty": self.dirty,
                "calibrated": bool(self.calibration)}


def _jsonable(d: dict) -> dict:
    """Round-trip a decision dict through JSON semantics now, so what the
    profile serves on a hit is bit-identical to what a reloaded file would
    serve (tuples become lists either way)."""
    return json.loads(json.dumps(d))


# ---------------------------------------------------------------------------
# backend descriptor
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BackendDescriptor:
    """Frozen description of a backend's optimisation surface.

    ``kernel_limits`` maps gate pattern -> max kernel-native k (None =
    no k ceiling for that pattern).  ``peak_flops_per_s`` /
    ``peak_bytes_per_s`` parameterise the HLO roofline proxy; ``host``
    fingerprints where they were calibrated (it scopes the backend's
    estimate cache, so a descriptor deserialised on another host can never
    serve that host's stale estimates).  ``profile`` / ``autotune*`` are
    the measurement-driven layer: see the module docstring.
    """

    capabilities: frozenset = DEFAULT_CAPABILITIES
    kernel_limits: tuple = ()
    peak_flops_per_s: float = 1.0e14
    peak_bytes_per_s: float = 1.0e12
    host: str = ""
    profile: TuningProfile | None = dataclasses.field(
        default=None, compare=False, repr=False)
    autotune: bool = False
    #: probe-measure both candidates when |fused - unfused| / unfused of the
    #: estimated proxies is within this band (the regime where the static
    #: roofline is least trustworthy)
    autotune_band: float = 0.25
    probe_queries: int = 4
    probe_repeats: int = 2
    #: route compile-pass and plan/trie execution spans to the
    #: process-global tracer (``repro.obs.set_tracer``); off, the
    #: instrumentation sites cost one attribute check
    observability: bool = False

    # -- construction -------------------------------------------------------
    @classmethod
    def default(cls, capabilities: frozenset | None = None,
                **overrides) -> "BackendDescriptor":
        """Descriptor for the in-process JAX backend: full (or given)
        capability set, kernel limits read off the kernel packages, nominal
        roofline peaks from ``analysis.hlo_cost``, this host's
        fingerprint."""
        from repro.analysis.hlo_cost import (PEAK_BYTES_PER_S,
                                             PEAK_FLOPS_PER_S,
                                             host_fingerprint)
        from repro.kernels.dense_scoring.ops import MAX_KERNEL_K as DENSE_K
        from repro.kernels.pq_scoring.ops import MAX_KERNEL_K as PQ_K
        from repro.kernels.topk.ops import MAX_KERNEL_K as TOPK_K
        kw = dict(
            capabilities=(DEFAULT_CAPABILITIES if capabilities is None
                          else frozenset(capabilities)),
            kernel_limits=(("topk", TOPK_K), ("fat", None),
                           ("dense_topk", DENSE_K), ("dense_rerank", DENSE_K),
                           ("pq_topk", PQ_K)),
            peak_flops_per_s=PEAK_FLOPS_PER_S,
            peak_bytes_per_s=PEAK_BYTES_PER_S,
            host=host_fingerprint(),
        )
        kw.update(overrides)
        return cls(**kw)

    def with_profile(self, profile: TuningProfile | None, *,
                     auto_refit: bool = True) -> "BackendDescriptor":
        """Attach a tuning profile.  If the profile carries a roofline
        calibration fit newer than this descriptor's ``peak_digest`` (the
        bench trajectory was re-fit since the profile last calibrated a
        descriptor), apply ``calibrated(fit)`` automatically."""
        d = dataclasses.replace(self, profile=profile)
        if auto_refit and profile is not None:
            fit = profile.pending_fit(d.peak_digest)
            if fit is not None:
                d = d.calibrated(fit)
                profile.mark_calibrated(d.peak_digest)
        return d

    def with_autotune(self, enabled: bool = True, *,
                      band: float | None = None,
                      probe_queries: int | None = None,
                      probe_repeats: int | None = None) -> "BackendDescriptor":
        kw: dict = {"autotune": enabled}
        if band is not None:
            kw["autotune_band"] = band
        if probe_queries is not None:
            kw["probe_queries"] = probe_queries
        if probe_repeats is not None:
            kw["probe_repeats"] = probe_repeats
        return dataclasses.replace(self, **kw)

    def with_observability(self, enabled: bool = True) -> "BackendDescriptor":
        """Descriptor whose compiles/plan executions emit spans through the
        process-global tracer (install one with ``repro.obs.set_tracer``)."""
        return dataclasses.replace(self, observability=enabled)

    def calibrated(self, fit: dict) -> "BackendDescriptor":
        """Descriptor with peaks replaced by a ``hlo_cost.fit_peaks``
        result (accepts any mapping with the two peak keys)."""
        return dataclasses.replace(
            self, peak_flops_per_s=float(fit["peak_flops_per_s"]),
            peak_bytes_per_s=float(fit["peak_bytes_per_s"]))

    # -- queries ------------------------------------------------------------
    def supports(self, capability: str) -> bool:
        return capability in self.capabilities

    def native_limit(self, pattern: str) -> int | None:
        for name, lim in self.kernel_limits:
            if name == pattern:
                return lim
        return None

    def kernel_native(self, pattern: str, k: int) -> bool:
        lim = self.native_limit(pattern)
        return lim is None or k <= lim

    @property
    def peak_digest(self) -> str:
        """Digest of (host, peak constants) — the estimate-cache scope: two
        descriptors pricing with different peaks (or calibrated on
        different hosts) must never share cached proxy estimates."""
        return hashlib.sha256(
            f"{self.host}:{self.peak_flops_per_s:.8e}:"
            f"{self.peak_bytes_per_s:.8e}".encode()).hexdigest()[:16]


def as_descriptor(backend) -> BackendDescriptor:
    """The descriptor of ``backend``: its own if it exposes one, else one
    adapted from a legacy flat ``capabilities`` frozenset (duck-typed
    backends in tests), else the full default."""
    desc = getattr(backend, "descriptor", None)
    if isinstance(desc, BackendDescriptor):
        return desc
    caps = getattr(backend, "capabilities", None)
    return BackendDescriptor.default(
        None if caps is None else frozenset(caps))
