"""Declarative IR pipeline framework (the paper's contribution, JAX-native).

    from repro.core import *
    be = JaxBackend(build_index(synthesize_corpus()))
    pipe = Retrieve("BM25") % 10
    res = Experiment([pipe], topics, qrels, ["map"], backend=be)
"""
from repro.core.compiler import Context, JaxBackend, run_pipeline  # noqa: F401
from repro.core.data import make_queries  # noqa: F401
from repro.core.descriptor import (BackendDescriptor,  # noqa: F401
                                   TuningProfile)
from repro.core.engine import (ShardedQueryEngine,  # noqa: F401
                               default_bucket_ladder)
from repro.core.experiment import Experiment, format_table  # noqa: F401
from repro.core.ir import Op, Schema, SchemaError, lower, raise_ir  # noqa: F401
from repro.core.passes import compile_pipeline, explain_pipeline  # noqa: F401
from repro.core.plan import ArtifactCache, ExperimentPlan  # noqa: F401
from repro.core.stages import (DenseRerank, DenseRetrieve,  # noqa: F401
                               Extract, FatRetrieve, FusedDenseRerank,
                               FusedDenseRetrieve, FusedFatRetrieve,
                               FusedTopKRetrieve, Generate, LTRRerank,
                               MultiRetrieve, PrunedRetrieve, Retrieve,
                               RM3Expand, SDMRewrite, StemRewrite)
from repro.core.transformer import Transformer  # noqa: F401
