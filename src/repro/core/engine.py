"""Device-sharded, shape-bucketed query execution engine.

Replaces the backend's ad-hoc sequential chunked-``vmap`` loop with a
serving-grade execution layer (ROADMAP: "heavy traffic from millions of
users"), built from three mechanisms:

* **Data-parallel sharding** — the query axis of every stage is sharded
  across all local devices through a 1-D ``("data",)`` mesh
  (:func:`repro.launch.mesh.make_query_mesh`, the serving counterpart of
  the training meshes).  Per-query stage functions are embarrassingly
  parallel along the batch, so GSPMD partitions them with zero collectives.

* **Bucket ladder** — query batches are padded up to a small fixed ladder
  of chunk sizes and executed through a persistent jit cache keyed by
  ``(stage key, bucket, trailing shapes)``.  Recompilation is therefore
  bounded by ``len(ladder)`` per stage/signature instead of scaling with
  the number of distinct query-set sizes an Experiment presents.

* **Async dispatch** — chunks are enqueued without ever blocking (JAX async
  dispatch overlaps host-side dispatch of chunk ``i+1`` with device compute
  of chunk ``i``, and chunks spread across devices run concurrently).  The
  engine never calls ``block_until_ready`` itself; the planner inserts an
  explicit :meth:`barrier` only at stage boundaries it needs timed
  (``ExperimentPlan.execute(record=...)``), so untimed plan executions
  pipeline across stage *and* pipeline boundaries.

A chunk cache makes stage-to-stage handoff cheap: when stage ``i+1``
consumes an array stage ``i`` produced, the engine reuses the per-chunk
sharded pieces directly instead of re-slicing, re-padding, and re-sharding
the concatenated result.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common import LRU, select_ladder_bucket
from repro.launch.mesh import make_query_mesh
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracing import NOOP_TRACER


def _key_label(key) -> str:
    """Short printable form of a jit-cache key for trace events (full keys
    embed content digests and param tuples — too long for a span arg)."""
    s = str(key)
    return s if len(s) <= 96 else s[:93] + "..."


def default_bucket_ladder(n_devices: int, *, base: int = 8,
                          steps: Sequence[int] = (1, 2, 4)) -> tuple[int, ...]:
    """Geometric bucket ladder, every bucket a multiple of the device count
    (shards must be even).  ``(8, 16, 32)`` on <=8 devices — the largest
    bucket is the steady-state chunk; small query sets pad only up to the
    smallest covering bucket."""
    quantum = max(int(n_devices), base)
    ladder = []
    for s in steps:
        b = s * quantum
        b = -(-b // n_devices) * n_devices      # round up to a device multiple
        if b not in ladder:
            ladder.append(b)
    return tuple(sorted(ladder))


def merge_shard_topk(parts, *, k: int):
    """Cross-shard top-k merge of per-shard ``(docids, scores)`` results
    (each ``[nq, k_s]``, global doc ids, invalid entries ``-1``/``-inf``).

    Host-side with *streaming-merge semantics*: the stable descending sort
    keeps the first-seen entry among score ties, and because shards are
    contiguous ascending doc-id ranges presented in shard order (and
    ``lax.top_k`` inside each shard already breaks ties to the lowest
    local = global id), ties resolve to the lowest global doc id — exactly
    the single-index oracle's rule, making the merge bit-identical to
    ``dense_retrieve_exact`` on the unsharded index."""
    docs = np.concatenate([np.asarray(d) for d, _ in parts], axis=1)
    vals = np.concatenate([np.asarray(v) for _, v in parts], axis=1)
    if docs.shape[1] < k:
        raise ValueError(f"merge width {docs.shape[1]} < k={k}")
    sel = np.argsort(-vals, axis=1, kind="stable")[:, :k]
    rows = np.arange(docs.shape[0])[:, None]
    return docs[rows, sel], vals[rows, sel]


@dataclasses.dataclass(frozen=True)
class StageProgram:
    """The engine's unit of execution: a per-query function plus the key
    that names its persistent jit-cache entry.

    The key must fully determine ``fn``'s behaviour — that is the soundness
    contract the jit cache relies on: two programs presenting the same key
    may share one compiled executable.  A typed-IR content key (``Op.key()``)
    embeds every static param and stateful-stage version marker but NOT the
    backend's array contents (index, embeddings), which ``fn`` closes over —
    so ``JaxBackend.vmap_queries`` scopes the key by a per-backend uid
    before it reaches the engine.  ``key=None`` marks an anonymous program
    that compiles fresh and stays out of the cache.
    """
    key: Any
    fn: Callable


class ShardedQueryEngine:
    """Executes per-query stage functions over the query axis: sharded
    across devices, padded to bucketed shapes, dispatched asynchronously.

    The jit cache requires that a stage function's behaviour is fully
    determined by its ``key`` (plus the backend the engine serves): two
    calls presenting the same key reuse the first call's compiled fn.
    ``Transformer.key()`` provides exactly this for pipeline stages.
    """

    def __init__(self, mesh=None, *, ladder: Sequence[int] | None = None,
                 max_devices: int | None = None,
                 max_jit_entries: int | None = 512,
                 max_chunk_entries: int | None = 64,
                 registry: MetricsRegistry | None = None):
        self.mesh = mesh if mesh is not None else make_query_mesh(
            max_devices=max_devices)
        # on a 2-D (query x doc-shard) mesh only the "data" axis carries
        # the query batch; the "docs" axis groups devices by document shard
        self.n_devices = int(dict(self.mesh.shape).get(
            "data", self.mesh.devices.size))
        self.ladder = (tuple(sorted(int(b) for b in ladder)) if ladder
                       else default_bucket_ladder(self.n_devices))
        if any(b % self.n_devices for b in self.ladder):
            raise ValueError(
                f"bucket ladder {self.ladder} not divisible by device count "
                f"{self.n_devices}")
        self._sharding = NamedSharding(self.mesh, P("data"))
        #: (stage key, bucket, trailing signature) -> jitted vmapped fn.
        #: LRU-bounded: a long-lived server touches unboundedly many stage
        #: keys over its lifetime, and an unbounded dict pins every compiled
        #: executable forever.  The ladder still bounds recompiles per
        #: *resident* stage; an evicted entry recompiles on next use.
        self._jit_cache: LRU = LRU(max_jit_entries)
        #: (stage key, trailing signature) -> number of buckets compiled;
        #: the bucket ladder bounds every entry by len(self.ladder) while
        #: the stage's entries stay resident in the jit cache.  Bounded for
        #: the same stage-key-diversity reason as the jit cache itself;
        #: the lossless total lives in ``n_compiles_total``.
        self.compiles: LRU = LRU(None if max_jit_entries is None
                                 else 4 * max_jit_entries)
        #: id(full array) -> (weakref, chunk plan, [sharded pieces]).
        #: LRU-bounded for the same reason (entries also die eagerly with
        #: their source array via the weakref callback).
        self._chunk_cache: LRU = LRU(max_chunk_entries)
        # counters are registry series (one source of truth for stats());
        # tracer/recorder are attached by the serving layer or the
        # descriptor's observability flag — NOOP/None by default, so the
        # disabled hot path is one attribute check
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._m_dispatches = self.metrics.counter(
            "engine_dispatches_total", "chunk/pinned program dispatches")
        self._m_compiles = self.metrics.counter(
            "engine_compiles_total", "jit compilations by cause", ("cause",))
        for c in ("cold_rung", "ladder_miss", "pinned"):
            self._m_compiles.touch((c,))
        self._m_chunk = self.metrics.counter(
            "engine_chunk_cache_total", "validated chunk-cache lookups",
            ("result",))
        for r in ("hit", "miss"):
            self._m_chunk.touch((r,))
        self.metrics.gauge(
            "engine_jit_cache_entries",
            "resident compiled executables").set_fn(lambda: len(self._jit_cache))
        self.tracer = NOOP_TRACER
        self.recorder = None
        #: bucket -> EWMA of measured batch service seconds, fed back by the
        #: serving layer (``note_service_time``) after each executed
        #: micro-batch; the deadline-aware scheduler prices its
        #: shed-before-execute decisions off these observations
        self._service_ewma: dict[int, float] = {}
        self._service_alpha = 0.2

    # -- observability ------------------------------------------------------
    def attach_observability(self, tracer=None, recorder=None) -> None:
        """Point the engine's compile/dispatch events at a tracer and/or
        flight recorder (the serving layer calls this when its config opts
        in; several servers sharing one engine share the last attachment)."""
        if tracer is not None:
            self.tracer = tracer
        if recorder is not None:
            self.recorder = recorder

    @property
    def n_compiles_total(self) -> int:
        return int(sum(self._m_compiles.series().values()))

    @property
    def n_dispatches(self) -> int:
        return int(self._m_dispatches.value())

    @property
    def n_chunk_cache_hits(self) -> int:
        return int(self._m_chunk.value(("hit",)))

    @property
    def n_chunk_cache_misses(self) -> int:
        return int(self._m_chunk.value(("miss",)))

    def _note_compile(self, cause: str, key, bucket) -> None:
        """Count one jit compilation and emit its attributed-cause event:
        ``cold_rung`` (first rung for a never-seen stage/signature),
        ``ladder_miss`` (additional rung for a known stage, or a re-compile
        after LRU eviction), ``pinned`` (fixed-shape decode program)."""
        self._m_compiles.inc(1, (cause,))
        self.tracer.event("engine.jit_compile", "engine", cause=cause,
                          bucket=bucket, key=_key_label(key))
        if self.recorder is not None:
            self.recorder.record("recompile", cause=cause, bucket=bucket,
                                 key=_key_label(key))

    # -- chunk planning -----------------------------------------------------
    def chunk_plan(self, nq: int) -> tuple[tuple[int, int, int], ...]:
        """Split ``nq`` queries into ``(start, n, bucket)`` chunks: full
        chunks of the largest bucket plus one tail padded to the smallest
        covering ladder bucket."""
        if nq <= 0:
            raise ValueError("empty query batch")
        mx = self.ladder[-1]
        plan, s = [], 0
        while nq - s > mx:
            plan.append((s, mx, mx))
            s += mx
        rem = nq - s
        plan.append((s, rem, self.select_bucket(rem)))
        return tuple(plan)

    # -- chunk extraction / caching ----------------------------------------
    def _remember(self, full, plan, pieces) -> None:
        # only cache pieces already laid out the way stage inputs are
        # (P("data")): a differently-sharded piece would silently recompile
        # the consumer jit and break the ladder's recompile bound.  A piece
        # that IS the full array (single exact-fit chunk) would make the
        # entry self-referential and immortal — nothing to cache there.
        if any(p is full for p in pieces):
            return
        if not all(getattr(p, "sharding", None) == self._sharding
                   for p in pieces):
            return
        key = id(full)
        try:
            # death callback evicts the entry, so the strong refs to the
            # sharded pieces never outlive the array they were cut from
            ref = weakref.ref(
                full, lambda _, k=key: self._chunk_cache.pop(k, None))
        except TypeError:
            return                                # non-weakrefable leaf
        self._chunk_cache.put(key, (ref, plan, pieces))

    def _pieces(self, arr, plan):
        """Per-chunk sharded pieces of ``arr``, padded to their buckets.
        Arrays the engine itself produced hit the chunk cache and skip the
        slice/pad/device_put entirely."""
        ent = self._chunk_cache.get(id(arr))
        if ent is not None and ent[0]() is arr and ent[1] == plan:
            self._m_chunk.inc(1, ("hit",))
            return ent[2]
        self._m_chunk.inc(1, ("miss",))
        pad_mod = np if isinstance(arr, np.ndarray) else jnp
        pieces = []
        for start, n, bucket in plan:
            piece = arr[start:start + n]
            if n < bucket:
                piece = pad_mod.pad(
                    piece, ((0, bucket - n),) + ((0, 0),) * (piece.ndim - 1))
            pieces.append(jax.device_put(piece, self._sharding))
        self._remember(arr, plan, pieces)
        return pieces

    # -- the jit cache ------------------------------------------------------
    def _jitted(self, key, fn, bucket: int, sig) -> Callable:
        jk = (key, bucket, sig)
        vf = self._jit_cache.get(jk)
        if vf is None:
            vf = jax.jit(jax.vmap(fn))
            self._jit_cache.put(jk, vf)
            ck = (key, sig)
            prior = self.compiles.get(ck, 0) or 0
            self.compiles.put(ck, prior + 1)
            self._note_compile("cold_rung" if prior == 0 else "ladder_miss",
                              key, bucket)
        return vf

    def max_compiles_per_stage(self) -> int:
        return max(self.compiles.values(), default=0)

    def total_compiles(self) -> int:
        """Total jit compilations across all stages/buckets, monotone even
        when per-stage counter entries age out — the serving layer
        snapshots this at warm-up to assert zero steady-state
        recompilation."""
        return self.n_compiles_total

    # -- execution ----------------------------------------------------------
    @staticmethod
    def _args_of(Q, extra) -> tuple:
        return ((Q["terms"], Q["weights"]) if Q is not None else ()) + extra

    def select_bucket(self, n: int) -> int:
        """Smallest ladder bucket covering an ``n``-query micro-batch — the
        serving scheduler's batch-closure rule (a batch at the largest
        bucket is 'full'; anything smaller pads up to its covering rung).
        One shared implementation (:func:`repro.common.select_ladder_bucket`)
        backs both this and the scheduler's copy, so the ladder policy
        cannot drift between them."""
        return select_ladder_bucket(self.ladder, n)

    # -- service-time feedback ----------------------------------------------
    def note_service_time(self, bucket: int, seconds: float) -> None:
        """Record one measured micro-batch service time for ``bucket``
        (EWMA).  Fed by the serving layer after each executed batch; the
        scheduler's shedding math and the bench's capacity accounting read
        the estimates back via :meth:`service_time_estimate`."""
        prev = self._service_ewma.get(bucket)
        a = self._service_alpha
        self._service_ewma[bucket] = (seconds if prev is None
                                      else (1.0 - a) * prev + a * seconds)

    def service_time_estimate(self, bucket: int | None = None) -> float | None:
        """EWMA service seconds for ``bucket`` (falling back to the nearest
        observed rung), or the worst observed rung when ``bucket`` is None.
        None until the first observation."""
        if not self._service_ewma:
            return None
        if bucket is None:
            return max(self._service_ewma.values())
        if bucket in self._service_ewma:
            return self._service_ewma[bucket]
        near = min(self._service_ewma,
                   key=lambda b: (abs(b - bucket), b))
        return self._service_ewma[near]

    def run(self, program: StageProgram, Q, *extra):
        """Execute one IR stage program over the query axis: vmap
        ``program.fn(terms, weights, *extra_i)`` (or ``fn(*extra_i)`` when Q
        is None) sharded/bucketed/async, with ``program.key`` naming the
        persistent jit-cache entry.  Returns full (concatenated, trimmed)
        arrays; dispatch is fully asynchronous.  Any batch that fits the
        largest bucket — every serving micro-batch — IS a
        :meth:`submit_chunk` call; bigger batches chunk-plan and loop the
        same single-dispatch primitive."""
        args = self._args_of(Q, extra)
        nq = int(args[0].shape[0])
        if 0 < nq <= self.ladder[-1]:
            return self.submit_chunk(program, Q, *extra)
        return self._run_plan(program, args, self.chunk_plan(nq))

    def submit_chunk(self, program: StageProgram, Q, *extra,
                     bucket: int | None = None):
        """Serving entry point: dispatch ONE micro-batch (``n`` <= largest
        bucket) as a single padded chunk, asynchronously — no whole-batch
        chunk planning.  ``bucket`` pins the ladder rung (defaults to
        :meth:`select_bucket`); returns trimmed full arrays like
        :meth:`run`."""
        args = self._args_of(Q, extra)
        nq = int(args[0].shape[0])
        if bucket is None:
            bucket = self.select_bucket(nq)
        elif bucket not in self.ladder or nq > bucket:
            raise ValueError(f"bucket {bucket} not a ladder rung covering "
                             f"{nq} queries (ladder {self.ladder})")
        return self._run_plan(program, args, ((0, nq, bucket),))

    def run_pinned(self, program: StageProgram, *args,
                   donate_argnums: tuple = ()):
        """Execute a *pinned-shape* program (the prefill / decode-step
        bodies of the generate stage) through the persistent jit cache: no
        vmap, no bucket padding — the caller guarantees every array shape
        is drawn from a finite, warmed set (decode batch = a ladder rung,
        prompt/decode lengths fixed by the stage's static params).  The
        entry is keyed ``(key, "pinned", leaf shapes)`` in the same LRU and
        counted by the same compile counters as the bucketed entries, so
        the recompiles-since-warmup invariant sees pinned programs exactly
        like vmapped ones.  ``donate_argnums`` lets a decode step donate
        its KV-cache buffers (the cache is threaded, never reread)."""
        leaves = jax.tree.leaves(args)
        sig = tuple((tuple(getattr(x, "shape", ())),
                     str(getattr(x, "dtype", type(x).__name__)))
                    for x in leaves)
        self._m_dispatches.inc()
        if program.key is None:
            return jax.jit(program.fn, donate_argnums=donate_argnums)(*args)
        jk = (program.key, "pinned", sig)
        vf = self._jit_cache.get(jk)
        if vf is None:
            vf = jax.jit(program.fn, donate_argnums=donate_argnums)
            self._jit_cache.put(jk, vf)
            ck = (program.key, "pinned")
            self.compiles.put(ck, (self.compiles.get(ck, 0) or 0) + 1)
            self._note_compile("pinned", program.key, None)
        return vf(*args)

    def _run_plan(self, program: StageProgram, args, plan):
        key, fn = program.key, program.fn
        sig = tuple((tuple(a.shape[1:]), str(a.dtype)) for a in args)
        pieces = [self._pieces(a, plan) for a in args]
        anon_vf = jax.jit(jax.vmap(fn)) if key is None else None
        outs = []
        for i, (start, n, bucket) in enumerate(plan):
            # keyless calls compile fresh and stay out of the persistent
            # cache (an id()-keyed entry could never be reused anyway)
            vf = anon_vf if key is None else self._jitted(key, fn, bucket, sig)
            # span covers host-side dispatch only — JAX dispatch is async,
            # so device compute completes after the span closes
            with self.tracer.span("engine.dispatch", "engine", bucket=bucket,
                                  n=n, key=_key_label(key)):
                outs.append(vf(*[p[i] for p in pieces]))
            self._m_dispatches.inc()
        full = self._materialize(outs, plan)
        self._remember_outputs(full, outs, plan)
        return full

    def map_queries(self, fn, Q, *extra, key=None):
        """Compatibility wrapper over :meth:`run`."""
        return self.run(StageProgram(key=key, fn=fn), Q, *extra)

    def run_doc_sharded(self, programs: Sequence[StageProgram], Q, *extra,
                        k: int):
        """Doc-axis sharded top-k: run one StageProgram per document shard
        (each closing over its contiguous shard and emitting *global* doc
        ids, e.g. built over ``index.dense.shard_dense_index``), then merge
        the per-shard ``(docids, scores)`` across shards on the host with
        :func:`merge_shard_topk`.  Per-shard dispatch stays fully async;
        the merge is the one synchronisation point."""
        parts = [self.run(p, Q, *extra) for p in programs]
        self.barrier(parts)
        return merge_shard_topk(parts, k=k)

    def _materialize(self, outs, plan):
        _, n_tail, b_tail = plan[-1]
        if len(outs) == 1:
            if n_tail == b_tail:
                return outs[0]
            return jax.tree.map(lambda x: x[:n_tail], outs[0])

        def cat(*xs):
            xs = list(xs)
            if n_tail != b_tail:
                xs[-1] = xs[-1][:n_tail]
            return jnp.concatenate(xs, 0)

        return jax.tree.map(cat, *outs)

    def _remember_outputs(self, full, outs, plan) -> None:
        """Seed the chunk cache so the next stage consuming ``full`` reuses
        the already-sharded chunk outputs instead of re-slicing."""
        flat_full, _ = jax.tree.flatten(full)
        flat_outs = [jax.tree.flatten(o)[0] for o in outs]
        for li, leaf in enumerate(flat_full):
            self._remember(leaf, plan, [fo[li] for fo in flat_outs])

    # -- barriers / reporting ----------------------------------------------
    def barrier(self, tree):
        """Block until every array in ``tree`` is computed.  The engine
        itself never blocks — this is for the planner's timed stage
        boundaries and for benchmark harnesses."""
        jax.block_until_ready(tree)
        return tree

    def cache_info(self) -> dict:
        """Sizes/bounds/hit counters of the engine's two bounded caches —
        surfaced by ``PipelineServer.stats()`` so a long-lived server's
        memory profile is observable.  ``chunk`` hit/miss counts are the
        engine's *validated* counters (an LRU entry whose weakref died or
        whose chunk plan changed counts as a miss)."""
        jit = self._jit_cache.info()
        chunk = self._chunk_cache.info()
        chunk["hits"] = self.n_chunk_cache_hits
        chunk["misses"] = self.n_chunk_cache_misses
        return {"jit": jit, "chunk": chunk}

    def stats(self) -> dict:
        return {
            "devices": self.n_devices,
            "ladder": list(self.ladder),
            "dispatches": self.n_dispatches,
            "compiled_variants": self.n_compiles_total,
            "max_compiles_per_stage": self.max_compiles_per_stage(),
            "chunk_cache_hits": self.n_chunk_cache_hits,
            "chunk_cache_misses": self.n_chunk_cache_misses,
            "cache_info": self.cache_info(),
            "service_ms_ewma": {b: round(1000.0 * s, 3)
                                for b, s in sorted(self._service_ewma.items())},
        }
