"""Transformers + operators: the declarative pipeline algebra (paper §3).

A :class:`Transformer` is a *declarative node*: composing transformers with
the eight overloaded operators (Table 2) builds an expression DAG — nothing
executes until ``transform()`` / ``Experiment`` triggers compilation.  The
DAG is normalised on construction (associative ops flattened to variadic
nodes) so the rewriter's pattern matching is canonical.

    pipe = (Retrieve(bm25) % 10) >> (Extract("QL") ** Extract("TF_IDF")) >> ltr
    R = pipe(Q, backend=backend)          # compile (+optimise) then execute

Operator -> node mapping:
    >> Then     + Linear     * Scale      ** FeatureUnion
    |  Union    & Intersect  % Cutoff     ^ Concat
"""
from __future__ import annotations

import itertools
from typing import Any, Callable, Sequence

_UID = itertools.count()


def canon_param_items(params: dict) -> tuple:
    """Canonical hashable view of a params dict — the single definition the
    Transformer algebra and the typed IR (core/ir.py) both key on, so a
    lowered op and the node it was lowered from always agree."""
    items = []
    for k, v in sorted(params.items()):
        if isinstance(v, (list, tuple)):
            v = tuple(v)
        elif not isinstance(v, (int, float, str, bool, type(None))):
            v = ("obj", id(v))
        items.append((k, v))
    return tuple(items)


class Transformer:
    kind: str = "abstract"
    #: stateful nodes (learned rerankers) include a version in their key
    stateful: bool = False
    #: primary output stream: "R" for result-producing stages, "Q" for
    #: query-rewrite (Q -> Q) stages.  Rank-cutoff rewrites consult this —
    #: a % K must only ever attach to an R-producing expression.
    out_kind: str = "R"
    #: whether execute() reads the incoming result list R.  A cutoff may
    #: hop over a Q -> Q stage only if that stage never looks at R.
    reads_results: bool = True

    def __init__(self, children: Sequence["Transformer"] = (), **params):
        self.children = tuple(children)
        self.params = dict(params)
        self.uid = next(_UID)
        self.version = 0

    # -- structural identity (for rewriting + plan/result caching) ---------
    def key(self) -> tuple:
        state = (self.uid, self.version) if self.stateful else ()
        return (self.kind, canon_param_items(self.params), state,
                tuple(c.key() for c in self.children))

    def __repr__(self):
        inner = ", ".join([repr(c) for c in self.children] +
                          [f"{k}={v!r}" for k, v in self.params.items()
                           if not hasattr(v, "shape") and k != "index"])
        return f"{type(self).__name__}({inner})"

    # -- execution ----------------------------------------------------------
    def transform(self, Q, R=None, *, backend=None, optimize: bool = True,
                  ctx=None):
        from repro.core.compiler import run_pipeline
        return run_pipeline(self, Q, R, backend=backend, optimize=optimize,
                            ctx=ctx)

    def __call__(self, Q, R=None, **kw):
        return self.transform(Q, R, **kw)

    def explain(self, backend=None, *, optimize: bool = True) -> str:
        """Render the typed IR of this pipeline before/after each compiler
        pass (schema annotations included once a backend is given)."""
        from repro.core.passes import explain_pipeline
        return explain_pipeline(self, backend, optimize=optimize)

    def execute(self, ctx, Q, R):  # overridden by concrete nodes
        raise NotImplementedError(self.kind)

    # -- training protocol (paper eq. 9) -------------------------------------
    def fit(self, Q_train, qrels_train, Q_valid=None, qrels_valid=None, *,
            backend=None):
        """Depth-first: fit every stateful stage, feeding it the output of
        its upstream prefix (other transformers applied as needed)."""
        from repro.core.compiler import fit_pipeline
        fit_pipeline(self, Q_train, qrels_train, Q_valid, qrels_valid,
                     backend=backend)
        return self

    def _fit_local(self, ctx, Q, R, qrels, Q_valid, R_valid, qrels_valid):
        pass  # stateless by default

    # -- operators ------------------------------------------------------------
    def __rshift__(self, other):
        return Then.of(self, _coerce(other))

    def __add__(self, other):
        return Linear.of((1.0, self), (1.0, _coerce(other)))

    def __radd__(self, other):
        if other == 0:   # support sum()
            return self
        return _coerce(other) + self

    def __mul__(self, alpha):
        return Scale.of(float(alpha), self)

    __rmul__ = __mul__

    def __pow__(self, other):
        return FeatureUnion.of(self, _coerce(other))

    def __or__(self, other):
        return SetOp(children=[self, _coerce(other)], op="union")

    def __and__(self, other):
        return SetOp(children=[self, _coerce(other)], op="intersect")

    def __mod__(self, k: int):
        return Cutoff(children=[self], k=int(k))

    def __xor__(self, other):
        return Concat(children=[self, _coerce(other)])


def _coerce(x) -> "Transformer":
    if isinstance(x, Transformer):
        return x
    if callable(x):
        return Generic(fn=x)
    raise TypeError(f"cannot use {x!r} as a transformer")


# ---------------------------------------------------------------------------
# combinator nodes (flattening constructors give canonical variadic forms)
# ---------------------------------------------------------------------------

class Then(Transformer):
    """Composition (>>): feed output of stage i to stage i+1."""
    kind = "then"

    @staticmethod
    def of(*stages: Transformer) -> "Then":
        flat: list[Transformer] = []
        for s in stages:
            flat.extend(s.children if isinstance(s, Then) else [s])
        return Then(children=flat)


class Linear(Transformer):
    """Weighted linear combination (+ / *): CombSUM over the union of the
    children's documents (missing scores contribute 0)."""
    kind = "linear"

    @staticmethod
    def of(*weighted: tuple[float, Transformer]) -> "Linear":
        ws, cs = [], []
        for w, t in weighted:
            if isinstance(t, Linear):
                for wi, ci in zip(t.params["weights"], t.children):
                    ws.append(w * wi)
                    cs.append(ci)
            elif isinstance(t, Scale):
                ws.append(w * t.params["alpha"])
                cs.append(t.children[0])
            else:
                ws.append(w)
                cs.append(t)
        return Linear(children=cs, weights=tuple(ws))


class Scale(Transformer):
    kind = "scale"

    @staticmethod
    def of(alpha: float, t: Transformer) -> Transformer:
        if isinstance(t, Scale):
            return Scale.of(alpha * t.params["alpha"], t.children[0])
        if isinstance(t, Linear):
            return Linear.of(*[(alpha * w, c) for w, c in
                               zip(t.params["weights"], t.children)])
        return Scale(children=[t], alpha=float(alpha))


class FeatureUnion(Transformer):
    """** : combine children's scores as feature columns (paper: R1 ⋈ R2
    with [f1, f2] -> f), aligned on the first child's candidate set."""
    kind = "feature_union"

    @staticmethod
    def of(*ts: Transformer) -> "FeatureUnion":
        flat: list[Transformer] = []
        for t in ts:
            flat.extend(t.children if isinstance(t, FeatureUnion) else [t])
        return FeatureUnion(children=flat)


class SetOp(Transformer):
    kind = "setop"


class Cutoff(Transformer):
    kind = "cutoff"


class Concat(Transformer):
    kind = "concat"


class Generic(Transformer):
    """Any callable (Q, R) -> (Q, R) as a transformer — paper §3.2 last ¶."""
    kind = "generic"

    def execute(self, ctx, Q, R):
        return self.params["fn"](Q, R)
