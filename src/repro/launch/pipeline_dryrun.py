import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run of the PAPER'S OWN workload: the IR pipeline stages
# themselves (multi-model postings scoring + top-k) lowered onto the
# production mesh — queries sharded over 'data' (+ 'pod'), the inverted
# file's postings sharded over 'model'.  This is the §6 "automatic
# parallelisation" future-work of the paper, compiled for 512 chips.
#
#   PYTHONPATH=src python -m repro.launch.pipeline_dryrun [--multi-pod]

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import hlo_cost
from repro.index import scoring
from repro.launch import mesh as mesh_lib

# ClueWeb09-scale descriptors (never materialised: ShapeDtypeStructs only)
N_DOCS = 50_220_423
MAXQ = 32
MAX_POSTINGS = 4_194_304      # longest non-stop posting list (padded)
N_QUERIES = 512
K = 1000
MODELS = ("BM25", "QL", "TF_IDF")
STATS = {"n_docs": float(N_DOCS), "avg_doclen": 800.0, "total_terms": 4.0e10}


def make_fat_pipeline_step(mesh, dp):
    def fat_pipeline_step(doc_ids, tfs, mask, dl, df, cf, weights):
        """One fused fat-retrieval step for a batch of queries.

        doc_ids/tfs/mask: [NQ, MAXQ, P] gathered postings (P sharded over
        'model').  The dense accumulator is doc-sharded over 'model' too —
        scores scatter locally per index shard, and only the per-query
        top-K (exact, via sharded max-reduction) crosses chips.  This is
        the compiled form of ``Retrieve(BM25) >> (Extract ** Extract)``
        after the fat rewrite, distributed per paper-§6 future work.
        """
        all_s = scoring.score_all(list(MODELS), tfs, dl,
                                  df[..., None], cf[..., None], STATS)
        all_s = all_s * (weights[..., None] * mask)[..., None]
        NQ = doc_ids.shape[0]
        flat_docs = doc_ids.reshape(NQ, -1)
        flat_s = all_s.reshape(NQ, -1, len(MODELS))
        dense = jnp.zeros((NQ, N_DOCS, len(MODELS)), jnp.float32)
        dense = jax.lax.with_sharding_constraint(
            dense, NamedSharding(mesh, P(dp, "model", None)))
        dense = jax.vmap(lambda d, s, i: d.at[i].add(s))(dense, flat_s,
                                                         flat_docs)
        top_s, top_d = jax.lax.top_k(dense[..., 0], K)
        feats = jnp.take_along_axis(dense[..., 1:], top_d[..., None], axis=1)
        return top_d.astype(jnp.int32), top_s, feats
    return fat_pipeline_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    SDS = jax.ShapeDtypeStruct
    shp3 = (N_QUERIES, MAXQ, MAX_POSTINGS)
    shp2 = (N_QUERIES, MAXQ)
    argspec = [
        (SDS(shp3, jnp.int32), P(dp, None, "model")),   # doc_ids
        (SDS(shp3, jnp.int32), P(dp, None, "model")),   # tfs
        (SDS(shp3, jnp.bool_), P(dp, None, "model")),   # mask
        (SDS(shp3, jnp.int32), P(dp, None, "model")),   # dl (per posting)
        (SDS(shp2, jnp.int32), P(dp, None)),            # df
        (SDS(shp2, jnp.int32), P(dp, None)),            # cf
        (SDS(shp2, jnp.float32), P(dp, None)),          # weights
    ]
    in_sh = tuple(NamedSharding(mesh, s) for _, s in argspec)
    out_sh = (NamedSharding(mesh, P(dp, None)),) * 2 + \
        (NamedSharding(mesh, P(dp, None, None)),)

    with mesh:
        lowered = jax.jit(make_fat_pipeline_step(mesh, dp),
                          in_shardings=in_sh,
                          out_shardings=out_sh).lower(
            *[a for a, _ in argspec])
        compiled = lowered.compile()
    walk = hlo_cost.analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    rec = {
        "workload": "fat_pipeline_step (ClueWeb09-scale descriptors)",
        "mesh": "2x16x16" if args.multi_pod else "16x16",
        "flops_per_chip": walk["flops_per_chip"],
        "bytes_per_chip": walk["bytes_per_chip"],
        "collective_bytes_per_chip": walk["collective_bytes_per_chip"],
        "collectives": walk["collectives"],
        "temp_bytes": int(mem.temp_size_in_bytes),
        "t_compute": walk["flops_per_chip"] / mesh_lib.PEAK_FLOPS_BF16,
        "t_memory": walk["bytes_per_chip"] / mesh_lib.HBM_BW,
        "t_collective": walk["collective_bytes_per_chip"] / mesh_lib.ICI_BW,
    }
    tag = "ir_pipeline__" + rec["mesh"]
    Path(args.out).mkdir(parents=True, exist_ok=True)
    (Path(args.out) / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
