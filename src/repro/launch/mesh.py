"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run overrides the host
platform device count while tests/benches must see one device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over the real local device (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_query_mesh(*, max_devices: int | None = None,
                    doc_shards: int | None = None):
    """``("data",)`` mesh over the local devices — the serving-side
    counterpart of the training meshes above, used by the sharded query
    execution engine (core/engine.py) to data-parallel the query axis.
    ``max_devices`` restricts the mesh (device-scaling benchmarks).

    ``doc_shards`` selects the 2-D ``(query x doc-shard)`` layout
    ``("data", "docs")``: the query axis data-parallels over the first
    axis while each ``docs`` group owns one contiguous slice of the
    document axis (``index.dense.shard_dense_index``), merged across
    shards by ``core.engine.merge_shard_topk``.  The device count must be
    divisible by ``doc_shards``."""
    devices = jax.local_devices()
    if max_devices is not None:
        devices = devices[:max(1, min(max_devices, len(devices)))]
    if doc_shards is None:
        return jax.make_mesh((len(devices),), ("data",), devices=devices)
    doc_shards = int(doc_shards)
    if doc_shards < 1 or len(devices) % doc_shards:
        raise ValueError(
            f"doc_shards={doc_shards} must divide the device count "
            f"{len(devices)}")
    return jax.make_mesh((len(devices) // doc_shards, doc_shards),
                         ("data", "docs"), devices=devices)


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12   # FLOP/s
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link
