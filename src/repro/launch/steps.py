"""Step bundles: (arch × shape × mesh) -> jittable fn + abstract inputs +
shardings.  This is the single bridge used by the dry-run, the roofline
benchmarks and the real train/serve launchers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as sh
from repro.common import round_up
from repro.configs.registry import ArchDef, get_arch
from repro.models import gnn as gnn_lib
from repro.models import transformer_lm as tlm
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class StepBundle:
    name: str
    fn: Callable
    args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple[int, ...] = ()
    model_flops_per_step: float = 0.0   # 6·N·D-style useful-FLOPs estimate


def _repl(mesh):
    return NamedSharding(mesh, P())


def _abstract_state(init_fn, logical, mesh, profile):
    aparams = jax.eval_shape(init_fn)
    pspecs = sh.spec_tree(aparams, logical, mesh, profile)
    aopt = jax.eval_shape(opt_lib.init, aparams)
    ospec = {"m": sh.zero1_sharding_tree(aparams, pspecs, mesh),
             "v": sh.zero1_sharding_tree(aparams, pspecs, mesh),
             "step": _repl(mesh)}
    astate = {"params": aparams, "opt": aopt}
    sstate = {"params": pspecs, "opt": ospec}
    return astate, sstate


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def _lm_model_flops(cfg: tlm.LMConfig, tokens: int, kind: str) -> float:
    n = cfg.params_active
    return (6.0 if kind == "train" else 2.0) * n * tokens


def _lm_train(arch: ArchDef, cell, mesh, opt_cfg) -> StepBundle:
    cfg = arch.model_cfg("train_4k")
    profile = sh.PROFILES[cfg.sharding_profile](mesh)
    astate, sstate = _abstract_state(
        lambda: tlm.init_params(cfg, jax.random.key(0)),
        tlm.param_logical(cfg), mesh, profile)
    B, S = cell["batch"], cell["seq"]
    abatch = {"tokens": SDS((B, S), jnp.int32), "targets": SDS((B, S), jnp.int32)}
    sbatch = {k: sh.named_sharding(mesh, (sh.BATCH, None), (B, S), profile)
              for k in abatch}
    loss = functools.partial(tlm.loss_fn, cfg, mesh=mesh)
    fn = ts.make_train_step(loss, opt_cfg, n_micro=arch.train_microbatches)
    return StepBundle(
        name="train_step", fn=fn, args=(astate, abatch),
        in_shardings=(sstate, sbatch), out_shardings=(sstate, _repl(mesh)),
        donate_argnums=(0,),
        model_flops_per_step=_lm_model_flops(cfg, B * S, "train"))


def _lm_serve(arch: ArchDef, shape_name: str, cell, mesh) -> StepBundle:
    cfg = arch.model_cfg(shape_name)
    profile = sh.PROFILES[cfg.sharding_profile](mesh)
    aparams = jax.eval_shape(lambda: tlm.init_params(cfg, jax.random.key(0)))
    pspecs = sh.spec_tree(aparams, tlm.param_logical(cfg), mesh, profile)

    if cell["kind"] == "prefill":
        B, S = cell["batch"], cell["seq"]
        T = S
        tok_sds = SDS((B, S), jnp.int32)
        new_tokens = B * S
    else:
        B, T = cell["batch"], cell["kv_len"]
        tok_sds = SDS((B, 1), jnp.int32)
        new_tokens = B
    acache = {"k": SDS((cfg.n_layers, B, T, cfg.n_kv, cfg.d_head), cfg.dtype),
              "v": SDS((cfg.n_layers, B, T, cfg.n_kv, cfg.d_head), cfg.dtype)}
    scache = sh.spec_tree(acache, tlm.kv_cache_logical(), mesh, profile)
    logits_sh = sh.named_sharding(mesh, (sh.BATCH, sh.VOCAB),
                                  (B, cfg.vocab), profile)
    tok_sh = sh.named_sharding(mesh, (sh.BATCH, None), tok_sds.shape, profile)

    if cell["kind"] == "prefill":
        def serve_step(params, tokens, cache):
            return tlm.prefill(cfg, params, tokens, cache, mesh=mesh)
        args = (aparams, tok_sds, acache)
        in_sh = (pspecs, tok_sh, scache)
        donate = (2,)
    else:
        def serve_step(params, tokens, cache, pos):
            return tlm.decode_step(cfg, params, tokens, cache, pos, mesh=mesh)
        args = (aparams, tok_sds, acache, SDS((), jnp.int32))
        in_sh = (pspecs, tok_sh, scache, _repl(mesh))
        donate = (2,)
    return StepBundle(
        name="serve_step", fn=serve_step, args=args, in_shardings=in_sh,
        out_shardings=(logits_sh, scache), donate_argnums=donate,
        model_flops_per_step=_lm_model_flops(cfg, new_tokens, "serve"))


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

def _pad_graph(batch: dict[str, jax.Array], multiple: int) -> dict[str, jax.Array]:
    """Pad nodes/edges (inside jit) to shardable multiples; padded edges
    self-loop on a dummy node, padded labels are masked out."""
    x, src, dst = batch["x"], batch["src"], batch["dst"]
    N, E = x.shape[0], src.shape[0]
    Np = round_up(N + 1, multiple)
    Ep = round_up(E, multiple)
    out = dict(batch)
    out["x"] = jnp.pad(x, ((0, Np - N), (0, 0)))
    dummy = jnp.int32(Np - 1)
    out["src"] = jnp.pad(src, (0, Ep - E), constant_values=dummy)
    out["dst"] = jnp.pad(dst, (0, Ep - E), constant_values=dummy)
    mask = batch.get("label_mask", jnp.ones((N,), bool))
    if "graph_ids" in batch:   # graph-level labels: pad a dummy graph
        G = batch["node_counts"].shape[0]
        out["graph_ids"] = jnp.pad(batch["graph_ids"], (0, Np - N),
                                   constant_values=G)
        out["node_counts"] = jnp.pad(batch["node_counts"], (0, 1),
                                     constant_values=1)
        out["labels"] = jnp.pad(batch["labels"], (0, 1))
        out["label_mask"] = jnp.pad(
            batch.get("label_mask", jnp.ones((G,), bool)), (0, 1))
    else:
        out["labels"] = jnp.pad(batch["labels"], (0, Np - N))
        out["label_mask"] = jnp.pad(mask, (0, Np - N))
    return out


def _gnn_flops(cfg: gnn_lib.GATConfig, n_nodes: int, n_edges: int) -> float:
    # dense projections + edge messages, fwd+bwd (×3 of fwd)
    f = 0.0
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        h = 1 if last else cfg.n_heads
        fdim = cfg.n_classes if last else cfg.d_hidden
        f += 2.0 * n_nodes * d_in * h * fdim      # X @ W
        f += 4.0 * n_edges * h * fdim             # messages + weighting
        d_in = h * fdim
    return 3.0 * f


def _gnn_train(arch: ArchDef, shape_name: str, cell, mesh, opt_cfg) -> StepBundle:
    cfg = arch.model_cfg(shape_name)
    profile = sh.PROFILES["tp"](mesh)
    astate, sstate = _abstract_state(
        lambda: gnn_lib.init_params(cfg, jax.random.key(0)),
        gnn_lib.param_logical(cfg), mesh, profile)

    if "n_graphs" in cell:
        G = cell["n_graphs"]
        N = G * cell["nodes_per_graph"]
        E = G * cell["edges_per_graph"]
        abatch = {
            "x": SDS((N, cell["d_feat"]), jnp.float32),
            "src": SDS((E,), jnp.int32), "dst": SDS((E,), jnp.int32),
            "graph_ids": SDS((N,), jnp.int32),
            "node_counts": SDS((G,), jnp.int32),
            "labels": SDS((G,), jnp.int32),
        }
    else:
        N, E = cell["n_nodes"], cell["n_edges"]
        abatch = {
            "x": SDS((N, cell["d_feat"]), jnp.float32),
            "src": SDS((E,), jnp.int32), "dst": SDS((E,), jnp.int32),
            "labels": SDS((N,), jnp.int32),
            "label_mask": SDS((N,), jnp.bool_),
        }
    # inputs arrive in their EXACT published sizes (replicated when they
    # don't divide the mesh); the step pads+constrains internally.
    multiple = 128
    for a in mesh.axis_names:
        multiple *= mesh.shape[a]

    def loss(params, batch):
        padded = _pad_graph(batch, multiple)
        prof = sh.PROFILES["tp"](mesh)
        padded["x"] = sh.constrain(padded["x"], (sh.NODES, None), mesh, prof)
        padded["src"] = sh.constrain(padded["src"], (sh.EDGES,), mesh, prof)
        padded["dst"] = sh.constrain(padded["dst"], (sh.EDGES,), mesh, prof)
        return gnn_lib.loss_fn(cfg, params, padded)

    sbatch = jax.tree.map(lambda a: _repl(mesh), abatch)
    fn = ts.make_train_step(loss, opt_cfg, n_micro=1)
    return StepBundle(
        name="train_step", fn=fn, args=(astate, abatch),
        in_shardings=(sstate, sbatch), out_shardings=(sstate, _repl(mesh)),
        donate_argnums=(0,),
        model_flops_per_step=_gnn_flops(cfg, N, E))


# ---------------------------------------------------------------------------
# recsys family
# ---------------------------------------------------------------------------

def _recsys_inputs(arch_id: str, cfg, B: int) -> dict[str, SDS]:
    if arch_id == "dcn-v2":
        return {"dense": SDS((B, cfg.n_dense), jnp.float32),
                "cat": SDS((B, cfg.n_sparse), jnp.int32),
                "label": SDS((B,), jnp.int32)}
    if arch_id == "autoint":
        return {"cat": SDS((B, cfg.n_sparse), jnp.int32),
                "label": SDS((B,), jnp.int32)}
    if arch_id == "dien":
        return {"hist_items": SDS((B, cfg.seq_len), jnp.int32),
                "hist_cates": SDS((B, cfg.seq_len), jnp.int32),
                "hist_mask": SDS((B, cfg.seq_len), jnp.float32),
                "target_item": SDS((B,), jnp.int32),
                "target_cate": SDS((B,), jnp.int32),
                "label": SDS((B,), jnp.int32)}
    if arch_id == "mind":
        return {"hist_items": SDS((B, cfg.seq_len), jnp.int32),
                "hist_mask": SDS((B, cfg.seq_len), jnp.float32),
                "target_item": SDS((B,), jnp.int32)}
    raise ValueError(arch_id)


def _recsys_flops(arch_id: str, cfg, B: int, kind: str) -> float:
    mult = 3.0 if kind == "train" else 1.0
    if arch_id == "dcn-v2":
        d = cfg.d_input
        f = cfg.n_cross_layers * 2 * d * d + 2 * d * cfg.mlp[0] + \
            2 * cfg.mlp[0] * cfg.mlp[1] + 2 * cfg.mlp[1] * cfg.mlp[2]
        return mult * B * f
    if arch_id == "autoint":
        F, dh = cfg.n_sparse, cfg.n_heads * cfg.d_attn
        f = cfg.n_attn_layers * (3 * 2 * F * cfg.embed_dim * dh +
                                 2 * 2 * F * F * dh)
        return mult * B * f
    if arch_id == "dien":
        h = cfg.gru_dim
        f = cfg.seq_len * 2 * 3 * ((cfg.d_behav + h) * h +   # GRU-1
                                   (h + h) * h)              # AUGRU
        return mult * B * f
    if arch_id == "mind":
        if kind == "retrieval":   # interests computed once; per-candidate dot
            return 2.0 * B * cfg.n_interests * cfg.embed_dim
        f = cfg.capsule_iters * 4 * cfg.seq_len * cfg.embed_dim * cfg.n_interests \
            + 2 * cfg.seq_len * cfg.embed_dim ** 2
        return mult * B * f
    raise ValueError(arch_id)


def _recsys_batch_shardings(abatch, mesh, profile):
    return {k: sh.named_sharding(mesh, (sh.BATCH,) + (None,) * (len(a.shape) - 1),
                                 a.shape, profile)
            for k, a in abatch.items()}


def _recsys_bundle(arch: ArchDef, shape_name: str, cell, mesh, opt_cfg) -> StepBundle:
    cfg = arch.model_cfg(shape_name)
    mod = arch.module
    profile = sh.PROFILES["tp"](mesh)

    if cell["kind"] == "train":
        astate, sstate = _abstract_state(
            lambda: mod.init_params(cfg, jax.random.key(0)),
            mod.param_logical(cfg), mesh, profile)
        abatch = _recsys_inputs(arch.arch_id, cfg, cell["batch"])
        sbatch = _recsys_batch_shardings(abatch, mesh, profile)
        loss = functools.partial(mod.loss_fn, cfg, mesh=mesh)
        fn = ts.make_train_step(loss, opt_cfg, n_micro=arch.train_microbatches)
        return StepBundle(
            name="train_step", fn=fn, args=(astate, abatch),
            in_shardings=(sstate, sbatch), out_shardings=(sstate, _repl(mesh)),
            donate_argnums=(0,),
            model_flops_per_step=_recsys_flops(arch.arch_id, cfg, cell["batch"], "train"))

    aparams = jax.eval_shape(lambda: mod.init_params(cfg, jax.random.key(0)))
    pspecs = sh.spec_tree(aparams, mod.param_logical(cfg), mesh, profile)

    if cell["kind"] == "serve":
        abatch = _recsys_inputs(arch.arch_id, cfg, cell["batch"])
        abatch.pop("label", None)
        sbatch = _recsys_batch_shardings(abatch, mesh, profile)

        def serve_step(params, batch):
            if arch.arch_id == "mind":
                return mod.forward(cfg, params, batch, mesh=mesh)
            return jax.nn.sigmoid(mod.forward(cfg, params, batch, mesh=mesh))

        out_sh = sh.named_sharding(mesh, (sh.BATCH,), (cell["batch"],), profile)
        return StepBundle(
            name="serve_step", fn=serve_step, args=(aparams, abatch),
            in_shardings=(pspecs, sbatch), out_shardings=out_sh,
            model_flops_per_step=_recsys_flops(arch.arch_id, cfg, cell["batch"], "serve"))

    # retrieval: 1 query context vs n_candidates item ids
    C = cell["candidates"]
    abatch = _recsys_inputs(arch.arch_id, cfg, cell["batch"])
    abatch.pop("label", None)
    abatch["candidates"] = SDS((C,), jnp.int32)
    sbatch = jax.tree.map(lambda a: _repl(mesh), abatch)
    sbatch["candidates"] = sh.named_sharding(mesh, (sh.CANDIDATES,), (C,), profile)

    def retrieval_step(params, batch):
        return mod.retrieval_score(cfg, params, batch, mesh=mesh)

    return StepBundle(
        name="retrieval_step", fn=retrieval_step, args=(aparams, abatch),
        in_shardings=(pspecs, sbatch),
        out_shardings=sh.named_sharding(mesh, (sh.CANDIDATES,), (C,), profile),
        model_flops_per_step=_recsys_flops(arch.arch_id, cfg, C, "retrieval"))


# ---------------------------------------------------------------------------
# entry
# ---------------------------------------------------------------------------

def _apply_overrides(arch: ArchDef, overrides: dict[str, str]) -> ArchDef:
    """Hillclimb lever: ``attn_impl=flash seq_parallel=true moe.dispatch=...``
    applied on top of the arch's model config (dataclasses.replace)."""
    if not overrides:
        return arch
    base_fn = arch.model_cfg

    def patched(shape):
        cfg = base_fn(shape)
        top, moe_kv = {}, {}
        for key, val in overrides.items():
            if key == "train_microbatches":   # ArchDef-level, not model cfg
                continue
            v: Any = val
            if isinstance(val, str):
                if val.lower() in ("true", "false"):
                    v = val.lower() == "true"
                elif val.isdigit():
                    v = int(val)
            if key.startswith("moe."):
                moe_kv[key[4:]] = v
            else:
                top[key] = v
        if moe_kv and getattr(cfg, "moe", None) is not None:
            top["moe"] = dataclasses.replace(cfg.moe, **moe_kv)
        return dataclasses.replace(cfg, **top) if top else cfg

    mb = overrides.get("train_microbatches")
    return dataclasses.replace(
        arch, model_cfg=patched,
        train_microbatches=int(mb) if mb else arch.train_microbatches)


def build_bundle(arch_id: str, shape_name: str, mesh,
                 opt_cfg: opt_lib.AdamWConfig | None = None,
                 overrides: dict[str, str] | None = None) -> StepBundle:
    arch = get_arch(arch_id)
    if shape_name not in arch.shapes:
        raise KeyError(f"{arch_id} has no shape {shape_name}; "
                       f"known: {sorted(arch.shapes)}")
    arch = _apply_overrides(arch, overrides or {})
    cell = arch.shapes[shape_name]
    opt_cfg = opt_cfg or opt_lib.AdamWConfig()
    if arch.family == "lm":
        if cell["kind"] == "train":
            return _lm_train(arch, cell, mesh, opt_cfg)
        return _lm_serve(arch, shape_name, cell, mesh)
    if arch.family == "gnn":
        return _gnn_train(arch, shape_name, cell, mesh, opt_cfg)
    if arch.family == "recsys":
        return _recsys_bundle(arch, shape_name, cell, mesh, opt_cfg)
    raise ValueError(arch.family)
