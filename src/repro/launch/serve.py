"""Serving driver: continuous-batching decode over a reduced (or full) LM.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --requests 8 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.models import transformer_lm as tlm
from repro.serve.batching import ContinuousBatcher, Request


def serve_demo(arch_id: str, *, n_requests: int = 8, max_new: int = 12,
               slots: int = 4, max_len: int = 128, seed: int = 0):
    arch = get_arch(arch_id)
    cfg, _ = arch.reduced()
    params = tlm.init_params(cfg, jax.random.key(seed))
    batcher = ContinuousBatcher(cfg, params, slots=slots, max_len=max_len)

    rng = np.random.default_rng(seed)
    t0 = time.time()
    for rid in range(n_requests):
        plen = int(rng.integers(4, 16))
        prompt = rng.integers(0, cfg.vocab, plen, dtype=np.int32)
        batcher.submit(Request(rid=rid, prompt=prompt, max_new_tokens=max_new))
    done = batcher.run_to_completion()
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in done)
    print(f"served {len(done)}/{n_requests} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s incl. compile)")
    for r in done[:4]:
        print(f"  rid={r.rid} prompt_len={len(r.prompt)} -> {r.generated}")
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()
    serve_demo(args.arch, n_requests=args.requests, max_new=args.max_new,
               slots=args.slots)


if __name__ == "__main__":
    main()
