"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Wires the full substrate: config registry -> sharded state -> deterministic
data pipeline -> StepGuard (checkpoint/restore/replay) -> AdamW train step.
``--reduced`` trains the smoke-scale config on the local device mesh; the
full configs use the production mesh (multi-host launch).
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer_lm as tlm
from repro.train import data as data_lib
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts
from repro.train.fault import StepGuard


def train_lm(arch_id: str, *, steps: int, batch: int, seq: int,
             ckpt_dir: str, reduced: bool = True, lr: float = 3e-3,
             ckpt_every: int = 20, log_every: int = 10,
             attn_impl: str | None = None):
    arch = get_arch(arch_id)
    if reduced:
        cfg, _ = arch.reduced()
    else:
        cfg = arch.model_cfg("train_4k")
    if attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)

    params = tlm.init_params(cfg, jax.random.key(0))
    state = ts.init_state(params)
    opt_cfg = opt_lib.AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                                  total_steps=steps)
    loss = functools.partial(tlm.loss_fn, cfg)
    step_fn = jax.jit(ts.make_train_step(loss, opt_cfg, n_micro=1),
                      donate_argnums=0)

    pipeline = data_lib.DataPipeline(
        data_lib.lm_batch_fn(cfg.vocab, batch, seq))
    guard = StepGuard(ckpt_dir, ckpt_every=ckpt_every)

    losses = []
    t0 = time.time()

    def logged_step(state, batch):
        new_state, metrics = step_fn(state, batch)
        losses.append(float(metrics["ce"]))
        n = len(losses)
        if n % log_every == 0:
            dt = (time.time() - t0) / n
            print(f"step {n:5d} ce={losses[-1]:.4f} "
                  f"({dt*1000:.0f} ms/step)")
        return new_state, metrics

    state, metrics, step = guard.run(
        state, pipeline.iter_from, logged_step, steps)
    print(f"done at step {step}: first ce={losses[0]:.4f} "
          f"last ce={losses[-1]:.4f}")
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--attn-impl", default=None)
    args = ap.parse_args()
    train_lm(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
             ckpt_dir=args.ckpt_dir, reduced=args.reduced, lr=args.lr,
             attn_impl=args.attn_impl)


if __name__ == "__main__":
    main()
