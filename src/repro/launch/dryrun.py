import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch × shape) on the production
# meshes and extract roofline terms from the compiled artifact.
#
# MUST be invoked as its own process (the device-count flag above is locked at
# first jax init):
#   PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
#       [--multi-pod | --both-meshes] [--out experiments/dryrun]

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.analysis import hlo_cost
from repro.configs.registry import all_arch_ids, get_arch
from repro.launch import mesh as mesh_lib
from repro.launch.steps import build_bundle

# ---------------------------------------------------------------------------
# dry-run of one cell
# ---------------------------------------------------------------------------

def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True,
             overrides: dict[str, str] | None = None) -> dict:
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    rec: dict = {"arch": arch_id, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "n_chips": mesh.size, "overrides": overrides or {}}
    t0 = time.time()
    bundle = build_bundle(arch_id, shape_name, mesh, overrides=overrides)
    with mesh:
        jitted = jax.jit(bundle.fn,
                         in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings,
                         donate_argnums=bundle.donate_argnums)
        lowered = jitted.lower(*bundle.args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    mem = compiled.memory_analysis()
    try:
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        }
        rec["bytes_per_device"] = (
            rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"]
            + rec["memory"]["output_bytes"] - rec["memory"]["alias_bytes"])
    except AttributeError:
        rec["memory"] = {"repr": str(mem)}

    cost = compiled.cost_analysis() or {}
    # raw XLA numbers (NOTE: count while bodies once — kept for reference)
    rec["xla_flops_raw"] = float(cost.get("flops", 0.0))
    rec["xla_bytes_raw"] = float(cost.get("bytes accessed", 0.0))

    # trip-count-aware per-chip cost (see repro.analysis.hlo_cost)
    hlo = compiled.as_text()
    walk = hlo_cost.analyze(hlo)
    rec["hlo_flops_per_chip"] = walk["flops_per_chip"]
    rec["hlo_bytes_per_chip"] = walk["bytes_per_chip"]
    rec["collectives"] = walk["collectives"]
    rec["collective_bytes_per_chip"] = walk["collective_bytes_per_chip"]
    rec["collective_counts"] = walk["collective_counts"]

    # roofline terms (seconds); cost_analysis FLOPs/bytes are per-chip
    rec["model_flops"] = bundle.model_flops_per_step
    rec["t_compute"] = rec["hlo_flops_per_chip"] / mesh_lib.PEAK_FLOPS_BF16
    rec["t_memory"] = rec["hlo_bytes_per_chip"] / mesh_lib.HBM_BW
    rec["t_collective"] = rec["collective_bytes_per_chip"] / mesh_lib.ICI_BW
    terms = {"compute": rec["t_compute"], "memory": rec["t_memory"],
             "collective": rec["t_collective"]}
    rec["bottleneck"] = max(terms, key=terms.get)
    total_chip_flops = rec["hlo_flops_per_chip"] * mesh.size
    rec["useful_flops_ratio"] = (
        rec["model_flops"] / total_chip_flops if total_chip_flops else 0.0)

    if verbose:
        print(f"[{rec['mesh']}] {arch_id} × {shape_name}: "
              f"lower {rec['lower_s']}s compile {rec['compile_s']}s | "
              f"flops/chip {rec['hlo_flops_per_chip']:.3g} "
              f"bytes/chip {rec['hlo_bytes_per_chip']:.3g} "
              f"coll/chip {rec['collective_bytes_per_chip']:.3g} | "
              f"t=(c {rec['t_compute']:.2e}, m {rec['t_memory']:.2e}, "
              f"x {rec['t_collective']:.2e}) -> {rec['bottleneck']}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (e.g. attn_impl=flash); "
                         "results tagged with --tag")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    overrides = dict(kv.split("=", 1) for kv in args.override)
    archs = [args.arch] if args.arch else all_arch_ids()
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch_id in archs:
        shapes = [args.shape] if args.shape else sorted(get_arch(arch_id).shapes)
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch_id}__{shape_name}__{'mp' if mp else 'sp'}"
                if args.tag:
                    tag += f"__{args.tag}"
                try:
                    rec = run_cell(arch_id, shape_name, multi_pod=mp,
                                   overrides=overrides or None)
                    (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures.append(tag)
                    print(f"FAILED {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("\nDRY-RUN PASS")


if __name__ == "__main__":
    main()
