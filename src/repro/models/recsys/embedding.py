"""Sparse embedding substrate for recsys: the JAX EmbeddingBag.

JAX has no native ``nn.EmbeddingBag`` / CSR sparse — lookups are built from
``jnp.take`` + ``jax.ops.segment_sum`` (this IS part of the system).  All
categorical fields of a model share one concatenated table so a batch does a
*single* gather regardless of field count; rows are shardable over the
``model`` mesh axis (TABLE_ROWS).

Criteo-style vocabularies are provided for the DCN-v2 / AutoInt configs.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.common import round_up
from repro.sharding import Ax

# Criteo-Kaggle per-field vocabulary sizes (DLRM convention), 26 fields.
CRITEO_VOCABS = [
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145, 5683,
    8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4, 7046547, 18,
    15, 286181, 105, 142572,
]


class FieldTable:
    """Concatenated per-field embedding table with precomputed offsets."""

    def __init__(self, vocabs: list[int], embed_dim: int, *, pad_rows_to: int = 1):
        self.vocabs = list(vocabs)
        self.embed_dim = embed_dim
        self.offsets = np.concatenate([[0], np.cumsum(vocabs)[:-1]]).astype(np.int64)
        self.total_rows = round_up(int(sum(vocabs)), pad_rows_to)

    def init(self, key, dtype=jnp.float32):
        scale = self.embed_dim ** -0.5
        return (jax.random.normal(key, (self.total_rows, self.embed_dim),
                                  jnp.float32) * scale).astype(dtype)

    def logical(self):
        return Ax(sh.TABLE_ROWS, None)

    def lookup(self, table: jax.Array, cat: jax.Array) -> jax.Array:
        """cat [B, F] per-field ids -> [B, F, D] in one gather."""
        flat = cat + jnp.asarray(self.offsets, cat.dtype)
        return jnp.take(table, flat, axis=0)


def embedding_bag(table: jax.Array, indices: jax.Array, segment_ids: jax.Array,
                  num_segments: int, *, combiner: str = "sum",
                  weights: jax.Array | None = None) -> jax.Array:
    """Multi-hot EmbeddingBag: gather rows then segment-reduce.

    indices/segment_ids: [nnz]; returns [num_segments, D].
    """
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    summed = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if combiner == "sum":
        return summed
    if combiner == "mean":
        counts = jax.ops.segment_sum(jnp.ones_like(indices, jnp.float32),
                                     segment_ids, num_segments=num_segments)
        return summed / jnp.maximum(counts, 1.0)[:, None]
    if combiner == "max":
        return jax.ops.segment_max(rows, segment_ids, num_segments=num_segments)
    raise ValueError(combiner)


def mlp_tower(key, dims: list[int], dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": (jax.random.normal(k, (a, b), jnp.float32) * a ** -0.5).astype(dtype),
             "b": jnp.zeros((b,), dtype)}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def mlp_tower_logical(dims: list[int]):
    return [{"w": Ax(None, sh.MLP), "b": Ax(sh.MLP)} for _ in range(len(dims) - 1)]


def mlp_tower_apply(layers, x, *, final_act: bool = False):
    for i, p in enumerate(layers):
        x = x @ p["w"] + p["b"]
        if final_act or i < len(layers) - 1:
            x = jax.nn.relu(x)
    return x


def bce_loss(logit: jax.Array, label: jax.Array):
    """Binary cross-entropy from logits (fp32)."""
    logit = logit.astype(jnp.float32)
    label = label.astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logit, 0) - logit * label +
                    jnp.log1p(jnp.exp(-jnp.abs(logit))))
    return loss
