from repro.models.recsys import autoint, dcn, dien, embedding, mind  # noqa: F401
