"""DCN-v2 (arXiv:2008.13535): cross network v2 + deep tower (stacked).

x_{l+1} = x_0 ⊙ (W_l x_l + b_l) + x_l  with full-rank W (paper default).
13 dense features (log-transformed), 26 Criteo sparse fields, dim-16 embeds.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.models.recsys import embedding as E
from repro.sharding import Ax


@dataclasses.dataclass(frozen=True, kw_only=True)
class DCNConfig:
    name: str = "dcn-v2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    n_cross_layers: int = 3
    mlp: tuple[int, ...] = (1024, 1024, 512)
    vocabs: tuple[int, ...] = tuple(E.CRITEO_VOCABS)
    dtype: Any = jnp.float32

    @property
    def d_input(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim

    def table(self) -> E.FieldTable:
        return E.FieldTable(list(self.vocabs), self.embed_dim)


def init_params(cfg: DCNConfig, key) -> dict[str, Any]:
    kt, kc, km, ko = jax.random.split(key, 4)
    d = cfg.d_input
    cross = [{"w": (jax.random.normal(jax.random.fold_in(kc, i), (d, d), jnp.float32)
                    * d ** -0.5).astype(cfg.dtype),
              "b": jnp.zeros((d,), cfg.dtype)} for i in range(cfg.n_cross_layers)]
    return {
        "table": cfg.table().init(kt, cfg.dtype),
        "cross": cross,
        "mlp": E.mlp_tower(km, [d, *cfg.mlp], cfg.dtype),
        "out": {"w": (jax.random.normal(ko, (cfg.mlp[-1], 1), jnp.float32)
                      * cfg.mlp[-1] ** -0.5).astype(cfg.dtype),
                "b": jnp.zeros((1,), cfg.dtype)},
    }


def param_logical(cfg: DCNConfig) -> dict[str, Any]:
    return {
        "table": cfg.table().logical(),
        "cross": [{"w": Ax(None, None), "b": Ax(None)}
                  for _ in range(cfg.n_cross_layers)],
        "mlp": E.mlp_tower_logical([cfg.d_input, *cfg.mlp]),
        "out": {"w": Ax(sh.MLP, None), "b": Ax(None)},
    }


def forward(cfg: DCNConfig, params, batch, *, mesh=None) -> jax.Array:
    """batch: {dense [B, n_dense] f32, cat [B, n_sparse] i32} -> logit [B]."""
    emb = cfg.table().lookup(params["table"], batch["cat"])     # [B, F, D]
    B = emb.shape[0]
    x0 = jnp.concatenate(
        [jnp.log1p(jnp.abs(batch["dense"])).astype(cfg.dtype),
         emb.reshape(B, -1)], axis=-1)
    if mesh is not None:
        x0 = sh.constrain(x0, (sh.BATCH, None), mesh, sh.PROFILES["tp"](mesh))
    x = x0
    for p in params["cross"]:
        x = x0 * (x @ p["w"] + p["b"]) + x
    h = E.mlp_tower_apply(params["mlp"], x, final_act=True)
    return (h @ params["out"]["w"] + params["out"]["b"])[:, 0]


def loss_fn(cfg: DCNConfig, params, batch, *, mesh=None):
    logit = forward(cfg, params, batch, mesh=mesh)
    loss = E.bce_loss(logit, batch["label"])
    return loss, {"bce": loss}


def retrieval_score(cfg: DCNConfig, params, batch, *, mesh=None) -> jax.Array:
    """Score ONE query context against n_candidates item ids — vectorised.

    batch: {dense [1, n_dense], cat [1, n_sparse], candidates [C] i32}.
    The candidate id replaces the last categorical field; all other features
    broadcast.  Returns scores [C].
    """
    C = batch["candidates"].shape[0]
    cand = batch["candidates"] % cfg.vocabs[-1]     # hash into the item field
    cat = jnp.broadcast_to(batch["cat"], (C, cfg.n_sparse)).copy()
    cat = cat.at[:, -1].set(cand)
    dense = jnp.broadcast_to(batch["dense"], (C, cfg.n_dense))
    return forward(cfg, params, {"dense": dense, "cat": cat}, mesh=mesh)
