"""MIND (arXiv:1904.08030): multi-interest retrieval with capsule routing.

Behaviour-to-Interest (B2I) dynamic routing extracts ``n_interests`` capsules
from the user history; training uses label-aware attention + sampled-softmax
(in-batch negatives); serving scores candidates against the max interest.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.models.recsys import embedding as E
from repro.sharding import Ax


@dataclasses.dataclass(frozen=True, kw_only=True)
class MINDConfig:
    name: str = "mind"
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    seq_len: int = 50
    item_vocab: int = 100000
    label_pow: float = 2.0       # label-aware attention sharpening
    dtype: Any = jnp.float32


def init_params(cfg: MINDConfig, key) -> dict[str, Any]:
    ki, ks, kb = jax.random.split(key, 3)
    return {
        "item_table": (jax.random.normal(ki, (cfg.item_vocab, cfg.embed_dim), jnp.float32)
                       * cfg.embed_dim ** -0.5).astype(cfg.dtype),
        # shared bilinear S of B2I routing
        "s": (jax.random.normal(ks, (cfg.embed_dim, cfg.embed_dim), jnp.float32)
              * cfg.embed_dim ** -0.5).astype(cfg.dtype),
        # fixed (non-trainable in paper: randomly initialised) routing logits init
        "b_init": (jax.random.normal(kb, (cfg.n_interests,), jnp.float32)).astype(cfg.dtype),
    }


def param_logical(cfg: MINDConfig) -> dict[str, Any]:
    return {"item_table": Ax(sh.TABLE_ROWS, None),
            "s": Ax(None, None), "b_init": Ax(None)}


def _squash(x, axis=-1):
    n2 = jnp.sum(jnp.square(x.astype(jnp.float32)), axis=axis, keepdims=True)
    return (x * (n2 / (1.0 + n2) * jax.lax.rsqrt(n2 + 1e-9)).astype(x.dtype))


def interests(cfg: MINDConfig, params, hist_items, hist_mask):
    """B2I dynamic routing: [B,T] history -> [B,K,D] interest capsules."""
    e = jnp.take(params["item_table"], hist_items, axis=0)      # [B,T,D]
    mask = hist_mask.astype(jnp.float32)
    low = jnp.einsum("btd,de->bte", e, params["s"])             # shared bilinear
    B, T, D = low.shape
    K = cfg.n_interests
    b = jnp.broadcast_to(params["b_init"][None, :, None].astype(jnp.float32),
                         (B, K, T))

    def routing_iter(b, _):
        w = jax.nn.softmax(b, axis=1)                           # over interests
        w = w * mask[:, None, :]
        caps = _squash(jnp.einsum("bkt,bte->bke", w.astype(low.dtype), low))
        b_new = b + jnp.einsum("bke,bte->bkt", caps, low).astype(jnp.float32)
        return b_new, caps

    b, caps_seq = jax.lax.scan(routing_iter, b, None, length=cfg.capsule_iters)
    return caps_seq[-1]                                          # [B,K,D]


def user_vector(cfg: MINDConfig, params, hist_items, hist_mask, target_items):
    """Label-aware attention pooled user vector for training. [B,D]"""
    caps = interests(cfg, params, hist_items, hist_mask)         # [B,K,D]
    t = jnp.take(params["item_table"], target_items, axis=0)     # [B,D]
    logits = jnp.einsum("bkd,bd->bk", caps, t).astype(jnp.float32)
    att = jax.nn.softmax(cfg.label_pow * logits, axis=-1)
    return jnp.einsum("bk,bkd->bd", att.astype(caps.dtype), caps), caps


def loss_fn(cfg: MINDConfig, params, batch, *, mesh=None):
    """Sampled-softmax with in-batch negatives over target items."""
    if mesh is not None:
        pass  # activations are tiny; table sharding drives the layout
    u, _ = user_vector(cfg, params, batch["hist_items"], batch["hist_mask"],
                       batch["target_item"])
    t = jnp.take(params["item_table"], batch["target_item"], axis=0)  # [B,D]
    scores = jnp.einsum("bd,cd->bc", u, t).astype(jnp.float32)        # in-batch
    labels = jnp.arange(scores.shape[0])
    logp = jax.nn.log_softmax(scores, axis=-1)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
    return loss, {"sampled_softmax": loss}


def forward(cfg: MINDConfig, params, batch, *, mesh=None) -> jax.Array:
    """Serving forward: score target item(s) against max interest. [B]"""
    caps = interests(cfg, params, batch["hist_items"], batch["hist_mask"])
    t = jnp.take(params["item_table"], batch["target_item"], axis=0)
    return jnp.max(jnp.einsum("bkd,bd->bk", caps, t), axis=-1)


def retrieval_score(cfg: MINDConfig, params, batch, *, mesh=None) -> jax.Array:
    """1 user's interests vs C candidates: batched dot + max, never a loop."""
    caps = interests(cfg, params, batch["hist_items"], batch["hist_mask"])  # [1,K,D]
    cand = jnp.take(params["item_table"], batch["candidates"], axis=0)      # [C,D]
    if mesh is not None:
        cand = sh.constrain(cand, (sh.CANDIDATES, None), mesh, sh.PROFILES["tp"](mesh))
    return jnp.max(jnp.einsum("kd,cd->kc", caps[0], cand), axis=0)          # [C]
