"""DIEN (arXiv:1809.03672): interest evolution via GRU + AUGRU.

Interest extractor GRU over the behaviour sequence (+ auxiliary next-item
loss), target-attention scores, and an attention-update-gate GRU (AUGRU)
whose final state feeds the prediction MLP.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.models.recsys import embedding as E
from repro.sharding import Ax


@dataclasses.dataclass(frozen=True, kw_only=True)
class DIENConfig:
    name: str = "dien"
    embed_dim: int = 18          # per feature; item+cate concat = 36
    seq_len: int = 100
    gru_dim: int = 108
    mlp: tuple[int, ...] = (200, 80)
    item_vocab: int = 63001
    cate_vocab: int = 801
    use_aux_loss: bool = True
    aux_weight: float = 1.0
    dtype: Any = jnp.float32

    @property
    def d_behav(self) -> int:
        return 2 * self.embed_dim


def _gru_init(key, d_in, d_h, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "wx": (jax.random.normal(k1, (d_in, 3 * d_h), jnp.float32) * d_in ** -0.5).astype(dtype),
        "wh": (jax.random.normal(k2, (d_h, 3 * d_h), jnp.float32) * d_h ** -0.5).astype(dtype),
        "b": jnp.zeros((3 * d_h,), dtype),
    }


def _gru_gates(p, x_t, h):
    z = x_t @ p["wx"] + h @ p["wh"] + p["b"]
    d_h = h.shape[-1]
    u = jax.nn.sigmoid(z[..., :d_h])            # update
    r = jax.nn.sigmoid(z[..., d_h:2 * d_h])     # reset
    # candidate uses reset-gated hidden: recompute its slice with r*h
    c_in = x_t @ p["wx"][:, 2 * d_h:] + (r * h) @ p["wh"][:, 2 * d_h:] + p["b"][2 * d_h:]
    c = jnp.tanh(c_in)
    return u, c


def gru_scan(p, xs, h0, att: jax.Array | None = None):
    """xs [B, T, d]; optional att [B, T] turns this into AUGRU."""
    def step(h, inp):
        if att is None:
            x_t = inp
            u, c = _gru_gates(p, x_t, h)
        else:
            x_t, a_t = inp
            u, c = _gru_gates(p, x_t, h)
            u = a_t[:, None] * u                 # attention-scaled update gate
        h_new = (1.0 - u) * h + u * c
        return h_new, h_new

    xs_t = jnp.swapaxes(xs, 0, 1)                # [T, B, d]
    inputs = xs_t if att is None else (xs_t, jnp.swapaxes(att, 0, 1))
    h_last, h_seq = jax.lax.scan(step, h0, inputs)
    return h_last, jnp.swapaxes(h_seq, 0, 1)     # [B, T, d_h]


def init_params(cfg: DIENConfig, key) -> dict[str, Any]:
    ki, kc, k1, k2, ka, km, ko = jax.random.split(key, 7)
    d_b, d_h = cfg.d_behav, cfg.gru_dim
    d_final = d_h + 2 * d_b  # [augru_state, target_emb, sum_pooled_hist]
    return {
        "item_table": (jax.random.normal(ki, (cfg.item_vocab, cfg.embed_dim), jnp.float32)
                       * cfg.embed_dim ** -0.5).astype(cfg.dtype),
        "cate_table": (jax.random.normal(kc, (cfg.cate_vocab, cfg.embed_dim), jnp.float32)
                       * cfg.embed_dim ** -0.5).astype(cfg.dtype),
        "gru1": _gru_init(k1, d_b, d_h, cfg.dtype),
        "augru": _gru_init(k2, d_h, d_h, cfg.dtype),
        "att_w": (jax.random.normal(ka, (d_h, d_b), jnp.float32) * d_h ** -0.5).astype(cfg.dtype),
        "mlp": E.mlp_tower(km, [d_final, *cfg.mlp], cfg.dtype),
        "out": {"w": (jax.random.normal(ko, (cfg.mlp[-1], 1), jnp.float32)
                      * cfg.mlp[-1] ** -0.5).astype(cfg.dtype),
                "b": jnp.zeros((1,), cfg.dtype)},
    }


def param_logical(cfg: DIENConfig) -> dict[str, Any]:
    gru = {"wx": Ax(None, None), "wh": Ax(None, None), "b": Ax(None)}
    return {
        "item_table": Ax(sh.TABLE_ROWS, None),
        "cate_table": Ax(sh.TABLE_ROWS, None),
        "gru1": dict(gru), "augru": dict(gru),
        "att_w": Ax(None, None),
        "mlp": E.mlp_tower_logical([cfg.gru_dim + 2 * cfg.d_behav, *cfg.mlp]),
        "out": {"w": Ax(None, None), "b": Ax(None)},
    }


def _behaviour_embed(cfg, params, items, cates):
    return jnp.concatenate([jnp.take(params["item_table"], items, axis=0),
                            jnp.take(params["cate_table"], cates, axis=0)], axis=-1)


def forward(cfg: DIENConfig, params, batch, *, mesh=None, with_aux=False):
    """batch: hist_items/hist_cates [B,T] i32, hist_mask [B,T] f32,
    target_item/target_cate [B] i32 -> logit [B] (+aux loss)."""
    hist = _behaviour_embed(cfg, params, batch["hist_items"], batch["hist_cates"])
    target = _behaviour_embed(cfg, params, batch["target_item"], batch["target_cate"])
    mask = batch["hist_mask"].astype(jnp.float32)
    if mesh is not None:
        hist = sh.constrain(hist, (sh.BATCH, None, None), mesh, sh.PROFILES["tp"](mesh))
    B, T, _ = hist.shape
    h0 = jnp.zeros((B, cfg.gru_dim), hist.dtype)
    _, h_seq = gru_scan(params["gru1"], hist, h0)            # [B, T, H]

    # target attention over interest states (bilinear)
    att_logits = jnp.einsum("bth,hd,bd->bt", h_seq, params["att_w"], target)
    att_logits = jnp.where(mask > 0, att_logits.astype(jnp.float32), -1e30)
    att = jax.nn.softmax(att_logits, axis=-1).astype(hist.dtype)

    h_final, _ = gru_scan(params["augru"], h_seq, h0, att=att)

    pooled = jnp.sum(hist * mask[..., None].astype(hist.dtype), axis=1) / \
        jnp.maximum(mask.sum(1), 1.0)[:, None].astype(hist.dtype)
    feats = jnp.concatenate([h_final, target, pooled], axis=-1)
    h = E.mlp_tower_apply(params["mlp"], feats, final_act=True)
    logit = (h @ params["out"]["w"] + params["out"]["b"])[:, 0]

    if not with_aux:
        return logit
    # auxiliary loss: h_t should predict behaviour t+1 (in-batch negatives)
    pos = jnp.einsum("bth,bth->bt", h_seq[:, :-1] @ params["att_w"], hist[:, 1:])
    neg_hist = jnp.roll(hist[:, 1:], 1, axis=0)              # other users' items
    neg = jnp.einsum("bth,bth->bt", h_seq[:, :-1] @ params["att_w"], neg_hist)
    m = mask[:, 1:]
    aux = -(jax.nn.log_sigmoid(pos) + jax.nn.log_sigmoid(-neg)).astype(jnp.float32)
    aux = jnp.sum(aux * m) / jnp.maximum(jnp.sum(m), 1.0)
    return logit, aux


def loss_fn(cfg: DIENConfig, params, batch, *, mesh=None):
    if cfg.use_aux_loss:
        logit, aux = forward(cfg, params, batch, mesh=mesh, with_aux=True)
    else:
        logit, aux = forward(cfg, params, batch, mesh=mesh), 0.0
    bce = E.bce_loss(logit, batch["label"])
    loss = bce + cfg.aux_weight * aux
    return loss, {"bce": bce, "aux": aux}


def retrieval_score(cfg: DIENConfig, params, batch, *, mesh=None) -> jax.Array:
    """1 user history vs C candidate items (category derived by hash)."""
    C = batch["candidates"].shape[0]
    rep = lambda x: jnp.broadcast_to(x, (C, *x.shape[1:]))
    b = {"hist_items": rep(batch["hist_items"]),
         "hist_cates": rep(batch["hist_cates"]),
         "hist_mask": rep(batch["hist_mask"]),
         "target_item": batch["candidates"],
         "target_cate": batch["candidates"] % cfg.cate_vocab}
    return forward(cfg, params, b, mesh=mesh)
