"""AutoInt (arXiv:1810.11921): multi-head self-attention feature interaction.

39 sparse fields (26 Criteo categorical + 13 bucketised dense), dim-16
embeddings, 3 interacting layers with 2 heads of d_attn=32, residual
connections, final flatten -> logit.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.models.recsys import embedding as E
from repro.sharding import Ax

#: 26 Criteo categorical vocabs + 13 bucketised-dense vocabs (1000 buckets).
AUTOINT_VOCABS = tuple(E.CRITEO_VOCABS) + (1000,) * 13


@dataclasses.dataclass(frozen=True, kw_only=True)
class AutoIntConfig:
    name: str = "autoint"
    n_sparse: int = 39
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    vocabs: tuple[int, ...] = AUTOINT_VOCABS
    dtype: Any = jnp.float32

    def table(self) -> E.FieldTable:
        return E.FieldTable(list(self.vocabs), self.embed_dim)


def init_params(cfg: AutoIntConfig, key) -> dict[str, Any]:
    kt, kl, ko = jax.random.split(key, 3)
    layers = []
    d_in = cfg.embed_dim
    d_out = cfg.n_heads * cfg.d_attn
    for i in range(cfg.n_attn_layers):
        k = jax.random.fold_in(kl, i)
        kq, kk, kv, kr = jax.random.split(k, 4)
        layers.append({
            "wq": (jax.random.normal(kq, (d_in, cfg.n_heads, cfg.d_attn), jnp.float32) * d_in ** -0.5).astype(cfg.dtype),
            "wk": (jax.random.normal(kk, (d_in, cfg.n_heads, cfg.d_attn), jnp.float32) * d_in ** -0.5).astype(cfg.dtype),
            "wv": (jax.random.normal(kv, (d_in, cfg.n_heads, cfg.d_attn), jnp.float32) * d_in ** -0.5).astype(cfg.dtype),
            "w_res": (jax.random.normal(kr, (d_in, d_out), jnp.float32) * d_in ** -0.5).astype(cfg.dtype),
        })
        d_in = d_out
    return {
        "table": cfg.table().init(kt, cfg.dtype),
        "layers": layers,
        "out": {"w": (jax.random.normal(ko, (cfg.n_sparse * d_out, 1), jnp.float32)
                      * (cfg.n_sparse * d_out) ** -0.5).astype(cfg.dtype),
                "b": jnp.zeros((1,), cfg.dtype)},
    }


def param_logical(cfg: AutoIntConfig) -> dict[str, Any]:
    layer = {"wq": Ax(None, None, None), "wk": Ax(None, None, None),
             "wv": Ax(None, None, None), "w_res": Ax(None, None)}
    return {"table": cfg.table().logical(),
            "layers": [dict(layer) for _ in range(cfg.n_attn_layers)],
            "out": {"w": Ax(None, None), "b": Ax(None)}}


def _interact(p, x):
    """x [B, F, d_in] -> [B, F, H*d_attn] self-attention over fields."""
    q = jnp.einsum("bfd,dha->bfha", x, p["wq"])
    k = jnp.einsum("bfd,dha->bfha", x, p["wk"])
    v = jnp.einsum("bfd,dha->bfha", x, p["wv"])
    scores = jnp.einsum("bfha,bgha->bhfg", q, k).astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhfg,bgha->bfha", probs, v)
    B, F = x.shape[:2]
    out = out.reshape(B, F, -1)
    return jax.nn.relu(out + x @ p["w_res"])


def forward(cfg: AutoIntConfig, params, batch, *, mesh=None) -> jax.Array:
    """batch: {cat [B, n_sparse] i32} -> logit [B]."""
    x = cfg.table().lookup(params["table"], batch["cat"])  # [B, F, D]
    if mesh is not None:
        x = sh.constrain(x, (sh.BATCH, None, None), mesh, sh.PROFILES["tp"](mesh))
    for p in params["layers"]:
        x = _interact(p, x)
    B = x.shape[0]
    return (x.reshape(B, -1) @ params["out"]["w"] + params["out"]["b"])[:, 0]


def loss_fn(cfg: AutoIntConfig, params, batch, *, mesh=None):
    logit = forward(cfg, params, batch, mesh=mesh)
    loss = E.bce_loss(logit, batch["label"])
    return loss, {"bce": loss}


def retrieval_score(cfg: AutoIntConfig, params, batch, *, mesh=None) -> jax.Array:
    C = batch["candidates"].shape[0]
    cand = batch["candidates"] % cfg.vocabs[-1]     # hash into the item field
    cat = jnp.broadcast_to(batch["cat"], (C, cfg.n_sparse)).copy()
    cat = cat.at[:, -1].set(cand)
    return forward(cfg, params, {"cat": cat}, mesh=mesh)
