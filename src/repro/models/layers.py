"""Core neural layers: RMSNorm, RoPE, GQA attention, gated MLP.

All layers are pure functions over explicit param pytrees (no framework
dependency).  Attention supports three execution paths selected by
``impl``:

* ``"xla"``    — einsum formulation; the path used for distributed
                 lowering/dry-run (GSPMD inserts the collectives).
* ``"pallas"`` — the Pallas flash-attention kernel (TPU target; validated
                 in interpret mode on CPU).
* ``"ref"``    — alias of xla kept for kernel oracles.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.common import DEFAULT_DTYPE

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype=DEFAULT_DTYPE, scale: float | None = None):
    """Truncated-normal fan-in init (LLM standard)."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # [head_dim/2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention masks
# ---------------------------------------------------------------------------

def attention_bias(
    q_positions: jax.Array,   # [S] int32
    k_positions: jax.Array,   # [T] int32
    *,
    causal: bool = True,
    chunk: int = 0,
    kv_valid_len: jax.Array | None = None,  # [B] or scalar
) -> jax.Array:
    """Additive fp32 bias [.., S, T]; -inf at masked positions.

    ``chunk > 0`` restricts attention to the same length-``chunk`` block
    (Llama-4 style chunked local attention).  ``kv_valid_len`` masks padded
    KV-cache slots during decode.
    """
    q = q_positions[:, None]
    k = k_positions[None, :]
    ok = jnp.ones((q_positions.shape[0], k_positions.shape[0]), bool)
    if causal:
        ok &= k <= q
    if chunk:
        ok &= (k // chunk) == (q // chunk)
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    if kv_valid_len is not None:
        valid = k_positions[None, None, :] < jnp.asarray(kv_valid_len).reshape(-1, 1, 1)
        bias = bias[None] + jnp.where(valid, 0.0, NEG_INF)
    return bias


# ---------------------------------------------------------------------------
# GQA attention core
# ---------------------------------------------------------------------------

#: Above this many query rows, the xla path switches to q-chunked attention
#: so the [S, T] score tensor never materialises whole (exact lazy-softmax —
#: each q row still sees the full T at once, no online rescaling needed).
Q_CHUNK = 1024


def _attn_core(qg, k, v, bias):
    """qg [B, s, n_kv, G, D] vs k/v [B, T, n_kv, D]; bias [..., s, T]."""
    D = qg.shape[-1]
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32)
    scores = scores * (D ** -0.5)
    while bias.ndim < scores.ndim:
        bias = bias[None]
    probs = jax.nn.softmax(scores + bias, axis=-1)
    return jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)


def gqa_attention(
    q: jax.Array,   # [B, S, n_q, D]
    k: jax.Array,   # [B, T, n_kv, D]
    v: jax.Array,   # [B, T, n_kv, D]
    bias: jax.Array,  # broadcastable to [B, n_kv, G, S, T] from [.., S, T]
    *,
    impl: str = "xla",
    q_chunk: int = Q_CHUNK,
) -> jax.Array:
    """Grouped-query attention; softmax in fp32. Returns [B, S, n_q, D]."""
    if impl == "flash":
        # q-chunked flash on the XLA path: caller guarantees pure-causal
        # masking (training path, no KV cache) — see transformer_lm._block
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention_xla(q, k, v, causal=True)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(
            q, k, v, causal=True,
            impl="interpret" if jax.default_backend() != "tpu" else "auto")
    B, S, n_q, D = q.shape
    n_kv = k.shape[2]
    G = n_q // n_kv
    qg = q.reshape(B, S, n_kv, G, D)

    if S <= q_chunk or S % q_chunk:
        return _attn_core(qg, k, v, bias).reshape(B, S, n_q, D)

    # q-chunked: scan over blocks of q rows; bias must carry full [S, T].
    n_blocks = S // q_chunk
    bias5 = bias  # [..., S, T] with S at axis -2
    qg_blk = qg.reshape(B, n_blocks, q_chunk, n_kv, G, D)

    def body(_, blk_idx):
        qb = jax.lax.dynamic_index_in_dim(qg_blk, blk_idx, 1, keepdims=False)
        bb = jax.lax.dynamic_slice_in_dim(bias5, blk_idx * q_chunk, q_chunk,
                                          axis=bias5.ndim - 2)
        return None, _attn_core(qb, k, v, bb)

    _, out = jax.lax.scan(body, None, jnp.arange(n_blocks))
    # out: [n_blocks, B, q_chunk, n_kv, G, D] -> [B, S, n_q, D]
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, n_q, D)
    return out


# ---------------------------------------------------------------------------
# attention block (projections + rope + core)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, kw_only=True)
class AttnDims:
    d_model: int
    n_q: int
    n_kv: int
    d_head: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0


def attn_init(key, dims: AttnDims, dtype=DEFAULT_DTYPE) -> dict[str, Any]:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (dims.d_model, dims.n_q, dims.d_head), dtype),
        "wk": dense_init(ks[1], (dims.d_model, dims.n_kv, dims.d_head), dtype),
        "wv": dense_init(ks[2], (dims.d_model, dims.n_kv, dims.d_head), dtype),
        "wo": dense_init(ks[3], (dims.n_q, dims.d_head, dims.d_model), dtype),
    }
    if dims.qkv_bias:
        p["bq"] = jnp.zeros((dims.n_q, dims.d_head), dtype)
        p["bk"] = jnp.zeros((dims.n_kv, dims.d_head), dtype)
        p["bv"] = jnp.zeros((dims.n_kv, dims.d_head), dtype)
    return p


def attn_apply(
    p: dict[str, Any],
    x: jax.Array,                  # [B, S, d]
    dims: AttnDims,
    *,
    positions: jax.Array,          # [S]
    kv_cache: tuple[jax.Array, jax.Array] | None = None,  # ([B,T,n_kv,D], ...)
    cache_index: jax.Array | None = None,  # scalar write offset
    causal: bool = True,
    chunk: int = 0,
    impl: str = "xla",
):
    """Returns (out [B,S,d], new_kv_cache or None)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if dims.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q, positions, dims.rope_theta)
    k = apply_rope(k, positions, dims.rope_theta)

    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_index, 0, 0))
        k, v = ck, cv
        new_cache = (ck, cv)
        k_positions = jnp.arange(ck.shape[1], dtype=jnp.int32)
        kv_valid = cache_index + x.shape[1]
        bias = attention_bias(positions, k_positions, causal=causal, chunk=chunk,
                              kv_valid_len=kv_valid)
        # [B', S, T] -> [B', 1, 1, S, T] so the batch dim lands correctly.
        bias = bias[:, None, None, :, :]
    else:
        bias = attention_bias(positions, positions, causal=causal, chunk=chunk)

    out = gqa_attention(q, k, v, bias, impl=impl)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, new_cache


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype=DEFAULT_DTYPE) -> dict[str, Any]:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), dtype),
    }


def mlp_apply(p: dict[str, Any], x: jax.Array) -> jax.Array:
    gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    hidden = jax.nn.silu(gate) * up
    return jnp.einsum("bsf,fd->bsd", hidden, p["w_down"])
