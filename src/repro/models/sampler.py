"""Host-side CSR neighbour sampler (GraphSAGE-style fanout sampling).

``minibatch_lg`` (Reddit-scale: 232,965 nodes / 114.6M edges, fanout 15-10)
requires a *real* sampler: we build a CSR adjacency once (numpy) and sample
k-hop neighbourhoods per minibatch, emitting fixed-size padded subgraphs so
the jitted train step sees static shapes.

Layout of a sampled subgraph for fanouts [f1, f2] and B seed nodes:
  layer-0 nodes: B seeds
  layer-1 nodes: B*f1 sampled neighbours (padded w/ self-loops)
  layer-2 nodes: B*f1*f2
Edges connect consecutive layers (child -> parent), giving
E = B*f1 + B*f1*f2 edges; node features are gathered on host.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray   # [N+1] int64
    indices: np.ndarray  # [E] int32
    features: np.ndarray  # [N, d] float32 (may be memory-mapped)
    labels: np.ndarray   # [N] int32

    @property
    def n_nodes(self) -> int:
        return self.indptr.shape[0] - 1


def random_graph(n_nodes: int, avg_degree: int, d_feat: int, n_classes: int,
                 seed: int = 0) -> CSRGraph:
    """Synthesise a power-law-ish random graph in CSR form."""
    rng = np.random.default_rng(seed)
    # degree ~ clipped zipf around avg_degree
    deg = np.minimum(rng.zipf(1.7, n_nodes) + avg_degree // 2, 16 * avg_degree)
    deg = (deg * (avg_degree / max(deg.mean(), 1))).astype(np.int64)
    deg = np.maximum(deg, 1)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    indices = rng.integers(0, n_nodes, indptr[-1], dtype=np.int32)
    feats = rng.standard_normal((n_nodes, d_feat), dtype=np.float32)
    labels = rng.integers(0, n_classes, n_nodes, dtype=np.int32)
    return CSRGraph(indptr, indices, feats, labels)


class NeighborSampler:
    def __init__(self, graph: CSRGraph, fanouts: list[int], seed: int = 0):
        self.g = graph
        self.fanouts = fanouts
        self.rng = np.random.default_rng(seed)

    def _sample_neighbors(self, nodes: np.ndarray, fanout: int) -> np.ndarray:
        """For each node pick ``fanout`` neighbours (with replacement;
        isolated nodes self-loop). Returns [len(nodes), fanout] int32."""
        g = self.g
        starts = g.indptr[nodes]
        degs = g.indptr[nodes + 1] - starts
        # random offsets into each adjacency row
        offs = (self.rng.random((nodes.shape[0], fanout)) *
                np.maximum(degs, 1)[:, None]).astype(np.int64)
        picked = g.indices[np.minimum(starts[:, None] + offs,
                                      g.indptr[-1] - 1)].astype(np.int32)
        return np.where(degs[:, None] > 0, picked, nodes[:, None].astype(np.int32))

    def sample(self, seeds: np.ndarray) -> dict[str, np.ndarray]:
        """Sample the k-hop padded subgraph for ``seeds`` [B]."""
        layers = [seeds.astype(np.int32)]
        src_l, dst_l = [], []
        offset = 0
        for fanout in self.fanouts:
            parents = layers[-1]
            children = self._sample_neighbors(parents, fanout).reshape(-1)
            child_off = offset + parents.shape[0]
            # edges: child -> parent (messages flow to the seed side)
            src = child_off + np.arange(children.shape[0], dtype=np.int32)
            dst = offset + np.repeat(np.arange(parents.shape[0], dtype=np.int32), fanout)
            src_l.append(src)
            dst_l.append(dst)
            layers.append(children)
            offset = child_off
        nodes = np.concatenate(layers)
        return {
            "x": self.g.features[nodes],
            "src": np.concatenate(src_l),
            "dst": np.concatenate(dst_l),
            "labels": np.where(
                np.arange(nodes.shape[0]) < seeds.shape[0],
                self.g.labels[nodes], 0).astype(np.int32),
            "label_mask": (np.arange(nodes.shape[0]) < seeds.shape[0]),
        }

    def batches(self, batch_size: int, n_batches: int):
        for _ in range(n_batches):
            seeds = self.rng.integers(0, self.g.n_nodes, batch_size, dtype=np.int64)
            yield self.sample(seeds)


def pack_molecule_batch(rng: np.random.Generator, n_graphs: int, n_nodes: int,
                        n_edges: int, d_feat: int, n_classes: int):
    """Pack ``n_graphs`` disjoint small graphs into one padded super-graph."""
    N = n_graphs * n_nodes
    src = np.concatenate([
        rng.integers(0, n_nodes, n_edges, dtype=np.int32) + g * n_nodes
        for g in range(n_graphs)])
    dst = np.concatenate([
        rng.integers(0, n_nodes, n_edges, dtype=np.int32) + g * n_nodes
        for g in range(n_graphs)])
    return {
        "x": rng.standard_normal((N, d_feat), dtype=np.float32),
        "src": src,
        "dst": dst,
        "graph_ids": np.repeat(np.arange(n_graphs, dtype=np.int32), n_nodes),
        "node_counts": np.full((n_graphs,), n_nodes, np.int32),
        "labels": rng.integers(0, n_classes, n_graphs, dtype=np.int32),
    }
