"""Graph attention network (GAT) via segment ops — the SpMM/SDDMM regime.

JAX has no CSR SpMM; message passing is built from first principles:
SDDMM-style edge scores -> segment-softmax over incoming edges ->
scatter-sum aggregation (``jax.ops.segment_sum``).  This *is* part of the
system, per the brief.

Covers all four gat-cora shape cells:
  full_graph_sm / ogb_products — full-batch node classification
  minibatch_lg                 — sampled subgraphs from :mod:`repro.models.sampler`
  molecule                     — batched small graphs packed disjointly + readout
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.common import DEFAULT_DTYPE
from repro.models.layers import dense_init
from repro.sharding import Ax


@dataclasses.dataclass(frozen=True, kw_only=True)
class GATConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 8           # per-head hidden dim
    n_heads: int = 8
    d_feat: int = 1433
    n_classes: int = 7
    negative_slope: float = 0.2
    readout: str | None = None  # None (node-level) | "mean" (graph-level)
    dtype: Any = jnp.float32


def init_params(cfg: GATConfig, key) -> dict[str, Any]:
    ks = jax.random.split(key, 2 * cfg.n_layers)
    layers = []
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        h = 1 if last else cfg.n_heads
        f = cfg.n_classes if last else cfg.d_hidden
        layers.append({
            "w": dense_init(ks[2 * i], (d_in, h, f), cfg.dtype),
            "a_src": dense_init(ks[2 * i + 1], (h, f), cfg.dtype),
            "a_dst": dense_init(jax.random.fold_in(ks[2 * i + 1], 1), (h, f), cfg.dtype),
            "bias": jnp.zeros((h, f), cfg.dtype),
        })
        d_in = h * f
    return {"layers": layers}


def param_logical(cfg: GATConfig) -> dict[str, Any]:
    layer = {"w": Ax(None, None, None), "a_src": Ax(None, None),
             "a_dst": Ax(None, None), "bias": Ax(None, None)}
    return {"layers": [dict(layer) for _ in range(cfg.n_layers)]}


def gat_layer(p, x, src, dst, n_nodes: int, *, negative_slope: float = 0.2,
              final: bool = False):
    """x [N, d_in]; src/dst [E] int32. Returns [N, H*F] (or [N, F] if final)."""
    h = jnp.einsum("nd,dhf->nhf", x, p["w"])               # [N, H, F]
    s_src = jnp.sum(h * p["a_src"], axis=-1)               # [N, H]
    s_dst = jnp.sum(h * p["a_dst"], axis=-1)
    e = s_src[src] + s_dst[dst]                            # [E, H] SDDMM scores
    e = jax.nn.leaky_relu(e, negative_slope).astype(jnp.float32)
    # segment softmax over incoming edges of each dst node
    e_max = jax.ops.segment_max(e, dst, num_segments=n_nodes)
    e_max = jnp.where(jnp.isfinite(e_max), e_max, 0.0)
    alpha = jnp.exp(e - e_max[dst])
    denom = jax.ops.segment_sum(alpha, dst, num_segments=n_nodes)
    alpha = alpha / jnp.maximum(denom[dst], 1e-9)
    # SpMM: aggregate alpha-weighted source features
    msgs = h[src] * alpha[..., None].astype(h.dtype)        # [E, H, F]
    agg = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes) + p["bias"]
    if final:
        return jnp.mean(agg, axis=1)                        # average heads
    return jax.nn.elu(agg).reshape(n_nodes, -1)             # concat heads


def forward(cfg: GATConfig, params, graph: dict[str, jax.Array], *, mesh=None):
    """graph: {x [N,d], src [E], dst [E], (graph_ids [N], n_graphs)}."""
    x, src, dst = graph["x"], graph["src"], graph["dst"]
    n_nodes = x.shape[0]
    if mesh is not None:
        profile = sh.PROFILES["tp"](mesh)
        src = sh.constrain(src, (sh.EDGES,), mesh, profile)
        dst = sh.constrain(dst, (sh.EDGES,), mesh, profile)
    for i, p in enumerate(params["layers"]):
        final = i == cfg.n_layers - 1
        x = gat_layer(p, x, src, dst, n_nodes,
                      negative_slope=cfg.negative_slope, final=final)
    if cfg.readout == "mean":
        gid = graph["graph_ids"]
        n_graphs = graph["node_counts"].shape[0]
        summed = jax.ops.segment_sum(x, gid, num_segments=n_graphs)
        return summed / jnp.maximum(graph["node_counts"][:, None], 1).astype(x.dtype)
    return x  # [N, n_classes] logits


def loss_fn(cfg: GATConfig, params, batch, *, mesh=None):
    """Masked node (or graph) classification cross-entropy."""
    logits = forward(cfg, params, batch, mesh=mesh).astype(jnp.float32)
    labels = batch["labels"]
    mask = batch.get("label_mask")
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if mask is not None:
        m = mask.astype(jnp.float32)
        loss = jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    else:
        loss = jnp.mean(nll)
    return loss, {"ce": loss}
