"""Mixture-of-Experts FFN with capacity-bounded top-k routing.

Two dispatch strategies (selectable per config; a §Perf lever):

* ``"scatter"`` — sort-free scatter/gather dispatch.  Positions within each
  expert are derived from a cumsum over the one-hot expert assignment; tokens
  are scattered into an ``[E, C, d]`` buffer, expert FFNs run as one batched
  einsum over ``E`` (EP-sharded over the ``model`` mesh axis), and outputs are
  gathered back.  Dispatch itself costs ~zero FLOPs.
* ``"einsum"`` — GShard/t5x-style one-hot einsum dispatch over token groups.
  Robust under any partitioner but pays O(g·k·cf/d_ff-ish) FLOP overhead.

Covers Llama-4-Scout (16 routed top-1 + 1 shared expert, sigmoid router) and
OLMoE (64 routed top-8, softmax, normalized gates).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.common import DEFAULT_DTYPE, cdiv, round_up
from repro.models.layers import dense_init, mlp_init, mlp_apply


@dataclasses.dataclass(frozen=True, kw_only=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0           # shared (always-on) experts
    d_ff_shared: int = 0
    router_act: str = "softmax"  # or "sigmoid" (llama4)
    normalize_gates: bool = True
    capacity_factor: float = 1.25
    dispatch: str = "scatter"    # or "einsum"
    group_size: int = 1024       # einsum dispatch group
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3


def moe_init(key, d_model: int, cfg: MoEConfig, dtype=DEFAULT_DTYPE) -> dict[str, Any]:
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d_model, cfg.n_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (cfg.n_experts, d_model, cfg.d_ff_expert), dtype),
        "w_up": dense_init(ks[2], (cfg.n_experts, d_model, cfg.d_ff_expert), dtype),
        "w_down": dense_init(ks[3], (cfg.n_experts, cfg.d_ff_expert, d_model), dtype),
    }
    if cfg.n_shared:
        p["shared"] = mlp_init(ks[4], d_model, cfg.d_ff_shared or cfg.d_ff_expert, dtype)
    return p


def _routing(xt: jax.Array, router: jax.Array, cfg: MoEConfig):
    """Returns (gates [N,k], expert_idx [N,k], aux_metrics dict)."""
    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32), router)
    if cfg.router_act == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(scores, cfg.top_k)
    if cfg.normalize_gates and cfg.top_k > 1:
        gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)

    # Switch-style load-balance loss + router z-loss.
    probs = scores if cfg.router_act == "softmax" else jax.nn.softmax(logits, -1)
    density = jnp.mean(
        jax.nn.one_hot(expert_idx, cfg.n_experts, dtype=jnp.float32).sum(1), axis=0)
    density_prob = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(density / cfg.top_k * density_prob)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    metrics = {"moe_aux": aux * cfg.aux_loss_weight,
               "moe_z": z * cfg.router_z_weight}
    return gates.astype(xt.dtype), expert_idx, metrics


def _expert_ffn(p, buf: jax.Array) -> jax.Array:
    """buf [E, C, d] -> [E, C, d] via per-expert SwiGLU."""
    gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up, p["w_down"])


def _dispatch_scatter(p, xt, gates, expert_idx, cfg: MoEConfig, capacity: int):
    N, d = xt.shape
    k, E, C = cfg.top_k, cfg.n_experts, capacity
    flat_e = expert_idx.reshape(-1)                                   # [N*k]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)               # [N*k, E]
    pos = jnp.cumsum(onehot, axis=0) - 1
    mypos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]   # [N*k]
    keep = mypos < C
    slot = jnp.where(keep, mypos, C)  # overflow slot C is sliced off below
    x_rep = jnp.repeat(xt, k, axis=0) * keep[:, None].astype(xt.dtype)
    buf = jnp.zeros((E, C + 1, d), xt.dtype).at[flat_e, slot].add(x_rep)
    y = _expert_ffn(p, buf[:, :C])                                    # [E, C, d]
    y = jnp.pad(y, ((0, 0), (0, 1), (0, 0)))                          # re-add slot C
    out_tok = y[flat_e, slot] * (gates.reshape(-1, 1) * keep[:, None].astype(xt.dtype))
    return out_tok.reshape(N, k, d).sum(axis=1)


def _dispatch_einsum(p, xt, gates, expert_idx, cfg: MoEConfig, capacity: int):
    N, d = xt.shape
    k, E = cfg.top_k, cfg.n_experts
    g = min(cfg.group_size, N)
    n_groups = cdiv(N, g)
    pad = n_groups * g - N
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
        gates = jnp.pad(gates, ((0, pad), (0, 0)))
        expert_idx = jnp.pad(expert_idx, ((0, pad), (0, 0)))
    C = max(1, round_up(cdiv(int(cfg.capacity_factor * k * g), E), 4))
    xg = xt.reshape(n_groups, g, d)
    eg = expert_idx.reshape(n_groups, g, k)
    wg = gates.reshape(n_groups, g, k)
    onehot = jax.nn.one_hot(eg, E, dtype=jnp.int32)                  # [G,g,k,E]
    pos = jnp.cumsum(onehot.reshape(n_groups, g * k, E), axis=1).reshape(
        n_groups, g, k, E) * onehot - 1
    keep = (pos < C) & (pos >= 0)
    dis = jax.nn.one_hot(jnp.where(keep, pos, C), C, dtype=xt.dtype) * keep[..., None]
    dispatch = (dis * onehot[..., None].astype(xt.dtype)).sum(2)      # [G,g,E,C]
    combine = (dis * (onehot.astype(xt.dtype) * wg[..., None])[..., None]).sum(2)
    buf = jnp.einsum("Ggec,Ggd->Gecd", dispatch, xg)
    y = jax.vmap(lambda b: _expert_ffn(p, b))(buf)                    # [G,E,C,d]
    out = jnp.einsum("Ggec,Gecd->Ggd", combine, y).reshape(-1, d)
    return out[:N]


def moe_apply(p: dict[str, Any], x: jax.Array, cfg: MoEConfig):
    """x [B, S, d] -> (out [B, S, d], metrics)."""
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    gates, expert_idx, metrics = _routing(xt, p["router"], cfg)
    capacity = max(1, round_up(
        cdiv(int(cfg.capacity_factor * cfg.top_k * B * S), cfg.n_experts), 8))
    if cfg.dispatch == "scatter":
        out = _dispatch_scatter(p, xt, gates, expert_idx, cfg, capacity)
    else:
        out = _dispatch_einsum(p, xt, gates, expert_idx, cfg, capacity)
    if cfg.n_shared:
        out = out + mlp_apply(p["shared"], x).reshape(B * S, d)
    return out.reshape(B, S, d), metrics
