"""Decoder-only GQA transformer LM (scan-over-layers, shardable).

One implementation covers all five assigned LM archs:
qwen2-1.5b (QKV bias, tied embeddings), glm4-9b, internlm2-1.8b,
llama4-scout-17b-a16e (MoE every layer, chunked local attention on 3/4
layers), olmoe-1b-7b (MoE 64e top-8).

Layer params are stacked on a leading ``L`` dim and the forward pass is a
``jax.lax.scan`` so compile time is O(1) in depth; layer bodies are
``jax.checkpoint``-rematerialised during training.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.common import DEFAULT_DTYPE
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.sharding import Ax


@dataclasses.dataclass(frozen=True, kw_only=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_q: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    moe: moe_lib.MoEConfig | None = None
    # per-layer chunked local attention: 0 = all-global; else layers whose
    # index % chunk_every != chunk_every-1 use chunked attention (llama4 iRoPE)
    attn_chunk: int = 0
    attn_chunk_every: int = 4
    # execution knobs
    attn_impl: str = "xla"           # "xla" | "pallas"
    remat: bool = True
    sharding_profile: str = "tp"     # "tp" | "fsdp"
    seq_parallel: bool = False       # shard residual seq dim over 'model'
    dtype: Any = DEFAULT_DTYPE

    @property
    def params_dense(self) -> int:
        """Approximate parameter count excluding MoE experts."""
        d, h = self.d_model, self.d_head
        attn = self.n_layers * d * h * (2 * self.n_q + 2 * self.n_kv)
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        mlp = 0 if self.moe else self.n_layers * 3 * d * self.d_ff
        return attn + emb + mlp + 2 * self.n_layers * d

    @property
    def params_total(self) -> int:
        n = self.params_dense
        if self.moe:
            m = self.moe
            n += self.n_layers * m.n_experts * 3 * self.d_model * m.d_ff_expert
            n += self.n_layers * self.d_model * m.n_experts
            if m.n_shared:
                n += self.n_layers * 3 * self.d_model * (m.d_ff_shared or m.d_ff_expert)
        return n

    @property
    def params_active(self) -> int:
        n = self.params_dense
        if self.moe:
            m = self.moe
            n += self.n_layers * m.top_k * 3 * self.d_model * m.d_ff_expert
            if m.n_shared:
                n += self.n_layers * 3 * self.d_model * (m.d_ff_shared or m.d_ff_expert)
        return n

    def attn_dims(self) -> L.AttnDims:
        return L.AttnDims(d_model=self.d_model, n_q=self.n_q, n_kv=self.n_kv,
                          d_head=self.d_head, qkv_bias=self.qkv_bias,
                          rope_theta=self.rope_theta)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(cfg: LMConfig, key) -> dict[str, Any]:
    kemb, kout, klay = jax.random.split(key, 3)

    def layer_init(k):
        ka, km, _ = jax.random.split(k, 3)
        p = {
            "attn": L.attn_init(ka, cfg.attn_dims(), cfg.dtype),
            "ln_attn": jnp.ones((cfg.d_model,), jnp.float32),
            "ln_mlp": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if cfg.moe:
            p["moe"] = moe_lib.moe_init(km, cfg.d_model, cfg.moe, cfg.dtype)
        else:
            p["mlp"] = L.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.dtype)
        return p

    layer_params = jax.vmap(layer_init)(jax.random.split(klay, cfg.n_layers))
    params = {
        "embed": L.dense_init(kemb, (cfg.vocab, cfg.d_model), cfg.dtype, scale=1.0),
        "layers": layer_params,
        "ln_final": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(kout, (cfg.d_model, cfg.vocab), cfg.dtype)
    return params


def param_logical(cfg: LMConfig) -> dict[str, Any]:
    """Logical-axis tree mirroring :func:`init_params` output."""
    attn = {
        "wq": Ax(None, sh.EMBED, sh.Q_HEADS, sh.HEAD_DIM),
        "wk": Ax(None, sh.EMBED, sh.KV_HEADS, sh.HEAD_DIM),
        "wv": Ax(None, sh.EMBED, sh.KV_HEADS, sh.HEAD_DIM),
        "wo": Ax(None, sh.Q_HEADS, sh.HEAD_DIM, sh.EMBED),
    }
    if cfg.qkv_bias:
        attn |= {"bq": Ax(None, sh.Q_HEADS, sh.HEAD_DIM),
                 "bk": Ax(None, sh.KV_HEADS, sh.HEAD_DIM),
                 "bv": Ax(None, sh.KV_HEADS, sh.HEAD_DIM)}
    layer = {"attn": attn,
             "ln_attn": Ax(None, None), "ln_mlp": Ax(None, None)}
    if cfg.moe:
        layer["moe"] = {
            "router": Ax(None, sh.EMBED, None),
            "w_gate": Ax(None, sh.EXPERTS, sh.EMBED, sh.MLP),
            "w_up": Ax(None, sh.EXPERTS, sh.EMBED, sh.MLP),
            "w_down": Ax(None, sh.EXPERTS, sh.MLP, sh.EMBED),
        }
        if cfg.moe.n_shared:
            layer["moe"]["shared"] = {
                "w_gate": Ax(None, sh.EMBED, sh.MLP),
                "w_up": Ax(None, sh.EMBED, sh.MLP),
                "w_down": Ax(None, sh.MLP, sh.EMBED),
            }
    else:
        layer["mlp"] = {"w_gate": Ax(None, sh.EMBED, sh.MLP),
                        "w_up": Ax(None, sh.EMBED, sh.MLP),
                        "w_down": Ax(None, sh.MLP, sh.EMBED)}
    tree = {"embed": Ax(sh.VOCAB, sh.EMBED),
            "layers": layer,
            "ln_final": Ax(None)}
    if not cfg.tie_embeddings:
        tree["unembed"] = Ax(sh.EMBED, sh.VOCAB)
    return tree


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_chunks(cfg: LMConfig) -> jax.Array:
    """Per-layer attention chunk size (0 = global attention)."""
    idx = jnp.arange(cfg.n_layers)
    if cfg.attn_chunk:
        is_chunked = (idx % cfg.attn_chunk_every) != (cfg.attn_chunk_every - 1)
        return jnp.where(is_chunked, cfg.attn_chunk, 0).astype(jnp.int32)
    return jnp.zeros((cfg.n_layers,), jnp.int32)


def _chunk_bias(q_pos, k_pos, chunk: jax.Array) -> jax.Array:
    """Causal+chunk additive bias with *traced* per-layer chunk size."""
    q = q_pos[:, None]
    k = k_pos[None, :]
    ok = k <= q
    in_chunk = jnp.where(chunk > 0, (k // jnp.maximum(chunk, 1)) == (q // jnp.maximum(chunk, 1)), True)
    return jnp.where(ok & in_chunk, 0.0, L.NEG_INF).astype(jnp.float32)


def _attn_with_traced_chunk(p, x, cfg: LMConfig, positions, chunk,
                            kv_cache=None, cache_index=None):
    """attn_apply variant where chunk is a traced scalar (scan-carried)."""
    dims = cfg.attn_dims()
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if dims.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = L.apply_rope(q, positions, dims.rope_theta)
    k = L.apply_rope(k, positions, dims.rope_theta)
    new_cache = None
    # prefill at offset 0: attention only sees the fresh tokens (causal),
    # so the flash path applies even though we also write the cache
    flash_prefill = (cfg.attn_impl in ("flash", "pallas")
                     and kv_cache is not None
                     and isinstance(cache_index, int) and cache_index == 0
                     and x.shape[1] > 1)
    if kv_cache is not None:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_index, 0, 0))
        new_cache = (ck, cv)
        if flash_prefill:
            bias = _chunk_bias(positions, positions, chunk)
        else:
            k, v = ck, cv
            k_pos = jnp.arange(ck.shape[1], dtype=jnp.int32)
            bias = _chunk_bias(positions, k_pos, chunk)
            valid = k_pos[None, :] < (cache_index + x.shape[1])
            bias = jnp.where(valid, bias, L.NEG_INF)
    else:
        bias = _chunk_bias(positions, positions, chunk)
    impl = cfg.attn_impl
    if impl in ("pallas", "flash") and kv_cache is not None and not flash_prefill:
        impl = "xla"   # decode stays on the xla path (bias-driven masks)
    if impl == "flash" and cfg.attn_chunk:
        # per-layer traced chunk flag selects between two static-chunk flash
        # branches (llama4 interleave: 3/4 chunked-local, 1/4 global)
        from repro.kernels.flash_attention.ops import flash_attention_xla
        out = jax.lax.cond(
            chunk > 0,
            lambda: flash_attention_xla(q, k, v, causal=True,
                                        chunk=cfg.attn_chunk),
            lambda: flash_attention_xla(q, k, v, causal=True, chunk=0))
    else:
        out = L.gqa_attention(q, k, v, bias, impl=impl)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def _block(cfg: LMConfig, mesh, profile, p, x, positions, chunk,
           kv_cache=None, cache_index=None):
    """One transformer layer. Returns (x', new_cache, metrics)."""
    def cst(t):
        if mesh is None:
            return t
        seq = "model" if cfg.seq_parallel else None
        return jax.lax.with_sharding_constraint(
            t, sh.named_sharding(mesh, (sh.BATCH, seq, None), t.shape, profile))

    h = L.rmsnorm(x, p["ln_attn"], cfg.norm_eps)
    attn_out, new_cache = _attn_with_traced_chunk(
        p["attn"], h, cfg, positions, chunk, kv_cache, cache_index)
    x = cst(x + attn_out)
    h = L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
    metrics = {}
    if cfg.moe:
        mlp_out, metrics = moe_lib.moe_apply(p["moe"], h, cfg.moe)
    else:
        mlp_out = L.mlp_apply(p["mlp"], h)
    x = cst(x + mlp_out)
    return x, new_cache, metrics


def forward(cfg: LMConfig, params, tokens: jax.Array, *, mesh=None) -> tuple[jax.Array, dict]:
    """Training forward: tokens [B, S] -> logits [B, S, V] (+ aux metrics)."""
    profile = sh.PROFILES[cfg.sharding_profile](mesh) if mesh is not None else None
    B, S = tokens.shape
    x = params["embed"].astype(cfg.dtype)[tokens]
    positions = jnp.arange(S, dtype=jnp.int32)
    chunks = _layer_chunks(cfg)

    def body(x, scanned):
        layer_p, chunk = scanned
        x, _, metrics = _block(cfg, mesh, profile, layer_p, x, positions, chunk)
        return x, metrics

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, metrics = jax.lax.scan(body, x, (params["layers"], chunks))
    x = L.rmsnorm(x, params["ln_final"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = jnp.einsum("bsd,dv->bsv", x, unembed.astype(cfg.dtype))
    if mesh is not None:
        logits = jax.lax.with_sharding_constraint(
            logits, sh.named_sharding(mesh, (sh.BATCH, None, sh.VOCAB),
                                      logits.shape, profile))
    aux = {k: jnp.sum(v) for k, v in metrics.items()}
    return logits, aux


def loss_fn(cfg: LMConfig, params, batch, *, mesh=None):
    """Next-token cross-entropy (fp32 logsumexp) + MoE aux losses."""
    tokens, targets = batch["tokens"], batch["targets"]
    logits, aux = forward(cfg, params, tokens, mesh=mesh)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    mask = (targets >= 0).astype(jnp.float32)
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = ce + sum(aux.values(), jnp.float32(0.0))
    return total, {"ce": ce, **aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode with stacked KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_logical():
    return {"k": Ax(None, sh.BATCH, sh.KV_SEQ, sh.KV_HEADS, None),
            "v": Ax(None, sh.BATCH, sh.KV_SEQ, sh.KV_HEADS, None)}


def _serve_pass(cfg: LMConfig, params, tokens, cache, start_pos, *, mesh=None):
    """Shared prefill/decode pass: runs tokens [B, S] at absolute offset
    ``start_pos`` against the cache; returns (logits_last, new_cache)."""
    profile = sh.PROFILES[cfg.sharding_profile](mesh) if mesh is not None else None
    B, S = tokens.shape
    x = params["embed"].astype(cfg.dtype)[tokens]
    positions = start_pos + jnp.arange(S, dtype=jnp.int32)
    chunks = _layer_chunks(cfg)

    def body(x, scanned):
        layer_p, chunk, ck, cv = scanned
        x, new_cache, _ = _block(cfg, mesh, profile, layer_p, x, positions, chunk,
                                 kv_cache=(ck, cv), cache_index=start_pos)
        return x, new_cache

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], chunks, cache["k"], cache["v"]))
    x = L.rmsnorm(x[:, -1:], params["ln_final"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = jnp.einsum("bsd,dv->bsv", x, unembed.astype(cfg.dtype))[:, 0]
    return logits, {"k": new_k, "v": new_v}


def prefill(cfg: LMConfig, params, tokens, cache, *, mesh=None):
    # static offset 0 keeps the cache update a statically-placed slice
    return _serve_pass(cfg, params, tokens, cache, 0, mesh=mesh)


def decode_step(cfg: LMConfig, params, token, cache, pos, *, mesh=None):
    """token [B, 1] int32, pos: scalar int32 absolute position."""
    return _serve_pass(cfg, params, token, cache, pos, mesh=mesh)
