"""Version-compat shims for the pinned accelerator stack.

``jax.experimental.pallas.tpu`` renamed ``TPUCompilerParams`` to
``CompilerParams`` across JAX releases; resolve whichever the installed
version exposes so kernels never hard-code either name.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
