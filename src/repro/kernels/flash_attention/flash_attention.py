"""Pallas TPU kernel: causal GQA flash attention (+ chunked-local masking).

The LM hot spot (train_4k / prefill_32k cells).  Grid =
(batch, q_heads, q_blocks, kv_blocks) with the kv dim innermost/sequential;
VMEM scratch carries the online-softmax state (m, l, acc) across kv blocks.
Causal + Llama-4 chunked-local masks are computed from global indices; fully
masked kv blocks are skipped before their compute issues (``@pl.when``),
so chunked layers cost O(S·chunk), not O(S²).

Memory: O(bq·bkv + bq·D) VMEM per step vs the O(S·T) HLO scores tensor of
the xla path — the §Perf memory-term fix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

DEFAULT_BQ = 512
DEFAULT_BKV = 512
NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq, bkv, n_kv_blocks, causal, chunk, scale):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_idx = qb * bq + jax.lax.iota(jnp.int32, bq)
    k_idx = kb * bkv + jax.lax.iota(jnp.int32, bkv)

    # block-level skip: causal (kv block entirely in the future) and
    # chunked-local (kv block entirely outside the q block's chunk range)
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, (kb * bkv) <= (qb * bq + bq - 1))
    if chunk:
        lo_chunk = (qb * bq) // chunk
        hi_chunk = (qb * bq + bq - 1) // chunk
        run = jnp.logical_and(run, (kb * bkv + bkv - 1) // chunk >= lo_chunk)
        run = jnp.logical_and(run, (kb * bkv) // chunk <= hi_chunk)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)                # [bkv, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [bq,bkv]
        ok = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            ok = ok & (k_idx[None, :] <= q_idx[:, None])
        if chunk:
            ok = ok & ((k_idx[None, :] // chunk) == (q_idx[:, None] // chunk))
        s = jnp.where(ok, s, NEG)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kb == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "chunk", "bq", "bkv", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, chunk: int = 0,
                           bq: int = DEFAULT_BQ, bkv: int = DEFAULT_BKV,
                           interpret: bool = False):
    """q [B,S,H,D]; k/v [B,T,Hkv,D] -> out [B,S,H,D] (GQA folded via the
    kv-head index map h -> h // G).  Requires S % bq == 0, T % bkv == 0."""
    B, S, H, D = q.shape
    T, HKV = k.shape[1], k.shape[2]
    G = H // HKV
    assert S % bq == 0 and T % bkv == 0, (S, T, bq, bkv)
    grid = (B, H, S // bq, T // bkv)
    scale = D ** -0.5

    # [B, H, S, D] layout so blocks are [1, 1, bq, D]
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)

    kernel = functools.partial(_kernel, bq=bq, bkv=bkv,
                               n_kv_blocks=T // bkv, causal=causal,
                               chunk=chunk, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # m: running max
            pltpu.VMEM((bq,), jnp.float32),      # l: running denominator
            pltpu.VMEM((bq, D), jnp.float32),    # acc: running numerator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.swapaxes(out, 1, 2)
