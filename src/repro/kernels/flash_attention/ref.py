"""Pure-jnp oracle for flash attention (GQA, causal + chunked-local)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, chunk: int = 0):
    B, S, H, D = q.shape
    T, HKV = k.shape[1], k.shape[2]
    G = H // HKV
    qg = q.reshape(B, S, HKV, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, kf) * (D ** -0.5)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= ki <= qi
    if chunk:
        ok &= (ki // chunk) == (qi // chunk)
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, vf)
    return out.reshape(B, S, H, D).astype(q.dtype)
