"""Jitted wrapper for flash attention with backend dispatch + custom VJP.

The backward pass recomputes attention flash-style (no O(S·T) residuals),
which is what collapses the memory roofline term of the train cells
(EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    DEFAULT_BKV, DEFAULT_BQ, flash_attention_pallas)
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash(q, k, v, causal, chunk):
    return flash_attention_ref(q, k, v, causal=causal, chunk=chunk)


def _flash_fwd(q, k, v, causal, chunk):
    return _flash(q, k, v, causal, chunk), (q, k, v)


def _flash_bwd(causal, chunk, res, g):
    q, k, v = res
    # rematerialised backward: recompute probs, no saved score tensors
    _, vjp = jax.vjp(
        lambda q_, k_, v_: flash_attention_ref(q_, k_, v_, causal=causal,
                                               chunk=chunk), q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# q-chunked flash on the XLA path (the §Perf memory-term optimisation)
# ---------------------------------------------------------------------------
#
# lax.scan over q blocks; each block sees its full kv row at once (row-exact
# softmax, no online rescale needed), so the largest transient is
# [B, bq, H, T] instead of [B, H, S, T], and the custom VJP saves only
# (q, k, v, o, lse) — O(S·D) residuals.  This is what a TPU flash kernel
# does, expressed in HLO so the CPU dry-run measures it.

FLASH_BQ = 512


def _mask(q_idx, k_idx, causal, chunk):
    ok = jnp.ones((q_idx.shape[0], k_idx.shape[0]), bool)
    if causal:
        ok &= k_idx[None, :] <= q_idx[:, None]
    if chunk:
        ok &= (k_idx[None, :] // chunk) == (q_idx[:, None] // chunk)
    return ok


def _fwd_block(qb, kh, vh, q_idx, k_idx, causal, chunk, scale):
    """qb [B,bq,H,D]; kh/vh [B,T,H,D] -> (ob, lse_b)."""
    s = jnp.einsum("bqhd,bthd->bqht", qb.astype(jnp.float32),
                   kh.astype(jnp.float32)) * scale
    ok = _mask(q_idx, k_idx, causal, chunk)
    s = jnp.where(ok[None, :, None, :], s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bqht,bthd->bqhd", p.astype(vh.dtype), vh)
    o = o / jnp.maximum(l, 1e-20)[..., None].astype(o.dtype)
    lse = m + jnp.log(jnp.maximum(l, 1e-20))
    return o, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_chunked(q, kh, vh, causal, chunk, bq):
    o, _ = _flash_chunked_fwd_impl(q, kh, vh, causal, chunk, bq)
    return o


def _flash_chunked_fwd_impl(q, kh, vh, causal, chunk, bq):
    B, S, H, D = q.shape
    T = kh.shape[1]
    nb = S // bq
    scale = D ** -0.5
    k_idx = jnp.arange(T, dtype=jnp.int32)
    qb = jnp.moveaxis(q.reshape(B, nb, bq, H, D), 1, 0)

    def body(_, inp):
        qblk, i = inp
        q_idx = i * bq + jnp.arange(bq, dtype=jnp.int32)
        return None, _fwd_block(qblk, kh, vh, q_idx, k_idx, causal, chunk,
                                scale)

    _, (o, lse) = jax.lax.scan(body, None, (qb, jnp.arange(nb)))
    o = jnp.moveaxis(o, 0, 1).reshape(B, S, H, D)
    lse = jnp.moveaxis(lse, 0, 1).reshape(B, S, H)
    return o, lse


def _flash_chunked_fwd(q, kh, vh, causal, chunk, bq):
    o, lse = _flash_chunked_fwd_impl(q, kh, vh, causal, chunk, bq)
    return o, (q, kh, vh, o, lse)


def _flash_chunked_bwd(causal, chunk, bq, res, do):
    q, kh, vh, o, lse = res
    B, S, H, D = q.shape
    T = kh.shape[1]
    nb = S // bq
    scale = D ** -0.5
    k_idx = jnp.arange(T, dtype=jnp.int32)
    mv = lambda x: jnp.moveaxis(x.reshape(B, nb, bq, *x.shape[2:]), 1, 0)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), -1)  # [B,S,H]

    def body(carry, inp):
        dk, dv = carry
        qblk, doblk, lseblk, dblk, i = inp
        q_idx = i * bq + jnp.arange(bq, dtype=jnp.int32)
        s = jnp.einsum("bqhd,bthd->bqht", qblk.astype(jnp.float32),
                       kh.astype(jnp.float32)) * scale
        ok = _mask(q_idx, k_idx, causal, chunk)
        s = jnp.where(ok[None, :, None, :], s, -1e30)
        p = jnp.exp(s - lseblk[..., None])                     # [B,bq,H,T]
        dp = jnp.einsum("bqhd,bthd->bqht", doblk.astype(jnp.float32),
                        vh.astype(jnp.float32))
        ds = p * (dp - dblk[..., None]) * scale
        dq_b = jnp.einsum("bqht,bthd->bqhd", ds,
                          kh.astype(jnp.float32))
        dk = dk + jnp.einsum("bqht,bqhd->bthd", ds, qblk.astype(jnp.float32))
        dv = dv + jnp.einsum("bqht,bqhd->bthd", p, doblk.astype(jnp.float32))
        return (dk, dv), dq_b

    zeros = jnp.zeros((B, T, H, D), jnp.float32)
    (dk, dv), dq = jax.lax.scan(
        body, (zeros, zeros),
        (mv(q), mv(do), mv(lse), mv(delta), jnp.arange(nb)))
    dq = jnp.moveaxis(dq, 0, 1).reshape(B, S, H, D).astype(q.dtype)
    return dq, dk.astype(kh.dtype), dv.astype(vh.dtype)


_flash_chunked.defvjp(_flash_chunked_fwd, _flash_chunked_bwd)


def flash_attention_xla(q, k, v, *, causal: bool = True, chunk: int = 0,
                        bq: int = FLASH_BQ):
    """GQA wrapper: expand kv heads (broadcast view) and run the q-chunked
    flash path; exact vs the naive reference, O(S·D) residuals."""
    B, S, H, D = q.shape
    HKV = k.shape[2]
    G = H // HKV
    kh = jnp.repeat(k, G, axis=2) if G > 1 else k
    vh = jnp.repeat(v, G, axis=2) if G > 1 else v
    bq_eff = min(bq, S)
    while S % bq_eff:
        bq_eff //= 2
    return _flash_chunked(q, kh, vh, causal, chunk, max(bq_eff, 1))


def flash_attention(q, k, v, *, causal: bool = True, chunk: int = 0,
                    bq: int = DEFAULT_BQ, bkv: int = DEFAULT_BKV,
                    impl: str = "auto", interpret: bool = False,
                    bias=None):
    """Causal GQA flash attention. [B,S,H,D] x [B,T,Hkv,D] -> [B,S,H,D].

    ``bias`` is accepted for interface parity with the xla path but must be
    None (masks are causal/chunk-structural in the kernel).
    """
    assert bias is None, "flash kernel computes masks structurally"
    S, T = q.shape[1], k.shape[1]
    if impl == "auto":
        use_pallas = (jax.default_backend() == "tpu" and S % bq == 0
                      and T % bkv == 0)
        impl = "pallas" if use_pallas else "remat_ref"
    if impl == "pallas":
        return flash_attention_pallas(q, k, v, causal=causal, chunk=chunk,
                                      bq=min(bq, S), bkv=min(bkv, T),
                                      interpret=interpret)
    if impl == "interpret":
        return flash_attention_pallas(q, k, v, causal=causal, chunk=chunk,
                                      bq=min(bq, S), bkv=min(bkv, T),
                                      interpret=True)
    return _flash(q, k, v, causal, chunk)
