"""Pure-jnp oracle for streaming_dense_topk.

Shape of the computation matters beyond correctness: the IR fusion pass
cost-gates the kernel lowering by comparing optimized-HLO proxies, and the
*unfused* dense paths (``index/dense.py``) score candidates with exactly the
expression below — so on hosts where the kernel falls back to this oracle, a
fused candidate at the same ``k`` prices identical to its unfused twin and
the strictly-cheaper gate correctly declines the rewrite.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_topk_ref(emb, qvec, base=None, *, k: int):
    scores = emb.astype(jnp.float32) @ qvec.astype(jnp.float32)
    if base is not None:
        scores = scores + base
    vals, idxs = jax.lax.top_k(scores, k)
    return vals, idxs.astype(jnp.int32)
