"""Pallas TPU kernel: blocked query x doc-embedding matmul fused with
streaming top-k (the dense second-stage hot path).

Candidate generation / re-scoring over a dense index is a matrix-vector
product ``emb @ qvec`` followed by a rank cutoff.  Unfused, the [N] score
vector round-trips through HBM and is then fully sorted; this kernel streams
embedding blocks through VMEM, scores each [BLOCK_D, dim] tile on the MXU,
adds a per-row ``base`` score (the sparse first-stage contribution of a
fused rerank, doubling as the validity mask: padded / invalid rows carry
``NEG``), and merges the block into a running [k] top-k scratch with the
``streaming_merge`` accumulator shared with ``kernels/topk``.  A block whose
best fused score is <= the running k-th score is skipped entirely
(``@pl.when``) — block-max pruning at dense-scoring granularity.

Intended for k <= 128 (the rank-cutoff regime); larger k falls back to the
``lax.top_k`` oracle in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.topk.topk import NEG, streaming_merge

BLOCK_D = 1024


def _kernel(emb_ref, q_ref, base_ref, vals_ref, idxs_ref, *, k, block):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        vals_ref[...] = jnp.full((k,), NEG, jnp.float32)
        idxs_ref[...] = jnp.full((k,), -1, jnp.int32)

    emb = emb_ref[...].astype(jnp.float32)               # [block, dim]
    q = q_ref[...].astype(jnp.float32)                   # [dim]
    scores = jnp.dot(emb, q, preferred_element_type=jnp.float32) \
        + base_ref[...].astype(jnp.float32)              # [block]
    gidx = b * block + jax.lax.iota(jnp.int32, block)
    theta = jnp.min(vals_ref[...])

    @pl.when(jnp.max(scores) > theta)                    # block-max skip
    def _merge():
        vals, idxs = streaming_merge(scores, gidx, vals_ref[...],
                                     idxs_ref[...], k=k)
        vals_ref[...] = vals
        idxs_ref[...] = idxs


@functools.partial(jax.jit, static_argnames=("k", "block", "interpret"))
def dense_topk_pallas(emb, qvec, base, *, k: int, block: int = BLOCK_D,
                      interpret: bool = False):
    """emb [N, dim] (N % block == 0), qvec [dim], base [N] ->
    (values [k], indices [k]) of ``emb @ qvec + base``, sorted descending."""
    n, dim = emb.shape
    assert n % block == 0, (n, block)
    kernel = functools.partial(_kernel, k=k, block=block)

    vals, idxs = pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block, dim), lambda i: (i, 0)),
                  pl.BlockSpec((dim,), lambda i: (0,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((k,), lambda i: (0,)),
                   pl.BlockSpec((k,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((k,), jnp.float32),
                   jax.ShapeDtypeStruct((k,), jnp.int32)],
        interpret=interpret,
    )(emb, qvec, base)
    order = jnp.argsort(-vals)
    return vals[order], idxs[order]
