from repro.kernels.dense_scoring.ops import streaming_dense_topk  # noqa: F401
