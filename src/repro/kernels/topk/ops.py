"""Jitted wrapper for streaming_topk (pads, falls back for large k)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import round_up
from repro.kernels.topk.ref import streaming_topk_ref
from repro.kernels.topk.topk import BLOCK_S, NEG, streaming_topk_pallas

MAX_KERNEL_K = 128


def kernel_native(k: int) -> bool:
    """Whether the Pallas kernel itself serves this ``k`` on TPU (larger k
    falls back to the ``lax.top_k`` oracle).  The IR fusion pass
    (core/passes.py) records this so gate decisions distinguish
    kernel-native lowerings from oracle-served ones."""
    return k <= MAX_KERNEL_K


def streaming_topk(scores, *, k: int, block: int = BLOCK_S,
                   impl: str = "auto", interpret: bool = False):
    """Top-k of a score vector with block-max skipping. Returns values
    sorted descending + their indices."""
    if impl == "auto":
        impl = "pallas" if (jax.default_backend() == "tpu" and
                            k <= MAX_KERNEL_K) else "ref"
    if impl == "ref" or k > MAX_KERNEL_K:
        return streaming_topk_ref(scores, k=k)
    n = scores.shape[0]
    n_pad = round_up(max(n, block), block)
    padded = jnp.pad(scores.astype(jnp.float32), (0, n_pad - n),
                     constant_values=NEG)
    return streaming_topk_pallas(
        padded, k=k, block=block,
        interpret=interpret or jax.default_backend() != "tpu")
