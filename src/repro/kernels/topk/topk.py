"""Pallas TPU kernel: streaming top-k with block-max skipping.

The TPU-idiomatic BlockMaxWAND (DESIGN.md §2): scores stream through VMEM in
blocks; a [k] scratch holds the running top-k.  A block whose max is ≤ the
running k-th score (θ) is *skipped entirely* (``@pl.when``) — the dynamic-
pruning threshold exactly as in WAND, at block granularity.  The grid is
sequential on TPU so the scratch carries across blocks.

Merge step: k iterations of (argmax over block, argmin over scratch) — pure
VPU masks/maxes, no sort.  Intended for k ≤ 128 (rank-cutoff regime of RQ1);
larger k falls back to ``lax.top_k`` in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_S = 4096
NEG = -3.0e38  # python float: jnp scalars would be captured as consts


def streaming_merge(cand, gidx, vals, idxs, *, k):
    """Merge a candidate block into the running top-k scratch: k iterations
    of (argmax over block, argmin over scratch) — pure VPU masks/maxes, no
    sort.  The streaming accumulator shared by the score-stream kernel here
    and the dense-scoring kernel (``kernels/dense_scoring``)."""

    def body(_, carry):
        cand, vals, idxs = carry
        j = jnp.argmax(cand)
        m = cand[j]
        mi = gidx[j]
        p = jnp.argmin(vals)
        take = m > vals[p]
        vals = vals.at[p].set(jnp.where(take, m, vals[p]))
        idxs = idxs.at[p].set(jnp.where(take, mi, idxs[p]))
        cand = cand.at[j].set(NEG)
        return cand, vals, idxs

    _, vals, idxs = jax.lax.fori_loop(0, k, body, (cand, vals, idxs))
    return vals, idxs


def _kernel(scores_ref, vals_ref, idxs_ref, *, k, block, n_blocks):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        vals_ref[...] = jnp.full((k,), NEG, jnp.float32)
        idxs_ref[...] = jnp.full((k,), -1, jnp.int32)

    blk = scores_ref[...].astype(jnp.float32)            # [block]
    gidx = b * block + jax.lax.iota(jnp.int32, block)
    blk_max = jnp.max(blk)
    theta = jnp.min(vals_ref[...])

    @pl.when(blk_max > theta)                            # block-max skip
    def _merge():
        vals, idxs = streaming_merge(blk, gidx, vals_ref[...], idxs_ref[...],
                                     k=k)
        vals_ref[...] = vals
        idxs_ref[...] = idxs


def _out_kernel(vals_ref, idxs_ref, ovals_ref, oidxs_ref):
    ovals_ref[...] = vals_ref[...]
    oidxs_ref[...] = idxs_ref[...]


@functools.partial(jax.jit, static_argnames=("k", "block", "interpret"))
def streaming_topk_pallas(scores, *, k: int, block: int = BLOCK_S,
                          interpret: bool = False):
    """scores [N] (N % block == 0) -> (values [k], indices [k]), unsorted."""
    n = scores.shape[0]
    assert n % block == 0, (n, block)
    n_blocks = n // block
    kernel = functools.partial(_kernel, k=k, block=block, n_blocks=n_blocks)

    vals, idxs = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((k,), lambda i: (0,)),
                   pl.BlockSpec((k,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((k,), jnp.float32),
                   jax.ShapeDtypeStruct((k,), jnp.int32)],
        interpret=interpret,
    )(scores)
    order = jnp.argsort(-vals)
    return vals[order], idxs[order]
