from repro.kernels.topk.ops import streaming_topk  # noqa: F401
