"""Pure-jnp oracle for streaming_topk."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def streaming_topk_ref(scores, *, k: int):
    vals, idxs = jax.lax.top_k(scores.astype(jnp.float32), k)
    return vals, idxs.astype(jnp.int32)
