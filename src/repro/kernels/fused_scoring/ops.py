"""Jitted public wrapper for fused_scoring (pads to the block multiple and
dispatches to the Pallas kernel, or the jnp oracle on non-TPU backends)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import round_up
from repro.kernels.fused_scoring.fused_scoring import (BLOCK_P, SUPPORTED,
                                                       fused_scoring_pallas)
from repro.kernels.fused_scoring.ref import fused_scoring_ref


def models_supported(models) -> bool:
    """Whether every weighting model has a kernel implementation — the
    eligibility predicate the IR fusion pass (core/passes.py) consults
    before lowering a scorer→cutoff chain onto this kernel."""
    return all(m in SUPPORTED for m in models)


def fused_scoring(tf, dl, df, cf, *, models: tuple[str, ...], stats: dict,
                  impl: str = "auto", interpret: bool = False):
    """[N] postings columns -> [N, F] multi-model scores (one HBM pass)."""
    assert all(m in SUPPORTED for m in models), models
    kw = dict(models=tuple(models), n_docs=stats["n_docs"],
              avg_dl=stats["avg_doclen"], total_terms=stats["total_terms"])
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return fused_scoring_ref(tf, dl, df, cf, **kw)
    n = tf.shape[0]
    n_pad = round_up(n, BLOCK_P)
    pad = lambda x: jnp.pad(x, (0, n_pad - n))
    out = fused_scoring_pallas(
        pad(tf).astype(jnp.int32), pad(dl).astype(jnp.int32),
        pad(df).astype(jnp.int32), pad(cf).astype(jnp.int32),
        interpret=interpret or jax.default_backend() != "tpu", **kw)
    return out[:n]
