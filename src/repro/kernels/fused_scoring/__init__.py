from repro.kernels.fused_scoring.ops import fused_scoring  # noqa: F401
