"""Pure-jnp oracle for the fused_scoring kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.index import scoring


def fused_scoring_ref(tf, dl, df, cf, *, models, n_docs, avg_dl, total_terms):
    stats = {"n_docs": float(n_docs), "avg_doclen": float(avg_dl),
             "total_terms": float(total_terms)}
    out = scoring.score_all(list(models), tf, dl, df, cf, stats)
    return jnp.where((tf > 0)[..., None], out, 0.0).astype(jnp.float32)
