"""Pallas TPU kernel: single-pass multi-model postings scoring (fat, RQ2).

One VMEM-resident postings tile (tf, doc_len, df, cf) produces F weighting-
model scores — the fat-postings insight as arithmetic-intensity: postings are
read from HBM once and every model's math runs on the registers/VMEM tile.

Grid: postings blocks of ``BLOCK_P`` rows; per block the kernel emits a
[BLOCK_P, F] score tile.  Pure VPU math (no MXU), bf16-safe in fp32 compute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.index.scoring import BM25_B, BM25_K1, QL_MU

BLOCK_P = 2048

#: model id order used by the kernel (a static tuple per call)
SUPPORTED = ("BM25", "TF_IDF", "QL", "DPH", "Coord")


def _model_scores(model, tf, dl, df, cf, n_docs, avg_dl, total_terms):
    """fp32 scalar math for one model over a [BLOCK_P] tile."""
    if model == "BM25":
        idf = jnp.log1p((n_docs - df + 0.5) / (df + 0.5))
        denom = tf + BM25_K1 * (1 - BM25_B + BM25_B * dl / avg_dl)
        return idf * tf * (BM25_K1 + 1.0) / jnp.maximum(denom, 1e-9)
    if model == "TF_IDF":
        idf = jnp.log(n_docs / jnp.maximum(df, 1.0))
        k = 1.2 * (0.25 + 0.75 * dl / avg_dl)
        return idf * tf / (tf + k)
    if model == "QL":
        p_c = cf / total_terms
        num = tf + QL_MU * p_c
        den = dl + QL_MU
        base = QL_MU * p_c / jnp.maximum(den, 1.0)
        return jnp.log(jnp.maximum(num, 1e-20) / jnp.maximum(den, 1.0)) - \
            jnp.log(jnp.maximum(base, 1e-20))
    if model == "DPH":
        dl1 = jnp.maximum(dl, 1.0)
        f = jnp.clip(tf / dl1, 1e-9, 1.0 - 1e-9)
        norm = (1.0 - f) ** 2 / (tf + 1.0)
        avg = total_terms / n_docs
        info = tf * jnp.log2(jnp.maximum(
            tf * avg / dl1 * n_docs / jnp.maximum(cf, 1.0), 1e-9))
        bonus = 0.5 * jnp.log2(2.0 * jnp.pi * tf * (1.0 - f) + 1e-9)
        return jnp.maximum(norm * (info + bonus), 0.0)
    if model == "Coord":
        return (tf > 0).astype(jnp.float32)
    raise ValueError(model)


def _kernel(tf_ref, dl_ref, df_ref, cf_ref, out_ref, *, models, n_docs,
            avg_dl, total_terms):
    tf = tf_ref[...].astype(jnp.float32)
    dl = dl_ref[...].astype(jnp.float32)
    df = df_ref[...].astype(jnp.float32)
    cf = cf_ref[...].astype(jnp.float32)
    for j, m in enumerate(models):
        s = _model_scores(m, tf, dl, df, cf, n_docs, avg_dl, total_terms)
        out_ref[:, j] = jnp.where(tf > 0, s, 0.0)


@functools.partial(jax.jit, static_argnames=("models", "n_docs", "avg_dl",
                                             "total_terms", "interpret"))
def fused_scoring_pallas(tf, dl, df, cf, *, models: tuple[str, ...],
                         n_docs: float, avg_dl: float, total_terms: float,
                         interpret: bool = False):
    """tf/dl/df/cf: [N] (N % BLOCK_P == 0) -> scores [N, F] fp32."""
    n = tf.shape[0]
    assert n % BLOCK_P == 0, n
    grid = (n // BLOCK_P,)
    kernel = functools.partial(_kernel, models=models, n_docs=float(n_docs),
                               avg_dl=float(avg_dl),
                               total_terms=float(total_terms))
    in_spec = pl.BlockSpec((BLOCK_P,), lambda i: (i,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[in_spec] * 4,
        out_specs=pl.BlockSpec((BLOCK_P, len(models)), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, len(models)), jnp.float32),
        interpret=interpret,
    )(tf, dl, df, cf)
