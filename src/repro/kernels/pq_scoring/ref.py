"""Pure-jnp oracle for streaming_pq_topk.

Shape of the computation matters beyond correctness: the IR fusion pass
cost-gates the kernel lowering by comparing optimized-HLO proxies, and the
*unfused* PQ path (``index/dense.py::ivfpq_retrieve_topk``) scores
candidates with exactly the expression below — so on hosts where the
kernel falls back to this oracle, a fused candidate at the same shortlist
depth prices identical to its unfused twin and the strictly-cheaper gate
correctly declines the rewrite.  ``lax.top_k`` breaks ADC ties (distinct
docs sharing a code word) to the lowest index, the same rule the kernel's
``lexsort`` ordering enforces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pq_topk_ref(codes, table, base=None, *, k: int):
    """codes [N, m] uint8, table [m, n_codes] -> top-k ADC scores
    ``sum_s table[s, codes[:, s]] + base`` (base defaults to 0)."""
    m = codes.shape[1]
    scores = jnp.sum(table[jnp.arange(m)[None, :], codes.astype(jnp.int32)],
                     axis=1)
    if base is not None:
        scores = scores + base
    vals, idxs = jax.lax.top_k(scores, k)
    return vals, idxs.astype(jnp.int32)
