"""Jitted wrapper for the PQ/ADC-scoring kernel (pads the candidate axis,
falls back to the ``lax.top_k`` oracle for large k / non-TPU backends)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import round_up
from repro.kernels.pq_scoring.pq_scoring import BLOCK_C, pq_topk_pallas
from repro.kernels.pq_scoring.ref import pq_topk_ref
from repro.kernels.topk.topk import NEG

MAX_KERNEL_K = 128


def kernel_native(k: int) -> bool:
    """Whether the Pallas kernel itself serves this shortlist depth on TPU
    (larger k falls back to the oracle).  The IR fusion pass
    (core/passes.py) records this so gate decisions distinguish
    kernel-native lowerings from oracle-served ones."""
    return k <= MAX_KERNEL_K


def streaming_pq_topk(codes, table, base=None, *, k: int,
                      block: int = BLOCK_C, impl: str = "auto",
                      interpret: bool = False):
    """Top-k of the ADC scores ``sum_s table[s, codes[:, s]] + base`` (base
    defaults to 0) without ever materialising + sorting the full score
    vector on the kernel path.  Returns values sorted descending (ties to
    the lowest index, matching ``lax.top_k``) + their row indices into
    ``codes``; padded rows score ``NEG`` and can never enter the top-k of
    real candidates."""
    if impl == "auto":
        impl = "pallas" if (jax.default_backend() == "tpu" and
                            k <= MAX_KERNEL_K) else "ref"
    if impl == "ref" or k > MAX_KERNEL_K:
        return pq_topk_ref(codes, table, base, k=k)
    n, m = codes.shape
    n_pad = round_up(max(n, block), block)
    if base is None:
        base = jnp.zeros((n,), jnp.float32)
    codes_p = jnp.pad(codes, ((0, n_pad - n), (0, 0)))
    base_p = jnp.pad(base.astype(jnp.float32), (0, n_pad - n),
                     constant_values=NEG)
    return pq_topk_pallas(
        codes_p, table.astype(jnp.float32), base_p, k=k, block=block,
        interpret=interpret or jax.default_backend() != "tpu")
