"""Pallas TPU kernel: PQ code gathers + ADC table adds fused with
streaming top-k (the compressed dense second-stage hot path).

ADC scoring of an IVF-PQ candidate block is ``m`` table lookups per row:
``score[i] = sum_s table[s, codes[i, s]] + base[i]``.  Unfused, the [N]
score vector round-trips through HBM and is then fully sorted; this kernel
streams uint8 code blocks through VMEM (``m`` bytes per candidate instead
of ``dim * 4`` — the memory axis the PQ layout buys), materialises each
subspace lookup as a one-hot [block, n_codes] matmul against the table row
(the standard MXU-friendly small-vocab gather), adds the per-row ``base``
(validity mask: padded rows carry ``NEG``), and merges the block into a
running [k] top-k scratch with the ``streaming_merge`` accumulator shared
with ``kernels/topk``.  A block whose best score is <= the running k-th
score is skipped entirely (``@pl.when``) — block-max pruning at ADC
granularity.

The final ordering is ``lexsort((idxs, -vals))`` — descending value, ties
to the lowest candidate row — which is exactly ``lax.top_k``'s rule, so
the fused and ref ADC stages produce bit-identical shortlists even when
distinct documents share a code word (ties are *expected* under
quantisation, unlike in float scoring).

Intended for k <= 128 (the shortlist regime); larger k falls back to the
``lax.top_k`` oracle in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.topk.topk import NEG, streaming_merge

BLOCK_C = 512


def _kernel(codes_ref, table_ref, base_ref, vals_ref, idxs_ref, *, k, block,
            m):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        vals_ref[...] = jnp.full((k,), NEG, jnp.float32)
        idxs_ref[...] = jnp.full((k,), -1, jnp.int32)

    codes = codes_ref[...].astype(jnp.int32)             # [block, m]
    table = table_ref[...].astype(jnp.float32)           # [m, n_codes]
    n_codes = table.shape[1]
    col = jax.lax.broadcasted_iota(jnp.int32, (block, n_codes), 1)
    scores = base_ref[...].astype(jnp.float32)           # [block]
    for s in range(m):                                   # static unroll
        onehot = (codes[:, s][:, None] == col).astype(jnp.float32)
        scores = scores + jnp.dot(onehot, table[s],
                                  preferred_element_type=jnp.float32)
    gidx = b * block + jax.lax.iota(jnp.int32, block)
    theta = jnp.min(vals_ref[...])

    @pl.when(jnp.max(scores) > theta)                    # block-max skip
    def _merge():
        vals, idxs = streaming_merge(scores, gidx, vals_ref[...],
                                     idxs_ref[...], k=k)
        vals_ref[...] = vals
        idxs_ref[...] = idxs


@functools.partial(jax.jit, static_argnames=("k", "block", "interpret"))
def pq_topk_pallas(codes, table, base, *, k: int, block: int = BLOCK_C,
                   interpret: bool = False):
    """codes [N, m] uint8 (N % block == 0), table [m, n_codes], base [N] ->
    (values [k], indices [k]) of the ADC scores, sorted descending with
    ties broken to the lowest index (``lax.top_k`` order)."""
    n, m = codes.shape
    assert n % block == 0, (n, block)
    n_codes = table.shape[1]
    kernel = functools.partial(_kernel, k=k, block=block, m=m)

    vals, idxs = pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block, m), lambda i: (i, 0)),
                  pl.BlockSpec((m, n_codes), lambda i: (0, 0)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((k,), lambda i: (0,)),
                   pl.BlockSpec((k,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((k,), jnp.float32),
                   jax.ShapeDtypeStruct((k,), jnp.int32)],
        interpret=interpret,
    )(codes, table, base)
    order = jnp.lexsort((idxs, -vals))
    return vals[order], idxs[order]
