from repro.kernels.pq_scoring.ops import streaming_pq_topk  # noqa: F401
