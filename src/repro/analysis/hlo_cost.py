"""Trip-count-aware cost model over post-SPMD optimized HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically: a 10-iteration scan reports 1/10th the flops of its unrolled
twin), which silently destroys roofline numbers for scan-over-layers models.
This walker parses the optimized per-device HLO, multiplies loop bodies by
their ``known_trip_count`` backend config, and accounts:

* flops        — dots (2·result·K from contracting dims), elementwise/reduce
                 ops at 1 flop/output element,
* bytes        — HBM traffic proxy: operands+result at fusion/op granularity;
                 gathers/scatters/dynamic-slices count touched bytes, not the
                 whole operand buffer,
* collectives  — per-kind per-chip ring traffic (all-reduce 2·b, others ~b),
                 inside loops correctly multiplied.

Post-SPMD shapes are per-shard, so every figure is PER CHIP.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
from typing import Any

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4,
    "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1,
    "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"\s*([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\((.*?)\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\)|[\w\[\],]+))")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

#: zero-traffic bookkeeping ops
_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "partition-id", "replica-id", "iota", "copy-start",
         "copy-done", "domain", "opt-barrier"}


def shape_elems(type_str: str) -> int:
    n = 0
    for m in _SHAPE_RE.finditer(type_str):
        if m.group(1) not in _DTYPE_BYTES:
            continue
        k = 1
        for d in m.group(2).split(","):
            if d:
                k *= int(d)
        n += k
    return n


def shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        k = 1
        for d in m.group(2).split(","):
            if d:
                k *= int(d)
        total += k * _DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    rtype: str
    op: str
    rest: str           # the raw tail of the line (operands + attrs)
    operands: list[str]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    shapes: dict[str, str]  # instr name -> result type string


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict[str, float] = dataclasses.field(default_factory=dict)
    collective_counts: dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.collective_bytes += mult * other.collective_bytes
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + mult * v
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0.0) + mult * v


def _match_paren(s: str, start: int) -> int:
    """Index just past the ')' matching the '(' at ``start``."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _parse_instr(line: str) -> Instr | None:
    m = _INSTR_HEAD_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2).strip()
    # result type: either a tuple "(...)" (may contain /*index=N*/ comments)
    # or a plain "dtype[dims]{layout}" token
    if rest.startswith("("):
        end = _match_paren(rest, 0)
        rtype, tail = rest[:end], rest[end:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype, tail = rest[:sp], rest[sp:]
    mo = _OP_RE.match(tail)
    if not mo:
        return None
    op = mo.group(1)
    open_idx = mo.end() - 1
    close = _match_paren(tail, open_idx)
    operand_str = tail[open_idx:close]
    attrs = tail[close:]
    operands = _OPERAND_RE.findall(operand_str)
    return Instr(name, rtype, op, attrs, operands)


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if stripped.endswith("{"):
            hdr = _COMP_HDR_RE.match(stripped)
            if hdr:
                cur = Computation(hdr.group(1), [], {})
                comps[cur.name] = cur
                # header params give shapes of %param names
                for pm in _PARAM_RE.finditer(hdr.group(2)):
                    cur.shapes[pm.group(1)] = pm.group(2)
                continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        inst = _parse_instr(line)
        if inst is None:
            continue
        cur.instrs.append(inst)
        cur.shapes[inst.name] = inst.rtype
    return comps


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALLED_RE = {
    "body": re.compile(r"body=%([\w.\-]+)"),
    "cond": re.compile(r"condition=%([\w.\-]+)"),
    "calls": re.compile(r"calls=%([\w.\-]+)"),
    "to_apply": re.compile(r"to_apply=%([\w.\-]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
}
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")


def _dot_flops(inst: Instr, shapes: dict[str, str]) -> float:
    out_elems = shape_elems(inst.rtype)
    lhs = shapes.get(inst.operands[0]) if inst.operands else None
    k = 1
    if lhs:
        dims = shape_dims(lhs)
        mc = _LHS_CONTRACT_RE.search(inst.rest)
        if mc and mc.group(1):
            for i in mc.group(1).split(","):
                idx = int(i)
                if idx < len(dims):
                    k *= dims[idx]
    return 2.0 * out_elems * k


def _instr_bytes(inst: Instr, shapes: dict[str, str]) -> float:
    """HBM-traffic proxy for one top-level instruction."""
    op = inst.op
    rb = shape_bytes(inst.rtype)
    if op == "gather":
        idx = shape_bytes(shapes.get(inst.operands[1], "")) if len(inst.operands) > 1 else 0
        return 2.0 * rb + idx
    if op == "scatter":
        upd = shape_bytes(shapes.get(inst.operands[-1], ""))
        return rb + 3.0 * upd
    if op == "dynamic-slice":
        return 2.0 * rb
    if op == "dynamic-update-slice":
        upd = shape_bytes(shapes.get(inst.operands[1], "")) if len(inst.operands) > 1 else 0
        return 2.0 * upd
    ob = sum(shape_bytes(shapes.get(o, "")) for o in inst.operands)
    return rb + ob


class CostModel:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self._memo: dict[str, Cost] = {}
        entry = None
        for raw in text.splitlines():
            if raw.startswith("ENTRY"):
                m = _COMP_HDR_RE.match(raw.strip())
                entry = m.group(1) if m else None
        self.entry = entry

    def cost(self, comp_name: str | None = None, _depth: int = 0) -> Cost:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        total = Cost()
        if comp is None or _depth > 64:
            return total
        for inst in comp.instrs:
            op = inst.op
            if op in _FREE:
                continue
            if op == "while":
                trips = 1.0
                mt = _TRIP_RE.search(inst.rest)
                if mt:
                    trips = float(mt.group(1))
                inner = Cost()
                for key in ("body", "cond"):
                    mm = _CALLED_RE[key].search(inst.rest)
                    if mm:
                        inner.add(self.cost(mm.group(1), _depth + 1))
                total.add(inner, trips)
                continue
            if op == "fusion":
                mm = _CALLED_RE["calls"].search(inst.rest)
                if mm:
                    sub = self.cost(mm.group(1), _depth + 1)
                    total.flops += sub.flops          # internal dots count
                total.bytes += shape_bytes(inst.rtype) + sum(
                    shape_bytes(comp.shapes.get(o, "")) for o in inst.operands)
                continue
            if op in ("call", "conditional", "async-start"):
                for key in ("calls", "to_apply", "branches"):
                    mm = _CALLED_RE[key].search(inst.rest)
                    if mm:
                        for sub in _OPERAND_RE.findall("%" + mm.group(1)):
                            total.add(self.cost(sub, _depth + 1))
                continue
            base = op.removesuffix("-start").removesuffix("-done")
            if base in COLLECTIVES:
                if op.endswith("-done"):
                    continue
                opb = sum(shape_bytes(comp.shapes.get(o, "")) for o in inst.operands)
                if base == "all-reduce":
                    vol = 2.0 * opb
                elif base == "all-gather":
                    vol = float(shape_bytes(inst.rtype))   # gathered result
                else:
                    vol = float(max(opb, shape_bytes(inst.rtype)))
                total.collectives[base] = total.collectives.get(base, 0.0) + vol
                total.collective_counts[base] = total.collective_counts.get(base, 0.0) + 1
                total.collective_bytes += vol
                total.bytes += _instr_bytes(inst, comp.shapes)
                continue
            if op == "dot":
                total.flops += _dot_flops(inst, comp.shapes)
                total.bytes += _instr_bytes(inst, comp.shapes)
                continue
            if op == "convolution":
                # rare here; approximate via output elems × window product
                total.flops += 2.0 * shape_elems(inst.rtype)
                total.bytes += _instr_bytes(inst, comp.shapes)
                continue
            # elementwise / reduce / misc: 1 flop per output element
            total.flops += float(shape_elems(inst.rtype))
            total.bytes += _instr_bytes(inst, comp.shapes)
        self._memo[comp_name] = total
        return total


def analyze(hlo_text: str) -> dict[str, Any]:
    cm = CostModel(hlo_text)
    c = cm.cost()
    return {
        "flops_per_chip": c.flops,
        "bytes_per_chip": c.bytes,
        "collective_bytes_per_chip": c.collective_bytes,
        "collectives": dict(sorted(c.collectives.items())),
        "collective_counts": dict(sorted(c.collective_counts.items())),
    }


# ---------------------------------------------------------------------------
# callable estimation — the pipeline compiler's cost gate (core/passes.py)
# ---------------------------------------------------------------------------

#: nominal per-chip peaks for the roofline time proxy — the *uncalibrated*
#: defaults (TPU-class chip).  A ratio gate only needs the flops:bytes
#: weighting to be plausible; a calibrated BackendDescriptor replaces both
#: constants with per-host fits from measured bench ratios (``fit_peaks``).
PEAK_FLOPS_PER_S = 1.0e14
PEAK_BYTES_PER_S = 1.0e12


def host_fingerprint() -> str:
    """Short identity digest of this host for scoping calibration data and
    cached estimates (peak constants are host properties, not code
    properties)."""
    import platform
    raw = f"{platform.node()}:{platform.machine()}:{os.cpu_count()}"
    return hashlib.sha256(raw.encode()).hexdigest()[:16]


def estimate_callable(fn, *args, peaks: tuple[float, float] | None = None
                      ) -> dict[str, Any]:
    """Lower ``fn(*args)`` (args may be ``jax.ShapeDtypeStruct`` pytrees) to
    post-optimisation HLO and run the trip-count-aware cost model over it.

    Adds ``time_proxy_s`` — flops/peak + bytes/peak, an additive roofline
    proxy: comparing two candidates' proxies orders them by modelled cost
    even when one resource dominates.  ``peaks`` overrides the nominal
    ``(PEAK_FLOPS_PER_S, PEAK_BYTES_PER_S)`` — calibrated descriptors pass
    their fitted per-host constants.  Used by the fusion pass's cost gate;
    callers should cache per content key (compilation is the expensive part).
    """
    import jax
    pf, pb = peaks if peaks is not None else (PEAK_FLOPS_PER_S,
                                              PEAK_BYTES_PER_S)
    text = jax.jit(fn).lower(*args).compile().as_text()
    out = analyze(text)
    out["time_proxy_s"] = (out["flops_per_chip"] / pf
                           + out["bytes_per_chip"] / pb)
    return out


# ---------------------------------------------------------------------------
# peak calibration from measured gate records (bench artifacts)
# ---------------------------------------------------------------------------

def _ratio(rec: dict, gamma: float) -> float | None:
    """Predicted fused/unfused time ratio at flops:bytes weight ``gamma``
    (gamma = peak_flops / peak_bytes — the byte premium in flop units)."""
    try:
        fu = rec["unfused"]["flops"] + gamma * rec["unfused"]["bytes"]
        ff = rec["fused"]["flops"] + gamma * rec["fused"]["bytes"]
    except (KeyError, TypeError):
        return None
    if fu <= 0 or ff <= 0:
        return None
    return ff / fu


def fit_peaks(records: list[dict]) -> dict | None:
    """Fit per-host roofline peaks from measured gate-calibration records.

    Each record carries, per candidate (``unfused`` / ``fused``), the HLO
    counts and a measured wall-clock: ``{"flops", "bytes", "measured_s"}``.
    The proxy is ``t = (F + gamma*B) / Pf`` with ``gamma = Pf/Pb``, so the
    *ratio* of two candidates depends only on gamma: step 1 grid-searches
    gamma to minimise the squared log-ratio error against the measured
    ratios; step 2 anchors the absolute scale by the median of
    ``(F + gamma*B) / measured_s`` over every candidate.  Returns None when
    no record is usable (the caller keeps the nominal constants)."""
    import math

    usable = []
    for rec in records or ():
        ok = True
        for side in ("unfused", "fused"):
            c = rec.get(side) or {}
            if not all(isinstance(c.get(f), (int, float)) and c.get(f) > 0
                       for f in ("flops", "bytes", "measured_s")):
                ok = False
        if ok:
            usable.append(rec)
    if not usable:
        return None

    def log_err(gamma: float) -> float:
        total = 0.0
        for rec in usable:
            pred = _ratio(rec, gamma)
            meas = rec["fused"]["measured_s"] / rec["unfused"]["measured_s"]
            total += (math.log(pred) - math.log(meas)) ** 2
        return total

    # gamma grid: 1 (pure-flops pricing) .. 1e4 (extreme byte premium);
    # the nominal constants sit at gamma = 100
    grid = [10 ** (e / 8.0) for e in range(0, 33)]
    gamma = min(grid, key=log_err)
    scales = []
    for rec in usable:
        for side in ("unfused", "fused"):
            c = rec[side]
            scales.append((c["flops"] + gamma * c["bytes"]) / c["measured_s"])
    scales.sort()
    pf = scales[len(scales) // 2]          # median: robust to one bad probe
    err = math.sqrt(log_err(gamma) / len(usable))
    return {"peak_flops_per_s": pf, "peak_bytes_per_s": pf / gamma,
            "gamma": gamma, "n_records": len(usable),
            "rms_log_ratio_error": err}


def calibration_records(summary: dict) -> list[dict]:
    """Extract usable calibration records from a bench ``summary.json``
    (the ``calibration`` blocks the fusion/dense/autotune sections emit per
    workload).  Tolerant of older artifacts that lack the per-candidate
    counts — those records are simply skipped by ``fit_peaks``."""
    out = []
    for section in ("fusion", "dense", "autotune"):
        sec = summary.get(section) or {}
        for w in (sec.get("workloads") or {}).values():
            cal = w.get("calibration")
            if cal:
                out.append(cal)
    return out


if __name__ == "__main__":
    import sys
    print(json.dumps(analyze(open(sys.argv[1]).read()), indent=1))
