"""repro: declarative IR experimentation, compiled and served (paper repro).

The v1 public surface — everything a README example needs, importable from
the top-level package:

    from repro import (Experiment, JaxBackend, Retrieve, DenseRerank,
                       Generate, PipelineServer, ServeConfig)

    be = JaxBackend(build_index(corpus)).register_lm("tiny", lm_cfg)
    rag = Retrieve("BM25") >> DenseRerank() % 8 >> Generate("tiny")
    server = PipelineServer(rag, be, ServeConfig.default())

Deeper layers (kernels, engine internals, pass construction) stay under
their subpackages; this module re-exports only the stable declarative API:
stage constructors, the compile entry point, the backend and its
descriptor, the experiment driver, and the serving front door.
"""
from repro.core.compiler import JaxBackend, run_pipeline
from repro.core.data import make_queries
from repro.core.descriptor import BackendDescriptor
from repro.core.experiment import Experiment, format_table
from repro.core.ir import Schema, SchemaError, lower, raise_ir
from repro.core.passes import compile_pipeline, explain_pipeline
from repro.core.stages import (DenseRerank, DenseRetrieve, Extract,
                               FatRetrieve, Generate, LTRRerank,
                               MultiRetrieve, Retrieve, RM3Expand,
                               SDMRewrite, StemRewrite)
from repro.serve.config import ServeConfig
from repro.serve.server import MultiPipelineServer, PipelineServer

__all__ = [
    # backend + compilation
    "JaxBackend", "BackendDescriptor", "compile_pipeline",
    "explain_pipeline", "run_pipeline", "lower", "raise_ir",
    "Schema", "SchemaError",
    # data + evaluation
    "make_queries", "Experiment", "format_table",
    # stage constructors
    "Retrieve", "MultiRetrieve", "FatRetrieve", "DenseRetrieve",
    "DenseRerank", "LTRRerank", "Extract", "RM3Expand", "SDMRewrite",
    "StemRewrite", "Generate",
    # serving
    "PipelineServer", "MultiPipelineServer", "ServeConfig",
]
