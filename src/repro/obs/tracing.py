"""Structured span tracing with Chrome trace-event export.

Spans carry explicit ``span_id``/``parent_id`` links — nesting is a
property of the data, not of wall-clock containment — so spans recorded
retrospectively (a request's queue/batch/decode children are emitted
when the request finishes, from its ``RequestTrace`` timestamps) link
exactly like spans recorded live around a synchronous call.

Parenting rules:
  * ``span(...)`` (context manager) nests via a thread-local stack: the
    enclosing live span on the same thread is the parent.
  * an explicit ``parent=`` always wins — this is how cross-thread
    lifecycles (request admitted on the caller thread, executed on the
    serving thread) attach their children.
  * ``add_span``/``event`` never touch the thread-local stack.

Clocks: every timestamp is ``time.monotonic()`` relative to the
tracer's epoch.  No wall-clock is recorded, so traces from restarted
processes never interleave misleadingly (Perfetto renders relative
time anyway).

The disabled path is one attribute check returning shared no-op
singletons; a disabled tracer allocates nothing per call.
"""
from __future__ import annotations

import itertools
import json
import threading
import time


class _NoopSpan:
    """Shared do-nothing span for the disabled path."""

    __slots__ = ()
    span_id = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw):
        return self

    def end(self, t=None):
        return self


NOOP_SPAN = _NoopSpan()


class Span:
    __slots__ = ("name", "cat", "span_id", "parent_id", "t0", "t1",
                 "tid", "args", "_tracer", "_on_stack")

    def __init__(self, tracer, name, cat, span_id, parent_id, t0, tid, args):
        self.name = name
        self.cat = cat
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = t0
        self.t1 = None
        self.tid = tid
        self.args = args
        self._tracer = tracer
        self._on_stack = False

    def set(self, **kw):
        self.args.update(kw)
        return self

    def end(self, t: float | None = None):
        if self.t1 is None:
            self._tracer._finish(self, t)
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.end()
        return False


class Tracer:
    """Bounded in-memory span/event collector.

    ``capacity`` bounds retained records (oldest dropped); ``enabled``
    may be flipped at runtime (``clear()`` resets retained records and
    the drop counter, not the id sequence).
    """

    def __init__(self, enabled: bool = False, capacity: int = 65536):
        self._enabled = bool(enabled)
        self.capacity = int(capacity)
        self._epoch = time.monotonic()
        self._lock = threading.Lock()
        self._records: list[dict] = []
        self._dropped = 0
        self._ids = itertools.count(1)
        self._tls = threading.local()

    # -- state ---------------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self, on: bool = True) -> None:
        self._enabled = bool(on)

    def clear(self) -> None:
        with self._lock:
            self._records = []
            self._dropped = 0

    def now(self) -> float:
        """Seconds since the tracer epoch (monotonic)."""
        return time.monotonic() - self._epoch

    def rel(self, t: float) -> float:
        """Convert a raw ``time.monotonic()`` stamp to epoch-relative —
        for :meth:`add_span` callers holding timestamps taken elsewhere
        (e.g. a ``RequestTrace``)."""
        return t - self._epoch

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def current_id(self) -> int | None:
        st = getattr(self._tls, "stack", None)
        return st[-1].span_id if st else None

    # -- recording -----------------------------------------------------------
    def span(self, name: str, cat: str = "", parent: int | None = None,
             **args):
        """Live nested span (context manager).  Parent defaults to the
        enclosing live span on this thread."""
        if not self._enabled:
            return NOOP_SPAN
        st = self._stack()
        pid = parent if parent is not None else (
            st[-1].span_id if st else None)
        sp = Span(self, name, cat, next(self._ids), pid, self.now(),
                  threading.get_ident(), args)
        sp._on_stack = True
        st.append(sp)
        return sp

    def begin(self, name: str, cat: str = "", parent: int | None = None,
              **args):
        """Manually-ended span; never joins the thread-local stack (safe
        to end from another thread)."""
        if not self._enabled:
            return NOOP_SPAN
        return Span(self, name, cat, next(self._ids), parent, self.now(),
                    threading.get_ident(), args)

    def _finish(self, sp: Span, t: float | None) -> None:
        sp.t1 = self.now() if t is None else t
        if sp._on_stack:
            st = self._stack()
            if sp in st:
                # pop through sp: tolerates a child left unended
                while st and st[-1] is not sp:
                    st.pop()
                if st:
                    st.pop()
        self._append({"ph": "X", "name": sp.name, "cat": sp.cat,
                      "id": sp.span_id, "parent": sp.parent_id,
                      "t0": sp.t0, "t1": sp.t1, "tid": sp.tid,
                      "args": sp.args})

    def add_span(self, name: str, t0: float, t1: float, *, cat: str = "",
                 parent: int | None = None, tid: int | None = None,
                 **args) -> int | None:
        """Retrospective span from explicit epoch-relative times."""
        if not self._enabled:
            return None
        sid = next(self._ids)
        self._append({"ph": "X", "name": name, "cat": cat, "id": sid,
                      "parent": parent, "t0": float(t0), "t1": float(t1),
                      "tid": tid if tid is not None
                      else threading.get_ident(), "args": args})
        return sid

    def event(self, name: str, cat: str = "", parent: int | None = None,
              t: float | None = None, tid: int | None = None,
              **args) -> int | None:
        """Instant event (a point, not a duration)."""
        if not self._enabled:
            return None
        sid = next(self._ids)
        self._append({"ph": "i", "name": name, "cat": cat, "id": sid,
                      "parent": parent,
                      "t0": self.now() if t is None else float(t),
                      "t1": None,
                      "tid": tid if tid is not None
                      else threading.get_ident(), "args": args})
        return sid

    def _append(self, rec: dict) -> None:
        with self._lock:
            self._records.append(rec)
            if len(self._records) > self.capacity:
                drop = len(self._records) - self.capacity
                del self._records[:drop]
                self._dropped += drop

    # -- reads ---------------------------------------------------------------
    def records(self) -> list[dict]:
        with self._lock:
            return list(self._records)

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    def export_chrome(self) -> dict:
        """Chrome trace-event JSON (Perfetto-loadable): complete (``X``)
        events with microsecond ``ts``/``dur``; ``args`` carries the
        explicit ``span_id``/``parent_id`` links."""
        events = []
        for r in self.records():
            args = {"span_id": r["id"], "parent_id": r["parent"], **r["args"]}
            ev = {"name": r["name"], "cat": r["cat"] or "default",
                  "pid": 1, "tid": int(r["tid"]) & 0x7FFFFFFF,
                  "ts": round(r["t0"] * 1e6, 3), "args": args}
            if r["ph"] == "X":
                ev["ph"] = "X"
                ev["dur"] = round(max(0.0, (r["t1"] - r["t0"])) * 1e6, 3)
            else:
                ev["ph"] = "i"
                ev["s"] = "t"
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"dropped_records": self._dropped}}

    def export_chrome_json(self) -> str:
        return json.dumps(self.export_chrome())


#: shared disabled tracer: the default wiring target, so instrumented
#: code never branches on None
NOOP_TRACER = Tracer(enabled=False)

_GLOBAL = NOOP_TRACER


def get_tracer() -> Tracer:
    """Process-global tracer (disabled no-op until ``set_tracer``)."""
    return _GLOBAL


def set_tracer(tracer: Tracer | None) -> Tracer:
    """Install (or with None, reset) the process-global tracer used by
    compile-pass / plan instrumentation gated on the descriptor flag."""
    global _GLOBAL
    _GLOBAL = tracer if tracer is not None else NOOP_TRACER
    return _GLOBAL
