"""Observability subsystem: metrics registry, span tracing, flight recorder.

    from repro.obs import MetricsRegistry, Tracer, FlightRecorder

Three layers, one design rule — observation must never change what it
observes:

* **Metrics** (always on): every counter the stack exposes through
  ``PipelineServer.stats()`` / ``pipeline.explain()`` lives in a
  :class:`MetricsRegistry`; an increment costs a dict lookup + float add.
* **Tracing** (opt-in): :class:`Tracer` records nested spans with
  explicit parent ids, exportable as Chrome trace-event JSON
  (Perfetto-loadable).  Disabled, every call returns a shared no-op.
* **Flight recorder** (opt-in): :class:`FlightRecorder` rings the last N
  scheduler/engine decisions for overload post-mortems.

Serving opts in via ``ServeConfig.with_observability(...)``; offline
compile/plan instrumentation via ``BackendDescriptor.with_observability()``
(which routes through the process-global tracer, see ``set_tracer``).
"""
from repro.obs.metrics import (LATENCY_BUCKETS_MS, Counter,  # noqa: F401
                               CounterMap, Gauge, Histogram,
                               MetricsRegistry, get_registry)
from repro.obs.recorder import FlightRecorder  # noqa: F401
from repro.obs.tracing import (NOOP_SPAN, NOOP_TRACER, Span,  # noqa: F401
                               Tracer, get_tracer, set_tracer)
