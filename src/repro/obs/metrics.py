"""Metrics registry: counters, gauges, and log-spaced histograms.

One ``MetricsRegistry`` is the source of truth for every counter the
stack used to keep as ad-hoc attributes (``TraceLog`` serve counters,
scheduler shed/lane counters, ``StageResultCache`` hits/misses, engine
jit/chunk cache stats, ``PassContext`` tuning counters).  Components
register *instruments* (``Counter``/``Gauge``/``Histogram``) keyed by
name; instrument registration is idempotent, so a component re-created
against the same registry shares the existing series.

Instruments carry label *names* at registration and label *values* per
observation; each distinct label-value tuple is an independent series.
Reads come in two shapes: ``snapshot()`` (a plain nested dict, the form
``stats()``/``summary()`` builders consume) and ``render_text()`` (the
Prometheus text exposition format, label escaping included).

Cost model: an increment is one dict lookup on the instrument's series
table plus a float add under a per-instrument lock — cheap enough to be
always-on.  The opt-in machinery (``ServeConfig.with_observability``)
gates only the *tracing* and *flight-recorder* layers, which allocate
per-event records.
"""
from __future__ import annotations

import threading
from typing import Callable, Iterator, Mapping

#: fixed log-spaced latency buckets (milliseconds): 0.1ms .. ~52s, x2 per
#: rung.  Shared by every latency histogram so series stay comparable.
LATENCY_BUCKETS_MS: tuple[float, ...] = tuple(
    0.1 * (2.0 ** i) for i in range(20))


def _label_key(labels) -> tuple:
    if isinstance(labels, tuple):
        return labels
    if isinstance(labels, (list,)):
        return tuple(labels)
    return (labels,)


def escape_label_value(v: str) -> str:
    """Prometheus label-value escaping: backslash, double-quote, newline."""
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    return repr(v)


class _Instrument:
    """Base: a named family of series, one per label-value tuple."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: tuple[str, ...] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._series: dict[tuple, float] = {}

    def _check(self, key: tuple) -> None:
        if len(key) != len(self.labelnames):
            raise ValueError(
                f"{self.name}: got {len(key)} label values for "
                f"{len(self.labelnames)} label names {self.labelnames}")

    def touch(self, labels=()) -> None:
        """Materialise a zero-valued series (so renders/summaries list it
        before the first observation)."""
        key = _label_key(labels)
        self._check(key)
        with self._lock:
            self._series.setdefault(key, 0.0)

    def value(self, labels=()) -> float:
        key = _label_key(labels)
        with self._lock:
            return self._series.get(key, 0.0)

    def series(self) -> dict[tuple, float]:
        with self._lock:
            return dict(self._series)

    def _set(self, labels, v: float) -> None:
        key = _label_key(labels)
        self._check(key)
        with self._lock:
            self._series[key] = float(v)

    def _render_series(self) -> Iterator[str]:
        for key, v in sorted(self.series().items(), key=lambda kv: kv[0]):
            yield f"{self.name}{self._labelstr(key)} {_fmt(float(v))}"

    def _labelstr(self, key: tuple, extra: str = "") -> str:
        parts = [f'{n}="{escape_label_value(v)}"'
                 for n, v in zip(self.labelnames, key)]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""


class Counter(_Instrument):
    """Monotone counter.  ``inc`` only; ``_set`` is reserved for internal
    views (``CounterMap``) that need dict-style assignment."""

    kind = "counter"

    def inc(self, n: float = 1.0, labels=()) -> None:
        key = _label_key(labels)
        self._check(key)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + n

    def snapshot_value(self, key: tuple):
        with self._lock:
            v = float(self._series.get(key, 0.0))
        return int(v) if v.is_integer() else v


class Gauge(_Instrument):
    """Point-in-time value.  ``set_fn`` registers a pull-style collector:
    the callable is invoked at snapshot/render time (used to surface LRU
    cache internals without mirroring every update)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 labelnames: tuple[str, ...] = ()):
        super().__init__(name, help, labelnames)
        self._fns: dict[tuple, Callable[[], float]] = {}

    def set(self, v: float, labels=()) -> None:
        self._set(labels, v)

    def add(self, n: float = 1.0, labels=()) -> None:
        key = _label_key(labels)
        self._check(key)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + n

    def set_fn(self, fn: Callable[[], float], labels=()) -> None:
        key = _label_key(labels)
        self._check(key)
        with self._lock:
            self._fns[key] = fn

    def series(self) -> dict[tuple, float]:
        with self._lock:
            out = dict(self._series)
            fns = dict(self._fns)
        for key, fn in fns.items():
            try:
                out[key] = float(fn())
            except Exception:
                out.setdefault(key, 0.0)
        return out


class Histogram(_Instrument):
    """Fixed-bucket histogram (log-spaced by default).  Each series keeps
    per-bucket counts plus sum/count/min/max; exposition renders the
    Prometheus cumulative ``_bucket{le=...}`` form."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: tuple[str, ...] = (),
                 buckets: tuple[float, ...] = LATENCY_BUCKETS_MS):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(buckets))
        self._h: dict[tuple, dict] = {}

    def _blank(self) -> dict:
        return {"counts": [0] * (len(self.buckets) + 1), "sum": 0.0,
                "count": 0, "min": None, "max": None}

    def touch(self, labels=()) -> None:
        key = _label_key(labels)
        self._check(key)
        with self._lock:
            self._h.setdefault(key, self._blank())

    def observe(self, v: float, labels=()) -> None:
        key = _label_key(labels)
        self._check(key)
        v = float(v)
        lo, hi = 0, len(self.buckets)
        while lo < hi:                      # first bucket with v <= bound
            mid = (lo + hi) // 2
            if v <= self.buckets[mid]:
                hi = mid
            else:
                lo = mid + 1
        with self._lock:
            h = self._h.get(key)
            if h is None:
                h = self._h[key] = self._blank()
            h["counts"][lo] += 1
            h["sum"] += v
            h["count"] += 1
            h["min"] = v if h["min"] is None else min(h["min"], v)
            h["max"] = v if h["max"] is None else max(h["max"], v)

    def stats(self, labels=()) -> dict:
        """sum/count/mean/min/max for one series (zeros when unseen)."""
        key = _label_key(labels)
        with self._lock:
            h = self._h.get(key)
            if h is None:
                return {"count": 0, "sum": 0.0, "mean": 0.0,
                        "min": None, "max": None}
            return {"count": h["count"], "sum": h["sum"],
                    "mean": h["sum"] / h["count"] if h["count"] else 0.0,
                    "min": h["min"], "max": h["max"]}

    def series(self) -> dict[tuple, dict]:
        with self._lock:
            return {k: {"counts": list(h["counts"]), "sum": h["sum"],
                        "count": h["count"], "min": h["min"],
                        "max": h["max"]}
                    for k, h in self._h.items()}

    def _render_series(self) -> Iterator[str]:
        for key, h in sorted(self.series().items(), key=lambda kv: kv[0]):
            cum = 0
            for bound, c in zip(self.buckets, h["counts"]):
                cum += c
                ls = self._labelstr(key, f'le="{_fmt(bound)}"')
                yield f"{self.name}_bucket{ls} {cum}"
            cum += h["counts"][-1]
            ls = self._labelstr(key, 'le="+Inf"')
            yield f"{self.name}_bucket{ls} {cum}"
            yield f"{self.name}_sum{self._labelstr(key)} {_fmt(h['sum'])}"
            yield f"{self.name}_count{self._labelstr(key)} {h['count']}"


class CounterMap(Mapping):
    """Dict-shaped view over one labelled ``Counter`` — the bridge that
    lets ``PassContext.counters['gate_estimates'] += 1`` land on the
    registry while ``dict(pctx.counters)`` keeps its legacy shape."""

    def __init__(self, counter: Counter, keys: tuple[str, ...]):
        self._counter = counter
        self._keys = tuple(keys)
        for k in self._keys:
            counter.touch((k,))

    def __getitem__(self, k: str):
        if k not in self._keys:
            raise KeyError(k)
        return self._counter.snapshot_value((k,))

    def __setitem__(self, k: str, v) -> None:
        if k not in self._keys:
            raise KeyError(k)
        self._counter._set((k,), v)

    def __iter__(self):
        return iter(self._keys)

    def __len__(self):
        return len(self._keys)


class MetricsRegistry:
    """Named instrument table.  ``counter``/``gauge``/``histogram`` are
    get-or-create: re-registration with the same name returns the
    existing instrument (kind-checked), so shared components aggregate
    into one series instead of colliding."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, _Instrument] = {}

    def _get(self, cls, name: str, help: str, labelnames, **kw):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is not None:
                if not isinstance(inst, cls):
                    raise TypeError(
                        f"instrument {name!r} already registered as "
                        f"{inst.kind}, requested {cls.kind}")
                return inst
            inst = cls(name, help, tuple(labelnames), **kw)
            self._instruments[name] = inst
            return inst

    def counter(self, name: str, help: str = "", labelnames=()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "", labelnames=()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "", labelnames=(),
                  buckets: tuple[float, ...] = LATENCY_BUCKETS_MS) -> Histogram:
        return self._get(Histogram, name, help, labelnames, buckets=buckets)

    def instruments(self) -> list[_Instrument]:
        with self._lock:
            return [self._instruments[n] for n in sorted(self._instruments)]

    def snapshot(self) -> dict:
        """name -> {kind, and per-series values keyed by the label tuple
        rendered ``a=x,b=y`` (empty string for the unlabelled series)}."""
        out: dict[str, dict] = {}
        for inst in self.instruments():
            entry: dict = {"kind": inst.kind, "series": {}}
            if isinstance(inst, Histogram):
                for key, h in inst.series().items():
                    entry["series"][self._keystr(inst, key)] = {
                        "count": h["count"], "sum": h["sum"],
                        "min": h["min"], "max": h["max"]}
            else:
                for key, v in inst.series().items():
                    v = float(v)
                    entry["series"][self._keystr(inst, key)] = (
                        int(v) if v.is_integer() else v)
            out[inst.name] = entry
        return out

    @staticmethod
    def _keystr(inst: _Instrument, key: tuple) -> str:
        return ",".join(f"{n}={v}" for n, v in zip(inst.labelnames, key))

    def render_text(self) -> str:
        """Prometheus text exposition (``# HELP`` / ``# TYPE`` + series)."""
        lines: list[str] = []
        for inst in self.instruments():
            if inst.help:
                lines.append(f"# HELP {inst.name} {inst.help}")
            lines.append(f"# TYPE {inst.name} {inst.kind}")
            lines.extend(inst._render_series())
        return "\n".join(lines) + ("\n" if lines else "")


#: process-global default registry (components take ``registry=None`` to
#: mean "a private registry"; pass this one to aggregate across them)
GLOBAL_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return GLOBAL_REGISTRY
