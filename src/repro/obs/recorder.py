"""Flight recorder: a bounded ring of recent scheduler/engine events.

The serving layer's aggregate counters say *how many* requests were
shed; they cannot say *why request 4117 specifically* was turned away.
The flight recorder keeps the last N decision-level events — admissions,
door/queue sheds with the service-model inputs (S(n) estimate, queue
depth, deadline slack) that justified them, deadline drops, and engine
recompiles — so an overload incident can be reconstructed after the
fact with ``dump()``.

Events are plain dicts (JSON-serialisable by construction: callers pass
only str/int/float/bool/None fields), appended to a ``deque(maxlen=N)``;
appends are atomic under the GIL, so the hot path takes no lock.  A
disabled recorder is represented by ``None`` at the call sites (one
``is not None`` check).
"""
from __future__ import annotations

import time
from collections import deque


class FlightRecorder:
    """Bounded event ring with a monotonic per-recorder clock."""

    def __init__(self, capacity: int = 1024):
        self.capacity = int(capacity)
        self._epoch = time.monotonic()
        self._events: deque[dict] = deque(maxlen=self.capacity)
        self.n_recorded = 0

    def now(self) -> float:
        return time.monotonic() - self._epoch

    def record(self, kind: str, **fields) -> None:
        self.n_recorded += 1
        self._events.append({"t": round(self.now(), 6), "kind": kind,
                             **fields})

    def dump(self, last: int | None = None) -> list[dict]:
        """Most recent events, oldest first (``last`` trims to a tail)."""
        evs = list(self._events)
        return evs[-last:] if last is not None else evs

    def clear(self) -> None:
        self._events.clear()
        self.n_recorded = 0

    def __len__(self) -> int:
        return len(self._events)

    def kinds(self) -> dict[str, int]:
        """Event-kind histogram of the retained window."""
        out: dict[str, int] = {}
        for e in self._events:
            out[e["kind"]] = out.get(e["kind"], 0) + 1
        return out
