"""Serving request objects: one submitted query + its lifecycle/trace.

A :class:`ServeRequest` is what ``PipelineServer.submit`` hands back: a
single-query slice of the Q relation plus a completion event the caller
waits on.  Every request carries a :class:`RequestTrace` — the structured
per-request accounting (queue wait, batch size, bucket, cache hit depth,
per-stage wall-clock) that ``server.stats()`` aggregates.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any


class ServerOverloaded(RuntimeError):
    """Admission control rejected the request: the bounded request queue is
    full.  Callers shed load (retry later / fail the caller) instead of the
    server growing an unbounded backlog."""


class DeadlineUnmeetable(ServerOverloaded):
    """Shed at the door: the scheduler's service-time model says this
    request's deadline cannot survive the estimated queue wait plus one
    batch service time, so admitting it would only burn a ladder slot on
    an answer nobody will accept.  Subclasses :class:`ServerOverloaded`
    because the remedy is the same — the caller sheds load."""


class RequestTimeout(TimeoutError):
    """The request's deadline expired before the server produced a result
    (the scheduler drops expired requests instead of wasting a batch slot
    on work nobody is waiting for)."""


@dataclasses.dataclass
class RequestTrace:
    """Structured per-request accounting, filled in as the request moves
    queue -> scheduler -> bucketed execution -> completion."""
    rid: int
    t_arrival: float = 0.0          # monotonic, set at submit
    t_scheduled: float = 0.0        # when its micro-batch closed
    t_done: float = 0.0             # result ready (or dropped)
    queue_wait_ms: float = 0.0
    service_ms: float = 0.0         # batch close -> result ready
    latency_ms: float = 0.0         # submit -> result ready
    batch_size: int = 0             # requests in its micro-batch
    bucket: int = 0                 # ladder rung the batch padded to
    cache_hit_depth: int = 0        # pipeline stages skipped via the cache
    chain_len: int = 0
    batch_reason: str = ""          # "full" | "deadline" | "drain"
    timed_out: bool = False
    shed: bool = False              # dropped pre-execution by the scheduler
    errored: bool = False           # execution raised; see request.error
    late: bool = False              # completed, but past its deadline
    lane: str = ""                  # WFQ lane it was served from
    tenant: str = ""                # pipeline (tenant) it executed under
    cross_prefix_hit: bool = False  # cache hit written by another pipeline
    stage_ms: tuple = ()            # ((stage label, ms), ...) of its batch
    # -- decode (generate-stage requests only; zero otherwise) --------------
    ttft_ms: float = 0.0            # submit -> first generated token
    n_tokens: int = 0               # tokens decoded for this request

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ServeRequest:
    """One in-flight query.  ``Q`` is an nq==1 slice of the Q relation
    (``{"qid", "terms", "weights"}``); ``result`` is the matching nq==1
    result slice once ``done`` is set."""
    rid: int
    Q: Any
    deadline: float | None          # absolute monotonic deadline, or None
    trace: RequestTrace
    t_enqueued: float = 0.0         # set by the scheduler on admission
    qdigest: str = ""               # content digest of terms/weights
    lane: str = "default"           # WFQ lane this request queues in
    tenant: str = "default"         # which of the server's pipelines runs it
    result: Any = None
    error: BaseException | None = None
    done: threading.Event = dataclasses.field(default_factory=threading.Event)

    @property
    def qid(self) -> int:
        import numpy as np
        return int(np.asarray(self.Q["qid"]).reshape(-1)[0])

    def expired(self, now: float | None = None) -> bool:
        if self.deadline is None:
            return False
        return (time.monotonic() if now is None else now) > self.deadline

    def wait(self, timeout: float | None = None):
        """Block until the result is ready and return it.  Raises
        :class:`RequestTimeout` if the server dropped the request at its
        deadline, or ``TimeoutError`` if ``timeout`` elapses first."""
        if not self.done.wait(timeout):
            raise TimeoutError(f"request {self.rid} still pending after "
                               f"{timeout}s")
        if self.error is not None:
            raise self.error
        if self.trace.timed_out:
            raise RequestTimeout(
                f"request {self.rid} "
                + ("shed pre-execution (deadline cannot survive the "
                   "estimated queue wait + one batch service time)"
                   if self.trace.shed else
                   "expired in queue (deadline passed before execution)"))
        return self.result
