"""Continuous-batching decode server (vLLM-style slot scheduler, JAX-native).

A fixed pool of B decode slots over one shared KV cache; finished/empty
slots are refilled from the request queue every step (prefill for the new
request writes into the slot's cache rows).  One jitted decode step serves
the whole pool; per-slot positions make ragged decode exact.

This is the serving half of the paper's pipeline story: a neural Rerank
stage (e.g. an LM scoring documents) runs behind this scheduler.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer_lm as tlm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [P] int32
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Iteration-level decode pool.

    When an ``engine`` (a :class:`~repro.core.engine.ShardedQueryEngine`)
    and a ``key`` are supplied, the prefill and decode-step bodies run
    through ``engine.run_pinned`` — the engine's persistent jit cache —
    so the serving layer's recompiles-since-warmup invariant covers them:
    both bodies have *fixed* shapes (prompt length and pool size are
    static), so a warmed server takes zero decode-path compiles.  Without
    an engine the batcher owns its jits (standalone/offline use).
    """

    def __init__(self, cfg: tlm.LMConfig, params, *, slots: int = 4,
                 max_len: int = 256, eos_id: int | None = None,
                 engine=None, key=None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = tlm.init_kv_cache(cfg, slots, max_len)
        self.slot_req: list[Request | None] = [None] * slots
        self.positions = np.zeros(slots, np.int32)
        self.last_token = np.zeros((slots, 1), np.int32)
        self.queue: deque[Request] = deque()
        self.completed: list[Request] = []
        self.n_decode_steps = 0

        # one ragged decode step for the whole pool
        def step(params, tokens, cache, positions, active):
            logits, cache = _ragged_decode(cfg, params, tokens, cache, positions)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            nxt = jnp.where(active, nxt, 0)
            return nxt, cache

        def prefill_one(params, tokens, cache, slot, length):
            return _slot_prefill(cfg, params, tokens, cache, slot, length)

        if engine is not None:
            from repro.core.engine import StageProgram
            step_prog = StageProgram(key=(key, "decode_step"), fn=step)
            pre_prog = StageProgram(key=(key, "decode_prefill"),
                                    fn=prefill_one)
            self._step = lambda *a: engine.run_pinned(
                step_prog, *a, donate_argnums=(2,))
            self._prefill = lambda *a: engine.run_pinned(
                pre_prog, *a, donate_argnums=(2,))
        else:
            self._step = jax.jit(step, donate_argnums=2)
            self._prefill = jax.jit(prefill_one, donate_argnums=2)

    def submit(self, req: Request):
        self.queue.append(req)

    def free_slots(self) -> int:
        return sum(1 for r in self.slot_req if r is None)

    def active_slots(self) -> int:
        return self.slots - self.free_slots()

    def prefill_request(self, req: Request) -> int:
        """Place ``req`` into a free slot: prefill its prompt into the
        slot's KV-cache rows and record the first generated token.  The
        caller (the server's decode pump) owns admission policy; here we
        only require a free slot."""
        for s in range(self.slots):
            if self.slot_req[s] is None:
                break
        else:
            raise RuntimeError("prefill_request with no free slot")
        P = len(req.prompt)
        toks = jnp.asarray(np.asarray(req.prompt)[None, :], jnp.int32)
        logits, self.cache = self._prefill(
            self.params, toks, self.cache, jnp.int32(s), jnp.int32(P))
        first = int(jnp.argmax(logits))
        req.generated.append(first)
        self.slot_req[s] = req
        self.positions[s] = P
        self.last_token[s, 0] = first
        return s

    def _admit(self):
        while self.queue and self.free_slots():
            self.prefill_request(self.queue.popleft())

    def step_active(self) -> list[Request]:
        """One decode step over the currently active slots (no admission).
        Returns the requests that finished on this step."""
        active = np.array([r is not None for r in self.slot_req])
        if not active.any():
            return []
        nxt, self.cache = self._step(
            self.params, jnp.asarray(self.last_token), self.cache,
            jnp.asarray(self.positions), jnp.asarray(active))
        self.n_decode_steps += 1
        nxt = np.asarray(nxt)
        finished: list[Request] = []
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = int(nxt[s])
            req.generated.append(tok)
            self.positions[s] += 1
            self.last_token[s, 0] = tok
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if (len(req.generated) >= req.max_new_tokens or hit_eos or
                    self.positions[s] >= self.max_len - 1):
                req.done = True
                self.completed.append(req)
                finished.append(req)
                self.slot_req[s] = None
        return finished

    def step(self):
        """Admit + one decode step for all active slots."""
        self._admit()
        return bool(self.step_active()) or any(
            r is not None for r in self.slot_req)

    def reset(self):
        """Forget all slot/queue state (the KV cache itself needs no
        clearing: the attention mask only reads positions a live request's
        prefill wrote).  Used after warmup's dummy prefill/decode."""
        self.slot_req = [None] * self.slots
        self.positions = np.zeros(self.slots, np.int32)
        self.last_token = np.zeros((self.slots, 1), np.int32)
        self.queue.clear()
        self.completed = []

    def run_to_completion(self, max_steps: int = 10000):
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return self.completed


# ---------------------------------------------------------------------------
# ragged decode internals (per-slot positions)
# ---------------------------------------------------------------------------

def _ragged_decode(cfg, params, tokens, cache, positions):
    """tokens [B,1]; positions [B] (absolute, per slot)."""
    B = tokens.shape[0]
    x = params["embed"].astype(cfg.dtype)[tokens]          # [B,1,d]
    chunks = tlm._layer_chunks(cfg)

    def body(x, scanned):
        layer_p, chunk, ck, cv = scanned
        x = _ragged_block(cfg, layer_p, x, positions, chunk, ck, cv)
        return x[0], x[1:]

    def scan_body(carry, scanned):
        x = carry
        layer_p, chunk, ck, cv = scanned
        x, ck, cv = _ragged_block(cfg, layer_p, x, positions, chunk, ck, cv)
        return x, (ck, cv)

    x, (new_k, new_v) = jax.lax.scan(
        scan_body, x, (params["layers"], chunks, cache["k"], cache["v"]))
    from repro.models import layers as L
    x = L.rmsnorm(x[:, -1:], params["ln_final"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = jnp.einsum("bsd,dv->bsv", x, unembed.astype(cfg.dtype))[:, 0]
    return logits, {"k": new_k, "v": new_v}


def _ragged_block(cfg, p, x, positions, chunk, ck, cv):
    from repro.models import layers as L
    dims = cfg.attn_dims()
    h = L.rmsnorm(x, p["ln_attn"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wv"])
    if dims.qkv_bias:
        q, k, v = q + p["attn"]["bq"], k + p["attn"]["bk"], v + p["attn"]["bv"]
    # per-slot absolute positions
    q = jax.vmap(lambda qq, pp: L.apply_rope(qq, pp[None], dims.rope_theta))(
        q, positions)
    kk = jax.vmap(lambda kx, pp: L.apply_rope(kx, pp[None], dims.rope_theta))(
        k, positions)
    B, T = ck.shape[0], ck.shape[1]
    onehot = jax.nn.one_hot(positions, T, dtype=ck.dtype)   # [B,T]
    ck = ck * (1 - onehot)[..., None, None] + \
        onehot[..., None, None] * kk.astype(ck.dtype)
    cv = cv * (1 - onehot)[..., None, None] + \
        onehot[..., None, None] * v.astype(cv.dtype)
    k_pos = jnp.arange(T, dtype=jnp.int32)
    valid = k_pos[None, :] <= positions[:, None]             # [B,T]
    bias = jnp.where(valid, 0.0, L.NEG_INF)[:, None, None, None, :]
    out = L.gqa_attention(q, ck, cv, bias, impl="xla")
    x = x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])
    h2 = L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
    if cfg.moe:
        from repro.models import moe as moe_lib
        mlp_out, _ = moe_lib.moe_apply(p["moe"], h2, cfg.moe)
    else:
        mlp_out = L.mlp_apply(p["mlp"], h2)
    return x + mlp_out, ck, cv


def _slot_prefill(cfg, params, tokens, cache, slot, length):
    """Prefill one slot's cache rows from a [1, P] prompt."""
    B1, P = tokens.shape
    slot_cache = {"k": jax.lax.dynamic_slice_in_dim(cache["k"], slot, 1, 1),
                  "v": jax.lax.dynamic_slice_in_dim(cache["v"], slot, 1, 1)}
    logits, new_slot = tlm.prefill(cfg, params, tokens, slot_cache)
    cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], new_slot["k"], slot, 1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], new_slot["v"], slot, 1),
    }
    return logits, cache
