"""Trace aggregation for the serving layer: latency percentiles, batch and
cache-depth histograms, per-stage running means.

The server keeps a bounded ring of recent :class:`RequestTrace` records
(percentiles are computed over the ring) plus running counters that never
reset — so ``stats()`` is O(ring) and a week-old server doesn't hold a
week of traces.
"""
from __future__ import annotations

import math
from collections import deque


def percentile(values, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of a sequence; 0.0 when
    empty.  Deliberately simple/deterministic — bench JSON comparisons
    diff across hosts, so no interpolation scheme to disagree over.
    (True ceil, not round(x + .5): banker's rounding returns one rank too
    high on exact-integer ties, e.g. the median of two values.)"""
    xs = sorted(values)
    if not xs:
        return 0.0
    rank = max(0, min(len(xs) - 1, math.ceil(q / 100.0 * len(xs)) - 1))
    return float(xs[rank])


def latency_summary(latencies_ms) -> dict:
    xs = list(latencies_ms)
    return {
        "n": len(xs),
        "mean_ms": round(sum(xs) / len(xs), 3) if xs else 0.0,
        "p50_ms": round(percentile(xs, 50), 3),
        "p95_ms": round(percentile(xs, 95), 3),
        "p99_ms": round(percentile(xs, 99), 3),
        "max_ms": round(max(xs), 3) if xs else 0.0,
    }


class TraceLog:
    """Bounded trace ring + unbounded scalar aggregates.

    Locked throughout: the serving thread records while monitoring threads
    call ``summary()`` — an unguarded deque/dict would raise
    "mutated during iteration" under continuous traffic."""

    def __init__(self, capacity: int = 2048):
        import threading
        self._lock = threading.Lock()
        self.ring: deque = deque(maxlen=capacity)
        self.n_served = 0
        self.n_timed_out = 0
        self.n_shed = 0
        self.n_errors = 0
        self.n_late = 0
        self.n_batches = 0
        self.sum_batch_size = 0
        self.max_batch_size = 0
        #: cache hit depth -> count (0 = no prefix reused)
        self.hit_depths: dict[int, int] = {}
        #: stage label -> [sum_ms, count]
        self.stage_ms: dict[str, list] = {}
        #: tenant (pipeline) name -> per-pipeline counters; populated even
        #: for a single-pipeline server (one "default" entry)
        self.tenants: dict[str, dict] = {}
        #: WFQ lane -> completed-request count
        self.lane_served: dict[str, int] = {}
        #: decode-side running counters (generate-stage requests)
        self.n_decoded = 0          # completed requests that decoded tokens
        self.n_tokens_total = 0     # tokens decoded across all of them

    # -- recording ----------------------------------------------------------
    def record_batch(self, size: int) -> None:
        with self._lock:
            self.n_batches += 1
            self.sum_batch_size += size
            self.max_batch_size = max(self.max_batch_size, size)

    def record_stage(self, label: str, ms: float) -> None:
        with self._lock:
            ent = self.stage_ms.setdefault(label, [0.0, 0])
            ent[0] += ms
            ent[1] += 1

    def register_tenant(self, name: str) -> None:
        """Pre-create a pipeline's counter entry so ``summary()`` lists
        every attached tenant, traffic or not."""
        with self._lock:
            self._tenant(name)

    def _tenant(self, name: str) -> dict:
        ent = self.tenants.get(name)
        if ent is None:
            ent = self.tenants[name] = {
                "served": 0, "timed_out": 0, "shed": 0, "errors": 0,
                "late": 0, "cache_hit_depths": {},
                "cross_pipeline_prefix_hits": 0}
        return ent

    def record(self, trace) -> None:
        with self._lock:
            self.ring.append(trace)
            ten = self._tenant(trace.tenant or "default")
            if trace.timed_out:
                self.n_timed_out += 1
                ten["timed_out"] += 1
                if trace.shed:
                    self.n_shed += 1
                    ten["shed"] += 1
                return
            if trace.errored:
                self.n_errors += 1
                ten["errors"] += 1
                return
            self.n_served += 1
            ten["served"] += 1
            if trace.lane:
                self.lane_served[trace.lane] = \
                    self.lane_served.get(trace.lane, 0) + 1
            if trace.late:
                self.n_late += 1
                ten["late"] += 1
            d = trace.cache_hit_depth
            self.hit_depths[d] = self.hit_depths.get(d, 0) + 1
            hd = ten["cache_hit_depths"]
            hd[d] = hd.get(d, 0) + 1
            if trace.cross_prefix_hit:
                ten["cross_pipeline_prefix_hits"] += 1
            if trace.n_tokens:
                self.n_decoded += 1
                self.n_tokens_total += trace.n_tokens

    # -- reporting ----------------------------------------------------------
    def summary(self) -> dict:
        with self._lock:
            done = [t for t in self.ring
                    if not (t.timed_out or t.errored)]
            out = {
                "served": self.n_served,
                "timed_out": self.n_timed_out,
                "shed": self.n_shed,
                "errors": self.n_errors,
                "late": self.n_late,
                "batches": self.n_batches,
                "mean_batch_size": (
                    round(self.sum_batch_size / self.n_batches, 2)
                    if self.n_batches else 0.0),
                "max_batch_size": self.max_batch_size,
                "cache_hit_depths": dict(sorted(self.hit_depths.items())),
                "lane_served": dict(sorted(self.lane_served.items())),
                "pipelines": {
                    name: {**ent, "cache_hit_depths":
                           dict(sorted(ent["cache_hit_depths"].items()))}
                    for name, ent in sorted(self.tenants.items())},
            }
            if self.stage_ms:
                out["stage_mean_ms"] = {
                    label: round(s / n, 3)
                    for label, (s, n) in self.stage_ms.items()}
        out["latency_ms"] = latency_summary([t.latency_ms for t in done])
        out["queue_wait_ms"] = latency_summary(
            [t.queue_wait_ms for t in done])
        decoded = [t for t in done if t.n_tokens]
        if decoded or self.n_decoded:
            # per-token latency excludes the first token (TTFT owns the
            # prompt prefill + retrieval); a 1-token decode has no steps
            out["decode"] = {
                "requests": self.n_decoded,
                "tokens": self.n_tokens_total,
                "ttft_ms": latency_summary([t.ttft_ms for t in decoded]),
                "per_token_ms": latency_summary(
                    [(t.latency_ms - t.ttft_ms) / max(t.n_tokens - 1, 1)
                     for t in decoded]),
            }
        return out
