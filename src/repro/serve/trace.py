"""Trace aggregation for the serving layer: latency percentiles, batch and
cache-depth histograms, per-stage running means.

The server keeps a bounded ring of recent :class:`RequestTrace` records
(percentiles are computed over the ring) plus running counters that never
reset — so ``stats()`` is O(ring) and a week-old server doesn't hold a
week of traces.

The counters live in a :class:`~repro.obs.metrics.MetricsRegistry`
(``serve_requests_total{tenant,outcome}``, ``serve_batch_size``,
``serve_cache_hit_depth_total{tenant,depth}``, ...): ``summary()`` keeps
its legacy dict shape but is a *view* over registry series, so the same
numbers are available as a Prometheus exposition via the server.
"""
from __future__ import annotations

import math
from collections import deque

from repro.obs.metrics import MetricsRegistry


def percentile(values, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of a sequence; 0.0 when
    empty.  Deliberately simple/deterministic — bench JSON comparisons
    diff across hosts, so no interpolation scheme to disagree over.
    (True ceil, not round(x + .5): banker's rounding returns one rank too
    high on exact-integer ties, e.g. the median of two values.)"""
    xs = sorted(values)
    if not xs:
        return 0.0
    return _rank(xs, q)


def _rank(sorted_xs, q: float) -> float:
    rank = max(0, min(len(sorted_xs) - 1,
                      math.ceil(q / 100.0 * len(sorted_xs)) - 1))
    return float(sorted_xs[rank])


def latency_summary(latencies_ms) -> dict:
    """p50/p95/p99/max over one *single* sort — this runs under the
    trace-log lock, so a per-percentile re-sort was pure lock-hold time."""
    xs = sorted(latencies_ms)
    if not xs:
        return {"n": 0, "mean_ms": 0.0, "p50_ms": 0.0, "p95_ms": 0.0,
                "p99_ms": 0.0, "max_ms": 0.0}
    return {
        "n": len(xs),
        "mean_ms": round(sum(xs) / len(xs), 3),
        "p50_ms": round(_rank(xs, 50), 3),
        "p95_ms": round(_rank(xs, 95), 3),
        "p99_ms": round(_rank(xs, 99), 3),
        "max_ms": round(xs[-1], 3),
    }


#: per-tenant request outcomes tracked in ``serve_requests_total``
_OUTCOMES = ("served", "timed_out", "shed", "errors", "late")

#: batch sizes are small integers; powers of two to 1024 cover any pool
_BATCH_BUCKETS = tuple(float(2 ** i) for i in range(11))


class TraceLog:
    """Bounded trace ring + registry-backed scalar aggregates.

    Locked throughout: the serving thread records while monitoring threads
    call ``summary()`` — an unguarded deque/dict would raise
    "mutated during iteration" under continuous traffic.  The whole
    summary (ring scan *and* percentile reduction) builds under the lock,
    so a concurrent ``record()`` can never tear it."""

    def __init__(self, capacity: int = 2048,
                 registry: MetricsRegistry | None = None):
        import threading
        self._lock = threading.Lock()
        self.ring: deque = deque(maxlen=capacity)
        self.metrics = registry if registry is not None else MetricsRegistry()
        m = self.metrics
        self._requests = m.counter(
            "serve_requests_total", "request outcomes by tenant",
            ("tenant", "outcome"))
        self._batches = m.counter("serve_batches_total", "closed batches")
        self._batch_size = m.histogram(
            "serve_batch_size", "requests per closed batch",
            buckets=_BATCH_BUCKETS)
        self._hit_depth = m.counter(
            "serve_cache_hit_depth_total",
            "stage-cache hit depth (0 = no prefix reused)",
            ("tenant", "depth"))
        self._cross_hits = m.counter(
            "serve_cross_prefix_hits_total",
            "stage-cache hits on a prefix another pipeline populated",
            ("tenant",))
        self._lane_served = m.counter(
            "serve_lane_served_total", "completed requests per WFQ lane",
            ("lane",))
        self._stage_ms = m.histogram(
            "serve_stage_ms", "per-stage execution time", ("stage",))
        self._decoded = m.counter(
            "serve_decode_requests_total",
            "completed requests that decoded tokens")
        self._tokens = m.counter(
            "serve_decode_tokens_total", "tokens decoded")
        #: tenant (pipeline) name registration order; populated even for a
        #: single-pipeline server (one "default" entry)
        self._tenant_names: list[str] = []

    # -- registry-backed views (legacy attribute surface) --------------------
    @property
    def n_served(self) -> int:
        return self._outcome_total("served")

    @property
    def n_timed_out(self) -> int:
        return self._outcome_total("timed_out")

    @property
    def n_shed(self) -> int:
        return self._outcome_total("shed")

    @property
    def n_errors(self) -> int:
        return self._outcome_total("errors")

    @property
    def n_late(self) -> int:
        return self._outcome_total("late")

    @property
    def n_batches(self) -> int:
        return int(self._batches.value())

    @property
    def n_decoded(self) -> int:
        return int(self._decoded.value())

    @property
    def n_tokens_total(self) -> int:
        return int(self._tokens.value())

    def _outcome_total(self, outcome: str) -> int:
        return int(sum(v for (tenant, o), v in self._requests.series().items()
                       if o == outcome))

    # -- recording ----------------------------------------------------------
    def record_batch(self, size: int) -> None:
        with self._lock:
            self._batches.inc()
            self._batch_size.observe(float(size))

    def record_stage(self, label: str, ms: float) -> None:
        with self._lock:
            self._stage_ms.observe(ms, (label,))

    def register_tenant(self, name: str) -> None:
        """Pre-create a pipeline's counter series so ``summary()`` lists
        every attached tenant, traffic or not."""
        with self._lock:
            self._tenant(name)

    def _tenant(self, name: str) -> str:
        if name not in self._tenant_names:
            self._tenant_names.append(name)
            for o in _OUTCOMES:
                self._requests.touch((name, o))
            self._cross_hits.touch((name,))
        return name

    def record(self, trace) -> None:
        with self._lock:
            self.ring.append(trace)
            ten = self._tenant(trace.tenant or "default")
            if trace.timed_out:
                self._requests.inc(labels=(ten, "timed_out"))
                if trace.shed:
                    self._requests.inc(labels=(ten, "shed"))
                return
            if trace.errored:
                self._requests.inc(labels=(ten, "errors"))
                return
            self._requests.inc(labels=(ten, "served"))
            if trace.lane:
                self._lane_served.inc(labels=(trace.lane,))
            if trace.late:
                self._requests.inc(labels=(ten, "late"))
            self._hit_depth.inc(labels=(ten, str(trace.cache_hit_depth)))
            if trace.cross_prefix_hit:
                self._cross_hits.inc(labels=(ten,))
            if trace.n_tokens:
                self._decoded.inc()
                self._tokens.inc(trace.n_tokens)

    # -- reporting ----------------------------------------------------------
    def summary(self) -> dict:
        with self._lock:
            req = self._requests.series()
            totals = {o: 0 for o in _OUTCOMES}
            depths: dict[int, int] = {}
            per_tenant_depths: dict[str, dict[int, int]] = {}
            for (tenant, o), v in req.items():
                totals[o] += int(v)
            for (tenant, d), v in self._hit_depth.series().items():
                d = int(d)
                depths[d] = depths.get(d, 0) + int(v)
                per_tenant_depths.setdefault(tenant, {})[d] = int(v)
            cross = self._cross_hits.series()
            bs = self._batch_size.stats()
            out = {
                "served": totals["served"],
                "timed_out": totals["timed_out"],
                "shed": totals["shed"],
                "errors": totals["errors"],
                "late": totals["late"],
                "batches": int(self._batches.value()),
                "mean_batch_size": round(bs["mean"], 2),
                "max_batch_size": int(bs["max"] or 0),
                "cache_hit_depths": dict(sorted(depths.items())),
                "lane_served": {
                    lane: int(v) for (lane,), v in
                    sorted(self._lane_served.series().items())},
                "pipelines": {
                    name: {
                        **{o: int(req.get((name, o), 0)) for o in _OUTCOMES},
                        "cache_hit_depths": dict(sorted(
                            per_tenant_depths.get(name, {}).items())),
                        "cross_pipeline_prefix_hits":
                            int(cross.get((name,), 0)),
                    }
                    for name in sorted(self._tenant_names)},
            }
            stage = self._stage_ms.series()
            if stage:
                out["stage_mean_ms"] = {
                    label: round(h["sum"] / h["count"], 3)
                    for (label,), h in stage.items() if h["count"]}
            done = [t for t in self.ring
                    if not (t.timed_out or t.errored)]
            out["latency_ms"] = latency_summary([t.latency_ms for t in done])
            out["queue_wait_ms"] = latency_summary(
                [t.queue_wait_ms for t in done])
            decoded = [t for t in done if t.n_tokens]
            n_decoded = int(self._decoded.value())
            if decoded or n_decoded:
                # per-token latency excludes the first token (TTFT owns the
                # prompt prefill + retrieval); a 1-token decode has no steps
                out["decode"] = {
                    "requests": n_decoded,
                    "tokens": int(self._tokens.value()),
                    "ttft_ms": latency_summary(
                        [t.ttft_ms for t in decoded]),
                    "per_token_ms": latency_summary(
                        [(t.latency_ms - t.ttft_ms) / max(t.n_tokens - 1, 1)
                         for t in decoded]),
                }
            return out
