"""Stage-keyed result cache for online traffic.

The experiment planner's trie shares pipeline *prefixes* across pipelines
within one batch execution; this cache shares them across *requests over
time*: every (pipeline prefix, source query) pair the server has executed
maps to the (Q, R) state flowing out of that prefix, so a repeated or
near-duplicate query resumes from the deepest cached prefix instead of
re-running the whole chain (cf. MacAvaney & Macdonald on precomputation
dominating pipeline cost).

Keys reuse the planner's machinery (`plan.chain_prefix_digests` chains the
stages' structural content keys; the query digest hashes the source row's
terms/weights).  ``qid`` is deliberately excluded from the digest — two
users issuing the same query share entries — and is re-stamped from the
requesting row when a cached value is served.

Values are nq==1 row slices of the stage-output pytrees, held as **host
numpy** arrays.  That choice is load-bearing for latency: row plumbing
(slice one request out of a batch, re-stack rows into the next batch) must
NOT be eager jax ops, because every distinct (batch arity, row index)
shape would trigger a fresh tiny XLA compilation — a compile storm that
dwarfs the pipeline itself under continuously varying micro-batch sizes.
numpy slicing/concatenation is plain C.  The store is LRU-bounded
(``repro.common.LRU``), so a long-lived server's memory is capped
regardless of traffic diversity.
"""
from __future__ import annotations

import hashlib

import numpy as np

from repro.common import LRU
from repro.obs.metrics import MetricsRegistry


def query_digest(Q_row) -> str:
    """Content digest of a single query row's terms+weights (qid excluded:
    identical queries from different callers must share cache entries)."""
    h = hashlib.sha256()
    for name in ("terms", "weights"):
        a = np.asarray(Q_row[name])
        h.update(str((a.dtype, a.shape)).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def _restamp_qid(part, qid_arr):
    if part is None:
        return None
    out = dict(part)
    out["qid"] = qid_arr
    return out


class StageResultCache:
    """(prefix digest, query digest) -> (Q row, R row, writer) after that
    prefix.

    One cache instance may back several pipelines (one multi-tenant
    server, or several servers over a shared backend): two pipelines whose
    leading stages carry identical structural keys chain to identical
    prefix digests, so tenant B's request resumes from state tenant A
    computed.  ``writer`` records which pipeline stored each entry — a hit
    whose writer differs from the requester is a *cross-pipeline* prefix
    hit, surfaced per tenant in ``server.stats()``.
    """

    def __init__(self, maxsize: int | None = 4096,
                 registry: MetricsRegistry | None = None):
        self.lru = LRU(maxsize)
        self.enabled = maxsize is None or maxsize > 0
        self.metrics = registry if registry is not None else MetricsRegistry()
        # request-level counters: ONE hit or miss per lookup_deepest call
        # (the raw LRU counters would count every probed depth of the
        # chain, making 'hit rate' uninterpretable per request); kept as
        # registry series, surfaced as attributes for the legacy readers
        self._lookups = self.metrics.counter(
            "stage_cache_lookups_total",
            "request-level stage-cache lookups", ("result",))
        for r in ("hit", "miss", "cross_pipeline_hit"):
            self._lookups.touch((r,))

    @property
    def hits(self) -> int:
        return int(self._lookups.value(("hit",)))

    @property
    def misses(self) -> int:
        return int(self._lookups.value(("miss",)))

    @property
    def cross_pipeline_hits(self) -> int:
        """Hits served from an entry a *different* pipeline wrote (the
        online realisation of cross-pipeline prefix reuse)."""
        return int(self._lookups.value(("cross_pipeline_hit",)))

    # -- lookup -------------------------------------------------------------
    def lookup_deepest(self, prefix_digests, qdigest: str,
                       reader: str = ""):
        """Deepest cached prefix for this query: returns
        ``(depth, (Q_row, R_row), writer)`` where ``depth`` stages are
        already computed (0 = nothing cached, value/writer None).  Scans
        deep-to-shallow so a full-pipeline hit wins outright.  ``reader``
        names the requesting pipeline for cross-pipeline accounting."""
        if not self.enabled:
            return 0, None, None
        for depth in range(len(prefix_digests), 0, -1):
            key = (prefix_digests[depth - 1], qdigest)
            if key not in self.lru:      # counter-free probe
                continue
            val = self.lru.get(key)      # refreshes recency
            if val is not None:          # (may have raced an eviction)
                self._lookups.inc(labels=("hit",))
                Q_row, R_row, writer = val
                if writer != reader:
                    self._lookups.inc(labels=("cross_pipeline_hit",))
                return depth, (Q_row, R_row), writer
        self._lookups.inc(labels=("miss",))
        return 0, None, None

    def store(self, prefix_digest: str, qdigest: str, Q_row, R_row,
              writer: str = "") -> None:
        if self.enabled:
            self.lru.put((prefix_digest, qdigest), (Q_row, R_row, writer))

    # -- row plumbing (host-side numpy on purpose — see module docstring) ----
    @staticmethod
    def to_host(tree):
        """One device->host conversion for a whole batched pytree; slice
        rows out of THIS, never out of the device arrays."""
        import jax
        return jax.tree.map(np.asarray, tree)

    @staticmethod
    def row(tree, j: int):
        """Slice request ``j``'s nq==1 row out of a (host) batched pytree.
        Copied, not a view: a view would pin the entire (padded) batch
        buffer for as long as the cache entry lives, and would alias the
        caller's result with the cache (an in-place mutation of a returned
        result must never rewrite what later hits serve)."""
        import jax
        return jax.tree.map(lambda x: np.asarray(x)[j:j + 1].copy(), tree)

    @staticmethod
    def stack_rows(rows):
        """Rebatch nq==1 host rows (inverse of :meth:`row`)."""
        import jax
        if len(rows) == 1:
            return rows[0]
        return jax.tree.map(lambda *xs: np.concatenate(xs, 0), *rows)

    @staticmethod
    def pad_rows(tree, pad: int):
        """Pad a host batch with ``pad`` copies of its last row, up to a
        ladder bucket.  Serving pads BEFORE stage execution so every stage
        (including eager pre-steps like query embedding) only ever sees
        ladder-sized batches — the shapes warm-up compiled — instead of one
        fresh compilation per distinct micro-batch size."""
        import jax
        if pad <= 0 or tree is None:
            return tree
        return jax.tree.map(
            lambda x: np.concatenate(
                [x, np.repeat(np.asarray(x)[-1:], pad, 0)], 0), tree)

    @staticmethod
    def restamp_qids(Q, R, qids):
        """Overwrite the qid columns with the requesting rows' qids (cached
        entries carry the original submitter's qid)."""
        qid_arr = np.asarray(qids, np.int32)
        return _restamp_qid(Q, qid_arr), _restamp_qid(R, qid_arr)

    def info(self) -> dict:
        out = self.lru.info()
        out["hits"] = self.hits          # request-level, not per-depth
        out["misses"] = self.misses
        out["cross_pipeline_hits"] = self.cross_pipeline_hits
        return out
