"""Deadline-aware continuous micro-batching scheduler over the engine's
bucket ladder: EDF packing, shed-before-execute, weighted-fair lanes.

The closure policy is the standard continuous-batching trade (cf.
vLLM-style LM serving, here over retrieval pipelines):

* **heavy load** — the queue reaches ``max_batch`` (the largest ladder
  bucket by default) and the batch closes immediately, "full": steady
  state packs every dispatch to the biggest compiled bucket.
* **light load** — the oldest waiting request hits the effective
  ``max_wait``: the batch closes with whatever is queued, "deadline", so
  latency under light load is bounded by ``max_wait`` + one batch's
  service time instead of waiting for a batch that may never fill.  With
  ``adaptive_wait`` the effective wait shrinks below ``max_wait_ms`` when
  the observed arrival rate (an EWMA of inter-arrival gaps) says the
  remaining slots cannot fill in time anyway — holding a batch open for
  arrivals that are not coming only adds latency.

What PACKS a batch is deadline-aware, not FIFO:

* **EDF within a lane** — each lane is an earliest-deadline-first heap
  (requests without a deadline order by arrival, after every
  deadline-bearing request at the same instant); the batch takes the most
  urgent work first, so a tight-deadline request never waits behind a
  loose one that happened to arrive earlier.
* **WFQ across lanes** — lanes are served by weighted fair queueing
  (virtual-time, one request per grant): lane ``i`` with weight ``w_i``
  receives ``w_i / sum(w)`` of batch slots under contention, so a
  background tenant cannot starve interactive traffic and interactive
  bursts cannot permanently lock background out either.
* **shed-before-execute** — the scheduler learns service times from
  measured batches, *per ladder rung*: ``S(b)`` is an EWMA per bucket
  (unmeasured rungs scale linearly from the nearest measured one — these
  padded pipelines cost ~linearly in the bucket), and a per-slot EWMA
  tracks the drain rate.  At submit, a request whose deadline cannot
  survive the estimated queue wait (``queued`` slots at the per-slot
  rate) plus one *smallest-rung* batch service time is rejected
  (:class:`~repro.serve.request.DeadlineUnmeetable`) — if even a
  minimum-size batch after the queue drains cannot make it, nothing can;
  at batch close the same test (queue wait already paid, the batch it
  would actually join) drops it into ``Batch.shed`` instead of a ladder
  slot.  Overloaded servers therefore spend capacity only on answers
  that can still arrive in time — goodput tracks throughput instead of
  collapsing.
* **deadline-capped packing** — a batch never packs past the rung the
  most urgent taken deadline can survive: when ``S(max_batch)`` exceeds
  the SLO but ``S(small rung)`` fits, the scheduler closes smaller
  batches rather than riding every deadline past its budget inside one
  giant bucket.  The cap re-tightens as more urgent requests join.

Admission control is a bounded queue: ``submit`` raises
:class:`~repro.serve.request.ServerOverloaded` rather than growing a
backlog nobody will be served from before their deadline.

For generate-stage pipelines the scheduler also owns the *decode* queue —
iteration-level scheduling: a request that finished its retrieval prefix
and assembled a prompt waits here until the decode pool frees a KV-cache
slot, and the server admits from this queue *between decode steps*
(``decode_take``), EDF-ordered so urgent answers claim slots first.

The scheduler is clock-driven and thread-safe but owns no thread itself —
``PipelineServer.step()`` (or its serving thread) pulls batches; tests
drive it synchronously with ``drain=True``.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import threading
import time
from collections import deque

from repro.common import select_ladder_bucket
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracing import NOOP_TRACER
from repro.serve.request import (DeadlineUnmeetable, ServeRequest,
                                 ServerOverloaded)

_INF = float("inf")


def _ms(seconds: float | None) -> float | None:
    return None if seconds is None else round(1000.0 * seconds, 3)


@dataclasses.dataclass
class Batch:
    requests: list       # EDF/WFQ-packed live requests (occupy ladder slots)
    reason: str          # "full" | "deadline" | "drain"
    t_closed: float
    shed: list = dataclasses.field(default_factory=list)   # dropped pre-exec


class _Lane:
    """One WFQ lane: an EDF heap plus its virtual-time account."""

    __slots__ = ("name", "weight", "heap", "vtime", "n_submitted", "n_taken")

    def __init__(self, name: str, weight: float):
        self.name = name
        self.weight = float(weight)
        #: (deadline key, seq, request); deadline None sorts after every
        #: deadline at +inf, then by arrival seq — EDF with FIFO fallback
        self.heap: list = []
        self.vtime = 0.0
        self.n_submitted = 0
        self.n_taken = 0


class MicroBatchScheduler:
    def __init__(self, *, ladder, max_queue: int = 1024,
                 max_wait_ms: float = 5.0, max_batch: int | None = None,
                 lanes=(("default", 1.0),), default_lane: str | None = None,
                 adaptive_wait: bool = False, shed: bool = True,
                 service_ewma_alpha: float = 0.2,
                 registry: MetricsRegistry | None = None,
                 tracer=None, recorder=None):
        self.ladder = tuple(sorted(int(b) for b in ladder))
        self.max_queue = int(max_queue)
        self.max_wait_s = float(max_wait_ms) / 1000.0
        self.max_batch = (self.ladder[-1] if max_batch is None
                          else min(int(max_batch), self.ladder[-1]))
        self.adaptive_wait = bool(adaptive_wait)
        self.shed_enabled = bool(shed)
        self._alpha = float(service_ewma_alpha)
        self.lanes: dict[str, _Lane] = {
            str(n): _Lane(str(n), w) for n, w in lanes}
        self.default_lane = (default_lane if default_lane is not None
                             else next(iter(self.lanes)))
        if self.default_lane not in self.lanes:
            raise ValueError(f"default lane {self.default_lane!r} not in "
                             f"{sorted(self.lanes)}")
        self._n_queued = 0
        self._seq = 0
        #: arrival-ordered view for the max_wait closure rule (heap order is
        #: deadline order); popped batches mark requests taken, and stale
        #: heads are lazily discarded
        self._arrivals: deque = deque()
        self._cv = threading.Condition()
        self._service_ewma: float | None = None   # seconds per batch (any)
        self._bucket_ewma: dict[int, float] = {}  # ladder rung -> seconds
        self._slot_ewma: float | None = None      # seconds per ladder slot
        self._gap_ewma: float | None = None       # seconds between arrivals
        self._last_arrival: float | None = None
        # counters live in the metrics registry (one source of truth for
        # stats()); tracer/recorder are the opt-in decision-event sinks
        self.metrics = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NOOP_TRACER
        self.recorder = recorder
        self._events = self.metrics.counter(
            "sched_requests_total", "scheduler admission/shedding events",
            ("event",))
        for e in ("submitted", "rejected", "shed_submit", "shed_queue",
                  "decode_submitted", "decode_taken"):
            self._events.touch((e,))
        self._batch_close = self.metrics.counter(
            "sched_batches_total", "closed batches by reason", ("reason",))
        #: decode-side EDF queue: (deadline key, seq, request) of requests
        #: whose retrieval prefix is done and whose prompt awaits a free
        #: KV-cache slot in the decode pool
        self._decode_heap: list = []

    # -- registry-backed views (legacy attribute surface) --------------------
    @property
    def n_submitted(self) -> int:
        return int(self._events.value(("submitted",)))

    @property
    def n_rejected(self) -> int:
        return int(self._events.value(("rejected",)))

    @property
    def n_shed_submit(self) -> int:
        return int(self._events.value(("shed_submit",)))

    @property
    def n_shed_queue(self) -> int:
        return int(self._events.value(("shed_queue",)))

    @property
    def n_decode_submitted(self) -> int:
        return int(self._events.value(("decode_submitted",)))

    @property
    def n_decode_taken(self) -> int:
        return int(self._events.value(("decode_taken",)))

    # -- feedback ------------------------------------------------------------
    def _ewma(self, old: float | None, new: float) -> float:
        return (new if old is None
                else (1.0 - self._alpha) * old + self._alpha * new)

    def note_service_time(self, seconds: float,
                          batch_size: int | None = None) -> None:
        """One measured batch service time (close -> results ready); the
        EWMAs of these are ``S`` in every shedding decision.  With
        ``batch_size`` the measurement also lands in the per-rung and
        per-slot EWMAs — service time depends strongly on the bucket a
        batch padded to, and feasibility must compare a deadline against
        the batch the request would actually ride in, not against
        whatever mix of sizes recent traffic happened to close."""
        with self._cv:
            self._service_ewma = self._ewma(self._service_ewma, seconds)
            if batch_size:
                b = select_ladder_bucket(self.ladder, int(batch_size),
                                         clamp=True)
                self._bucket_ewma[b] = self._ewma(self._bucket_ewma.get(b),
                                                  seconds)
                self._slot_ewma = self._ewma(self._slot_ewma, seconds / b)

    def _bucket_est(self, n: int) -> float | None:
        """Estimated service time of a batch of ``n``: the covering rung's
        EWMA if measured; else an affine fit ``c0 + c1*b`` through the
        measured rungs (padded pipeline cost is ~linear in the bucket PLUS
        a fixed dispatch/plumbing term — pure linear scaling from a small
        rung wildly underestimates big batches and vice versa); with a
        single measured rung, linear scaling; else the scalar EWMA, else
        None (nothing measured yet)."""
        if self._bucket_ewma:
            b = select_ladder_bucket(self.ladder, max(int(n), 1), clamp=True)
            S = self._bucket_ewma.get(b)
            if S is not None:
                return S
            pts = sorted(self._bucket_ewma.items())
            if len(pts) == 1:
                b0, S0 = pts[0]
                return S0 * (b / b0)
            m = len(pts)
            mx = sum(p[0] for p in pts) / m
            my = sum(p[1] for p in pts) / m
            denom = sum((p[0] - mx) ** 2 for p in pts)
            c1 = (sum((p[0] - mx) * (p[1] - my) for p in pts) / denom
                  if denom else 0.0)
            c1 = max(c1, 0.0)            # noise can invert the slope
            c0 = max(my - c1 * mx, 0.0)
            est = c0 + c1 * b
            if est <= 0.0:               # degenerate fit: fall back to scale
                b0 = min(self._bucket_ewma,
                         key=lambda r: abs(math.log(b / r)))
                est = self._bucket_ewma[b0] * (b / b0)
            return est
        return self._service_ewma

    def service_estimate(self, n: int | None = None) -> float | None:
        """Scalar service-time EWMA, or — with ``n`` — the per-bucket
        estimate for a batch of ``n`` requests."""
        with self._cv:
            return self._service_ewma if n is None else self._bucket_est(n)

    def arrival_gap_estimate(self) -> float | None:
        with self._cv:
            return self._gap_ewma

    # -- shedding math -------------------------------------------------------
    def _infeasible(self, req: ServeRequest, now: float, n_ahead: int,
                    own_n: int = 1) -> bool:
        """True when ``req``'s deadline cannot survive the estimated queue
        wait (``n_ahead`` slots at the per-slot drain rate) plus its own
        batch's service time (a batch of ``own_n`` — at the door that is
        the *smallest* rung: if even a minimum-size batch after the queue
        drains cannot make it, no packing can).  Never sheds before the
        first measurement (no estimate) except for already-expired
        deadlines."""
        if req.deadline is None:
            return False
        S_own = self._bucket_est(own_n)
        if S_own is None:
            return req.deadline <= now
        wait_est = (n_ahead * self._slot_ewma if self._slot_ewma is not None
                    else (n_ahead / self.max_batch) * S_own)
        return now + wait_est + S_own > req.deadline

    def _deadline_cap(self, d_min: float | None, now: float) -> int:
        """Largest batch size whose estimated service time still fits the
        most urgent taken deadline — packing past it would ride that
        request (and every tighter one) past its budget inside a bucket
        too big to finish in time."""
        if d_min is None:
            return self.max_batch
        budget = d_min - now
        cap = 0
        for b in self.ladder:
            if b > self.max_batch:
                break
            S = self._bucket_est(b)
            if S is not None and S > budget:
                break
            cap = b
        # the head passed its own feasibility test, so never cap below it
        return max(cap, 1)

    # -- producer side ------------------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        self.submit_many([req])

    def submit_many(self, reqs) -> None:
        """Admit a burst atomically: all requests enqueue, or none do and
        :class:`ServerOverloaded` is raised (partial admission would leak
        in-flight requests the caller holds no handles to).  Shedding is
        part of admission: a burst containing a request whose deadline the
        service-time model says cannot be met is rejected whole with
        :class:`DeadlineUnmeetable` before it occupies queue space."""
        with self._cv:
            if self._n_queued + len(reqs) > self.max_queue:
                self._events.inc(len(reqs), ("rejected",))
                if self.recorder is not None:
                    self.recorder.record(
                        "reject_overload", n=len(reqs),
                        queued=self._n_queued, max_queue=self.max_queue)
                raise ServerOverloaded(
                    f"request queue full ({self._n_queued}/{self.max_queue}, "
                    f"burst of {len(reqs)}); shedding load")
            now = time.monotonic()
            if self.shed_enabled:
                doomed = [r for r in reqs
                          if self._infeasible(r, now, self._n_queued)]
                if doomed:
                    self._events.inc(len(reqs), ("rejected",))
                    self._events.inc(len(reqs), ("shed_submit",))
                    S = self._service_ewma
                    if self.recorder is not None:
                        r0 = doomed[0]
                        self.recorder.record(
                            "shed_door", n=len(reqs),
                            rid=r0.trace.rid, queued=self._n_queued,
                            service_ewma_ms=_ms(S),
                            s1_ms=_ms(self._bucket_est(1)),
                            slot_ms=_ms(self._slot_ewma),
                            slack_ms=(None if r0.deadline is None
                                      else _ms(r0.deadline - now)))
                    self.tracer.event(
                        "sched.shed_door", "sched", n=len(reqs),
                        queued=self._n_queued, service_ewma_ms=_ms(S))
                    raise DeadlineUnmeetable(
                        f"deadline cannot be met: ~{self._n_queued} queued, "
                        f"EWMA batch service "
                        f"{0.0 if S is None else 1000.0 * S:.1f}ms; "
                        f"shedding before execution")
            for req in reqs:
                lane = self.lanes.get(req.lane)
                if lane is None:
                    raise KeyError(f"unknown lane {req.lane!r}; configured "
                                   f"lanes: {sorted(self.lanes)}")
                req.t_enqueued = now
                if self._last_arrival is not None:
                    gap = now - self._last_arrival
                    self._gap_ewma = (gap if self._gap_ewma is None
                                      else 0.8 * self._gap_ewma + 0.2 * gap)
                self._last_arrival = now
                self._seq += 1
                dl = _INF if req.deadline is None else req.deadline
                heapq.heappush(lane.heap, (dl, self._seq, req))
                lane.n_submitted += 1
                self._arrivals.append(req)
                self._n_queued += 1
                if self.recorder is not None:
                    self.recorder.record(
                        "admit", rid=req.trace.rid, lane=req.lane,
                        queued=self._n_queued,
                        slack_ms=(None if req.deadline is None
                                  else _ms(req.deadline - now)))
            self._events.inc(len(reqs), ("submitted",))
            self._cv.notify()

    def qsize(self) -> int:
        with self._cv:
            return self._n_queued

    # -- consumer side ------------------------------------------------------
    def select_bucket(self, n: int) -> int:
        """Smallest ladder rung covering ``n`` — the same shared policy as
        ``ShardedQueryEngine.select_bucket``
        (:func:`repro.common.select_ladder_bucket`), clamped so a
        sequential backend without an engine still reports a bucket for
        any batch this scheduler could close."""
        return select_ladder_bucket(self.ladder, n, clamp=True)

    def _oldest_wait(self, now: float) -> float | None:
        while self._arrivals and self._arrivals[0].done.is_set():
            self._arrivals.popleft()
        # a request is removed from _arrivals lazily; anything still queued
        # has done unset (it is set only at completion, post-scheduling),
        # so the head may be an already-taken-but-unfinished request:
        while self._arrivals and getattr(self._arrivals[0], "_taken", False):
            self._arrivals.popleft()
        if not self._arrivals:
            return None
        return now - self._arrivals[0].t_enqueued

    def _effective_wait(self) -> float:
        """Batch-close wait bound: ``max_wait_s``, shrunk under
        ``adaptive_wait`` to the time the arrival-rate EWMA says the
        remaining batch slots could plausibly fill in."""
        if not self.adaptive_wait or self._gap_ewma is None:
            return self.max_wait_s
        remaining = max(self.max_batch - self._n_queued, 0)
        return min(self.max_wait_s, self._gap_ewma * remaining)

    def _next_lane(self) -> _Lane | None:
        """WFQ grant: the non-empty lane with the smallest virtual time;
        charging ``1/weight`` per granted request yields weight-
        proportional batch slots under contention."""
        active = [ln for ln in self.lanes.values() if ln.heap]
        if not active:
            return None
        return min(active, key=lambda ln: (ln.vtime, ln.name))

    def _take(self, n: int, reason: str, now: float) -> Batch:
        """Pack a batch of up to ``n`` live requests: WFQ across lanes, EDF
        within a lane, shedding requests that cannot survive one more batch
        service time — a shed request never occupies a ladder slot, so the
        batch back-fills with the next most urgent feasible work.  The
        batch never packs past the rung the most urgent taken deadline can
        survive (``_deadline_cap``); later-granted requests with tighter
        deadlines re-shrink the cap."""
        live: list = []
        shed: list = []
        vbase = None
        d_min: float | None = None
        cap = self.max_batch
        while len(live) < min(n, cap):
            lane = self._next_lane()
            if lane is None:
                break
            if vbase is None:
                vbase = lane.vtime
            _, _, req = heapq.heappop(lane.heap)
            req._taken = True
            self._n_queued -= 1
            if self.shed_enabled and self._infeasible(req, now, 0,
                                                      own_n=len(live) + 1):
                self._events.inc(1, ("shed_queue",))
                req.trace.shed = True
                shed.append(req)
                if self.recorder is not None:
                    self.recorder.record(
                        "shed_queue", rid=req.trace.rid, lane=req.lane,
                        own_n=len(live) + 1,
                        s_own_ms=_ms(self._bucket_est(len(live) + 1)),
                        slack_ms=(None if req.deadline is None
                                  else _ms(req.deadline - now)))
                continue
            lane.vtime += 1.0 / lane.weight
            lane.n_taken += 1
            live.append(req)
            if req.deadline is not None and (d_min is None
                                             or req.deadline < d_min):
                d_min = req.deadline
                cap = self._deadline_cap(d_min, now)
        # keep idle lanes' virtual clocks from lagging unboundedly behind
        # (an hours-idle lane would otherwise monopolise every batch until
        # its stale clock caught up)
        if vbase is not None:
            for ln in self.lanes.values():
                if ln.vtime < vbase:
                    ln.vtime = vbase
        self._batch_close.inc(1, (reason,))
        rung = select_ladder_bucket(self.ladder, max(len(live), 1),
                                    clamp=True)
        if self.recorder is not None:
            self.recorder.record(
                "batch_close", reason=reason, size=len(live), rung=rung,
                shed=len(shed), cap=cap, queued_after=self._n_queued,
                s_rung_ms=_ms(self._bucket_est(rung)))
        self.tracer.event(
            "sched.batch_close", "sched", reason=reason, size=len(live),
            rung=rung, shed=len(shed), cap=cap,
            s_rung_ms=_ms(self._bucket_est(rung)),
            slot_ms=_ms(self._slot_ewma))
        return Batch(requests=live, reason=reason, t_closed=now, shed=shed)

    def next_batch(self, *, block: bool = False, timeout: float | None = None,
                   drain: bool = False) -> Batch | None:
        """Return the next micro-batch, or None.

        Non-blocking unless ``block``: then waits until a batch closes (or
        ``timeout`` elapses).  ``drain=True`` closes a batch from whatever
        is queued immediately — the synchronous replay/test mode.  A batch
        that shed its every candidate (all deadlines infeasible) is still
        returned — the server must fail the shed requests' waiters."""
        t_give_up = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                now = time.monotonic()
                wait = None
                if self._n_queued:
                    if self._n_queued >= self.max_batch:
                        return self._take(self.max_batch, "full", now)
                    if drain:
                        return self._take(self._n_queued, "drain", now)
                    oldest = self._oldest_wait(now)
                    eff = self._effective_wait()
                    if oldest is not None and oldest >= eff:
                        return self._take(self._n_queued, "deadline", now)
                    wait = (eff if oldest is None else eff - oldest)
                elif drain:
                    return None
                if not block:
                    return None
                if t_give_up is not None:
                    remaining = t_give_up - now
                    if remaining <= 0:
                        return None
                    wait = remaining if wait is None else min(wait, remaining)
                self._cv.wait(wait)

    # -- decode-side (iteration-level) queue ---------------------------------
    def decode_submit(self, req: ServeRequest) -> None:
        """Queue a retrieval-complete request for a decode slot.  No
        bounded-queue check: the request was already admitted at the door
        and holds no ladder slot while waiting here."""
        with self._cv:
            self._seq += 1
            dl = _INF if req.deadline is None else req.deadline
            heapq.heappush(self._decode_heap, (dl, self._seq, req))
            self._events.inc(1, ("decode_submitted",))

    def decode_take(self, n: int) -> list:
        """Admit up to ``n`` requests into freed decode slots, most urgent
        deadline first — called between decode steps, which is what makes
        the decode loop iteration-level rather than run-to-completion."""
        out: list = []
        with self._cv:
            while self._decode_heap and len(out) < n:
                _, _, req = heapq.heappop(self._decode_heap)
                self._events.inc(1, ("decode_taken",))
                out.append(req)
        return out

    def decode_pending(self) -> int:
        with self._cv:
            return len(self._decode_heap)

    def stats(self) -> dict:
        with self._cv:
            S = self._service_ewma
            gap = self._gap_ewma
            return {
                "queued": self._n_queued,
                "submitted": self.n_submitted,
                "rejected": self.n_rejected,
                "shed_submit": self.n_shed_submit,
                "shed_queue": self.n_shed_queue,
                "max_queue": self.max_queue,
                "max_batch": self.max_batch,
                "max_wait_ms": 1000.0 * self.max_wait_s,
                "adaptive_wait": self.adaptive_wait,
                "effective_wait_ms": round(1000.0 * self._effective_wait(), 3),
                "service_ewma_ms": (None if S is None
                                    else round(1000.0 * S, 3)),
                "service_ms_by_bucket": {
                    b: round(1000.0 * v, 3)
                    for b, v in sorted(self._bucket_ewma.items())},
                "slot_ms_ewma": (None if self._slot_ewma is None
                                 else round(1000.0 * self._slot_ewma, 3)),
                "arrival_gap_ewma_ms": (None if gap is None
                                        else round(1000.0 * gap, 3)),
                "decode_pending": len(self._decode_heap),
                "decode_submitted": self.n_decode_submitted,
                "decode_taken": self.n_decode_taken,
                "lanes": {ln.name: {"weight": ln.weight,
                                    "queued": len(ln.heap),
                                    "submitted": ln.n_submitted,
                                    "served_slots": ln.n_taken}
                          for ln in self.lanes.values()},
            }
