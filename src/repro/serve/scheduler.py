"""Continuous micro-batching scheduler over the engine's bucket ladder.

The policy is the standard continuous-batching trade (cf. vLLM-style LM
serving, here over retrieval pipelines):

* **heavy load** — the queue reaches ``max_batch`` (the largest ladder
  bucket by default) and the batch closes immediately, "full": steady
  state packs every dispatch to the biggest compiled bucket.
* **light load** — the oldest waiting request hits ``max_wait``: the batch
  closes with whatever is queued, "deadline", so latency under light load
  is bounded by ``max_wait`` + one batch's service time instead of waiting
  for a batch that may never fill.

Admission control is a bounded queue: ``submit`` raises
:class:`~repro.serve.request.ServerOverloaded` rather than growing a
backlog nobody will be served from before their deadline.

The scheduler is clock-driven and thread-safe but owns no thread itself —
``PipelineServer.step()`` (or its serving thread) pulls batches; tests
drive it synchronously with ``drain=True``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

from repro.serve.request import ServeRequest, ServerOverloaded


@dataclasses.dataclass
class Batch:
    requests: list
    reason: str          # "full" | "deadline" | "drain"
    t_closed: float


class MicroBatchScheduler:
    def __init__(self, *, ladder, max_queue: int = 1024,
                 max_wait_ms: float = 5.0, max_batch: int | None = None):
        self.ladder = tuple(sorted(int(b) for b in ladder))
        self.max_queue = int(max_queue)
        self.max_wait_s = float(max_wait_ms) / 1000.0
        self.max_batch = (self.ladder[-1] if max_batch is None
                          else min(int(max_batch), self.ladder[-1]))
        self._q: deque[ServeRequest] = deque()
        self._cv = threading.Condition()
        self.n_submitted = 0
        self.n_rejected = 0

    # -- producer side ------------------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        self.submit_many([req])

    def submit_many(self, reqs) -> None:
        """Admit a burst atomically: all requests enqueue, or none do and
        :class:`ServerOverloaded` is raised.  Partial admission would leak
        in-flight requests the caller holds no handles to (it got an
        exception, not the request list)."""
        with self._cv:
            if len(self._q) + len(reqs) > self.max_queue:
                self.n_rejected += len(reqs)
                raise ServerOverloaded(
                    f"request queue full ({len(self._q)}/{self.max_queue}, "
                    f"burst of {len(reqs)}); shedding load")
            now = time.monotonic()
            for req in reqs:
                req.t_enqueued = now
                self._q.append(req)
            self.n_submitted += len(reqs)
            self._cv.notify()

    def qsize(self) -> int:
        with self._cv:
            return len(self._q)

    # -- consumer side ------------------------------------------------------
    def select_bucket(self, n: int) -> int:
        """Smallest ladder rung covering ``n`` (mirrors
        ``ShardedQueryEngine.select_bucket``; kept here so a sequential
        backend without an engine still reports buckets)."""
        return next((b for b in self.ladder if b >= n), self.ladder[-1])

    def _take(self, n: int, reason: str, now: float) -> Batch:
        reqs = [self._q.popleft() for _ in range(n)]
        return Batch(requests=reqs, reason=reason, t_closed=now)

    def next_batch(self, *, block: bool = False, timeout: float | None = None,
                   drain: bool = False) -> Batch | None:
        """Return the next micro-batch, or None.

        Non-blocking unless ``block``: then waits until a batch closes (or
        ``timeout`` elapses).  ``drain=True`` closes a batch from whatever
        is queued immediately — the synchronous replay/test mode.
        """
        t_give_up = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                now = time.monotonic()
                wait = None
                if self._q:
                    if len(self._q) >= self.max_batch:
                        return self._take(self.max_batch, "full", now)
                    oldest = now - self._q[0].t_enqueued
                    if drain:
                        return self._take(len(self._q), "drain", now)
                    if oldest >= self.max_wait_s:
                        return self._take(len(self._q), "deadline", now)
                    wait = self.max_wait_s - oldest
                elif drain:
                    return None
                if not block:
                    return None
                if t_give_up is not None:
                    remaining = t_give_up - now
                    if remaining <= 0:
                        return None
                    wait = remaining if wait is None else min(wait, remaining)
                self._cv.wait(wait)

    def stats(self) -> dict:
        return {"queued": self.qsize(), "submitted": self.n_submitted,
                "rejected": self.n_rejected, "max_queue": self.max_queue,
                "max_batch": self.max_batch,
                "max_wait_ms": 1000.0 * self.max_wait_s}
