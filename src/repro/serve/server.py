"""PipelineServer: a compiled pipeline as a long-lived online service.

The offline stack executes a *batch* of queries through a compiled
pipeline; serving inverts the shape: queries arrive one at a time and the
server re-creates the batch axis continuously —

    submit() -> bounded queue -> micro-batch scheduler -> bucket ladder
             -> stage-keyed result cache -> per-stage execution -> result

* The pipeline is compiled ONCE (pass manager, fusion gate) at server
  construction; serving executes the compiled IR chain, so steady-state
  traffic never touches the compiler.
* Micro-batches pack into the engine's existing bucket ladder and reuse
  its persistent jit cache: after :meth:`warmup` every (stage, bucket)
  variant is compiled and serving never recompiles.
* A :class:`~repro.serve.cache.StageResultCache` keyed by the planner's
  chained stage digests lets repeated queries skip whole pipeline
  prefixes (the online mirror of the experiment-plan trie).
* Admission control (bounded queue), per-request deadlines (expired
  requests are dropped, not executed), and structured per-request traces
  surfaced via :meth:`stats`.

The server owns no thread until :meth:`start`; tests and replay drive it
synchronously with :meth:`pump`.
"""
from __future__ import annotations

import threading
import time

import jax
import numpy as np

from repro.core import ir
from repro.core.compiler import Context, _execute
from repro.core.passes import compile_pipeline
from repro.core.plan import chain_prefix_digests
from repro.serve.cache import StageResultCache, query_digest
from repro.serve.request import RequestTrace, ServeRequest
from repro.serve.scheduler import MicroBatchScheduler
from repro.serve.trace import TraceLog

#: bucket ladder used when the backend has no sharded engine attached
#: (REPRO_ENGINE=sequential): the sequential path pads per-chunk itself,
#: so these rungs only shape the scheduler's batching decisions
_FALLBACK_LADDER = (1, 2, 4, 8, 16)

#: sentinel distinguishing "caller said nothing" (inherit the server
#: default) from an explicit ``timeout_ms=None`` ("no deadline")
_UNSET = object()


class PipelineServer:
    """Serve single queries (or small bursts) through a compiled pipeline.

    >>> server = PipelineServer(Retrieve("BM25") % 10, backend)
    >>> server.warmup(Q_sample)
    >>> req = server.submit(q_row)      # non-blocking
    >>> server.pump()                   # or server.start() for a thread
    >>> R = req.wait(timeout=5.0)
    """

    def __init__(self, pipeline, backend, *, optimize: bool = True,
                 max_queue: int = 1024, max_wait_ms: float = 5.0,
                 max_batch: int | None = None,
                 cache_entries: int | None = 4096,
                 cache_stages: bool = True,
                 default_timeout_ms: float | None = None,
                 trace_stages: bool = False,
                 trace_capacity: int = 2048,
                 cache: StageResultCache | None = None):
        self.backend = backend
        self.engine = backend.engine
        #: compile report: pass timings, gate decisions and tuning counters
        #: (``compile_report['tuning']['profile_hits']`` > 0 with zero
        #: gate_estimates/probe_measurements = a profile-warm restart)
        self.compile_report: dict = {}
        self.op = compile_pipeline(pipeline, backend, optimize=optimize,
                                   report=self.compile_report)
        self.chain = ir.chain(self.op)
        self._stateful = self.op.stateful_subtree()
        self._digest_scope = f"serve:be{backend.uid}:"
        self._prefixes = chain_prefix_digests(self.chain,
                                              scope=self._digest_scope)
        ladder = (self.engine.ladder if self.engine is not None
                  else _FALLBACK_LADDER)
        self.scheduler = MicroBatchScheduler(
            ladder=ladder, max_queue=max_queue, max_wait_ms=max_wait_ms,
            max_batch=max_batch)
        self.cache = cache if cache is not None \
            else StageResultCache(cache_entries)
        self.cache_stages = cache_stages
        self.default_timeout_ms = default_timeout_ms
        self.trace_stages = trace_stages
        self.log = TraceLog(trace_capacity)
        self._rid = 0
        self._rid_lock = threading.Lock()
        self._warm_compiles: int | None = None
        self._thread: threading.Thread | None = None
        self._stop = False
        self.last_error: BaseException | None = None

    # -- key management -----------------------------------------------------
    def _prefix_digests(self) -> list[str]:
        """Chained stage digests; recomputed per batch when the chain holds
        a stateful stage (fit() bumps its version marker — the recompute is
        what invalidates the online cache)."""
        if self._stateful:
            self._prefixes = chain_prefix_digests(self.chain,
                                                  scope=self._digest_scope)
        return self._prefixes

    # -- submission ---------------------------------------------------------
    def _next_rid(self) -> int:
        with self._rid_lock:
            self._rid += 1
            return self._rid

    def submit(self, Q, *, timeout_ms=_UNSET):
        """Enqueue the queries in ``Q`` (an nq>=1 Q relation).  Returns one
        :class:`ServeRequest` for nq==1, else a list.  Raises
        :class:`~repro.serve.request.ServerOverloaded` when admission
        control rejects (bounded queue full).  ``timeout_ms`` omitted =
        inherit the server's ``default_timeout_ms``; an explicit ``None``
        = this request has no deadline."""
        nq = int(np.asarray(Q["qid"]).shape[0])
        if nq <= 0:
            raise ValueError("empty query batch")
        if timeout_ms is _UNSET:
            timeout_ms = self.default_timeout_ms
        now = time.monotonic()
        deadline = None if timeout_ms is None else now + timeout_ms / 1000.0
        reqs = []
        for j in range(nq):
            row = StageResultCache.row(Q, j)
            rid = self._next_rid()
            req = ServeRequest(rid=rid, Q=row, deadline=deadline,
                               trace=RequestTrace(rid=rid, t_arrival=now,
                                                  chain_len=len(self.chain)))
            req.qdigest = query_digest(row)
            reqs.append(req)
        # atomic: a burst admits whole or not at all (partial admission
        # would execute requests the caller holds no handles to)
        self.scheduler.submit_many(reqs)
        return reqs[0] if nq == 1 else reqs

    def submit_wait(self, Q, *, timeout: float = 60.0):
        """Synchronous convenience: submit + pump + wait."""
        req = self.submit(Q)
        self.pump()
        one = not isinstance(req, list)
        return req.wait(timeout) if one else [r.wait(timeout) for r in req]

    # -- serving loop -------------------------------------------------------
    def step(self, *, block: bool = False, timeout: float | None = None,
             drain: bool = False) -> int:
        """Close and execute at most one micro-batch; returns the number of
        requests it completed (0 = no batch closed)."""
        batch = self.scheduler.next_batch(block=block, timeout=timeout,
                                          drain=drain)
        if batch is None:
            return 0
        self._execute_batch(batch)
        return len(batch.requests)

    def pump(self) -> int:
        """Drain the queue synchronously (replay/test mode)."""
        total = 0
        while True:
            n = self.step(drain=True)
            if n == 0:
                return total
            total += n

    def start(self) -> "PipelineServer":
        """Spawn the serving thread (continuous mode)."""
        if self._thread is None:
            self._stop = False
            self._thread = threading.Thread(target=self._loop, daemon=True,
                                            name="pipeline-server")
            self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is not None:
            self._stop = True
            self._thread.join()
            self._thread = None
        self.pump()                      # never strand queued requests

    def _loop(self) -> None:
        while not self._stop:
            try:
                self.step(block=True, timeout=0.02)
            except BaseException as e:             # keep the loop alive
                self.last_error = e

    # -- warm-up ------------------------------------------------------------
    def warmup(self, Q_sample) -> dict:
        """Compile every (stage, bucket) jit variant by replaying a sample
        query at each ladder rung, then snapshot the engine's compile
        counter: ``stats()['recompiles_since_warmup']`` must stay 0 in
        steady state.  Cache writes are skipped (the tiled duplicates would
        only pollute the LRU)."""
        row = StageResultCache.row(Q_sample, 0)
        t0 = time.monotonic()
        for bucket in self.scheduler.ladder:
            Qb = jax.tree.map(
                lambda x: np.tile(x, (bucket,) + (1,) * (x.ndim - 1)), row)
            ctx = Context(self.backend)
            Q, R, tok = Qb, None, None
            for stage in self.chain:
                Q, R, tok = _execute(stage, ctx, Q, R, tok)
            jax.block_until_ready((Q, R))
        if self.engine is not None:
            self._warm_compiles = self.engine.total_compiles()
        out = {"warmup_s": round(time.monotonic() - t0, 3),
               "buckets": list(self.scheduler.ladder),
               "compiles": (None if self.engine is None
                            else self.engine.total_compiles())}
        # persist any autotune decisions taken at compile time, so the next
        # server process starts profile-warm (zero gate compiles / probes)
        desc = getattr(self.backend, "descriptor", None)
        if desc is not None and desc.profile is not None:
            desc.profile.save()
            out["tuning_profile"] = desc.profile.info()
        if self.compile_report:
            out["tuning"] = self.compile_report.get("tuning")
        return out

    # -- batch execution ----------------------------------------------------
    def _execute_batch(self, batch) -> None:
        now = batch.t_closed
        live = []
        for req in batch.requests:
            req.trace.t_scheduled = now
            req.trace.queue_wait_ms = 1000.0 * (now - req.t_enqueued)
            req.trace.batch_size = len(batch.requests)
            req.trace.batch_reason = batch.reason
            if req.expired(now):
                self._finish(req, None, timed_out=True)
            else:
                live.append(req)
        if not live:
            return
        self.log.record_batch(len(live))
        prefixes = self._prefix_digests()
        # deepest cached prefix per request, then group by resume depth so
        # each group executes its remaining suffix as one micro-batch
        groups: dict[int, list] = {}
        cached: dict[int, tuple] = {}
        for req in live:
            depth, val = self.cache.lookup_deepest(prefixes, req.qdigest)
            req.trace.cache_hit_depth = depth
            cached[req.rid] = val
            groups.setdefault(depth, []).append(req)
        for depth in sorted(groups, reverse=True):
            try:
                self._run_group(groups[depth], depth,
                                [cached[r.rid] for r in groups[depth]],
                                prefixes)
            except BaseException as e:
                self.last_error = e
                for req in groups[depth]:
                    req.error = e
                    self._finish(req, None)

    def _run_group(self, reqs, depth: int, cached_vals, prefixes) -> None:
        L = len(self.chain)
        qids = [r.qid for r in reqs]
        if depth >= L:                       # full-pipeline cache hits
            for req, (Qc, Rc) in zip(reqs, cached_vals):
                Qr, Rr = StageResultCache.restamp_qids(Qc, Rc, [req.qid])
                # row(…, 0) copies: the served result must never alias the
                # live cache entry (same invariant as the miss path)
                self._finish(req, StageResultCache.row(
                    Rr if Rr is not None else Qr, 0))
            return
        if depth == 0:
            Q = StageResultCache.stack_rows([r.Q for r in reqs])
            R = None
        else:                                # resume mid-chain
            Q = StageResultCache.stack_rows([v[0] for v in cached_vals])
            R_rows = [v[1] for v in cached_vals]
            R = (None if R_rows[0] is None
                 else StageResultCache.stack_rows(R_rows))
            Q, R = StageResultCache.restamp_qids(Q, R, qids)
        n = len(reqs)
        bucket = (self.engine.select_bucket(n) if self.engine is not None
                  else self.scheduler.select_bucket(n))
        for req in reqs:
            req.trace.bucket = bucket
        # pad up to the bucket BEFORE execution: every stage then sees
        # exactly the ladder shapes warm-up compiled (no per-size variants
        # anywhere, eager pre-steps included); padded rows are dropped when
        # results are sliced per request below
        Q = StageResultCache.pad_rows(Q, bucket - n)
        R = StageResultCache.pad_rows(R, bucket - n)
        ctx = Context(self.backend)
        tok = ctx.source_token(Q, R)
        stage_times = []
        for i in range(depth, L):
            stage = self.chain[i]
            t0 = time.monotonic() if self.trace_stages else 0.0
            Q, R, tok = _execute(stage, ctx, Q, R, tok)
            if self.trace_stages:
                jax.block_until_ready((Q, R))
                ms = 1000.0 * (time.monotonic() - t0)
                label = stage.label()
                stage_times.append((label, round(ms, 3)))
                self.log.record_stage(label, ms)
            if self.cache_stages and self.cache.enabled and i < L - 1:
                # one device->host conversion per stage, rows sliced from
                # the host copy (per-row device slicing would compile a
                # tiny XLA program per (arity, index) — a latency storm)
                Qh = StageResultCache.to_host(Q)
                Rh = None if R is None else StageResultCache.to_host(R)
                for j, req in enumerate(reqs):
                    self.cache.store(prefixes[i], req.qdigest,
                                     StageResultCache.row(Qh, j),
                                     None if Rh is None
                                     else StageResultCache.row(Rh, j))
        jax.block_until_ready((Q, R))
        Qh = StageResultCache.to_host(Q)
        Rh = None if R is None else StageResultCache.to_host(R)
        result = Rh if Rh is not None else Qh
        for j, req in enumerate(reqs):
            req.trace.stage_ms = tuple(stage_times)
            if self.cache.enabled:
                self.cache.store(
                    prefixes[L - 1], req.qdigest,
                    StageResultCache.row(Qh, j),
                    None if Rh is None else StageResultCache.row(Rh, j))
            self._finish(req, StageResultCache.row(result, j))

    def _finish(self, req, result, *, timed_out: bool = False) -> None:
        t = time.monotonic()
        tr = req.trace
        tr.t_done = t
        tr.timed_out = timed_out
        tr.errored = req.error is not None
        tr.latency_ms = 1000.0 * (t - tr.t_arrival)
        tr.service_ms = 1000.0 * (t - tr.t_scheduled) if tr.t_scheduled else 0.0
        tr.late = (not timed_out and not tr.errored
                   and req.deadline is not None and t > req.deadline)
        req.result = result
        self.log.record(tr)
        req.done.set()

    # -- reporting ----------------------------------------------------------
    def stats(self) -> dict:
        out = {
            "pipeline": self.op.label(),
            "chain_len": len(self.chain),
            "scheduler": self.scheduler.stats(),
            **self.log.summary(),
            "stage_cache": self.cache.info(),
        }
        if self.engine is not None:
            out["engine"] = self.engine.stats()
            total = self.engine.total_compiles()
            out["recompiles_since_warmup"] = (
                None if self._warm_compiles is None
                else total - self._warm_compiles)
        else:
            out["engine"] = None
            out["recompiles_since_warmup"] = None
        out["tuning"] = self.compile_report.get("tuning")
        desc = getattr(self.backend, "descriptor", None)
        out["tuning_profile"] = (desc.profile.info()
                                 if desc is not None and desc.profile
                                 else None)
        return out
