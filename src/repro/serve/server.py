"""PipelineServer: compiled pipelines as a long-lived online service.

The offline stack executes a *batch* of queries through a compiled
pipeline; serving inverts the shape: queries arrive one at a time and the
server re-creates the batch axis continuously —

    submit() -> bounded queue -> deadline-aware micro-batch scheduler
             -> bucket ladder -> stage-keyed result cache
             -> per-stage execution -> result

* Each pipeline is compiled ONCE (pass manager, fusion gate) when it is
  attached; serving executes the compiled IR chains, so steady-state
  traffic never touches the compiler.
* Micro-batches pack into the engine's existing bucket ladder and reuse
  its persistent jit cache: after :meth:`warmup` every
  (pipeline stage, bucket) variant is compiled and serving never
  recompiles.
* **Multi-tenancy**: :meth:`add_pipeline` multiplexes several compiled
  pipelines over ONE engine, ONE scheduler, and ONE shared
  :class:`~repro.serve.cache.StageResultCache`.  Pipelines sharing a
  structural prefix share cache entries (the chained prefix digests make
  that sound), so tenant B resumes from state tenant A computed —
  cross-pipeline hits are surfaced per tenant in :meth:`stats`.
* **Deadline awareness**: the scheduler packs batches EDF, sheds requests
  whose deadline its service-time EWMA says cannot be met *before* they
  occupy a ladder slot, and serves priority lanes by weighted fair
  queueing.  The server feeds measured batch service times back to the
  scheduler (and the engine) after every executed batch.
* **RAG serving**: a pipeline ending in a ``generate`` stage splits at the
  answer boundary — the retrieval prefix rides the micro-batch/bucket
  machinery above, then the request's assembled prompt enters a per-tenant
  continuous-batching decode pool (:class:`~repro.serve.batching
  .ContinuousBatcher` slots over one block-allocated KV cache).  The
  scheduler's decode queue admits new prompts *between* decode steps
  (iteration-level scheduling), so one long answer never blocks admission,
  and :meth:`step` interleaves one retrieval batch with one decode step —
  batches mix retrieval-resume and mid-decode requests.  Prefill and
  decode-step programs are keyed into the engine's jit cache, so
  ``recompiles_since_warmup`` covers the decode path too.
* Policy lives in one frozen :class:`~repro.serve.config.ServeConfig`.

The server owns no thread until :meth:`start`; tests and replay drive it
synchronously with :meth:`pump`.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Any

import jax
import numpy as np

from repro.core import ir
from repro.obs import NOOP_TRACER, FlightRecorder, MetricsRegistry, Tracer
from repro.core.compiler import Context, _execute
from repro.core.passes import compile_pipeline
from repro.core.plan import chain_prefix_digests
from repro.serve.batching import ContinuousBatcher
from repro.serve.batching import Request as _DecodeRequest
from repro.serve.cache import StageResultCache, query_digest
from repro.serve.config import ServeConfig
from repro.serve.request import RequestTrace, ServeRequest
from repro.serve.scheduler import MicroBatchScheduler
from repro.serve.trace import TraceLog

#: bucket ladder used when the backend has no sharded engine attached
#: (REPRO_ENGINE=sequential): the sequential path pads per-chunk itself,
#: so these rungs only shape the scheduler's batching decisions
_FALLBACK_LADDER = (1, 2, 4, 8, 16)

#: sentinel distinguishing "caller said nothing" (inherit the server
#: default) from an explicit ``timeout_ms=None`` ("no deadline")
_UNSET = object()


@dataclasses.dataclass
class _Tenant:
    """One served pipeline: its compiled chain plus cache-key material.
    ``generate`` is the chain's trailing :class:`~repro.core.stages
    .Generate` stage instance when the pipeline ends in one (the tenant
    then serves retrieval through the micro-batcher and decode through
    its pool), else None."""
    name: str
    op: Any                       # compiled IR root
    chain: list                   # ir.chain(op)
    stateful: bool                # any stage with a version marker?
    prefixes: list                # chained stage digests (shared scope)
    compile_report: dict
    generate: Any = None          # trailing Generate stage ref, if any


class PipelineServer:
    """Serve single queries (or small bursts) through compiled pipelines.

    >>> cfg = ServeConfig.default(max_wait_ms=4.0).with_deadlines(250.0)
    >>> server = PipelineServer(Retrieve("BM25") % 10, backend, cfg)
    >>> server.add_pipeline(other_pipe, name="background")
    >>> server.warmup(Q_sample)
    >>> req = server.submit_one(q_row)  # non-blocking
    >>> server.pump()                   # or server.start() for a thread
    >>> R = req.wait(timeout=5.0)
    """

    def __init__(self, pipeline, backend, config: ServeConfig | None = None,
                 *, cache: StageResultCache | None = None,
                 name: str = "default"):
        self.config = config if config is not None else ServeConfig()
        self.backend = backend
        self.engine = backend.engine
        self._digest_scope = f"serve:be{backend.uid}:"
        self._tenants: dict[str, _Tenant] = {}
        self._default_tenant = name
        ladder = (self.engine.ladder if self.engine is not None
                  else _FALLBACK_LADDER)
        cfg = self.config
        # one registry per server: every counter stats() reports lives
        # here; tracer/recorder are the opt-in layers (ServeConfig
        # .with_observability) and default to shared no-ops
        self.metrics = MetricsRegistry()
        self.tracer = (Tracer(enabled=True, capacity=cfg.obs_trace_events)
                       if cfg.obs_tracing else NOOP_TRACER)
        self.recorder = (FlightRecorder(cfg.obs_recorder_events)
                         if cfg.obs_recorder else None)
        self.scheduler = MicroBatchScheduler(
            ladder=ladder, max_queue=cfg.max_queue,
            max_wait_ms=cfg.max_wait_ms, max_batch=cfg.max_batch,
            lanes=cfg.lanes, default_lane=cfg.default_lane,
            adaptive_wait=cfg.adaptive_wait, shed=cfg.shed,
            service_ewma_alpha=cfg.service_ewma_alpha,
            registry=self.metrics, tracer=self.tracer,
            recorder=self.recorder)
        self.cache = cache if cache is not None \
            else StageResultCache(cfg.cache_entries, registry=self.metrics)
        self.cache_stages = cfg.cache_stages
        self.default_timeout_ms = cfg.default_timeout_ms
        self.trace_stages = cfg.trace_stages
        self.log = TraceLog(cfg.trace_capacity, registry=self.metrics)
        if self.engine is not None and (cfg.obs_tracing or cfg.obs_recorder):
            self.engine.attach_observability(tracer=self.tracer,
                                             recorder=self.recorder)
        self._rid = 0
        self._rid_lock = threading.Lock()
        self._warm_compiles: int | None = None
        #: tenant name -> decode pool (generate-stage tenants only)
        self._pools: dict[str, ContinuousBatcher] = {}
        #: rid -> in-flight ServeRequest currently decoding in some pool
        self._decoding: dict[int, ServeRequest] = {}
        self._thread: threading.Thread | None = None
        self._stop = False
        self.last_error: BaseException | None = None
        self.add_pipeline(pipeline, name=name)

    # -- tenancy ------------------------------------------------------------
    def add_pipeline(self, pipeline, *, name: str | None = None,
                     optimize: bool | None = None) -> str:
        """Attach another pipeline to this server (compiled now, once).
        All pipelines share the engine, the scheduler, and the stage cache
        — identical structural prefixes share cache entries across
        tenants.  Returns the tenant name (``submit(..., pipeline=name)``
        routes to it).  Call :meth:`warmup` again after attaching so the
        new chain's (stage, bucket) variants are compiled before traffic
        hits them."""
        if name is None:
            name = f"pipe{len(self._tenants)}"
        if name in self._tenants:
            raise ValueError(f"pipeline name {name!r} already attached "
                             f"(attached: {sorted(self._tenants)})")
        report: dict = {}
        op = compile_pipeline(
            pipeline, self.backend,
            optimize=self.config.optimize if optimize is None else optimize,
            report=report)
        chain = ir.chain(op)
        gen = self._generate_ref(chain[-1]) if chain[-1].kind == "generate" \
            else None
        self._tenants[name] = _Tenant(
            name=name, op=op, chain=chain,
            stateful=op.stateful_subtree(),
            prefixes=chain_prefix_digests(chain, scope=self._digest_scope),
            compile_report=report, generate=gen)
        if gen is not None:
            # per-tenant decode pool over one block-allocated KV cache;
            # prefill/decode-step programs key into the engine's jit cache
            # so warmup covers them and steady state never recompiles
            cfg_lm, params_lm = self.backend.lm(gen.params["model"])
            self._pools[name] = ContinuousBatcher(
                cfg_lm, params_lm, slots=self.config.decode_slots,
                max_len=(gen.params["max_prompt_len"]
                         + gen.params["max_new_tokens"] + 1),
                engine=self.engine,
                key=(self.backend.uid, chain[-1].key()))
        self.log.register_tenant(name)
        self._warm_compiles = None      # new chain: warm-up snapshot stale
        return name

    @staticmethod
    def _generate_ref(op):
        """The Generate stage instance behind a compiled ``generate`` op
        (rebuilt from the op's params if a rewrite dropped the ref)."""
        if op.ref is not None:
            return op.ref
        from repro.core.stages import Generate
        return Generate(**op.params)

    def pipelines(self) -> list[str]:
        return list(self._tenants)

    def _tenant(self, name: str | None) -> _Tenant:
        if name is None:
            name = self._default_tenant
        try:
            return self._tenants[name]
        except KeyError:
            raise KeyError(f"unknown pipeline {name!r}; attached: "
                           f"{sorted(self._tenants)}") from None

    # back-compat accessors: the default tenant's compiled pipeline
    @property
    def op(self):
        return self._tenant(None).op

    @property
    def chain(self):
        return self._tenant(None).chain

    @property
    def compile_report(self) -> dict:
        """Compile report of the default pipeline: pass timings, gate
        decisions, tuning counters (``['tuning']['profile_hits']`` > 0 with
        zero gate_estimates/probe_measurements = a profile-warm restart)."""
        return self._tenant(None).compile_report

    # -- key management -----------------------------------------------------
    def _prefix_digests(self, tenant: _Tenant) -> list:
        """Chained stage digests; recomputed per batch when the chain holds
        a stateful stage (fit() bumps its version marker — the recompute is
        what invalidates the online cache)."""
        if tenant.stateful:
            tenant.prefixes = chain_prefix_digests(tenant.chain,
                                                   scope=self._digest_scope)
        return tenant.prefixes

    # -- submission ---------------------------------------------------------
    def _next_rid(self) -> int:
        with self._rid_lock:
            self._rid += 1
            return self._rid

    def _make_requests(self, Q, timeout_ms, lane, pipeline) -> list:
        tenant = self._tenant(pipeline)
        lane = self.config.default_lane if lane is None else lane
        nq = int(np.asarray(Q["qid"]).shape[0])
        if nq <= 0:
            raise ValueError("empty query batch")
        if timeout_ms is _UNSET:
            timeout_ms = self.default_timeout_ms
        now = time.monotonic()
        deadline = None if timeout_ms is None else now + timeout_ms / 1000.0
        reqs = []
        for j in range(nq):
            row = StageResultCache.row(Q, j)
            rid = self._next_rid()
            req = ServeRequest(
                rid=rid, Q=row, deadline=deadline, lane=lane,
                tenant=tenant.name,
                trace=RequestTrace(rid=rid, t_arrival=now,
                                   chain_len=len(tenant.chain),
                                   lane=lane, tenant=tenant.name))
            req.qdigest = query_digest(row)
            reqs.append(req)
        # atomic: a burst admits whole or not at all (partial admission
        # would execute requests the caller holds no handles to)
        self.scheduler.submit_many(reqs)
        return reqs

    def submit_one(self, Q, *, timeout_ms=_UNSET, lane: str | None = None,
                   pipeline: str | None = None) -> ServeRequest:
        """Enqueue exactly one query (an nq==1 Q relation) and return its
        :class:`ServeRequest`.  ``timeout_ms`` omitted = inherit the
        server's ``default_timeout_ms``; an explicit ``None`` = no
        deadline.  ``lane`` routes into a WFQ priority lane; ``pipeline``
        names the tenant (default: the constructor pipeline).  Raises
        :class:`~repro.serve.request.ServerOverloaded` when admission
        control rejects, and its subclass
        :class:`~repro.serve.request.DeadlineUnmeetable` when
        shed-before-execute rejects the deadline at the door."""
        nq = int(np.asarray(Q["qid"]).shape[0])
        if nq != 1:
            raise ValueError(f"submit_one takes exactly one query row, got "
                             f"nq={nq}; use submit() for bursts")
        return self._make_requests(Q, timeout_ms, lane, pipeline)[0]

    def submit(self, Q, *, timeout_ms=_UNSET, lane: str | None = None,
               pipeline: str | None = None) -> list:
        """Enqueue the queries in ``Q`` (an nq>=1 Q relation).  Always
        returns a plain list of :class:`ServeRequest` — one per row
        (:meth:`submit_one` is the single-request API).  See
        :meth:`submit_one` for ``timeout_ms`` / ``lane`` / ``pipeline``
        semantics and the overload exceptions."""
        return self._make_requests(Q, timeout_ms, lane, pipeline)

    def submit_wait(self, Q, *, timeout: float = 60.0, timeout_ms=_UNSET,
                    lane: str | None = None, pipeline: str | None = None):
        """Synchronous convenience: submit + pump + wait.  ``timeout_ms``
        is the per-request deadline (forwarded to :meth:`submit`, so the
        synchronous path can express deadlines too); ``timeout`` bounds
        the local wait for results.  Returns one result for an nq==1
        submission, else a list of results."""
        reqs = self._make_requests(Q, timeout_ms, lane, pipeline)
        self.pump()
        outs = [r.wait(timeout) for r in reqs]
        return outs[0] if len(outs) == 1 else outs

    # -- serving loop -------------------------------------------------------
    def _decode_busy(self) -> bool:
        return bool(self._decoding) or self.scheduler.decode_pending() > 0

    def step(self, *, block: bool = False, timeout: float | None = None,
             drain: bool = False) -> int:
        """Close and execute at most one micro-batch, then advance every
        decode pool by one iteration (admit freed slots, one ragged decode
        step); returns the number of requests retired (served + shed;
        0 = no batch closed and no decode finished).  Never blocks while
        decodes are in flight — a blocked wait for retrieval arrivals must
        not stall token production."""
        if block and self._decode_busy():
            block = False
        batch = self.scheduler.next_batch(block=block, timeout=timeout,
                                          drain=drain)
        n = 0
        if batch is not None:
            self._execute_batch(batch)
            n += len(batch.requests) + len(batch.shed)
        n += self._decode_pump()
        return n

    def pump(self) -> int:
        """Drain the queue synchronously (replay/test mode): retrieval
        batches and decode iterations until nothing is queued, waiting for
        a slot, or mid-decode."""
        total = 0
        while True:
            n = self.step(drain=True)
            total += n
            if n == 0 and not self._decode_busy():
                return total

    def start(self) -> "PipelineServer":
        """Spawn the serving thread (continuous mode)."""
        if self._thread is None:
            self._stop = False
            self._thread = threading.Thread(target=self._loop, daemon=True,
                                            name="pipeline-server")
            self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is not None:
            self._stop = True
            self._thread.join()
            self._thread = None
        self.pump()                      # never strand queued requests

    def _loop(self) -> None:
        while not self._stop:
            try:
                self.step(block=True, timeout=0.02)
            except BaseException as e:             # keep the loop alive
                self.last_error = e

    # -- warm-up ------------------------------------------------------------
    def warmup(self, Q_sample) -> dict:
        """Compile every (pipeline stage, bucket) jit variant by replaying a
        sample query at each ladder rung through every attached pipeline,
        then snapshot the engine's compile counter:
        ``stats()['recompiles_since_warmup']`` must stay 0 in steady
        state.  Cache writes are skipped (the tiled duplicates would only
        pollute the LRU)."""
        row = StageResultCache.row(Q_sample, 0)
        t0 = time.monotonic()
        for tenant in self._tenants.values():
            pool = self._pools.get(tenant.name)
            # a generate tenant serves its chain split at the answer
            # boundary, so warm exactly what serving runs: the retrieval
            # prefix + prompt assembly at every rung, then the pool's
            # prefill and decode-step programs once — their shapes are
            # fixed (static prompt length, full-pool decode arrays), so
            # one compile each covers every future mix of slots
            chain = (tenant.chain if pool is None else tenant.chain[:-1])
            for bucket in self.scheduler.ladder:
                Qb = jax.tree.map(
                    lambda x: np.tile(x, (bucket,) + (1,) * (x.ndim - 1)),
                    row)
                ctx = Context(self.backend)
                Q, R, tok = Qb, None, None
                for stage in chain:
                    Q, R, tok = _execute(stage, ctx, Q, R, tok)
                if pool is not None:
                    jax.block_until_ready(tenant.generate.assemble(ctx, Q, R))
                jax.block_until_ready((Q, R))
            if pool is not None:
                P = tenant.generate.params["max_prompt_len"]
                pool.prefill_request(_DecodeRequest(
                    rid=-1, prompt=np.zeros(P, np.int32), max_new_tokens=2))
                pool.step_active()
                pool.reset()
        if self.engine is not None:
            self._warm_compiles = self.engine.total_compiles()
        out = {"warmup_s": round(time.monotonic() - t0, 3),
               "buckets": list(self.scheduler.ladder),
               "pipelines": list(self._tenants),
               "compiles": (None if self.engine is None
                            else self.engine.total_compiles())}
        # persist any autotune decisions taken at compile time, so the next
        # server process starts profile-warm (zero gate compiles / probes)
        desc = getattr(self.backend, "descriptor", None)
        if desc is not None and desc.profile is not None:
            desc.profile.save()
            out["tuning_profile"] = desc.profile.info()
        if self.compile_report:
            out["tuning"] = self.compile_report.get("tuning")
        return out

    # -- batch execution ----------------------------------------------------
    def _execute_batch(self, batch) -> None:
        now = batch.t_closed
        for req in batch.shed:          # shed pre-execution by the scheduler
            req.trace.t_scheduled = now
            req.trace.queue_wait_ms = 1000.0 * (now - req.t_enqueued)
            req.trace.batch_reason = batch.reason
            self._finish(req, None, timed_out=True)
        live = []
        for req in batch.requests:
            req.trace.t_scheduled = now
            req.trace.queue_wait_ms = 1000.0 * (now - req.t_enqueued)
            req.trace.batch_size = len(batch.requests)
            req.trace.batch_reason = batch.reason
            if req.expired(now):        # expired while queued (no EWMA yet)
                self._finish(req, None, timed_out=True)
            else:
                live.append(req)
        if not live:
            return
        self.log.record_batch(len(live))
        t_exec0 = time.monotonic()
        # deepest cached prefix per request, then group by (tenant, resume
        # depth) so each group executes its remaining suffix as one
        # micro-batch of its own pipeline
        groups: dict[tuple, list] = {}
        cached: dict[int, tuple] = {}
        max_bucket = 0
        for req in live:
            tenant = self._tenants[req.tenant]
            depth, val, writer = self.cache.lookup_deepest(
                self._prefix_digests(tenant), req.qdigest,
                reader=tenant.name)
            req.trace.cache_hit_depth = depth
            req.trace.cross_prefix_hit = (depth > 0 and writer is not None
                                          and writer != tenant.name)
            cached[req.rid] = val
            groups.setdefault((req.tenant, depth), []).append(req)
        for tname, depth in sorted(groups, key=lambda g: (g[0], -g[1])):
            grp = groups[(tname, depth)]
            try:
                bucket = self._run_group(self._tenants[tname], grp, depth,
                                         [cached[r.rid] for r in grp])
                max_bucket = max(max_bucket, bucket)
            except BaseException as e:
                self.last_error = e
                for req in grp:
                    req.error = e
                    self._finish(req, None)
        # service-time feedback: the per-bucket/per-slot EWMAs of these are
        # the scheduler's S in every shed decision and its deadline cap on
        # batch packing; the engine keeps its own per-bucket view
        dt = time.monotonic() - t_exec0
        self.scheduler.note_service_time(dt, len(live))
        if self.engine is not None and max_bucket:
            self.engine.note_service_time(max_bucket, dt)

    def _run_group(self, tenant: _Tenant, reqs, depth: int,
                   cached_vals) -> int:
        """Execute one (tenant, resume-depth) group as a padded micro-batch;
        returns the ladder bucket it padded to (0 = pure cache replay)."""
        chain, prefixes = tenant.chain, self._prefix_digests(tenant)
        L = len(chain)
        qids = [r.qid for r in reqs]
        if depth >= L:                       # full-pipeline cache hits
            for req, (Qc, Rc) in zip(reqs, cached_vals):
                Qr, Rr = StageResultCache.restamp_qids(Qc, Rc, [req.qid])
                # row(…, 0) copies: the served result must never alias the
                # live cache entry (same invariant as the miss path)
                self._finish(req, StageResultCache.row(
                    Rr if Rr is not None else Qr, 0))
            return 0
        if depth == 0:
            Q = StageResultCache.stack_rows([r.Q for r in reqs])
            R = None
        else:                                # resume mid-chain
            Q = StageResultCache.stack_rows([v[0] for v in cached_vals])
            R_rows = [v[1] for v in cached_vals]
            R = (None if R_rows[0] is None
                 else StageResultCache.stack_rows(R_rows))
            Q, R = StageResultCache.restamp_qids(Q, R, qids)
        n = len(reqs)
        bucket = (self.engine.select_bucket(n) if self.engine is not None
                  else self.scheduler.select_bucket(n))
        for req in reqs:
            req.trace.bucket = bucket
        # pad up to the bucket BEFORE execution: every stage then sees
        # exactly the ladder shapes warm-up compiled (no per-size variants
        # anywhere, eager pre-steps included); padded rows are dropped when
        # results are sliced per request below
        Q = StageResultCache.pad_rows(Q, bucket - n)
        R = StageResultCache.pad_rows(R, bucket - n)
        ctx = Context(self.backend)
        tok = ctx.source_token(Q, R)
        stage_times = []
        # a generate tenant runs only its retrieval prefix here; the final
        # stage is decode, which the request rides iteration-level in the
        # tenant's pool (handoff below) instead of run-to-completion
        L_here = L - 1 if tenant.generate is not None else L
        for i in range(depth, L_here):
            stage = chain[i]
            t0 = time.monotonic() if self.trace_stages else 0.0
            Q, R, tok = _execute(stage, ctx, Q, R, tok)
            if self.trace_stages:
                jax.block_until_ready((Q, R))
                ms = 1000.0 * (time.monotonic() - t0)
                label = stage.label()
                stage_times.append((label, round(ms, 3)))
                self.log.record_stage(label, ms)
            if self.cache_stages and self.cache.enabled and i < L - 1:
                # one device->host conversion per stage, rows sliced from
                # the host copy (per-row device slicing would compile a
                # tiny XLA program per (arity, index) — a latency storm)
                Qh = StageResultCache.to_host(Q)
                Rh = None if R is None else StageResultCache.to_host(R)
                for j, req in enumerate(reqs):
                    self.cache.store(prefixes[i], req.qdigest,
                                     StageResultCache.row(Qh, j),
                                     None if Rh is None
                                     else StageResultCache.row(Rh, j),
                                     writer=tenant.name)
        if tenant.generate is not None:
            # answer boundary: assemble each live row's prompt (batched at
            # the same bucket shape warm-up compiled) and queue it for a
            # decode slot — these requests retire from _decode_pump, and
            # the batch they just rode mixed with pure-retrieval tenants
            gen = tenant.generate
            prompts = gen.assemble(ctx, Q, R)
            jax.block_until_ready(prompts)
            prompts = np.asarray(prompts)
            Qh = StageResultCache.to_host(Q)
            Rh = StageResultCache.to_host(R)
            for j, req in enumerate(reqs):
                req.trace.stage_ms = tuple(stage_times)
                req._prompt = prompts[j]
                req._Q_row = StageResultCache.row(Qh, j)
                req._R_row = StageResultCache.row(Rh, j)
                self.scheduler.decode_submit(req)
            return bucket
        jax.block_until_ready((Q, R))
        Qh = StageResultCache.to_host(Q)
        Rh = None if R is None else StageResultCache.to_host(R)
        result = Rh if Rh is not None else Qh
        for j, req in enumerate(reqs):
            req.trace.stage_ms = tuple(stage_times)
            if self.cache.enabled:
                self.cache.store(
                    prefixes[L - 1], req.qdigest,
                    StageResultCache.row(Qh, j),
                    None if Rh is None else StageResultCache.row(Rh, j),
                    writer=tenant.name)
            self._finish(req, StageResultCache.row(result, j))
        return bucket

    def _decode_pump(self) -> int:
        """One iteration of every decode pool: admit queued prompts into
        freed KV-cache slots (EDF order — this between-steps admission is
        what makes decode scheduling iteration-level), one ragged decode
        step per active pool, then retire finished answers.  Returns the
        number of requests retired."""
        retired = 0
        free = sum(p.free_slots() for p in self._pools.values())
        if free and self.scheduler.decode_pending():
            now = time.monotonic()
            for req in self.scheduler.decode_take(free):
                if req.expired(now):
                    self._finish(req, None, timed_out=True)
                    retired += 1
                    continue
                pool = self._pools[req.tenant]
                if pool.free_slots() == 0:
                    # the freed slot was another tenant's pool: wait on
                    self.scheduler.decode_submit(req)
                    continue
                tenant = self._tenants[req.tenant]
                pool.prefill_request(_DecodeRequest(
                    rid=req.rid, prompt=req._prompt,
                    max_new_tokens=tenant.generate.params["max_new_tokens"]))
                # the prefill produced the first answer token
                req.trace.ttft_ms = 1000.0 * (time.monotonic()
                                              - req.trace.t_arrival)
                self._decoding[req.rid] = req
        for pool in self._pools.values():
            if pool.active_slots() == 0:
                continue
            for dreq in pool.step_active():
                req = self._decoding.pop(dreq.rid)
                tenant = self._tenants[req.tenant]
                tokens = np.asarray(dreq.generated, np.int32)[None, :]
                row = dict(req._R_row)
                row["tokens"] = tokens
                req.trace.n_tokens = int(tokens.shape[1])
                if self.cache.enabled:
                    self.cache.store(
                        self._prefix_digests(tenant)[-1], req.qdigest,
                        req._Q_row, row, writer=tenant.name)
                # row(…, 0) copies: the served result must never alias the
                # live cache entry (same invariant as the retrieval path)
                self._finish(req, StageResultCache.row(row, 0))
                retired += 1
        return retired

    def _finish(self, req, result, *, timed_out: bool = False) -> None:
        t = time.monotonic()
        tr = req.trace
        tr.t_done = t
        tr.timed_out = timed_out
        tr.errored = req.error is not None
        tr.latency_ms = 1000.0 * (t - tr.t_arrival)
        tr.service_ms = 1000.0 * (t - tr.t_scheduled) if tr.t_scheduled else 0.0
        tr.late = (not timed_out and not tr.errored
                   and req.deadline is not None and t > req.deadline)
        req.result = result
        if timed_out and self.recorder is not None and not tr.shed:
            # shed drops are recorded by the scheduler at decision time
            # (with the S(n) inputs); this covers expiry in queue/decode
            self.recorder.record("deadline_drop", rid=tr.rid,
                                 tenant=tr.tenant, lane=tr.lane,
                                 queue_wait_ms=round(tr.queue_wait_ms, 3))
        if self.tracer.enabled:
            self._emit_request_spans(tr)
        self.log.record(tr)
        req.done.set()

    def _emit_request_spans(self, tr) -> None:
        """Retrospective per-request lifecycle spans, emitted at finish
        from the ``RequestTrace`` timestamps.  Spans link by explicit
        parent id (nesting is data, not wall-clock containment), so a
        request admitted on the caller thread and executed on the serving
        thread still exports as one nested tree; each request gets its
        own synthetic Perfetto track (``tid = rid``)."""
        tracer, rel, tid = self.tracer, self.tracer.rel, tr.rid
        outcome = ("errors" if tr.errored else "shed" if tr.shed
                   else "timed_out" if tr.timed_out
                   else "late" if tr.late else "served")
        root = tracer.add_span(
            "serve.request", rel(tr.t_arrival), rel(tr.t_done), cat="serve",
            tid=tid, rid=tr.rid, tenant=tr.tenant, lane=tr.lane,
            outcome=outcome, latency_ms=round(tr.latency_ms, 3))
        if not tr.t_scheduled:
            return
        tracer.add_span("serve.queue", rel(tr.t_arrival),
                        rel(tr.t_scheduled), cat="serve", parent=root,
                        tid=tid, queue_wait_ms=round(tr.queue_wait_ms, 3))
        # decode start = first generated token; before it, the request
        # was riding its retrieval micro-batch
        t_dec0 = (tr.t_arrival + tr.ttft_ms / 1000.0 if tr.ttft_ms else None)
        batch = tracer.add_span(
            "serve.batch", rel(tr.t_scheduled),
            rel(t_dec0 if t_dec0 is not None else tr.t_done), cat="serve",
            parent=root, tid=tid, reason=tr.batch_reason,
            batch_size=tr.batch_size, bucket=tr.bucket,
            cache_hit_depth=tr.cache_hit_depth,
            cross_prefix_hit=tr.cross_prefix_hit)
        t = tr.t_scheduled            # stage stamps are durations only:
        for label, ms in tr.stage_ms:  # lay them end-to-end from close
            tracer.add_span(f"serve.stage:{label}", rel(t),
                            rel(t + ms / 1000.0), cat="serve",
                            parent=batch, tid=tid, ms=ms)
            t += ms / 1000.0
        if t_dec0 is not None:
            tracer.add_span("serve.decode", rel(t_dec0), rel(tr.t_done),
                            cat="serve", parent=root, tid=tid,
                            n_tokens=tr.n_tokens,
                            ttft_ms=round(tr.ttft_ms, 3))

    # -- observability ------------------------------------------------------
    def trace_export(self, path: str | None = None) -> dict:
        """Chrome trace-event JSON of every retained span (request
        lifecycles, scheduler batch closes, engine dispatches and
        cause-tagged jit compiles).  Load the written file in Perfetto
        (https://ui.perfetto.dev) to see per-request tracks with nested
        queue/batch/stage/decode children.  Requires
        ``ServeConfig.with_observability()``; disabled tracing exports an
        empty event list."""
        out = self.tracer.export_chrome()
        if path is not None:
            with open(path, "w") as f:
                json.dump(out, f)
        return out

    def flight_record(self, last: int | None = None) -> list:
        """The flight recorder's ring — the last N scheduler/engine
        decisions (admissions, sheds with their service-model inputs,
        deadline drops, recompiles), oldest first.  Empty when the
        recorder is disabled."""
        return [] if self.recorder is None else self.recorder.dump(last)

    def metrics_snapshot(self) -> dict:
        """Structured dump of the metrics behind :meth:`stats`
        (name -> {kind, series}): the server's own registry merged with
        the shared engine's (the engine serves every server on its
        backend, so it keeps a registry of its own)."""
        out = (self.engine.metrics.snapshot()
               if self.engine is not None else {})
        out.update(self.metrics.snapshot())
        return out

    def metrics_text(self) -> str:
        """Prometheus text exposition of :meth:`metrics_snapshot`."""
        parts = [self.metrics.render_text()]
        if self.engine is not None:
            parts.append(self.engine.metrics.render_text())
        return "".join(parts)

    # -- reporting ----------------------------------------------------------
    def stats(self) -> dict:
        default = self._tenant(None)
        # NOTE: log.summary() supplies "pipelines" — the per-tenant counter
        # dict, keyed by every attached pipeline name
        out = {
            "pipeline": default.op.label(),
            "chain_len": len(default.chain),
            "config": self.config.as_dict(),
            "scheduler": self.scheduler.stats(),
            **self.log.summary(),
            "stage_cache": self.cache.info(),
        }
        out["cross_pipeline_hits"] = self.cache.cross_pipeline_hits
        if self._pools:
            out["decode_pools"] = {
                name: {"slots": p.slots,
                       "active": p.active_slots(),
                       "queued": self.scheduler.decode_pending(),
                       "decode_steps": p.n_decode_steps,
                       "max_len": p.max_len}
                for name, p in self._pools.items()}
        if self.engine is not None:
            out["engine"] = self.engine.stats()
            total = self.engine.total_compiles()
            out["recompiles_since_warmup"] = (
                None if self._warm_compiles is None
                else total - self._warm_compiles)
        else:
            out["engine"] = None
            out["recompiles_since_warmup"] = None
        out["tuning"] = default.compile_report.get("tuning")
        desc = getattr(self.backend, "descriptor", None)
        out["tuning_profile"] = (desc.profile.info()
                                 if desc is not None and desc.profile
                                 else None)
        return out


class MultiPipelineServer(PipelineServer):
    """Several named pipelines multiplexed over one engine, one scheduler,
    and one shared stage cache from construction:

    >>> server = MultiPipelineServer(
    ...     {"interactive": bm25 >> rerank % 10, "batch": bm25 % 100},
    ...     backend, ServeConfig.default().with_lanes(
    ...         ("interactive", 4.0), ("background", 1.0)))
    >>> server.warmup(Q)
    >>> server.submit_one(row, pipeline="batch", lane="background")

    The first entry is the default tenant (``submit`` with no ``pipeline=``
    routes there).  Equivalent to ``PipelineServer`` + ``add_pipeline``
    per extra entry.
    """

    def __init__(self, pipelines: dict, backend,
                 config: ServeConfig | None = None, *,
                 cache: StageResultCache | None = None):
        if not pipelines:
            raise ValueError("MultiPipelineServer needs at least one "
                             "pipeline")
        items = list(pipelines.items())
        first_name, first = items[0]
        super().__init__(first, backend, config, cache=cache,
                         name=first_name)
        for tname, pipe in items[1:]:
            self.add_pipeline(pipe, name=tname)
