"""ServeConfig: the serving layer's frozen front-door configuration.

``PipelineServer`` used to take ten loose constructor kwargs (``max_queue``,
``max_wait_ms``, ``max_batch``, ``cache_entries``, ...); this module
consolidates them into one frozen dataclass — the serving counterpart of
the compiler's :class:`~repro.core.descriptor.BackendDescriptor` — so a
deployment's serving policy is a single inspectable value that can be
shared across servers, logged, and diffed:

* **batching**     — micro-batch closure (``max_batch``, ``max_wait_ms``,
                     arrival-rate-adaptive wait),
* **admission**    — queue bound + deadline policy (default timeout,
                     EDF shed-before-execute, service-time EWMA smoothing),
* **lanes**        — weighted-fair-queueing priority lanes,
* **caching**      — the stage-result cache bound and per-stage writes,
* **decode**       — the generate stage's decode-slot pool size,
* **tracing**      — per-stage timing and the trace-ring capacity.

Construction mirrors the descriptor idiom: ``ServeConfig.default()`` plus
chained ``with_*()`` builders returning new frozen values.  The config is
the only constructor surface — the pre-config loose-kwarg shim was removed
after its deprecation cycle, so unknown kwargs fail as a plain
``TypeError`` from the signature itself.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Frozen serving policy for a :class:`~repro.serve.server.PipelineServer`.

    ``lanes`` is a tuple of ``(name, weight)`` pairs — the scheduler serves
    lanes in weighted-fair order so a low-weight background tenant cannot
    starve interactive traffic; ``default_lane`` is where ``submit`` routes
    when the caller names none.  ``shed`` enables shed-before-execute: a
    request whose deadline cannot survive the estimated queue wait plus one
    batch service time (an EWMA with ``service_ewma_alpha``) is rejected at
    submit / dropped at batch close *before* it occupies a ladder slot.
    ``adaptive_wait`` shrinks the batch-close wait below ``max_wait_ms``
    when the observed arrival rate says the batch cannot fill in time.
    """

    # -- compilation --------------------------------------------------------
    optimize: bool = True
    # -- admission / queue --------------------------------------------------
    max_queue: int = 1024
    default_timeout_ms: float | None = None
    # -- batching -----------------------------------------------------------
    max_wait_ms: float = 5.0
    max_batch: int | None = None
    adaptive_wait: bool = False
    # -- deadline policy ----------------------------------------------------
    shed: bool = True
    service_ewma_alpha: float = 0.2
    # -- priority lanes (WFQ) -----------------------------------------------
    lanes: tuple = (("default", 1.0),)
    default_lane: str = "default"
    # -- stage-result cache -------------------------------------------------
    cache_entries: int | None = 4096
    cache_stages: bool = True
    # -- decode (generate-stage serving) --------------------------------------
    #: KV-cache slots per generate tenant's decode pool: the iteration-level
    #: scheduler admits up to this many concurrent decodes; each slot is one
    #: row of the block-allocated cache
    decode_slots: int = 8
    # -- tracing ------------------------------------------------------------
    trace_stages: bool = False
    trace_capacity: int = 2048
    # -- observability (span tracing + flight recorder; metrics are
    # -- always-on registry counters and have no switch) ---------------------
    #: span tracing of the serve lifecycle (admit -> queue -> batch ->
    #: stages -> decode -> reply), exportable as Chrome trace-event JSON
    obs_tracing: bool = False
    #: flight recorder: bounded ring of scheduler/engine decision events
    obs_recorder: bool = False
    obs_trace_events: int = 65536
    obs_recorder_events: int = 1024

    def __post_init__(self):
        if not self.lanes:
            raise ValueError("ServeConfig.lanes must name at least one lane")
        names = [n for n, _ in self.lanes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate lane names in {names}")
        if any(w <= 0 for _, w in self.lanes):
            raise ValueError("lane weights must be positive")
        if self.default_lane not in names:
            raise ValueError(f"default_lane {self.default_lane!r} not in "
                             f"lanes {names}")
        if not 0.0 < self.service_ewma_alpha <= 1.0:
            raise ValueError("service_ewma_alpha must be in (0, 1]")
        if self.decode_slots < 1:
            raise ValueError("decode_slots must be >= 1")

    # -- construction -------------------------------------------------------
    @classmethod
    def default(cls, **overrides) -> "ServeConfig":
        return cls(**overrides)

    def replace(self, **changes) -> "ServeConfig":
        return dataclasses.replace(self, **changes)

    def with_batching(self, *, max_batch: int | None = ...,
                      max_wait_ms: float | None = None,
                      adaptive_wait: bool | None = None) -> "ServeConfig":
        kw: dict = {}
        if max_batch is not ...:
            kw["max_batch"] = max_batch
        if max_wait_ms is not None:
            kw["max_wait_ms"] = float(max_wait_ms)
        if adaptive_wait is not None:
            kw["adaptive_wait"] = bool(adaptive_wait)
        return self.replace(**kw)

    def with_queue(self, max_queue: int) -> "ServeConfig":
        return self.replace(max_queue=int(max_queue))

    def with_deadlines(self, default_timeout_ms: float | None = ...,
                       *, shed: bool | None = None,
                       service_ewma_alpha: float | None = None
                       ) -> "ServeConfig":
        kw: dict = {}
        if default_timeout_ms is not ...:
            kw["default_timeout_ms"] = default_timeout_ms
        if shed is not None:
            kw["shed"] = bool(shed)
        if service_ewma_alpha is not None:
            kw["service_ewma_alpha"] = float(service_ewma_alpha)
        return self.replace(**kw)

    def with_lanes(self, *lanes, default: str | None = None) -> "ServeConfig":
        """Lanes as ``(name, weight)`` pairs; the default lane is ``default``
        (or the first lane)."""
        spec = tuple((str(n), float(w)) for n, w in lanes)
        return self.replace(lanes=spec,
                            default_lane=default if default is not None
                            else spec[0][0])

    def with_cache(self, entries: int | None = ...,
                   *, cache_stages: bool | None = None) -> "ServeConfig":
        kw: dict = {}
        if entries is not ...:
            kw["cache_entries"] = entries
        if cache_stages is not None:
            kw["cache_stages"] = bool(cache_stages)
        return self.replace(**kw)

    def with_decode(self, slots: int) -> "ServeConfig":
        """Decode-pool size for generate-stage tenants (KV-cache slots the
        iteration-level scheduler fills between decode steps)."""
        return self.replace(decode_slots=int(slots))

    def with_tracing(self, stages: bool | None = None,
                     *, capacity: int | None = None) -> "ServeConfig":
        kw: dict = {}
        if stages is not None:
            kw["trace_stages"] = bool(stages)
        if capacity is not None:
            kw["trace_capacity"] = int(capacity)
        return self.replace(**kw)

    def with_observability(self, enabled: bool = True, *,
                           tracing: bool | None = None,
                           recorder: bool | None = None,
                           trace_events: int | None = None,
                           recorder_events: int | None = None
                           ) -> "ServeConfig":
        """Opt in to span tracing and/or the flight recorder.

        ``with_observability()`` turns both on; ``tracing=``/``recorder=``
        override the master switch per layer (e.g. recorder-only for an
        overload post-mortem without per-request span cost).  Metrics are
        not gated here — the registry is always on (an increment is a dict
        lookup); these switches govern the layers that allocate per-event
        records.
        """
        kw: dict = {
            "obs_tracing": bool(enabled if tracing is None else tracing),
            "obs_recorder": bool(enabled if recorder is None else recorder),
        }
        if trace_events is not None:
            kw["obs_trace_events"] = int(trace_events)
        if recorder_events is not None:
            kw["obs_recorder_events"] = int(recorder_events)
        return self.replace(**kw)

    # -- queries ------------------------------------------------------------
    def lane_weights(self) -> dict[str, float]:
        return {n: float(w) for n, w in self.lanes}

    def as_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out["lanes"] = [list(p) for p in self.lanes]
        return out
