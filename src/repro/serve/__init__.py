"""Online serving subsystem: compiled pipelines as a long-lived service.

    from repro.serve import PipelineServer, ServeConfig
    cfg = ServeConfig.default(max_wait_ms=4.0).with_deadlines(250.0)
    server = PipelineServer(Retrieve("BM25") % 10, backend, cfg)
    server.warmup(Q_sample)
    result = server.submit_wait(q_row)
    print(server.stats())

``repro.serve.batching`` (the LM decode continuous batcher) is a separate,
heavier module and is intentionally not imported here.
"""
from repro.serve.cache import StageResultCache, query_digest  # noqa: F401
from repro.serve.config import ServeConfig  # noqa: F401
from repro.serve.request import (DeadlineUnmeetable,  # noqa: F401
                                 RequestTimeout, RequestTrace, ServeRequest,
                                 ServerOverloaded)
from repro.serve.scheduler import Batch, MicroBatchScheduler  # noqa: F401
from repro.serve.server import (MultiPipelineServer,  # noqa: F401
                                PipelineServer)
from repro.serve.trace import TraceLog, latency_summary  # noqa: F401
