"""Online serving subsystem: a compiled pipeline as a long-lived service.

    from repro.serve import PipelineServer
    server = PipelineServer(Retrieve("BM25") % 10, backend)
    server.warmup(Q_sample)
    result = server.submit_wait(q_row)
    print(server.stats())

``repro.serve.batching`` (the LM decode continuous batcher) is a separate,
heavier module and is intentionally not imported here.
"""
from repro.serve.cache import StageResultCache, query_digest  # noqa: F401
from repro.serve.request import (RequestTimeout, RequestTrace,  # noqa: F401
                                 ServeRequest, ServerOverloaded)
from repro.serve.scheduler import Batch, MicroBatchScheduler  # noqa: F401
from repro.serve.server import PipelineServer  # noqa: F401
from repro.serve.trace import TraceLog, latency_summary  # noqa: F401
