"""Bench-trajectory regression gate: fail CI when fused-path throughput
regresses against the previous push's bench artifact.

    python scripts/compare_bench.py PREV CUR [--max-regression-pct 25]

``PREV`` is the previous summary — either the JSON file itself or a
directory the previous ``bench-*`` artifact was unzipped into (the newest
``summary.json`` / ``BENCH_*.json`` found under it is used).  A missing /
unreadable PREV is tolerated (first run on a branch, expired artifact):
the gate prints a note and passes.  ``CUR`` must exist — the current run
just produced it.

Compared metrics are the fused-path QPS figures the fusion work optimises
for (``fusion`` + ``dense`` workloads and the IVF probe path) plus the
serving trajectory (light-load p95 latency, mid-load and saturation
goodput, saturation throughput per serve workload, and the RAG decode
figures — continuous-batched tokens/s higher-is-better, TTFT and
per-token p95 lower-is-better — all from the serve section's ``gated``
block, which carries each metric's explicit ``better`` direction).  A metric
present in both summaries that regressed by more than the threshold fails
the job — "regressed" is direction-aware (QPS falling, latency rising).
Metrics only present on one side (new workload, renamed section) are
reported but never fail; a whole section missing from PREV (the previous
artifact predates it) is warned about and skipped, never a crash.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def fused_qps_metrics(summary: dict) -> dict[str, tuple[float, str]]:
    """name -> (QPS, "higher") for every fused execution path in a bench
    summary (the gated trajectory; the IVF probe is reported but not gated
    — it is a recall/MRT trade, not a fused kernel path)."""
    out: dict[str, tuple[float, str]] = {}
    for section in ("fusion", "dense"):
        for name, w in (summary.get(section) or {}).get("workloads",
                                                        {}).items():
            qps = w.get("fused_qps")
            if qps is not None:     # 0.0 is a (catastrophic) data point
                out[f"{section}.{name}.fused_qps"] = (float(qps), "higher")
    return out


def dense_pq_metrics(summary: dict) -> dict[str, tuple[float, str]]:
    """name -> (value, direction) for the IVF-PQ path: fused ADC QPS is
    the gated throughput figure; the memory-reduction factor is gated too
    (a shrinking factor means the compressed store silently grew)."""
    out: dict[str, tuple[float, str]] = {}
    pq = (summary.get("dense") or {}).get("dense_pq") or {}
    for key in ("fused_qps", "unfused_qps"):
        v = pq.get(key)
        if v is not None:
            out[f"dense.dense_pq.{key}"] = (float(v), "higher")
    red = pq.get("memory_reduction_x")
    if red is not None:
        out["dense.dense_pq.memory_reduction_x"] = (float(red), "higher")
    return out


def serve_metrics(summary: dict) -> dict[str, tuple[float, str]]:
    """name -> (value, direction) for the serving trajectory: the serve
    bench pre-selects its gated metrics (light-load batched p95, saturation
    batched throughput) into ``serve.gated`` with an explicit ``better``
    direction."""
    out: dict[str, tuple[float, str]] = {}
    for name, ent in ((summary.get("serve") or {}).get("gated") or {}).items():
        try:
            out[f"serve.{name}"] = (float(ent["value"]),
                                    str(ent.get("better", "higher")))
        except (TypeError, KeyError, ValueError):
            print(f"  serve.{name}: malformed gated entry {ent!r} "
                  "(skipped)")
    return out


def obs_metrics(summary: dict) -> dict[str, tuple[float, str]]:
    """name -> (value, direction) for the observability overhead gate:
    the obs bench pre-selects the enabled/disabled throughput ratio into
    ``obs.gated`` (higher is better — a falling ratio means tracing got
    more expensive relative to the plain serve path)."""
    out: dict[str, tuple[float, str]] = {}
    for name, ent in ((summary.get("obs") or {}).get("gated") or {}).items():
        try:
            out[f"obs.{name}"] = (float(ent["value"]),
                                  str(ent.get("better", "higher")))
        except (TypeError, KeyError, ValueError):
            print(f"  obs.{name}: malformed gated entry {ent!r} (skipped)")
    return out


def collect_metrics(summary: dict, label: str) -> dict[str, tuple[float, str]]:
    """All gated metrics of one summary.  Extraction must never take the
    gate down: a summary written by an older revision (an artifact that
    predates a section or a schema change) is degraded to 'fewer metrics',
    with a warning, instead of crashing the job."""
    out: dict[str, tuple[float, str]] = {}
    for extract in (fused_qps_metrics, dense_pq_metrics, serve_metrics,
                    obs_metrics):
        try:
            out.update(extract(summary))
        except Exception as e:      # old-schema artifact: warn and skip
            print(f"  warning: {extract.__name__} failed on {label} "
                  f"summary ({e!r}); its metrics are skipped")
    return out


def missing_sections(prev: dict, cur: dict) -> list[str]:
    return [s for s in ("fusion", "dense", "serve", "autotune", "obs")
            if cur.get(s) and not prev.get(s)]


def calibration_errors(summary: dict) -> list[float]:
    """Per-workload cost-gate calibration errors, as a >=1 'times-off'
    factor (``max(r, 1/r)`` of ``measured_over_predicted``), across every
    section that emits calibration blocks."""
    out: list[float] = []
    for section in ("fusion", "dense", "autotune"):
        wls = (summary.get(section) or {}).get("workloads") or {}
        for w in wls.values():
            cal = w.get("calibration") if isinstance(w, dict) else None
            r = (cal or {}).get("measured_over_predicted")
            if isinstance(r, (int, float)) and r > 0:
                out.append(max(float(r), 1.0 / float(r)))
    return out


def _median(xs: list[float]) -> float:
    xs = sorted(xs)
    return xs[len(xs) // 2]


def resolve_summary(path: Path) -> Path | None:
    """PREV as given, or the newest summary-like JSON under a directory."""
    if path.is_file():
        return path
    if path.is_dir():
        hits = sorted(list(path.rglob("summary.json")) +
                      list(path.rglob("BENCH_*.json")),
                      key=lambda p: p.stat().st_mtime)
        if hits:
            return hits[-1]
    return None


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("prev", help="previous summary.json (file or artifact dir)")
    ap.add_argument("cur", help="current summary.json")
    ap.add_argument("--max-regression-pct", type=float, default=25.0)
    args = ap.parse_args()

    try:
        cur = json.loads(Path(args.cur).read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: cannot read current bench summary {args.cur}: {e}",
              file=sys.stderr)
        return 1

    prev_path = resolve_summary(Path(args.prev))
    if prev_path is None:
        print(f"no previous bench artifact under {args.prev!r}: "
              "first run on this ref, skipping regression check")
        return 0
    try:
        prev = json.loads(prev_path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"previous bench summary {prev_path} unreadable ({e}): "
              "skipping regression check")
        return 0

    cur_m = collect_metrics(cur, "current")
    prev_m = collect_metrics(prev, "previous")
    if not any(n.endswith(".fused_qps") for n in cur_m):
        print("FAIL: current summary has no fused-path QPS metrics "
              "(did the fusion/dense sections go missing?)", file=sys.stderr)
        return 1
    if cur.get("serve") and not any(n.endswith(".sat.goodput_qps")
                                    for n in cur_m):
        print("FAIL: current summary's serve section gates no saturation "
              "goodput metric (did the deadline-aware levels go missing?)",
              file=sys.stderr)
        return 1
    if (cur.get("serve") or {}).get("rag") and \
            "serve.rag.sat.decode_tokens_per_s" not in cur_m:
        print("FAIL: current summary's serve section has a rag workload "
              "but gates no decode throughput metric (did bench_rag's "
              "gated entries go missing?)", file=sys.stderr)
        return 1
    for section in missing_sections(prev, cur):
        print(f"  note: previous artifact predates the {section!r} section; "
              "its metrics are reported but not gated this run")

    frac = args.max_regression_pct / 100.0
    failures = []
    for name in sorted(set(cur_m) | set(prev_m)):
        pe, ce = prev_m.get(name), cur_m.get(name)
        if pe is None or ce is None:
            print(f"  {name}: only in "
                  f"{'current' if pe is None else 'previous'}"
                  " summary (not compared)")
            continue
        (p, better), (c, _) = pe, ce
        if p == 0.0:
            print(f"  {name}: prev=0.0 cur={c:.1f} (previous run recorded "
                  "zero; not gated)")
            continue
        delta = 100.0 * (c - p) / p
        regressed = (c < p * (1.0 - frac) if better == "higher"
                     else c > p * (1.0 + frac))
        status = "ok"
        if regressed:
            status = "REGRESSION"
            failures.append((name, p, c, delta))
        print(f"  {name}: prev={p:.1f} cur={c:.1f} ({delta:+.1f}%, "
              f"{better} is better) {status}")
    # calibration drift: warn-only — a cost-gate whose predictions drift
    # away from measurement wants re-fitting (hlo_cost.fit_peaks), but a
    # noisy CI host must never fail the build over it
    cur_err, prev_err = calibration_errors(cur), calibration_errors(prev)
    if cur_err and prev_err:
        cm, pm = _median(cur_err), _median(prev_err)
        print(f"  calibration: median gate error prev={pm:.2f}x "
              f"cur={cm:.2f}x ({len(prev_err)} -> {len(cur_err)} records)")
        if cm > 2.0 * pm:
            print(f"WARNING: median cost-gate calibration error drifted "
                  f">2x vs {prev_path} ({pm:.2f}x -> {cm:.2f}x); re-fit the "
                  "roofline peaks from the bench artifacts "
                  "(analysis.hlo_cost.fit_peaks / "
                  "BackendDescriptor.calibrated)")
    elif cur_err:
        print(f"  calibration: {len(cur_err)} records in current summary; "
              "previous artifact has none (drift not compared)")
    ivf_p = ((prev.get("dense") or {}).get("ivf") or {}).get("ivf_qps")
    ivf_c = ((cur.get("dense") or {}).get("ivf") or {}).get("ivf_qps")
    if ivf_p and ivf_c:
        print(f"  dense.ivf.ivf_qps: prev={ivf_p:.1f} cur={ivf_c:.1f} "
              f"({100.0 * (ivf_c - ivf_p) / ivf_p:+.1f}%) informational")
    if failures:
        print(f"FAIL: gated bench metrics regressed more than "
              f"{args.max_regression_pct:.0f}% vs {prev_path}:",
              file=sys.stderr)
        for name, p, c, delta in failures:
            print(f"  {name}: {p:.1f} -> {c:.1f} ({delta:+.1f}%)",
                  file=sys.stderr)
        return 1
    print(f"bench trajectory OK vs {prev_path} "
          f"({len(cur_m)} gated metrics within "
          f"{args.max_regression_pct:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
