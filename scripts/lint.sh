#!/usr/bin/env sh
# Lint gate (ruff, pinned in requirements-dev.txt): `ruff check` plus
# `ruff format --check`. Degrades to a warning where ruff is not installed
# (e.g. the baked runtime image) so the tier-1 entrypoint still runs
# everywhere; GitHub CI always installs it.
set -eu
cd "$(dirname "$0")/.."
fmt_hint() {
    echo "format gate failed: run 'ruff format .' (or 'python -m ruff format .') and commit the result" >&2
    exit 1
}
if command -v ruff >/dev/null 2>&1; then
    ruff check .
    ruff format --check . || fmt_hint
elif python -m ruff --version >/dev/null 2>&1; then
    python -m ruff check .
    python -m ruff format --check . || fmt_hint
else
    echo "lint skipped: ruff not installed (python -m pip install -r requirements-dev.txt)" >&2
fi
