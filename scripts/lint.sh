#!/usr/bin/env sh
# Lint gate (ruff, pinned in requirements-dev.txt). Degrades to a warning
# where ruff is not installed (e.g. the baked runtime image) so the tier-1
# entrypoint still runs everywhere; GitHub CI always installs it.
set -eu
cd "$(dirname "$0")/.."
if command -v ruff >/dev/null 2>&1; then
    ruff check .
elif python -m ruff --version >/dev/null 2>&1; then
    python -m ruff check .
else
    echo "lint skipped: ruff not installed (python -m pip install -r requirements-dev.txt)" >&2
fi
