#!/usr/bin/env sh
# Lint gate (ruff, pinned in requirements-dev.txt). `ruff check` always
# blocks. `ruff format --check` is a ratchet: advisory (one-line warning)
# until the tree has actually been formatted and the .ruff-formatted marker
# committed, blocking (one-line remediation hint) from then on. The ratchet
# exists because the baked runtime image has neither ruff nor network
# access, so the one-shot `ruff format .` cannot be run from inside it —
# PR 3's unconditional gate was red on every CI run for that reason (see
# CHANGES.md). Degrades to a warning where ruff is missing entirely so the
# tier-1 entrypoint still runs everywhere; GitHub CI always installs it.
set -eu
cd "$(dirname "$0")/.."
fmt_hint() {
    echo "format gate failed: run 'ruff format .' (or 'python -m ruff format .') and commit the result" >&2
    exit 1
}
fmt_warn() {
    echo "warning: tree is not ruff-format clean; run 'ruff format .', commit the result, then 'touch .ruff-formatted' + commit to make this gate blocking" >&2
}
run_ruff() {
    "$@" check .
    if [ -f .ruff-formatted ]; then
        "$@" format --check . || fmt_hint
    else
        "$@" format --check . >/dev/null 2>&1 || fmt_warn
    fi
}
if command -v ruff >/dev/null 2>&1; then
    run_ruff ruff
elif python -m ruff --version >/dev/null 2>&1; then
    run_ruff python -m ruff
else
    echo "lint skipped: ruff not installed (python -m pip install -r requirements-dev.txt)" >&2
fi
