#!/usr/bin/env sh
# Tier-1 verification — the exact command the roadmap pins. Run from the
# repo root. Catches environment drift (e.g. a missing test dependency
# breaking collection) mechanically instead of at review time.
set -eu
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
