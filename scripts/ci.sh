#!/usr/bin/env sh
# Tier-1 verification — lint, then the exact pytest command the roadmap
# pins. Run from the repo root. Local `make test` and GitHub CI both enter
# here, so environment drift (missing test dependency, lint regression)
# surfaces mechanically instead of at review time.
set -eu
cd "$(dirname "$0")/.."
sh scripts/lint.sh
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
