"""Fail loudly when the bench run silently dropped a section.

The bench-smoke CI job uploads ``summary.json`` as the per-push trajectory
artifact; a section that vanishes (e.g. the engine-scaling subprocess died,
or the fusion bench was skipped) used to pass silently and poison the
trajectory.  This gate requires the sections the trajectory tracks to be
present AND non-empty.

    python scripts/check_bench.py [experiments/bench/summary.json]
"""
from __future__ import annotations

import json
import sys

REQUIRED = ("engine_scaling", "fusion", "rq1", "rq2", "dense")


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "experiments/bench/summary.json"
    try:
        summary = json.load(open(path))
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: cannot read bench summary {path}: {e}", file=sys.stderr)
        return 1
    missing = [k for k in REQUIRED if not summary.get(k)]
    if missing:
        print(f"FAIL: bench summary {path} is missing sections: {missing} "
              f"(present: {sorted(summary)})", file=sys.stderr)
        return 1
    fus = summary["fusion"].get("workloads", {})
    if not fus:
        print("FAIL: fusion section has no workloads", file=sys.stderr)
        return 1
    dense = summary["dense"]
    if not dense.get("workloads"):
        print("FAIL: dense section has no workloads", file=sys.stderr)
        return 1
    if not dense.get("ivf"):
        print("FAIL: dense section has no ivf report", file=sys.stderr)
        return 1
    print(f"bench summary OK: sections {list(REQUIRED)} all present; "
          f"fusion workloads: {sorted(fus)}; "
          f"dense workloads: {sorted(dense['workloads'])}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
