"""Fail loudly when the bench run silently dropped a section.

The bench-smoke CI job uploads ``summary.json`` as the per-push trajectory
artifact; a section that vanishes (e.g. the engine-scaling subprocess died,
or the fusion bench was skipped) used to pass silently and poison the
trajectory.  This gate requires the sections the trajectory tracks to be
present AND non-empty.

    python scripts/check_bench.py [experiments/bench/summary.json]
"""
from __future__ import annotations

import json
import sys

REQUIRED = ("engine_scaling", "fusion", "rq1", "rq2", "dense", "serve",
            "autotune", "obs")

#: every serve workload must report at least this many offered-load levels
#: (p50/p95/p99 batched vs naive at light/mid/sat/overload)
SERVE_WORKLOADS = ("bm25_topk", "bm25_dense_rerank")
SERVE_MIN_LEVELS = 4

#: at saturation the deadline-aware scheduler must keep goodput tracking
#: throughput on the heavy workload (pre-shedding it collapsed to ~0: the
#: unbounded backlog blew every SLO)
SERVE_GOODPUT_WORKLOAD = "bm25_dense_rerank"
SERVE_MIN_GOODPUT_FRAC = 0.5

#: the IVF-PQ scan store must compress to at most 1/4 of the flat float
#: store, while full-probe recall (every list scanned; only the
#: exact-re-scored ADC shortlist bounds it) stays above the floor
PQ_MAX_BYTES_FRACTION_DEN = 4
PQ_MIN_FULL_PROBE_RECALL = 0.8


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "experiments/bench/summary.json"
    try:
        summary = json.load(open(path))
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: cannot read bench summary {path}: {e}", file=sys.stderr)
        return 1
    missing = [k for k in REQUIRED if not summary.get(k)]
    if missing:
        print(f"FAIL: bench summary {path} is missing sections: {missing} "
              f"(present: {sorted(summary)})", file=sys.stderr)
        return 1
    fus = summary["fusion"].get("workloads", {})
    if not fus:
        print("FAIL: fusion section has no workloads", file=sys.stderr)
        return 1
    dense = summary["dense"]
    if not dense.get("workloads"):
        print("FAIL: dense section has no workloads", file=sys.stderr)
        return 1
    if not dense.get("ivf"):
        print("FAIL: dense section has no ivf report", file=sys.stderr)
        return 1
    pq = dense.get("dense_pq")
    if not pq:
        print("FAIL: dense section has no dense_pq report", file=sys.stderr)
        return 1
    if not pq.get("pq_bytes_per_doc", 1e18) <= \
            pq.get("flat_bytes_per_doc", 0) / PQ_MAX_BYTES_FRACTION_DEN:
        print(f"FAIL: IVF-PQ store not <= 1/{PQ_MAX_BYTES_FRACTION_DEN} of "
              f"the flat store: {pq.get('pq_bytes_per_doc')} vs "
              f"{pq.get('flat_bytes_per_doc')} bytes/doc", file=sys.stderr)
        return 1
    if not pq.get("recall_at_k_full_probe", 0.0) >= PQ_MIN_FULL_PROBE_RECALL:
        print(f"FAIL: IVF-PQ full-probe recall@k "
              f"{pq.get('recall_at_k_full_probe')} < "
              f"{PQ_MIN_FULL_PROBE_RECALL}", file=sys.stderr)
        return 1
    shard_rows = {r.get("shards"): r for r in pq.get("doc_shards", [])}
    missing_shards = [s for s in (2, 4) if s not in shard_rows]
    if missing_shards:
        print(f"FAIL: dense_pq doc-shard scaling lacks shard counts "
              f"{missing_shards} (present: {sorted(shard_rows)})",
              file=sys.stderr)
        return 1
    bad_merge = [s for s, r in shard_rows.items()
                 if not r.get("merge_matches_oracle")]
    if bad_merge:
        print(f"FAIL: doc-shard merge diverged from the single-shard "
              f"oracle at shard counts {bad_merge}", file=sys.stderr)
        return 1
    serve = summary["serve"]
    sw = serve.get("workloads", {})
    missing_wl = [w for w in SERVE_WORKLOADS if w not in sw]
    if missing_wl:
        print(f"FAIL: serve section is missing workloads {missing_wl} "
              f"(present: {sorted(sw)})", file=sys.stderr)
        return 1
    for name in SERVE_WORKLOADS:
        levels = sw[name].get("levels", [])
        if len(levels) < SERVE_MIN_LEVELS:
            print(f"FAIL: serve workload {name!r} reports {len(levels)} "
                  f"offered-load levels (< {SERVE_MIN_LEVELS})",
                  file=sys.stderr)
            return 1
        for lvl in levels:
            for side in ("batched", "naive"):
                if "p95_ms" not in lvl.get(side, {}):
                    print(f"FAIL: serve workload {name!r} level "
                          f"{lvl.get('level')!r} lacks {side} p95_ms",
                          file=sys.stderr)
                    return 1
        if not sw[name].get("batched_beats_naive_at_saturation"):
            print(f"FAIL: serve workload {name!r}: continuous batching did "
                  "not beat naive per-request throughput at saturation",
                  file=sys.stderr)
            return 1
        by_level = {lvl.get("level"): lvl for lvl in levels}
        for lname in ("sat", "overload"):
            b = by_level.get(lname, {}).get("batched", {})
            missing_keys = [k for k in ("goodput_qps", "shed", "shed_door",
                                        "shed_queue") if k not in b]
            if lname not in by_level or missing_keys:
                print(f"FAIL: serve workload {name!r} lacks a deadline-"
                      f"aware {lname!r} level with goodput + shed counts "
                      f"(missing: {missing_keys or 'level'})",
                      file=sys.stderr)
                return 1
        sat_b = by_level["sat"]["batched"]
        if sat_b["goodput_qps"] < SERVE_MIN_GOODPUT_FRAC * \
                sat_b["throughput_qps"] and name == SERVE_GOODPUT_WORKLOAD:
            print(f"FAIL: serve workload {name!r} saturation goodput "
                  f"{sat_b['goodput_qps']} < {SERVE_MIN_GOODPUT_FRAC}x "
                  f"throughput {sat_b['throughput_qps']} (deadline-aware "
                  "shedding is not holding the SLO)", file=sys.stderr)
            return 1
    if not serve.get("gated"):
        print("FAIL: serve section has no gated trajectory metrics",
              file=sys.stderr)
        return 1
    missing_gate = [f"{w}.sat.goodput_qps" for w in SERVE_WORKLOADS
                    if f"{w}.sat.goodput_qps" not in serve["gated"]]
    if missing_gate:
        print(f"FAIL: serve gated block lacks saturation goodput metrics: "
              f"{missing_gate}", file=sys.stderr)
        return 1
    tt = serve.get("two_tenant")
    if not tt:
        print("FAIL: serve section has no two_tenant workload",
              file=sys.stderr)
        return 1
    if not tt.get("cross_pipeline_hits", 0) > 0:
        print(f"FAIL: two-tenant serve workload recorded no cross-pipeline "
              f"prefix hits: {tt}", file=sys.stderr)
        return 1
    if tt.get("recompiles_since_warmup") != 0:
        print(f"FAIL: two-tenant serve workload recompiled after warmup "
              f"({tt.get('recompiles_since_warmup')})", file=sys.stderr)
        return 1
    starved = [n for n, p in tt.get("per_pipeline", {}).items()
               if not p.get("served")]
    if len(tt.get("per_pipeline", {})) < 2 or starved:
        print(f"FAIL: two-tenant serve workload did not serve every "
              f"pipeline (starved: {starved})", file=sys.stderr)
        return 1
    rag = serve.get("rag")
    if not rag:
        print("FAIL: serve section has no rag workload", file=sys.stderr)
        return 1
    if not rag.get("continuous_beats_sequential_at_saturation"):
        print("FAIL: rag serve workload: continuous-batched decode did not "
              f"beat the sequential one-slot baseline at saturation "
              f"({(rag.get('continuous') or {}).get('decode_tokens_per_s')} "
              f"vs {(rag.get('sequential') or {}).get('decode_tokens_per_s')}"
              " tokens/s)", file=sys.stderr)
        return 1
    cont = rag.get("continuous") or {}
    for field in ("ttft_ms", "per_token_ms"):
        if "p95_ms" not in (cont.get(field) or {}):
            print(f"FAIL: rag serve workload lacks {field} p95 in its "
                  "continuous-decode traces", file=sys.stderr)
            return 1
    if cont.get("recompiles_since_warmup") != 0:
        print("FAIL: rag serve workload recompiled after warmup "
              f"({cont.get('recompiles_since_warmup')}) — decode "
              "prefill/step must ride the pinned jit-cache entries",
              file=sys.stderr)
        return 1
    if "rag.sat.decode_tokens_per_s" not in serve["gated"]:
        print("FAIL: serve gated block lacks rag.sat.decode_tokens_per_s",
              file=sys.stderr)
        return 1
    # overload post-mortems must ship the scheduler's decision log
    for name in SERVE_WORKLOADS:
        over = {lvl.get("level"): lvl
                for lvl in sw[name]["levels"]}.get("overload", {})
        fr = over.get("flight_record")
        if not fr:
            print(f"FAIL: serve workload {name!r} overload level lacks a "
                  "flight_record dump", file=sys.stderr)
            return 1
        bad_ev = [e for e in fr if "kind" not in e or "t" not in e]
        if bad_ev:
            print(f"FAIL: serve workload {name!r} flight_record has "
                  f"malformed events: {bad_ev[:3]}", file=sys.stderr)
            return 1
    obs = summary["obs"]
    for field in ("disabled_qps", "enabled_qps", "enabled_over_disabled_qps"):
        if obs.get(field) is None:
            print(f"FAIL: obs section lacks {field!r}", file=sys.stderr)
            return 1
    if "enabled_over_disabled_qps" not in obs.get("gated", {}):
        print("FAIL: obs gated block lacks enabled_over_disabled_qps",
              file=sys.stderr)
        return 1
    trace = obs.get("trace") or {}
    evs = trace.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        print("FAIL: obs section lacks a Chrome trace export "
              "(trace.traceEvents)", file=sys.stderr)
        return 1
    malformed = [e for e in evs
                 if not {"name", "ph", "ts", "pid", "tid"} <= set(e)]
    if malformed:
        print(f"FAIL: obs trace has malformed trace events: "
              f"{malformed[:3]}", file=sys.stderr)
        return 1
    span_ids = {e["args"].get("span_id") for e in evs if "args" in e}
    n_nested = sum(1 for e in evs
                   if e.get("cat") == "serve"
                   and e.get("args", {}).get("parent_id") in span_ids)
    if n_nested < 1:
        print("FAIL: obs trace export contains no nested serve span "
              "(no event's parent_id matches another's span_id)",
              file=sys.stderr)
        return 1
    at = summary["autotune"]
    for field in ("cold_tune_s", "warm_compile_s", "warm_profile_reuse"):
        if not at.get(field):
            print(f"FAIL: autotune section lacks {field!r}", file=sys.stderr)
            return 1
    reuse = at["warm_profile_reuse"]
    if reuse.get("probe_measurements", 1) != 0 or \
            reuse.get("gate_estimates", 1) != 0:
        print("FAIL: warm profile-reuse compile performed probe "
              f"measurements / gate compiles: {reuse}", file=sys.stderr)
        return 1
    if not at["warm_compile_s"] < at["cold_tune_s"]:
        print(f"FAIL: warm profile-reuse compile ({at['warm_compile_s']}s) "
              f"not faster than cold tune ({at['cold_tune_s']}s)",
              file=sys.stderr)
        return 1
    at_wl = at.get("workloads", {})
    bad = [n for n, w in at_wl.items()
           if not any(d.get("predicted_ratio") is not None
                      and d.get("measured_ratio") is not None
                      for d in w.get("decisions", []))]
    if not at_wl or bad:
        print("FAIL: autotune workloads lack per-decision measured/"
              f"predicted ratios: {bad or 'no workloads'}", file=sys.stderr)
        return 1
    print(f"bench summary OK: sections {list(REQUIRED)} all present; "
          f"fusion workloads: {sorted(fus)}; "
          f"dense workloads: {sorted(dense['workloads'])}; "
          f"serve workloads: {sorted(sw)} "
          f"({len(sw[SERVE_WORKLOADS[0]]['levels'])} load levels)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
