"""Assemble the §Perf hillclimb summary table from tagged dry-run records."""
from __future__ import annotations

import json
from pathlib import Path

CELLS = [
    ("qwen2-1.5b", "train_4k"),
    ("llama4-scout-17b-a16e", "train_4k"),
    ("glm4-9b", "prefill_32k"),
]


def rows(dryrun_dir="experiments/dryrun"):
    out = []
    for f in sorted(Path(dryrun_dir).glob("*__sp*.json")):
        r = json.loads(f.read_text())
        if (r["arch"], r["shape"]) not in CELLS:
            continue
        tag = f.stem.split("__")[3] if len(f.stem.split("__")) > 3 else "baseline"
        temp_gb = r.get("memory", {}).get("temp_bytes", 0) / 2 ** 30
        out.append({
            "cell": f"{r['arch']} × {r['shape']}",
            "variant": tag,
            "t_compute_s": round(r["t_compute"], 3),
            "t_memory_s": round(r["t_memory"], 2),
            "t_collective_s": round(r["t_collective"], 2),
            "bound": r["bottleneck"],
            "roofline_frac": round(
                r["t_compute"] / max(r["t_compute"], r["t_memory"],
                                     r["t_collective"]), 4),
            "temp_GB_per_chip": round(temp_gb, 1),
        })
    out.sort(key=lambda x: (x["cell"], x["variant"] != "baseline", x["variant"]))
    return out


def main():
    rs = rows()
    cols = list(rs[0].keys()) if rs else []
    lines = ["| " + " | ".join(cols) + " |", "|" + "---|" * len(cols)]
    for r in rs:
        lines.append("| " + " | ".join(str(r[c]) for c in cols) + " |")
    md = "\n".join(lines)
    Path("experiments/perf_summary.md").write_text(md)
    print(md)


if __name__ == "__main__":
    main()
