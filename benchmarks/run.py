"""Benchmark harness — one section per paper table/figure.

  RQ1   rank-cutoff optimisation (paper Table 3 top)    [ir_bench]
  RQ2   fat feature extraction  (paper Table 3 bottom)  [ir_bench]
  ROOF  roofline terms per (arch x shape x mesh)        [roofline]
  KERN  kernel micro-benches                            [kernel_bench]

Prints ``name,us_per_call,derived`` CSV rows per the harness contract, plus
the full tables; writes JSON artifacts under experiments/bench/.

  PYTHONPATH=src python -m benchmarks.run [--scale robust|small] [--skip-ir]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from pathlib import Path

from benchmarks import ir_bench, kernel_bench, roofline, serve_bench

OUT = Path("experiments/bench")


def run_engine_bench(scale: str, repeats: int, devices: int = 8) -> dict | None:
    """Device-sharded engine scaling, in a subprocess: the simulated-device
    XLA flag must be set before jax initialises, which this (already
    jax-initialised) process can no longer do."""
    out = OUT / "engine_scaling.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "benchmarks.engine_bench",
           "--devices", str(devices), "--scale", scale,
           "--repeats", str(repeats), "--out", str(out)]
    proc = subprocess.run(cmd, env=env)
    if proc.returncode != 0 or not out.exists():
        print("# engine scaling bench failed; see output above")
        return None
    return json.loads(out.read_text())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="robust", choices=["robust", "small"])
    ap.add_argument("--skip-ir", action="store_true")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    OUT.mkdir(parents=True, exist_ok=True)
    # clear stale section files: summary.json is merged from OUT/*.json, so
    # a leftover section from a previous run would mask exactly the
    # missing-section failures scripts/check_bench.py exists to catch
    for stale in OUT.glob("*.json"):
        stale.unlink()
    csv_rows: list[dict] = []

    # --- KERN ---------------------------------------------------------------
    kern = kernel_bench.bench_fused_scoring() + kernel_bench.bench_topk()
    csv_rows += kern
    (OUT / "kernels.json").write_text(json.dumps(kern, indent=1))

    # --- RQ1 / RQ2 ----------------------------------------------------------
    if not args.skip_ir:
        if args.scale == "robust":
            # 50 topics per formulation keeps the unoptimised doc-vectors
            # baseline tractable on this 1-core host; MRT is per query.
            env = ir_bench.build_robust_env(n_topics=50)
        else:
            env = ir_bench.build_robust_env(n_docs=20000, n_topics=32,
                                            vocab=40000)
        print(f"# corpus: {env['index'].n_docs} docs, "
              f"built in {env['build_s']:.0f}s")
        rq1 = ir_bench.bench_rq1(env, repeats=args.repeats)
        rq2 = ir_bench.bench_rq2(env, repeats=args.repeats)
        cw = ir_bench.clueweb_extrapolation(env, rq1, rq2)
        (OUT / "rq1.json").write_text(json.dumps(rq1, indent=1))
        (OUT / "rq2.json").write_text(json.dumps(rq2, indent=1))
        (OUT / "clueweb_extrapolation.json").write_text(json.dumps(cw, indent=1))
        print("\n== RQ1: rank-cutoff optimisation (MRT ms/query) ==")
        for r in rq1:
            print(r)
            csv_rows.append({
                "name": f"rq1_{r['formulation']}_opt",
                "us_per_call": r["opt_mrt_ms"] * 1000,
                "derived": f"delta={r['delta_pct']}%,overlap={r['topk_overlap']}"})
            csv_rows.append({
                "name": f"rq1_{r['formulation']}_orig",
                "us_per_call": r["orig_mrt_ms"] * 1000, "derived": ""})
        print("\n== RQ2: fat feature extraction (MRT ms/query) ==")
        for r in rq2:
            print(r)
            csv_rows.append({
                "name": f"rq2_{r['formulation']}_opt",
                "us_per_call": r["opt_mrt_ms"] * 1000,
                "derived": f"delta={r['delta_pct']}%"})
            csv_rows.append({
                "name": f"rq2_{r['formulation']}_orig",
                "us_per_call": r["orig_mrt_ms"] * 1000, "derived": ""})
        print("\n== ClueWeb09 extrapolation ==")
        print(cw)

        # --- planner: amortised shared-prefix speedup --------------------
        pl = ir_bench.bench_planner(env, repeats=args.repeats)
        (OUT / "planner.json").write_text(json.dumps(pl, indent=1))
        print("\n== Planner: shared-prefix amortisation ==")
        print(pl)
        csv_rows.append({
            "name": "planner_shared_prefix",
            "us_per_call": pl["planned_mrt_ms"] * 1000,
            "derived": (f"speedup={pl['amortised_speedup']}x,"
                        f"stages={pl['stage_executions']}/"
                        f"{pl['stage_requests']}")})

        # --- fusion: cost-gated kernel lowering --------------------------
        fus = ir_bench.bench_fusion(env, repeats=args.repeats)
        (OUT / "fusion.json").write_text(json.dumps(fus, indent=1))
        print("\n== Fusion: cost-gated kernel lowering (MRT ms/query) ==")
        print(f"compile breakdown (ms/pass): {fus['compile_breakdown_ms']}")
        for name, w in fus["workloads"].items():
            print(f"[{name}] {w}")
            csv_rows.append({
                "name": f"fusion_{name}_fused",
                "us_per_call": w["fused_mrt_ms"] * 1000,
                "derived": (f"speedup={w['speedup']}x,"
                            f"fused_stage={w['fused_stage']},"
                            f"overlap={w['topk_overlap']}")})
            csv_rows.append({
                "name": f"fusion_{name}_unfused",
                "us_per_call": w["unfused_mrt_ms"] * 1000, "derived": ""})

        # --- autotune: measured gating + persisted tuning profiles -------
        at = ir_bench.bench_autotune(env)
        (OUT / "autotune.json").write_text(json.dumps(at, indent=1))
        print("\n== Autotune: measured gating + persisted tuning profile ==")
        print(f"cold tune {at['cold_tune_s']}s vs warm profile-reuse "
              f"compile {at['warm_compile_s']}s ({at['warm_speedup']}x); "
              f"warm reuse counters: {at['warm_profile_reuse']}")
        print(f"calibration fit: {at['calibration_fit']}")
        print(f"seed 0.41x fused-gather case: {at['seed_fused_gather_case']}")
        for name, w in at["workloads"].items():
            print(f"[{name}] {w['decisions']}")
        n_dec = sum(len(w["decisions"]) for w in at["workloads"].values())
        csv_rows.append({
            "name": "autotune_cold_tune",
            "us_per_call": round(at["cold_tune_s"] * 1e6, 1),
            "derived": f"decisions={n_dec}"})
        csv_rows.append({
            "name": "autotune_warm_compile",
            "us_per_call": round(at["warm_compile_s"] * 1e6, 1),
            "derived": (
                f"speedup={at['warm_speedup']}x,"
                f"probes={at['warm_profile_reuse']['probe_measurements']},"
                f"gate_compiles={at['warm_profile_reuse']['gate_estimates']},"
                f"hits={at['warm_profile_reuse']['profile_hits']}")})

        # --- dense second stage: fused rerank + IVF candidate gen --------
        dn = ir_bench.bench_dense(env, repeats=args.repeats)
        (OUT / "dense.json").write_text(json.dumps(dn, indent=1))
        print("\n== Dense: fused second-stage rerank + IVF (MRT ms/query) ==")
        for name, w in dn["workloads"].items():
            print(f"[{name}] {w}")
            csv_rows.append({
                "name": f"dense_{name}_fused",
                "us_per_call": w["fused_mrt_ms"] * 1000,
                "derived": (f"speedup={w['speedup']}x,"
                            f"fused_stage={w['fused_stage']},"
                            f"overlap={w['topk_overlap']}")})
            csv_rows.append({
                "name": f"dense_{name}_unfused",
                "us_per_call": w["unfused_mrt_ms"] * 1000, "derived": ""})
        print(f"[ivf] {dn['ivf']}")
        csv_rows.append({
            "name": "dense_ivf_retrieve",
            "us_per_call": dn["ivf"]["ivf_mrt_ms"] * 1000,
            "derived": (f"speedup={dn['ivf']['speedup']}x,"
                        f"recall={dn['ivf']['recall_at_k']},"
                        f"nprobe={dn['ivf']['nprobe']}/"
                        f"{dn['ivf']['n_lists']}")})
        csv_rows.append({
            "name": "dense_brute_retrieve",
            "us_per_call": dn["ivf"]["brute_mrt_ms"] * 1000, "derived": ""})

        # --- serving: continuous micro-batching vs naive per-request -----
        sv = serve_bench.bench_serving(env)
        (OUT / "serve.json").write_text(json.dumps(sv, indent=1))
        print("\n== Serve: continuous micro-batching (open-loop Poisson) ==")
        for name, wl in sv["workloads"].items():
            print(f"[{name}] capacity {wl['capacity_qps']} "
                  f"recompiles_after_warmup={wl['recompiles_since_warmup']} "
                  f"beats_naive_at_saturation="
                  f"{wl['batched_beats_naive_at_saturation']}")
            for lvl in wl["levels"]:
                b, nv = lvl["batched"], lvl["naive"]
                shed = (f" shed={b['shed']}"
                        if lvl.get("deadline_ms") is not None else "")
                print(f"  [{lvl['level']}] {b['offered_qps']} q/s offered: "
                      f"batched p95={b['p95_ms']}ms "
                      f"tput={b['throughput_qps']} "
                      f"goodput={b['goodput_qps']}{shed} "
                      f"| naive p95={nv['p95_ms']}ms "
                      f"tput={nv['throughput_qps']}")
                csv_rows.append({
                    "name": f"serve_{name}_{lvl['level']}_batched",
                    "us_per_call": round(b["p95_ms"] * 1000, 1),
                    "derived": (f"tput={b['throughput_qps']}q/s,"
                                f"goodput={b['goodput_qps']}q/s,"
                                f"shed={b['shed']},"
                                f"batch={b['mean_batch_size']},"
                                f"offered={b['offered_qps']}q/s")})
                csv_rows.append({
                    "name": f"serve_{name}_{lvl['level']}_naive",
                    "us_per_call": round(nv["p95_ms"] * 1000, 1),
                    "derived": f"tput={nv['throughput_qps']}q/s"})
        # --- observability: enabled-vs-disabled serve overhead -----------
        ob = serve_bench.bench_obs(env)
        (OUT / "obs.json").write_text(json.dumps(ob, indent=1))
        print("\n== Observability: enabled-vs-disabled serve overhead ==")
        print(f"disabled {ob['disabled_qps']} q/s vs enabled "
              f"{ob['enabled_qps']} q/s "
              f"(ratio {ob['enabled_over_disabled_qps']}, overhead "
              f"{ob['overhead_pct']}%); trace events={ob['trace_events']} "
              f"nested_serve_spans={ob['nested_serve_spans']} "
              f"recorder={ob['flight_record_kinds']}")
        csv_rows.append({
            "name": "obs_enabled_serve",
            "us_per_call": round(1e6 / max(ob["enabled_qps"], 1e-9), 1),
            "derived": (f"ratio={ob['enabled_over_disabled_qps']},"
                        f"overhead={ob['overhead_pct']}%,"
                        f"spans={ob['nested_serve_spans']}")})
        csv_rows.append({
            "name": "obs_disabled_serve",
            "us_per_call": round(1e6 / max(ob["disabled_qps"], 1e-9), 1),
            "derived": ""})

        tt = sv.get("two_tenant")
        if tt:
            print(f"[two_tenant] pipelines={tt['pipelines']} "
                  f"served={tt['served']}/{tt['n_requests']} "
                  f"cross_prefix_hits={tt['cross_pipeline_hits']} "
                  f"lanes={tt['lane_served']} "
                  f"recompiles_after_warmup={tt['recompiles_since_warmup']}")
            csv_rows.append({
                "name": "serve_two_tenant",
                "us_per_call": round(1e6 / max(tt["throughput_qps"], 1e-9),
                                     1),
                "derived": (f"cross_hits={tt['cross_pipeline_hits']},"
                            f"served={tt['served']},"
                            f"recompiles={tt['recompiles_since_warmup']}")})

    # --- ENGINE: device-sharded query throughput -------------------------
    if not args.skip_ir:
        eng = run_engine_bench(args.scale, args.repeats)
        if eng is not None:
            print("\n== Engine: device-sharded scaling ==")
            print(f"(host cpus: {eng['host_cpus']}; device speedup "
                  f"saturates at host cores)")
            n_ladder = len(eng["bucket_ladder"])
            for name, wl in eng["workloads"].items():
                print(f"[{name}] sequential: {wl['sequential_qps']} q/s")
                csv_rows.append({
                    "name": f"engine_{name}_sequential",
                    "us_per_call": round(1e6 / max(wl["sequential_qps"],
                                                   1e-9), 2),
                    "derived": ""})
                for row in wl["rows"]:
                    print(f"  {row}")
                    csv_rows.append({
                        "name": f"engine_{name}_{row['devices']}dev",
                        "us_per_call": round(1e6 / max(row["qps"], 1e-9), 2),
                        "derived": (f"qps={row['qps']},"
                                    f"speedup={row['speedup_vs_sequential']}x,"
                                    f"recompiles="
                                    f"{row['max_recompiles_per_stage']}"
                                    f"<=ladder={n_ladder}")})

    # --- ROOF ---------------------------------------------------------------
    recs = roofline.load_records()
    for mesh in ["16x16", "2x16x16"]:
        rows = roofline.roofline_rows(recs, mesh=mesh)
        if rows:
            print(f"\n== Roofline ({mesh}, {len(rows)} cells) ==")
            print(roofline.format_csv(rows))
            (OUT / f"roofline_{mesh.replace('x','_')}.json").write_text(
                json.dumps(rows, indent=1))

    print("\n== CSV ==")
    print("name,us_per_call,derived")
    for r in csv_rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")

    # one merged artifact for CI's per-push bench trajectory (BENCH_<sha>)
    summary = {"scale": args.scale, "rows": csv_rows}
    for f in OUT.glob("*.json"):
        if f.name != "summary.json":
            summary[f.stem] = json.loads(f.read_text())
    (OUT / "summary.json").write_text(json.dumps(summary, indent=1))


if __name__ == "__main__":
    main()
