"""Open-loop serving benchmark: continuous micro-batching vs naive
per-request execution under Poisson arrivals, with deadline-aware shedding
at and past saturation, plus a two-tenant shared-cache workload.

Protocol (open loop — the standard serving methodology): arrival times are
drawn ahead of time from a Poisson process at several offered-QPS levels; a
submission thread releases each request at its scheduled instant regardless
of how the server is doing (so queueing shows up as latency, not reduced
load); latency is measured from the *intended* arrival.  The naive baseline
is the same server with ``max_batch=1`` — every request executes alone, in
arrival order — so the delta isolates exactly the micro-batching policy.

Levels are placed relative to *measured* capacity — see ``LOAD_LEVELS``
for the placement and why light load references naive capacity.  The two
under-capacity levels run without deadlines (every request must complete);
``sat`` and ``overload`` attach the SLO as a per-request deadline, which
engages shed-before-execute: the scheduler rejects/drops requests whose
deadline cannot survive the estimated queue wait, so ladder slots are
spent only on answers that arrive in time and **goodput tracks throughput**
instead of collapsing to ~0 as the unbounded queue blows every SLO.
Throughput/goodput therefore count *served* completions (shed requests are
reported separately), and each level reports shed/rejection counts.

The ``gated`` block names the trajectory metrics CI compares across
pushes: light-load batched p95 (``<wl>.light.p95_ms``, lower better),
mid-load batched goodput (``<wl>.mid.goodput_qps``, higher better — under
capacity the value is stable and an SLO-violating batching regression
collapses it), saturation batched throughput
(``<wl>.sat.throughput_qps``, higher better), and saturation batched
goodput (``<wl>.sat.goodput_qps``, higher better — the shedding policy's
headline: before deadline-aware shedding this was ~0).

``two_tenant`` serves two pipelines sharing a retrieval prefix over ONE
server (one engine, one scheduler, one stage cache, WFQ lanes): tenant B
resumes mid-chain from prefix state tenant A computed, surfaced as
``cross_pipeline_hits``, with zero steady-state recompiles.

``rag`` serves a full ``bm25 >> dense_rerank % k >> generate`` chain:
retrieval-only vs full-RAG throughput, and continuous-batched decode
(iteration-level slot admission) vs a sequential one-slot baseline at
saturation, with TTFT and per-token p95 — see :func:`bench_rag`.

    PYTHONPATH=src python -m benchmarks.serve_bench [--scale small]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import DenseRerank, Extract, Generate, JaxBackend, Retrieve
from repro.core.data import make_queries
from repro.models import transformer_lm as tlm
from repro.serve import (DeadlineUnmeetable, MultiPipelineServer,
                         PipelineServer, ServeConfig, ServerOverloaded)
from repro.serve.trace import latency_summary

#: offered-load levels as (name, capacity reference, multiplier, deadline?).
#: Light load is placed relative to the NAIVE capacity: with near-empty
#: queues batches do not fill, so the batched server's effective light-load
#: capacity is the per-request one — a level at a fraction of *batched*
#: capacity would already saturate it.  Saturation/overload are relative to
#: batched capacity so both configurations are past their limit and the
#: comparison is pure throughput; those levels attach the SLO as each
#: request's deadline so shed-before-execute engages.
LOAD_LEVELS = (("light", "naive", 0.4, False),
               ("mid", "naive", 1.2, False),
               ("sat", "batched", 2.0, True),
               ("overload", "batched", 4.0, True))
SLO_MS = 250.0


def _workloads(k: int = 10, k_in: int = 100) -> dict:
    return {
        "bm25_topk": lambda: Retrieve("BM25") % k,
        "bm25_dense_rerank":
            lambda: (Retrieve("BM25", k=k_in) >> DenseRerank(alpha=0.3)) % k,
    }


def _rows(Q, n: int, seed: int = 0):
    """n single-query rows cycled from the topic set, distinct qids."""
    nq = int(np.asarray(Q["qid"]).shape[0])
    host = {k: np.asarray(v) for k, v in Q.items()}
    rng = np.random.default_rng(seed)
    order = rng.integers(0, nq, n)
    rows = []
    for j, i in enumerate(order):
        row = {k: v[i:i + 1].copy() for k, v in host.items()}
        row["qid"] = np.asarray([j], np.int32)
        rows.append(row)
    return rows


def _measure_capacity(server: PipelineServer, rows, *, burst: int = 64) -> float:
    """Closed-loop capacity: serve a standing burst, steady-state QPS."""
    for row in rows[:burst]:
        server.submit_one(row)
    server.pump()                                     # warm path
    t0 = time.monotonic()
    for row in rows[:burst]:
        server.submit_one(row)
    server.pump()
    return burst / (time.monotonic() - t0)


def _run_level(server: PipelineServer, rows, offered_qps: float,
               seed: int, *, timeout_ms: float | None = None) -> dict:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / offered_qps, len(rows))
    arrivals = np.cumsum(gaps)
    server.start()
    reqs, n_rejected, n_shed_door = [], 0, 0
    t0 = time.monotonic() + 0.005
    for row, a in zip(rows, arrivals):
        dt = t0 + a - time.monotonic()
        if dt > 0:
            time.sleep(dt)
        try:
            # under-capacity levels run deadline-free (every request must
            # complete); sat/overload attach the SLO so shedding engages
            reqs.append((a, server.submit_one(row, timeout_ms=timeout_ms)))
        except DeadlineUnmeetable:       # shed at the door (before queueing)
            n_shed_door += 1
        except ServerOverloaded:         # bounded queue full
            n_rejected += 1
    for _, r in reqs:
        r.done.wait(timeout=300)
    server.stop()
    lat, n_good, t_last = [], 0, t0
    n_shed_queue = n_expired = 0
    for a, r in reqs:
        if r.trace.timed_out:
            if r.trace.shed:
                n_shed_queue += 1        # shed at batch close, pre-execution
            else:
                n_expired += 1           # expired in queue (no shed verdict)
            continue
        l_ms = 1000.0 * (r.trace.t_done - (t0 + a))   # open-loop latency
        lat.append(l_ms)
        t_last = max(t_last, r.trace.t_done)
        if l_ms <= SLO_MS:
            n_good += 1
    makespan = max(t_last - t0, 1e-9)
    sizes = [r.trace.batch_size for _, r in reqs if not r.trace.timed_out]
    return {
        "offered_qps": round(offered_qps, 1),
        "n_requests": len(rows),
        "served": len(lat),
        "rejected": n_rejected,
        "shed": n_shed_door + n_shed_queue,
        "shed_door": n_shed_door,
        "shed_queue": n_shed_queue,
        "expired": n_expired,
        "throughput_qps": round(len(lat) / makespan, 1),
        "goodput_qps": round(n_good / makespan, 1),
        "goodput_over_throughput": (round(n_good / len(lat), 3)
                                    if lat else 0.0),
        "mean_batch_size": (round(sum(sizes) / len(sizes), 2)
                            if sizes else 0.0),
        **latency_summary(lat),
    }


def _server(pipe, backend, *, naive: bool) -> PipelineServer:
    # naive = per-request execution: batches of one, closed immediately.
    # Caches identical on both sides so the delta is the batching policy.
    cfg = (ServeConfig.default(max_queue=4096, cache_entries=0)
           .with_batching(max_batch=1 if naive else None,
                          max_wait_ms=0.0 if naive else 4.0))
    if not naive:
        # flight recorder only (no tracing): ring-buffer appends are cheap
        # enough to leave on while measuring, and the overload level dumps
        # the shed/drop decision log into the bench artifact
        cfg = cfg.with_observability(True, tracing=False)
    return PipelineServer(pipe, backend, cfg)


def bench_two_tenant(index, Q, dense, *, k_in: int = 100,
                     n_requests: int = 96, seed: int = 0) -> dict:
    """Two pipelines sharing a retrieval prefix multiplexed over ONE server:
    one engine, one scheduler, one stage cache, WFQ lanes.  Tenant B
    resumes mid-chain from prefix state tenant A computed (and vice versa)
    — the online realisation of the planner's shared-prefix trie."""
    be = JaxBackend(index, default_k=1000, query_chunk=8, dense=dense)
    cfg = (ServeConfig.default(optimize=False, max_queue=4096)
           .with_lanes(("interactive", 4.0), ("background", 1.0)))
    server = MultiPipelineServer(
        {"ql": Retrieve("BM25", k=k_in) >> Extract("QL"),
         "tfidf": Retrieve("BM25", k=k_in) >> Extract("TF_IDF")},
        be, cfg)
    warm = server.warmup(Q)
    rows = _rows(Q, n_requests, seed)
    t0 = time.monotonic()
    reqs = []
    for j, row in enumerate(rows):
        reqs.append(server.submit_one(
            row, pipeline=("ql", "tfidf")[j % 2],
            lane=("interactive", "background")[j % 2]))
        if j % 16 == 15:                 # several mixed-tenant batches
            server.pump()
    server.pump()
    for r in reqs:
        r.done.wait(60)
    dt = max(time.monotonic() - t0, 1e-9)
    s = server.stats()
    return {
        "pipelines": sorted(s["pipelines"]),
        "n_requests": len(rows),
        "served": s["served"],
        "throughput_qps": round(len(rows) / dt, 1),
        "cross_pipeline_hits": s["cross_pipeline_hits"],
        "lane_served": s["lane_served"],
        "per_pipeline": {
            name: {"served": t["served"],
                   "cross_prefix_hits": t["cross_pipeline_prefix_hits"]}
            for name, t in s["pipelines"].items()},
        "recompiles_since_warmup": s["recompiles_since_warmup"],
        "warmup_s": warm["warmup_s"],
    }


def _bench_lm_cfg() -> tlm.LMConfig:
    return tlm.LMConfig(name="bench-lm", n_layers=2, d_model=64, n_q=4,
                        n_kv=2, d_head=16, d_ff=128, vocab=256, remat=False)


def bench_rag(index, Q, dense, *, k: int = 8, k_in: int = 100,
              n_requests: int = 48, seed: int = 0) -> dict:
    """RAG serving workload: ``bm25 >> dense_rerank % k >> generate``.

    Two comparisons, both closed-loop at saturation (a standing burst, so
    every decode slot that CAN be busy IS busy — the regime where
    iteration-level scheduling pays):

    - retrieval-only vs full RAG on the same prefix — what answering
      costs on top of ranking;
    - continuous-batched decode (``decode_slots`` slots, admission
      between decode steps) vs a sequential one-slot baseline (each
      request decodes alone, in order) on the *same* RAG chain — the
      delta isolates exactly token-level continuous batching, the
      ragged-decode analogue of the batched-vs-naive split above.

    Reports decode tokens/s, served QPS, TTFT and per-token p95 (from the
    request traces), and the warmed zero-recompile invariant — decode
    prefill/step are pinned-shape engine programs, so the invariant
    covers them."""
    cfg_lm = _bench_lm_cfg()
    T = 16

    def _mk(pipe, slots):
        be = JaxBackend(index, default_k=1000, query_chunk=8, dense=dense)
        be.register_lm(cfg_lm.name, cfg_lm)
        cfg = (ServeConfig.default(max_queue=4096, cache_entries=0)
               .with_batching(max_wait_ms=4.0).with_decode(slots))
        return PipelineServer(pipe, be, cfg)

    def _rag_pipe():
        return ((Retrieve("BM25", k=k_in) >> DenseRerank(alpha=0.3)) % k
                >> Generate(cfg_lm.name, max_new_tokens=T,
                            max_prompt_len=64, prompt_docs=3))

    def _sat(server, rows):
        t0 = time.monotonic()
        reqs = [server.submit_one(row) for row in rows]
        server.pump()
        for r in reqs:
            r.done.wait(300)
        dt = max(time.monotonic() - t0, 1e-9)
        st = server.stats()
        dec = st.get("decode", {})
        return {
            "served": st["served"],
            "throughput_qps": round(len(rows) / dt, 1),
            "decode_tokens_per_s": round(len(rows) * T / dt, 1),
            "ttft_ms": dec.get("ttft_ms"),
            "per_token_ms": dec.get("per_token_ms"),
            "recompiles_since_warmup": st["recompiles_since_warmup"],
        }

    rows = _rows(Q, n_requests, seed)
    ret_server = _mk((Retrieve("BM25", k=k_in)
                      >> DenseRerank(alpha=0.3)) % k, 1)
    ret_server.warmup(Q)
    t0 = time.monotonic()
    ret_reqs = [ret_server.submit_one(row) for row in rows]
    ret_server.pump()
    for r in ret_reqs:
        r.done.wait(300)
    retrieval_qps = round(len(rows) / max(time.monotonic() - t0, 1e-9), 1)

    cont = _mk(_rag_pipe(), 8)
    warm = cont.warmup(Q)
    continuous = _sat(cont, rows)
    seqs = _mk(_rag_pipe(), 1)
    seqs.warmup(Q)
    sequential = _sat(seqs, rows)
    return {
        "lm": {"name": cfg_lm.name, "n_layers": cfg_lm.n_layers,
               "d_model": cfg_lm.d_model, "vocab": cfg_lm.vocab},
        "max_new_tokens": T,
        "n_requests": n_requests,
        "decode_slots": {"continuous": 8, "sequential": 1},
        "retrieval_only_qps": retrieval_qps,
        "continuous": continuous,
        "sequential": sequential,
        "continuous_beats_sequential_at_saturation":
            (continuous["decode_tokens_per_s"]
             > sequential["decode_tokens_per_s"]),
        "warmup_s": warm["warmup_s"],
    }


def bench_obs(env, *, k: int = 10, k_in: int = 100,
              n_requests: int = 64, repeats: int = 3, seed: int = 0) -> dict:
    """Observability overhead: the same closed-loop burst served with
    observability disabled (the production default — the metrics registry
    is always on, so "disabled" IS the metrics-instrumented fast path)
    vs fully enabled (span tracer + flight recorder).  Reports best-of-
    ``repeats`` QPS per configuration and gates the enabled/disabled
    ratio; the disabled path's own cost vs earlier pushes is covered by
    the serve section's throughput trajectory.  The enabled run's Chrome
    trace export is embedded so CI can assert the span tree actually
    nests (request -> queue/batch children) and stays valid JSON."""
    index = env["index"]
    topics = env["formulations"]["T"]
    Q = make_queries(np.asarray(topics.terms), np.asarray(topics.weights),
                     np.asarray(topics.qids))
    dense_holder = [None]

    def _mk(obs: bool) -> PipelineServer:
        be = JaxBackend(index, default_k=1000, query_chunk=8,
                        dense=dense_holder[0])
        dense_holder[0] = be.dense      # share the doc matrix across servers
        cfg = (ServeConfig.default(max_queue=4096, cache_entries=0)
               .with_batching(max_wait_ms=4.0))
        if obs:
            cfg = cfg.with_observability(True)
        return PipelineServer(
            (Retrieve("BM25", k=k_in) >> DenseRerank(alpha=0.3)) % k,
            be, cfg)

    rows = _rows(Q, n_requests, seed)

    def _qps(server: PipelineServer) -> float:
        server.warmup(Q)
        for row in rows[:16]:                       # warm the measured path
            server.submit_one(row)
        server.pump()
        best = 0.0
        for _ in range(repeats):
            t0 = time.monotonic()
            reqs = [server.submit_one(row) for row in rows]
            server.pump()
            for r in reqs:
                r.done.wait(300)
            best = max(best, len(rows) / max(time.monotonic() - t0, 1e-9))
        return best

    disabled, enabled = _mk(False), _mk(True)
    qps_off, qps_on = _qps(disabled), _qps(enabled)
    trace = enabled.trace_export()
    evs = trace["traceEvents"]
    ids = {e["args"]["span_id"] for e in evs}
    n_nested = sum(1 for e in evs
                   if e.get("cat") == "serve"
                   and e["args"].get("parent_id") in ids)
    ratio = round(qps_on / max(qps_off, 1e-9), 3)
    return {
        "n_requests": n_requests,
        "repeats": repeats,
        "disabled_qps": round(qps_off, 1),
        "enabled_qps": round(qps_on, 1),
        "enabled_over_disabled_qps": ratio,
        "overhead_pct": round(100.0 * (1.0 - ratio), 1),
        "trace_events": len(evs),
        "nested_serve_spans": n_nested,
        "flight_record_kinds": (enabled.recorder.kinds()
                                if enabled.recorder else {}),
        "trace": trace,
        "gated": {"enabled_over_disabled_qps":
                  {"value": ratio, "better": "higher"}},
    }


def bench_serving(env, *, k: int = 10, k_in: int = 100, seed: int = 0) -> dict:
    index = env["index"]
    topics = env["formulations"]["T"]
    Q = make_queries(np.asarray(topics.terms), np.asarray(topics.weights),
                     np.asarray(topics.qids))
    out = {"slo_ms": SLO_MS,
           "load_levels": [list(lv) for lv in LOAD_LEVELS],
           "workloads": {}, "gated": {}}
    dense = None
    for name, mk in _workloads(k, k_in).items():
        be = JaxBackend(index, default_k=1000, query_chunk=8, dense=dense)
        dense = be.dense
        batched = _server(mk(), be, naive=False)
        naive = _server(mk(), be, naive=True)
        warm = batched.warmup(Q)
        naive.warmup(Q)
        rows = _rows(Q, 64, seed)
        cap = {"batched": _measure_capacity(batched, rows),
               "naive": _measure_capacity(naive, rows)}
        levels = []
        for li, (lname, ref, mult, deadline) in enumerate(LOAD_LEVELS):
            offered = max(mult * cap[ref], 2.0)
            n = int(np.clip(round(offered * 1.2), 32, 192))
            lvl_rows = _rows(Q, n, seed + 11 * li)
            tmo = SLO_MS if deadline else None
            levels.append({
                "level": lname,
                "offered": f"{mult}x {ref} capacity",
                "deadline_ms": tmo,
                "batched": _run_level(batched, lvl_rows, offered, seed + 1,
                                      timeout_ms=tmo),
                "naive": _run_level(naive, lvl_rows, offered, seed + 2,
                                    timeout_ms=tmo),
            })
        by_name = {lvl["level"]: lvl for lvl in levels}
        light, mid, sat = by_name["light"], by_name["mid"], by_name["sat"]
        wl = {
            "chain_len": len(batched.chain),
            "warmup": warm,
            "recompiles_since_warmup":
                batched.stats()["recompiles_since_warmup"],
            "capacity_qps": {k_: round(v, 1) for k_, v in cap.items()},
            "levels": levels,
            "batched_beats_naive_at_saturation":
                (sat["batched"]["throughput_qps"]
                 > sat["naive"]["throughput_qps"]),
        }
        # post-mortem artifact: the flight recorder's view of the overload
        # level — every shed carries the service-model inputs (S(n), slack)
        # the scheduler decided with
        over = by_name["overload"]
        over["flight_record"] = batched.flight_record(last=64)
        over["flight_record_kinds"] = (batched.recorder.kinds()
                                       if batched.recorder else {})
        out["workloads"][name] = wl
        out["gated"][f"{name}.light.p95_ms"] = {
            "value": light["batched"]["p95_ms"], "better": "lower"}
        # goodput gates BOTH under capacity (mid: stable ~offered, collapses
        # on an SLO-violating batching regression) and at saturation (the
        # shedding policy's headline — pre-shedding this was ~0 because the
        # unbounded backlog blew every SLO)
        out["gated"][f"{name}.mid.goodput_qps"] = {
            "value": mid["batched"]["goodput_qps"], "better": "higher"}
        out["gated"][f"{name}.sat.throughput_qps"] = {
            "value": sat["batched"]["throughput_qps"], "better": "higher"}
        out["gated"][f"{name}.sat.goodput_qps"] = {
            "value": sat["batched"]["goodput_qps"], "better": "higher"}
    out["two_tenant"] = bench_two_tenant(index, Q, dense, k_in=k_in,
                                         seed=seed)
    rag = bench_rag(index, Q, dense, k_in=k_in, seed=seed)
    out["rag"] = rag
    out["gated"]["rag.sat.decode_tokens_per_s"] = {
        "value": rag["continuous"]["decode_tokens_per_s"],
        "better": "higher"}
    out["gated"]["rag.sat.throughput_qps"] = {
        "value": rag["continuous"]["throughput_qps"], "better": "higher"}
    if rag["continuous"].get("ttft_ms"):
        out["gated"]["rag.ttft_p95_ms"] = {
            "value": rag["continuous"]["ttft_ms"]["p95_ms"],
            "better": "lower"}
        out["gated"]["rag.per_token_p95_ms"] = {
            "value": rag["continuous"]["per_token_ms"]["p95_ms"],
            "better": "lower"}
    return out


def main() -> None:
    from benchmarks.ir_bench import build_robust_env
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["robust", "small"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.scale == "robust":
        env = build_robust_env(n_topics=50)
    else:
        env = build_robust_env(n_docs=20000, n_topics=32, vocab=40000)
    res = bench_serving(env)
    print(json.dumps(res, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
