"""RQ1/RQ2 efficiency benchmarks (paper Table 3) on the JAX backend.

Reproduction protocol: synthetic corpus at TREC Robust04 scale (528,155
docs), 250 topics in T/TD/TDN formulations (3/10/30 terms).  Backend
capability variants emulate the paper's engines:

  * terrier-like   — no dynamic pruning (cutoff stays post-hoc)
  * anserini-orig  — pruning-capable backend, pipeline NOT rewritten
  * anserini-opt   — same backend, cutoff pushdown applied         [RQ1]
  * per-feature    — Extract passes over doc vectors (unoptimised)
  * fat-opt        — fused single-pass multi-model retrieval       [RQ2]

MRT (mean response time, ms/query) is wall-clock with compilation excluded
(one warm-up pass).  Validation target vs the paper: the *sign and rough
magnitude of the optimisation deltas*, not absolute Java-vs-JAX times.
ClueWeb09 (50.2M docs) is not materialisable on this host; we report a
documented per-posting-throughput extrapolation.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np
import jax

from repro.core import (BackendDescriptor, DenseRerank, DenseRetrieve,
                        Experiment, ExperimentPlan, Extract, FatRetrieve,
                        PrunedRetrieve, Retrieve, ShardedQueryEngine,
                        compile_pipeline, raise_ir)
from repro.core.compiler import Context, JaxBackend, run_pipeline
from repro.core.data import make_queries
from repro.launch.mesh import make_query_mesh
from repro.index import build_index, synthesize_corpus, synthesize_topics
from repro.index.corpus import ROBUST_DOCS, CLUEWEB_DOCS, expand_topics

CACHE = Path("experiments/cache")


def build_robust_env(n_docs: int = ROBUST_DOCS, n_topics: int = 250,
                     vocab: int = 200_000, seed: int = 0):
    """Build the Robust-scale corpus+index+topics (in-memory; ~10 min, a few
    GB — no pickle cache, the dump would double peak memory)."""
    t0 = time.time()
    corpus = synthesize_corpus(n_docs=n_docs, vocab=vocab, mean_len=300,
                               seed=seed)
    topics_t = synthesize_topics(corpus, n_topics=n_topics, q_len=3,
                                 rels_per_topic=30, seed=seed + 1)
    topics_td = expand_topics(topics_t, q_len=10, seed=seed + 2)
    topics_tdn = expand_topics(topics_td, q_len=30, seed=seed + 3)
    index = build_index(corpus)
    del corpus  # free the raw token stream before retrieval runs
    env = {
        "index": index,
        "formulations": {"T": topics_t, "TD": topics_td, "TDN": topics_tdn},
        "build_s": time.time() - t0,
    }
    return env


def host_info() -> dict:
    """Host identity recorded with every calibration entry, so the bench
    trajectory accumulates measured-vs-predicted data *per host* (gate peak
    constants are host properties, not code properties)."""
    import os
    import platform
    return {"cpus": os.cpu_count(), "machine": platform.machine(),
            "node": platform.node()}


def gate_calibration(decisions, mrt_fused_ms: float,
                     mrt_unfused_ms: float) -> dict | None:
    """Measured-vs-predicted cost-gate ratio for one workload (the ROADMAP
    calibration item): the gate compares HLO roofline proxies, this records
    how the proxy ratio tracked the wall-clock ratio so the bench
    trajectory can fit per-host peak constants later."""
    usable = [d for d in decisions
              if d.get("fused_proxy_s") and d.get("unfused_proxy_s")]
    if not usable or mrt_unfused_ms <= 0:
        return None
    d = usable[-1]                  # the decision that shaped this pipeline
    predicted = d["fused_proxy_s"] / d["unfused_proxy_s"]
    measured = mrt_fused_ms / mrt_unfused_ms
    out = {
        "pattern": d["pattern"],
        "accepted": d["accepted"],
        "predicted_ratio": round(predicted, 4),
        "measured_ratio": round(measured, 4),
        "measured_over_predicted": round(measured / predicted, 4),
    }
    # per-candidate HLO counts + wall-clock: the exact record shape
    # ``analysis.hlo_cost.fit_peaks`` consumes to calibrate the roofline
    # (decisions carry the counts since the descriptor refactor)
    for side, mrt in (("unfused", mrt_unfused_ms), ("fused", mrt_fused_ms)):
        if d.get(f"{side}_flops") and d.get(f"{side}_bytes"):
            out[side] = {"flops": d[f"{side}_flops"],
                         "bytes": d[f"{side}_bytes"],
                         "measured_s": mrt / 1000.0}
    return out


def topk_overlap(A, B, k: int) -> float:
    """Mean per-query overlap@k of two docid matrices (the semantics check
    every fused/pruned-vs-exact comparison reports)."""
    return float(np.mean([
        len(set(a[a >= 0].tolist()) & set(b[b >= 0].tolist())) / k
        for a, b in zip(np.asarray(A), np.asarray(B))]))


def _time_pipeline(pipe, Q, backend, *, optimize, repeats=3):
    node = raise_ir(compile_pipeline(pipe, backend)) if optimize else pipe
    # warm-up (compile)
    R = node.transform(Q, backend=backend, optimize=False)
    jax.block_until_ready(R["scores"])
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        R = node.transform(Q, backend=backend, optimize=False)
        jax.block_until_ready(R["scores"])
        times.append(time.perf_counter() - t0)
    nq = int(Q["qid"].shape[0])
    return 1000.0 * min(times) / nq, R


def bench_rq1(env, k: int = 10, repeats: int = 3) -> list[dict]:
    """Rank-cutoff optimisation across T/TD/TDN formulations."""
    index = env["index"]
    be_nopruning = JaxBackend(
        index, default_k=1000, query_chunk=8,
        descriptor=BackendDescriptor.default(frozenset({"fat",
                                                        "multi_model"})))
    be_full = JaxBackend(index, default_k=1000, query_chunk=8,
                         dense=be_nopruning.dense)
    rows = []
    for form, topics in env["formulations"].items():
        Q = make_queries(np.asarray(topics.terms), np.asarray(topics.weights),
                         np.asarray(topics.qids))
        pipe = Retrieve("BM25") % k
        mrt_terrier, _ = _time_pipeline(pipe, Q, be_nopruning, optimize=True,
                                        repeats=repeats)
        mrt_orig, R_orig = _time_pipeline(pipe, Q, be_full, optimize=False,
                                          repeats=repeats)
        mrt_opt, R_opt = _time_pipeline(pipe, Q, be_full, optimize=True,
                                        repeats=repeats)
        # semantics check: pruned top-k must overlap the exhaustive top-k
        overlap = topk_overlap(R_orig["docids"], R_opt["docids"], k)
        rows.append({
            "formulation": form, "k": k,
            "terrier_like_mrt_ms": round(mrt_terrier, 2),
            "orig_mrt_ms": round(mrt_orig, 2),
            "opt_mrt_ms": round(mrt_opt, 2),
            "delta_pct": round(100 * (mrt_opt - mrt_orig) / mrt_orig, 1),
            "topk_overlap": round(float(overlap), 3),
        })
    return rows


def bench_rq2(env, k: int = 1000, repeats: int = 3) -> list[dict]:
    """Fat-postings LTR feature extraction across formulations."""
    index = env["index"]
    be = JaxBackend(index, default_k=k, query_chunk=8)
    rows = []
    for form, topics in env["formulations"].items():
        Q = make_queries(np.asarray(topics.terms), np.asarray(topics.weights),
                         np.asarray(topics.qids))
        pipe = Retrieve("BM25", k=k) >> (Extract("QL") ** Extract("TF_IDF"))
        mrt_orig, R_orig = _time_pipeline(pipe, Q, be, optimize=False,
                                          repeats=repeats)
        mrt_opt, R_opt = _time_pipeline(pipe, Q, be, optimize=True,
                                        repeats=repeats)
        feat_diff = float(np.nanmax(np.abs(
            np.asarray(R_orig["features"]) - np.asarray(R_opt["features"]))))
        rows.append({
            "formulation": form, "k": k,
            "orig_mrt_ms": round(mrt_orig, 2),
            "opt_mrt_ms": round(mrt_opt, 2),
            "delta_pct": round(100 * (mrt_opt - mrt_orig) / mrt_orig, 1),
            "feature_maxdiff": feat_diff,
        })
    return rows


def bench_planner(env, k: int = 1000, repeats: int = 3,
                  features=("QL", "TF_IDF", "DPH")) -> dict:
    """Amortised shared-prefix speedup (the planner's reason to exist): N
    pipelines sharing one retrieval prefix, executed by the trie plan
    (prefix runs once) vs sequentially with no sharing (prefix runs N
    times).  Steady-state wall-clock — both paths are warmed first, so JIT
    compilation does not pollute the ratio."""
    index = env["index"]
    be = JaxBackend(index, default_k=k, query_chunk=8)
    topics = env["formulations"]["T"]
    Q = make_queries(np.asarray(topics.terms), np.asarray(topics.weights),
                     np.asarray(topics.qids))
    pipes = [Retrieve("BM25", k=k) >> Extract(m) for m in features]

    plan = ExperimentPlan(pipes, be, optimize=False)
    plan.execute(Q, ctx=Context(be))               # warm-up (compile)
    t_planned = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        plan.execute(Q, ctx=Context(be))
        t_planned.append(time.perf_counter() - t0)

    for p in pipes:                                 # warm-up sequential path
        jax.block_until_ready(
            run_pipeline(p, Q, backend=be, optimize=False)["scores"])
    t_seq = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for p in pipes:                             # fresh memo: no sharing
            jax.block_until_ready(
                run_pipeline(p, Q, backend=be, optimize=False)["scores"])
        t_seq.append(time.perf_counter() - t0)

    nq = int(Q["qid"].shape[0])
    return {
        "n_pipelines": len(pipes), "k": k,
        "stage_requests": plan.n_stage_requests,
        "stage_executions": plan.n_stage_executions,
        "planned_mrt_ms": round(1000 * min(t_planned) / nq, 2),
        "sequential_mrt_ms": round(1000 * min(t_seq) / nq, 2),
        "amortised_speedup": round(min(t_seq) / min(t_planned), 2),
    }


def bench_fusion(env, k: int = 10, repeats: int = 3) -> dict:
    """Cost-gated kernel lowering (the IR compiler's fusion pass): fused vs
    unfused MRT/QPS per workload, the fusion gate's decisions, and the
    per-pass compile-time breakdown of the pass-manager compiler.

    Both backends lack dynamic pruning, so ``Retrieve % K`` survives the
    rewrite pass intact and the only difference is the kernel lowering:
    ``fused`` carries the ``fused_topk`` / ``fused_scoring`` capabilities,
    ``unfused`` keeps the interpreter path (slice-after-full-k)."""
    from repro.core import compile_pipeline

    index = env["index"]
    base = frozenset({"fat", "multi_model"})
    be_fused = JaxBackend(
        index, default_k=1000, query_chunk=8,
        descriptor=BackendDescriptor.default(
            base | {"fused_topk", "fused_scoring"}))
    be_unfused = JaxBackend(index, default_k=1000, query_chunk=8,
                            dense=be_fused.dense,
                            descriptor=BackendDescriptor.default(base))
    topics = env["formulations"]["T"]
    Q = make_queries(np.asarray(topics.terms), np.asarray(topics.weights),
                     np.asarray(topics.qids))
    workloads = {
        "retrieve_topk": Retrieve("BM25") % k,
        "fat_scorer_topk": (Retrieve("BM25")
                            >> (Extract("QL") ** Extract("TF_IDF"))) % k,
    }
    out = {"k": k, "workloads": {}, "compile_breakdown_ms": {},
           "host": host_info()}
    breakdown: dict[str, float] = {}
    for name, pipe in workloads.items():
        report = {}
        op = compile_pipeline(pipe, be_fused, report=report)
        for pname, secs in report["pass_timings_s"]:
            breakdown[pname] = breakdown.get(pname, 0.0) + 1000 * secs
        mrt_f, Rf = _time_pipeline(pipe, Q, be_fused, optimize=True,
                                   repeats=repeats)
        mrt_u, Ru = _time_pipeline(pipe, Q, be_unfused, optimize=True,
                                   repeats=repeats)
        overlap = topk_overlap(Rf["docids"], Ru["docids"], k)
        out["workloads"][name] = {
            "calibration": gate_calibration(report["fusion_decisions"],
                                            mrt_f, mrt_u),
            "fused_stage": op.kind.startswith("fused"),
            "gate_decisions": [
                {"pattern": d["pattern"], "accepted": d["accepted"],
                 "fused_proxy_s": d["fused_proxy_s"],
                 "unfused_proxy_s": d["unfused_proxy_s"]}
                for d in report["fusion_decisions"]],
            "fused_mrt_ms": round(mrt_f, 2),
            "unfused_mrt_ms": round(mrt_u, 2),
            "fused_qps": round(1000.0 / mrt_f, 1),
            "unfused_qps": round(1000.0 / mrt_u, 1),
            "speedup": round(mrt_u / mrt_f, 2),
            "topk_overlap": round(float(overlap), 3),
        }
    out["compile_breakdown_ms"] = {p: round(ms, 2)
                                   for p, ms in breakdown.items()}
    return out


#: the seed's fused-gather regression: the fused path ran at 0.41x the
#: unfused speed, yet the static roofline proxy would have accepted it —
#: the motivating case for measured gating
SEED_FUSED_GATHER_SPEEDUP = 0.41


def _probe_calibration(d: dict) -> dict | None:
    """fit_peaks-shaped calibration record from one *probe-measured* gate
    decision (per-candidate HLO counts + probe wall-clock)."""
    if not (d.get("fused_measured_s") and d.get("unfused_measured_s")
            and d.get("fused_proxy_s") and d.get("unfused_proxy_s")
            and d.get("fused_flops") and d.get("unfused_flops")):
        return None
    predicted = d["fused_proxy_s"] / d["unfused_proxy_s"]
    measured = d["fused_measured_s"] / d["unfused_measured_s"]
    return {
        "pattern": d["pattern"], "accepted": d["accepted"],
        "predicted_ratio": round(predicted, 4),
        "measured_ratio": round(measured, 4),
        "measured_over_predicted": round(measured / predicted, 4),
        "unfused": {"flops": d["unfused_flops"], "bytes": d["unfused_bytes"],
                    "measured_s": d["unfused_measured_s"]},
        "fused": {"flops": d["fused_flops"], "bytes": d["fused_bytes"],
                  "measured_s": d["fused_measured_s"]},
    }


def bench_autotune(env, k: int = 10) -> dict:
    """Measurement-driven compiler (ISSUE 6): cold autotune — probe-measure
    both candidate lowerings per gate decision and persist the winners to an
    on-disk TuningProfile — vs warm profile-reuse compilation, which must
    replay every decision with ZERO gate-candidate compiles and ZERO probe
    measurements.  Also fits per-host roofline peaks from the probe
    calibration records, and reports whether measured gating would have
    rejected the seed's 0.41x fused-gather case (the static proxy accepted
    it)."""
    from repro.analysis.hlo_cost import fit_peaks
    from repro.core import BackendDescriptor, TuningProfile, compile_pipeline

    index = env["index"]
    caps = frozenset({"fat", "multi_model", "fused_topk", "fused_scoring"})
    CACHE.mkdir(parents=True, exist_ok=True)
    prof_path = CACHE / "tuning_profile.json"
    prof_path.unlink(missing_ok=True)          # a genuinely cold tune

    def mk_backend():
        desc = (BackendDescriptor.default(caps)
                .with_profile(TuningProfile(prof_path))
                .with_autotune(True, band=10.0))
        return JaxBackend(index, default_k=1000, query_chunk=8,
                          descriptor=desc)

    workloads = {
        "retrieve_topk": Retrieve("BM25") % k,
        "fat_scorer_topk": (Retrieve("BM25")
                            >> (Extract("QL") ** Extract("TF_IDF"))) % k,
        "mixed_k_linear": 0.5 * Retrieve("BM25", k=200)
                          + 0.5 * Retrieve("QL", k=1000),
    }
    out = {"k": k, "workloads": {}, "host": host_info(),
           "profile_path": str(prof_path)}
    phases = {}
    for phase in ("cold", "warm"):
        be = mk_backend()                      # fresh estimate cache + a
        totals = {"elapsed_s": 0.0}            # profile freshly re-read
        for name, pipe in workloads.items():
            report = {}
            t0 = time.perf_counter()
            compile_pipeline(pipe, be, report=report)
            elapsed = time.perf_counter() - t0
            totals["elapsed_s"] += elapsed
            w = out["workloads"].setdefault(name, {})
            w[f"{phase}_compile_s"] = round(elapsed, 4)
            w[f"{phase}_tuning"] = report["tuning"]
            if phase == "cold":
                w["decisions"] = [
                    {"pattern": d["pattern"], "accepted": d["accepted"],
                     "source": d["source"],
                     "predicted_ratio": (
                         None if not (d["fused_proxy_s"]
                                      and d["unfused_proxy_s"])
                         else round(d["fused_proxy_s"]
                                    / d["unfused_proxy_s"], 4)),
                     "measured_ratio": (
                         None if not (d.get("fused_measured_s")
                                      and d.get("unfused_measured_s"))
                         else round(d["fused_measured_s"]
                                    / d["unfused_measured_s"], 4))}
                    for d in report["fusion_decisions"]]
                w["calibration"] = next(
                    (c for c in map(_probe_calibration,
                                    report["fusion_decisions"]) if c), None)
            for key, v in report["tuning"].items():
                totals[key] = totals.get(key, 0) + v
        phases[phase] = totals
    out["cold_tune_s"] = round(phases["cold"]["elapsed_s"], 4)
    out["warm_compile_s"] = round(phases["warm"]["elapsed_s"], 4)
    out["warm_speedup"] = round(phases["cold"]["elapsed_s"]
                                / max(phases["warm"]["elapsed_s"], 1e-9), 1)
    out["warm_profile_reuse"] = {
        k_: phases["warm"][k_]
        for k_ in ("gate_estimates", "probe_measurements",
                   "profile_hits", "profile_misses")}
    cal_records = [w["calibration"] for w in out["workloads"].values()
                   if w.get("calibration")]
    out["calibration_fit"] = fit_peaks(cal_records)
    # persist the fit into the profile: the next descriptor attaching this
    # profile (with_profile auto_refit) re-prices its roofline peaks from
    # the measured trajectory instead of the hardware defaults
    prof = TuningProfile(prof_path)
    prof.note_calibration(out["calibration_fit"])
    prof.save()
    out["calibration_persisted"] = prof.info().get("calibrated", False)
    out["seed_fused_gather_case"] = {
        "seed_speedup": SEED_FUSED_GATHER_SPEEDUP,
        "measured_ratio": round(1.0 / SEED_FUSED_GATHER_SPEEDUP, 4),
        # the measured gate accepts only fused_measured < unfused_measured,
        # i.e. measured_ratio < 1 — a 2.4x-slower fused path cannot pass
        "autotune_would_reject": (1.0 / SEED_FUSED_GATHER_SPEEDUP) >= 1.0,
    }
    return out


def bench_dense(env, k: int = 10, k_in: int = 200, nprobe: int = 8,
                repeats: int = 3) -> dict:
    """Dense second stage (the ROADMAP's top open item): fused vs unfused
    ``retrieve >> dense_rerank % K`` (the cost-gated FusedDenseRerank
    lowering) and IVF vs brute-force dense candidate generation (the
    recall/MRT trade of the coarse quantiser)."""
    from repro.core import compile_pipeline

    index = env["index"]
    base = frozenset({"fat", "multi_model"})
    be_fused = JaxBackend(
        index, default_k=1000, query_chunk=8,
        descriptor=BackendDescriptor.default(
            base | {"fused_dense", "dense_topk"}))
    be_unfused = JaxBackend(index, default_k=1000, query_chunk=8,
                            dense=be_fused.dense,
                            descriptor=BackendDescriptor.default(base))
    topics = env["formulations"]["T"]
    Q = make_queries(np.asarray(topics.terms), np.asarray(topics.weights),
                     np.asarray(topics.qids))
    out = {"k": k, "k_in": k_in, "workloads": {}, "host": host_info()}

    # --- fused vs unfused dense rerank -----------------------------------
    pipe = (Retrieve("BM25", k=k_in) >> DenseRerank(alpha=0.3)) % k
    report = {}
    op = compile_pipeline(pipe, be_fused, report=report)
    mrt_f, Rf = _time_pipeline(pipe, Q, be_fused, optimize=True,
                               repeats=repeats)
    mrt_u, Ru = _time_pipeline(pipe, Q, be_unfused, optimize=True,
                               repeats=repeats)
    overlap = topk_overlap(Rf["docids"], Ru["docids"], k)
    out["workloads"]["dense_rerank_topk"] = {
        "calibration": gate_calibration(report["fusion_decisions"],
                                        mrt_f, mrt_u),
        "fused_stage": op.kind == "fused_dense_rerank",
        "gate_decisions": [
            {"pattern": d["pattern"], "accepted": d["accepted"],
             "fused_proxy_s": d["fused_proxy_s"],
             "unfused_proxy_s": d["unfused_proxy_s"]}
            for d in report["fusion_decisions"]],
        "fused_mrt_ms": round(mrt_f, 2),
        "unfused_mrt_ms": round(mrt_u, 2),
        "fused_qps": round(1000.0 / mrt_f, 1),
        "unfused_qps": round(1000.0 / mrt_u, 1),
        "speedup": round(mrt_u / mrt_f, 2),
        "topk_overlap": round(float(overlap), 3),
    }

    # --- IVF vs brute-force candidate generation -------------------------
    ivf = be_fused.ivf
    npb = min(nprobe, ivf.n_lists)
    brute_pipe = DenseRetrieve(k=k, nprobe=0)
    ivf_pipe = DenseRetrieve(k=k, nprobe=npb)
    mrt_b, Rb = _time_pipeline(brute_pipe, Q, be_fused, optimize=False,
                               repeats=repeats)
    mrt_i, Ri = _time_pipeline(ivf_pipe, Q, be_fused, optimize=False,
                               repeats=repeats)
    recall = topk_overlap(Ri["docids"], Rb["docids"], k)
    out["ivf"] = {
        "n_lists": ivf.n_lists, "nprobe": npb,
        "max_list_len": ivf.max_list_len,
        "brute_mrt_ms": round(mrt_b, 2),
        "ivf_mrt_ms": round(mrt_i, 2),
        "brute_qps": round(1000.0 / mrt_b, 1),
        "ivf_qps": round(1000.0 / mrt_i, 1),
        "speedup": round(mrt_b / mrt_i, 2),
        "recall_at_k": round(float(recall), 3),
    }

    # --- IVF-PQ compressed store: memory / QPS / recall ------------------
    out["dense_pq"] = bench_dense_pq(env, be_fused, Q, Rb, k=k, k_in=k_in,
                                     nprobe=npb, repeats=repeats)
    return out


def bench_dense_pq(env, be_flat, Q, R_exact, *, k: int, k_in: int,
                   nprobe: int, repeats: int = 3) -> dict:
    """Memory-scale dense retrieval (IVF-PQ): bytes/doc of the compressed
    scan store vs the flat float store, fused/unfused PQ QPS at matched
    ``nprobe`` against IVF-flat, recall@k at the working and full probe
    widths, and doc-axis sharded top-k scaling (1/2/4 shards, cross-shard
    merge checked bit-identical against the single-shard oracle)."""
    import jax.numpy as jnp
    from repro.core import compile_pipeline
    from repro.core.engine import ShardedQueryEngine, StageProgram
    from repro.index.dense import (dense_retrieve_exact, pq_store_bytes,
                                   shard_dense_index)

    index = env["index"]
    base = frozenset({"fat", "multi_model"})
    # the PQ backend drops the duplicated list-ordered float copy
    # (keep_flat=False): resident dense state = codes + codebooks +
    # centroids + the single doc-order float store used for re-scoring
    # m=16 subspaces + an 8x-k ADC shortlist hold full-probe recall@10
    # near-exact at this scale (m=8/refine=4 sits at ~0.54: the 40-deep
    # shortlist is too shallow for 20k docs) while the store still
    # compresses >10x — both CI floors pass with margin
    be_pq = JaxBackend(index, default_k=1000, query_chunk=8,
                       dense=be_flat.dense, ivf_keep_flat=False,
                       pq_m=16, pq_refine=8,
                       descriptor=BackendDescriptor.default(
                           base | {"fused_dense", "dense_topk", "pq_topk"}))
    pq = be_pq.ivfpq
    n_docs = int(index.n_docs)
    dense = be_flat.dense
    flat_bytes = int(dense.emb.size) * dense.emb.dtype.itemsize
    pq_bytes = pq_store_bytes(pq)

    # fused PQ (gated lowering) vs fused IVF-flat at matched nprobe and
    # matched retrieval depth k — the ANN candidate-generation shape.  A
    # deep k_in retrieve + cutoff would be asymmetric: flat fusion
    # collapses its top-k to the cutoff depth while PQ must keep the
    # refine*k_in shortlist for exactness, burying the ADC saving under
    # exact re-scoring work the flat side never does
    pq_pipe = DenseRetrieve(k=k, nprobe=nprobe, pq=True) % k
    flat_pipe = DenseRetrieve(k=k, nprobe=nprobe) % k
    report = {}
    op = compile_pipeline(pq_pipe, be_pq, report=report)
    mrt_pq_f, Rpf = _time_pipeline(pq_pipe, Q, be_pq, optimize=True,
                                   repeats=repeats)
    mrt_pq_u, Rpu = _time_pipeline(pq_pipe, Q, be_pq, optimize=False,
                                   repeats=repeats)
    mrt_flat_f, Rff = _time_pipeline(flat_pipe, Q, be_flat, optimize=True,
                                     repeats=repeats)
    # recall at full probe: every list scanned, so only the ADC shortlist
    # (exact-re-scored) bounds recall — the acceptance floor lives here
    full_pipe = DenseRetrieve(k=k, nprobe=pq.n_lists, pq=True)
    _, Rfull = _time_pipeline(full_pipe, Q, be_pq, optimize=False, repeats=1)

    # doc-axis sharded exact top-k: 1/2/4 contiguous shards through the
    # engine on the 2-D (query x doc-shard) mesh, host cross-shard merge
    eng = ShardedQueryEngine(mesh=make_query_mesh(doc_shards=1))
    qvecs = be_flat.embed_queries(Q)
    shard_rows, oracle = [], None
    for s in (1, 2, 4):
        progs = []
        for shard, off in shard_dense_index(dense, s):
            ks = min(k, int(shard.emb.shape[0]))
            fn = (lambda sh, o, kk: (lambda qv: (
                (lambda dv: (dv[0] + jnp.int32(o), dv[1]))(
                    dense_retrieve_exact(sh, qv, k=kk)))))(shard, off, ks)
            progs.append(StageProgram(key=("dense_shard", s, off), fn=fn))
        eng.run_doc_sharded(progs, None, qvecs, k=k)      # warm-up/compile
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            docs, vals = eng.run_doc_sharded(progs, None, qvecs, k=k)
            times.append(time.perf_counter() - t0)
        mrt = 1000.0 * min(times) / int(Q["qid"].shape[0])
        if s == 1:
            oracle = (docs, vals)
        shard_rows.append({
            "shards": s, "mrt_ms": round(mrt, 2),
            "qps": round(1000.0 / mrt, 1),
            "merge_matches_oracle": bool(
                np.array_equal(docs, oracle[0])
                and np.array_equal(vals, oracle[1])),
        })

    return {
        "n_docs": n_docs, "m": pq.m, "n_codes": pq.codebook.n_codes,
        "k": k, "nprobe": nprobe, "n_lists": pq.n_lists,
        "refine": be_pq.pq_refine,
        "flat_bytes_per_doc": round(flat_bytes / n_docs, 2),
        "pq_bytes_per_doc": round(pq_bytes / n_docs, 2),
        "memory_reduction_x": round(flat_bytes / pq_bytes, 1),
        "fused_stage": op.kind == "fused_dense_retrieve",
        "gate_decisions": [
            {"pattern": d["pattern"], "accepted": d["accepted"],
             "source": d.get("source"),
             "fused_proxy_s": d["fused_proxy_s"],
             "unfused_proxy_s": d["unfused_proxy_s"]}
            for d in report["fusion_decisions"]],
        "fused_mrt_ms": round(mrt_pq_f, 2),
        "unfused_mrt_ms": round(mrt_pq_u, 2),
        "fused_qps": round(1000.0 / mrt_pq_f, 1),
        "unfused_qps": round(1000.0 / mrt_pq_u, 1),
        "ivf_flat_fused_mrt_ms": round(mrt_flat_f, 2),
        "ivf_flat_fused_qps": round(1000.0 / mrt_flat_f, 1),
        "fused_vs_ivf_flat_speedup": round(mrt_flat_f / mrt_pq_f, 2),
        "fused_unfused_overlap": round(
            topk_overlap(Rpf["docids"], Rpu["docids"], k), 3),
        "recall_at_k": round(
            topk_overlap(Rpf["docids"], R_exact["docids"], k), 3),
        "ivf_flat_recall_at_k": round(
            topk_overlap(Rff["docids"], R_exact["docids"], k), 3),
        "recall_at_k_full_probe": round(
            topk_overlap(Rfull["docids"], R_exact["docids"], k), 3),
        "doc_shards": shard_rows,
    }


#: serving-profile bucket ladder: large steady-state chunks amortise
#: dispatch; three rungs bound recompilation at 3 variants per stage
ENGINE_BENCH_LADDER = (16, 64, 128)

ENGINE_WORKLOADS = {
    # multi-model retrieval at the paper's default depth (Table 3 config)
    "experiment_k1000": {
        "pipes": lambda: [Retrieve("BM25", k=1000), Retrieve("QL", k=1000),
                          Retrieve("TF_IDF", k=1000)],
        "optimize": False,
    },
    # the RQ1-optimised serving path: % 10 rewritten to PrunedRetrieve
    "serving_pruned_k10": {
        "pipes": lambda: [Retrieve("BM25") % 10, Retrieve("QL") % 10],
        "optimize": True,
    },
}


def bench_engine_scaling(env, device_counts=(1, 2, 4, 8), repeats: int = 5,
                         n_queries: int = 256) -> dict:
    """Queries/sec scaling of the sharded bucketed engine across local
    devices, against the single-device sequential path (the seed's chunked
    ``vmap_queries`` loop plus the planner's per-stage barriers), over
    experiment plans.  Also reports per-stage recompile counts, which the
    bucket ladder must bound.

    Device-parallel speedup saturates at min(host cores, devices) on the
    forced-host-platform simulation — the ``host_cpus`` field gives the
    context for the reported ratios.  Simulated devices must exist before
    jax initialises, so run through ``python -m benchmarks.engine_bench``
    (it sets ``XLA_FLAGS=--xla_force_host_platform_device_count`` first)."""
    import os

    index = env["index"]
    topics = env["formulations"]["T"]
    terms = np.asarray(topics.terms)
    reps = n_queries // terms.shape[0] + 1
    Q = make_queries(np.tile(terms, (reps, 1))[:n_queries],
                     np.tile(np.asarray(topics.weights), (reps, 1))[:n_queries])

    def time_plan(pipes, optimize, be, record):
        plan = ExperimentPlan(pipes, be, optimize=optimize)
        res = plan.execute(Q, ctx=Context(be), record=record)   # compile
        jax.block_until_ready(res)
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = plan.execute(Q, ctx=Context(be), record=record)
            jax.block_until_ready(res)
            times.append(time.perf_counter() - t0)
        return min(times)

    n_local = jax.local_device_count()
    be_seq = JaxBackend(index, default_k=1000, query_chunk=8, sharded=False)
    out = {"n_queries": n_queries, "simulated_devices": n_local,
           "host_cpus": os.cpu_count(),
           "bucket_ladder": list(ENGINE_BENCH_LADDER), "workloads": {}}
    for name, wl in ENGINE_WORKLOADS.items():
        pipes = wl["pipes"]()
        work = n_queries * len(pipes)
        # baseline: the seed's execution path verbatim — sequential chunked
        # vmap on device 0, block_until_ready at every stage boundary
        t_seq = time_plan(pipes, wl["optimize"], be_seq, record="cold")
        rows = []
        for nd in sorted({min(d, n_local) for d in device_counts}):
            eng = ShardedQueryEngine(make_query_mesh(max_devices=nd),
                                     ladder=ENGINE_BENCH_LADDER)
            be = JaxBackend(index, default_k=1000, query_chunk=8,
                            dense=be_seq.dense, engine=eng)
            t = time_plan(pipes, wl["optimize"], be, record=None)  # async
            rows.append({
                "devices": nd,
                "qps": round(work / t, 1),
                "speedup_vs_sequential": round(t_seq / t, 2),
                "max_recompiles_per_stage": eng.max_compiles_per_stage(),
                "recompiles_bounded": (eng.max_compiles_per_stage()
                                       <= len(eng.ladder)),
            })
        out["workloads"][name] = {
            "n_pipelines": len(pipes),
            "sequential_qps": round(work / t_seq, 1),
            "rows": rows,
        }
    return out


def clueweb_extrapolation(env, rq1, rq2) -> dict:
    """Documented extrapolation to ClueWeb09 scale: MRT scales with postings
    volume per query (measured throughput held fixed)."""
    scale = CLUEWEB_DOCS / env["index"].n_docs
    t_row = rq1[0]
    f_row = rq2[0]
    return {
        "scale_factor": round(scale, 1),
        "rq1_orig_mrt_ms_est": round(t_row["orig_mrt_ms"] * scale, 1),
        "rq1_opt_mrt_ms_est": round(t_row["opt_mrt_ms"] * scale ** 0.5, 1),
        "rq2_orig_mrt_ms_est": round(f_row["orig_mrt_ms"] * scale, 1),
        "rq2_opt_mrt_ms_est": round(f_row["opt_mrt_ms"] * scale, 1),
        "note": "pruned path scales ~sqrt (block budget fixed, deeper lists "
                "skipped); exhaustive paths scale ~linearly with postings",
    }
