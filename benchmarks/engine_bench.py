"""Device-scaling benchmark for the sharded query execution engine.

The ``--xla_force_host_platform_device_count`` flag must reach XLA before
jax initialises, so this module is a standalone entrypoint that sets the
flag and only then imports the benchmark stack; ``benchmarks/run.py``
launches it as a subprocess.

    PYTHONPATH=src python -m benchmarks.engine_bench --devices 8 \
        --scale small --out experiments/bench/engine_scaling.json
"""
from __future__ import annotations

import argparse
import json
import os
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8,
                    help="simulated host devices (data-parallel width)")
    ap.add_argument("--scale", default="small", choices=["robust", "small"])
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--n-queries", type=int, default=256)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={args.devices}"
    ).strip()

    from benchmarks import ir_bench         # imports jax with the flag set

    if args.scale == "robust":
        env = ir_bench.build_robust_env(n_topics=50)
    else:
        env = ir_bench.build_robust_env(n_docs=20000, n_topics=32,
                                        vocab=40000)
    rec = ir_bench.bench_engine_scaling(
        env, device_counts=(1, 2, 4, args.devices), repeats=args.repeats,
        n_queries=args.n_queries)

    print("\n== Engine: device-sharded query throughput ==")
    print(f"simulated devices: {rec['simulated_devices']}, "
          f"host cpus: {rec['host_cpus']} "
          f"(device speedup saturates at host cores)")
    for name, wl in rec["workloads"].items():
        print(f"[{name}] sequential (1 device, chunked loop + stage "
              f"barriers): {wl['sequential_qps']} q/s")
        for row in wl["rows"]:
            print(f"  {row}")
    if args.out:
        out = Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
