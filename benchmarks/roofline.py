"""Roofline table assembly from the dry-run artifacts (§Roofline).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
per (arch × shape × mesh): the three roofline terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS usefulness, and a one-line prescription.
"""
from __future__ import annotations

import json
from pathlib import Path

PRESCRIPTION = {
    "compute": "compute-bound: raise MXU utilisation (bigger tiles, bf16 "
               "matmuls, fewer small einsums)",
    "memory": "HBM-bound: cut activation materialisation (flash attention, "
              "bf16 intermediates, fewer remat round-trips)",
    "collective": "ICI-bound: reshard to cut gathers (seq-parallel residual, "
                  "overlap collectives with compute, int8 cross-pod grads)",
}


def load_records(dryrun_dir: str = "experiments/dryrun") -> list[dict]:
    recs = []
    for f in sorted(Path(dryrun_dir).glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def roofline_rows(recs: list[dict], mesh: str | None = "16x16",
                  include_variants: bool = False) -> list[dict]:
    rows = []
    for r in recs:
        if "arch" not in r:
            continue  # auxiliary records (e.g. ir_pipeline__*) — not cells
        if mesh and r["mesh"] != mesh:
            continue
        if not include_variants and r.get("overrides"):
            continue  # hillclimb variants live in §Perf, not the baseline table
        terms = {"compute": r["t_compute"], "memory": r["t_memory"],
                 "collective": r["t_collective"]}
        dom = max(terms, key=terms.get)
        total = max(sum(terms.values()), 1e-30)
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "t_compute_s": f"{r['t_compute']:.3e}",
            "t_memory_s": f"{r['t_memory']:.3e}",
            "t_collective_s": f"{r['t_collective']:.3e}",
            "bottleneck": dom,
            "roofline_fraction": round(terms["compute"] / max(terms.values()), 4),
            "useful_flops_ratio": round(r.get("useful_flops_ratio", 0.0), 4),
            "fix": PRESCRIPTION[dom],
        })
    rows.sort(key=lambda x: (x["arch"], x["shape"]))
    return rows


def format_csv(rows: list[dict], cols: list[str] | None = None) -> str:
    if not rows:
        return "(no dry-run records found — run repro.launch.dryrun first)"
    cols = cols or [c for c in rows[0] if c != "fix"]
    out = [",".join(cols)]
    for r in rows:
        out.append(",".join(str(r[c]) for c in cols))
    return "\n".join(out)
