"""Kernel micro-benchmarks: fused vs per-model scoring, streaming vs full
top-k — CPU wall-clock for the jnp paths + interpret-mode validation of the
Pallas kernels (the TPU numbers come from the dry-run roofline)."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.index import scoring

STATS = {"n_docs": 528155.0, "avg_doclen": 300.0, "total_terms": 1.58e8}
MODELS = ("BM25", "QL", "TF_IDF")


def _time(fn, *args, repeats=5):
    fn(*args)  # warm-up/compile
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return 1e6 * min(times)


def bench_fused_scoring(n: int = 1 << 18, pool: int = 1 << 22) -> list[dict]:
    """The fat-postings contrast INCLUDING the postings gather — the shared
    HBM read is where the single-pass win lives (RQ2)."""
    rng = np.random.default_rng(0)
    # big postings pool (simulates the inverted file resident in HBM)
    pool_tf = jnp.asarray(rng.integers(1, 30, pool), jnp.int32)
    pool_dl = jnp.asarray(rng.integers(20, 2000, pool), jnp.int32)
    pool_df = jnp.asarray(rng.integers(1, 50000, pool), jnp.int32)
    pool_cf = jnp.asarray(rng.integers(1, 500000, pool), jnp.int32)
    idx = jnp.asarray(rng.integers(0, pool, n), jnp.int32)

    @jax.jit
    def fused(idx):
        tf, dl = pool_tf[idx], pool_dl[idx]
        df, cf = pool_df[idx], pool_cf[idx]
        return scoring.score_all(list(MODELS), tf, dl, df, cf, STATS)

    @jax.jit
    def per_model(idx):
        outs = []
        for m in MODELS:            # one gather PER feature pass
            tf, dl = pool_tf[idx], pool_dl[idx]
            df, cf = pool_df[idx], pool_cf[idx]
            outs.append(scoring.WEIGHTING_MODELS[m](tf, dl, df, cf, STATS))
        return outs

    t_fused = _time(fused, idx)
    t_sep = _time(per_model, idx)
    return [{"name": "fused_scoring_gather_256k", "us_per_call": round(t_fused, 1),
             "derived": "3models_one_gather"},
            {"name": "per_model_scoring_256k", "us_per_call": round(t_sep, 1),
             "derived": f"fused_speedup={t_sep/max(t_fused,1e-9):.2f}x"}]


def bench_topk(n: int = 1 << 20, k: int = 10) -> list[dict]:
    rng = np.random.default_rng(1)
    scores = jnp.asarray(rng.standard_normal(n).astype(np.float32))

    topk = jax.jit(lambda s: jax.lax.top_k(s, k))
    sort_full = jax.jit(lambda s: jnp.sort(s)[-k:])
    t_topk = _time(topk, scores)
    t_sort = _time(sort_full, scores)
    return [{"name": f"lax_topk_{k}_of_1M", "us_per_call": round(t_topk, 1),
             "derived": ""},
            {"name": f"full_sort_1M", "us_per_call": round(t_sort, 1),
             "derived": f"topk_speedup={t_sort/max(t_topk,1e-9):.2f}x"}]
