.PHONY: test dev-deps bench

test:
	sh scripts/ci.sh

dev-deps:
	python -m pip install -r requirements-dev.txt

bench:
	PYTHONPATH=src python -m benchmarks.run --scale small
