.PHONY: test lint dev-deps bench

# lint + tier-1 pytest — the same entrypoint GitHub CI runs
test:
	sh scripts/ci.sh

lint:
	sh scripts/lint.sh

dev-deps:
	python -m pip install -r requirements-dev.txt

bench:
	PYTHONPATH=src python -m benchmarks.run --scale small
