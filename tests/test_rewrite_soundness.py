"""cutoff_into_then soundness: a rank cutoff may only attach to an
R-producing stage.  Pure Q -> Q rewrites are hopped over; R-reading query
rewrites (RM3) block the push entirely."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import (BackendDescriptor, JaxBackend, Retrieve, RM3Expand,
                        SDMRewrite, StemRewrite, compile_pipeline, raise_ir)
from repro.core.stages import PrunedRetrieve
from repro.core.transformer import Cutoff, Then


def optimize(pipe, backend, trace=None):
    return raise_ir(compile_pipeline(pipe, backend, trace=trace))


def _no_prune_backend(env):
    return JaxBackend(env["index"], default_k=60, dense=env["backend"].dense,
                      descriptor=BackendDescriptor.default(
                          frozenset({"fat", "multi_model"})))


def _kinds(node):
    if isinstance(node, Then):
        return [type(c).__name__ for c in node.children]
    return [type(node).__name__]


# ---------------------------------------------------------------------------
# structure: where the cutoff lands
# ---------------------------------------------------------------------------

def test_cutoff_lands_on_r_producer_not_query_rewrite(small_ir):
    """(Retrieve >> SDM) % K: the cutoff hops over the trailing Q -> Q
    stage onto Retrieve, where the RQ1 pushdown can fire."""
    be = small_ir["backend"]
    opt = optimize((Retrieve("BM25", k=30) >> SDMRewrite()) % 10, be)
    assert isinstance(opt, Then)
    assert isinstance(opt.children[0], PrunedRetrieve)
    assert opt.children[0].params["k"] == 10
    assert type(opt.children[-1]).__name__ == "SDMRewrite"
    # no Cutoff survives anywhere, and none wraps a Q -> Q stage
    def walk(n):
        assert not (isinstance(n, Cutoff) and n.children[0].out_kind == "Q")
        for c in n.children:
            walk(c)
    walk(opt)


def test_cutoff_hops_multiple_trailing_rewrites(small_ir):
    be = _no_prune_backend(small_ir)
    pipe = (Retrieve("BM25", k=30) >> SDMRewrite() >> StemRewrite()) % 10
    opt = optimize(pipe, be)
    assert isinstance(opt, Then)
    assert isinstance(opt.children[0], Cutoff)        # no pruning capability
    assert isinstance(opt.children[0].children[0], Retrieve)
    assert _kinds(opt)[1:] == ["SDMRewrite", "StemRewrite"]


def test_cutoff_blocked_by_r_reading_rewrite(small_ir):
    """RM3 reads fb_docs from R, so the cutoff must stay outside the Then —
    truncating R before RM3 would change the expansion."""
    be = small_ir["backend"]
    pipe = (Retrieve("BM25", k=30) >> RM3Expand(fb_docs=5)) % 10
    trace = []
    opt = optimize(pipe, be, trace=trace)
    assert isinstance(opt, Cutoff)
    assert not any(name == "cutoff_into_then" for name, *_ in trace)


def test_cutoff_still_pushes_past_rm3_onto_final_retrieve(small_ir):
    """RM3 in the middle is untouched: the cutoff attaches to the final
    R-producing Retrieve as before."""
    be = small_ir["backend"]
    pipe = (Retrieve("BM25", k=30) >> RM3Expand(fb_docs=5)
            >> Retrieve("BM25", k=30)) % 10
    opt = optimize(pipe, be)
    assert isinstance(opt, Then)
    assert isinstance(opt.children[-1], PrunedRetrieve)
    assert type(opt.children[1]).__name__ == "RM3Expand"


# ---------------------------------------------------------------------------
# semantics: optimised == unoptimised (exact on a no-pruning backend)
# ---------------------------------------------------------------------------

def _check_rankings_preserved(env, k, trailing):
    be = _no_prune_backend(env)
    pipe = Retrieve("BM25", k=30)
    for t in trailing:
        pipe = pipe >> t
    pipe = pipe % k
    Ro = pipe.transform(env["Q"], backend=be, optimize=True)
    Ru = pipe.transform(env["Q"], backend=be, optimize=False)
    np.testing.assert_array_equal(np.asarray(Ro["docids"]),
                                  np.asarray(Ru["docids"]))
    np.testing.assert_allclose(np.asarray(Ro["scores"]),
                               np.asarray(Ru["scores"]), rtol=1e-6)


TRAILING = {
    "sdm": SDMRewrite(),
    "stem": StemRewrite(),
    "rm3": RM3Expand(fb_docs=5, fb_terms=5),
}

if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=1, max_value=25),
           st.lists(st.sampled_from(sorted(TRAILING)), max_size=3))
    def test_cutoff_rewrite_preserves_rankings(small_ir, k, names):
        _check_rankings_preserved(small_ir, k,
                                  [TRAILING[n] for n in names])


# deterministic fallbacks so coverage survives without hypothesis
@pytest.mark.parametrize("k,names", [
    (10, ["sdm"]), (5, ["stem", "sdm"]), (10, ["rm3"]),
    (7, ["sdm", "rm3"]), (12, []),
])
def test_cutoff_rewrite_preserves_rankings_fixed(small_ir, k, names):
    _check_rankings_preserved(small_ir, k, [TRAILING[n] for n in names])
