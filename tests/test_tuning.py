"""GridSearch + CrossValidate (paper §3.4 Experiment variants)."""
import numpy as np

from repro.core import Extract, LTRRerank, Retrieve
from repro.core.tuning import CrossValidate, GridSearch, kfold_splits


def test_grid_search_shares_prefix_cache(small_ir):
    env = small_ir
    calls = {"n": 0}

    def counting(Q, R):
        calls["n"] += 1
        return Q, R

    from repro.core.transformer import Generic
    probe = Generic(fn=counting)
    base = Retrieve("BM25", k=30) >> probe

    def build(alpha):
        return alpha * base + (1 - alpha) * Retrieve("QL", k=30)

    res = GridSearch(build, {"alpha": [0.2, 0.5, 0.8]},
                     env["Q"], env["topics"].qrels, metric="map",
                     backend=env["backend"], optimize=False)
    assert len(res["table"]) == 3
    assert res["best_params"]["alpha"] in (0.2, 0.5, 0.8)
    assert 0 < res["best_score"] <= 1.0
    assert calls["n"] == 1          # shared prefix ran ONCE across the grid


def test_kfold_splits_partition():
    qids = np.arange(10)
    seen = []
    for train, test in kfold_splits(qids, 5, seed=1):
        assert set(train) | set(test) == set(range(10))
        assert not (set(train) & set(test))
        seen.extend(test.tolist())
    assert sorted(seen) == list(range(10))


def test_cross_validate_ltr(small_ir):
    env = small_ir

    def build():
        return (Retrieve("BM25", k=20) >> (Extract("QL") ** Extract("TF_IDF"))
                >> LTRRerank(n_features=2, epochs=5))

    res = CrossValidate(build, env["Q"], env["topics"].qrels, k=2,
                        metrics=["map"], backend=env["backend"])
    assert len(res["folds"]) == 2
    assert 0 <= res["mean"]["map"] <= 1.0
