"""Typed IR + pass-manager compiler: round-trip identity, optimisation
soundness on random pipelines, cost-gated kernel lowering (both gate
branches), schema validation, and the _clone params regression."""
import random

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import (BackendDescriptor, Extract, FatRetrieve,
                        FusedFatRetrieve, FusedTopKRetrieve, JaxBackend,
                        LTRRerank, Retrieve, RM3Expand, SchemaError,
                        SDMRewrite, StemRewrite, compile_pipeline, lower,
                        raise_ir)
from repro.core.compiler import Context
from repro.core.plan import ExperimentPlan
from repro.core.transformer import Cutoff, Generic, Then


def optimize_pipeline(pipe, backend):
    return raise_ir(compile_pipeline(pipe, backend))


def _fused_backend(env, default_k=60):
    """No dynamic pruning (keeps semantics exact), kernel lowerings on."""
    return JaxBackend(env["index"], default_k=default_k,
                      dense=env["backend"].dense,
                      descriptor=BackendDescriptor.default(
                          frozenset({"fat", "fused_topk",
                                     "fused_scoring"})))


# ---------------------------------------------------------------------------
# raise_ir must hand back nodes owning their params dicts
# ---------------------------------------------------------------------------

def test_raised_rebuilt_nodes_own_their_params_dicts():
    """When ``raise_ir`` must rebuild a combinator (its params diverged
    from the ref's), the rebuilt node owns a fresh params dict: mutating
    it must never corrupt the source pipeline or the IR op — the invariant
    the old rewriter's ``_clone`` guarded.  (``raise_ir(lower(t))`` with
    untouched params returns ``t`` itself by design.)"""
    pipe = Retrieve("BM25", k=10) % 5
    op = lower(pipe).with_params(k=3)       # diverged: forces a rebuild
    raised = raise_ir(op)
    assert raised is not pipe and raised.params["k"] == 3
    raised.params["k"] = 999
    assert pipe.params["k"] == 5
    assert op.params["k"] == 3
    assert raise_ir(lower(pipe)) is pipe    # identity fast path intact


# ---------------------------------------------------------------------------
# lower -> raise round trip preserves key()
# ---------------------------------------------------------------------------

def _roundtrip_pipelines():
    probe = Generic(fn=lambda Q, R: (Q, R))
    return [
        Retrieve("BM25", k=20),
        Retrieve("BM25", k=30) % 10,
        (Retrieve("BM25", k=30) >> SDMRewrite() >> StemRewrite()) % 10,
        0.5 * Retrieve("BM25", k=20) + 2.0 * Retrieve("QL", k=20),
        Retrieve("BM25", k=20) >> (Extract("QL") ** Extract("TF_IDF"))
        >> LTRRerank(n_features=3),
        Retrieve("BM25", k=15) | Retrieve("QL", k=15),
        Retrieve("BM25", k=15) ^ Retrieve("QL", k=15),
        Retrieve("BM25", k=20) >> RM3Expand(fb_docs=5) >> probe,
    ]


@pytest.mark.parametrize("i", range(8))
def test_lower_raise_preserves_key(i):
    pipe = _roundtrip_pipelines()[i]
    op = lower(pipe)
    assert op.key() == pipe.key()
    raised = raise_ir(op)
    assert raised is pipe                     # untouched IR raises to itself
    assert raised.key() == pipe.key()


def test_op_key_tracks_stateful_descendant_version():
    """An op whose SUBTREE contains a stateful stage must never cache its
    key: fit() bumps the stage version, and a stale ancestor key would serve
    pre-training memo entries."""
    ltr = LTRRerank(n_features=2)
    pipe = Retrieve("BM25", k=10) >> ltr
    op = lower(pipe)
    k1 = op.key()
    assert k1 == pipe.key()
    ltr.version += 1                      # what _fit_local does after fit
    assert op.key() != k1
    assert op.key() == pipe.key()
    # fully stateless subtrees still cache (and stay correct)
    stateless = lower(Retrieve("BM25", k=10) % 5)
    assert stateless.key() == stateless.key()


# ---------------------------------------------------------------------------
# random pipelines: optimisation on == off (rankings preserved)
# ---------------------------------------------------------------------------

_MODELS = ["BM25", "QL", "TF_IDF"]


def _random_pipeline(rng: random.Random):
    k_in = rng.choice([20, 30])
    p = Retrieve(rng.choice(_MODELS), k=k_in)
    if rng.random() < 0.4:
        p = p >> SDMRewrite()
    if rng.random() < 0.3:
        p = p >> StemRewrite()
    r = rng.random()
    if r < 0.25:
        p = p >> (Extract("QL") ** Extract("DPH"))
    elif r < 0.45:
        q = Retrieve(rng.choice(_MODELS), k=k_in)
        p = rng.uniform(0.2, 2.0) * p + rng.uniform(0.2, 2.0) * q
    elif r < 0.6:
        p = rng.uniform(0.5, 3.0) * p
    if rng.random() < 0.7:
        p = p % rng.choice([5, 10])
    return p


def _check_optimized_preserves_rankings(env, seed):
    be = _fused_backend(env)
    pipe = _random_pipeline(random.Random(seed))
    Ro = pipe.transform(env["Q"], backend=be, optimize=True)
    Ru = pipe.transform(env["Q"], backend=be, optimize=False)
    np.testing.assert_array_equal(np.asarray(Ro["docids"]),
                                  np.asarray(Ru["docids"]))
    np.testing.assert_allclose(np.asarray(Ro["scores"]),
                               np.asarray(Ru["scores"]), rtol=1e-4,
                               atol=1e-5)
    if "features" in Ro and "features" in Ru:
        np.testing.assert_allclose(np.asarray(Ro["features"]),
                                   np.asarray(Ru["features"]), atol=1e-3)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=0, max_value=10**6))
    def test_random_pipeline_optimization_sound(small_ir, seed):
        _check_optimized_preserves_rankings(small_ir, seed)


# deterministic fallbacks so coverage survives without hypothesis
@pytest.mark.parametrize("seed", [0, 1, 2, 3, 5, 8, 13, 21])
def test_random_pipeline_optimization_sound_fixed(small_ir, seed):
    _check_optimized_preserves_rankings(small_ir, seed)


# ---------------------------------------------------------------------------
# cost-gated kernel lowering: both gate branches
# ---------------------------------------------------------------------------

def test_cost_gate_fuses_and_falls_back(small_ir):
    be = _fused_backend(small_ir, default_k=200)

    # deep retrieve + shallow cutoff: fused strictly cheaper -> lowered
    rep1 = {}
    op1 = compile_pipeline(Retrieve("BM25", k=200) % 10, be, report=rep1)
    assert op1.kind == "fused_topk_retrieve"
    assert isinstance(raise_ir(op1), FusedTopKRetrieve)

    # cutoff at the retrieve depth: nothing to save, the estimate ties and
    # the gate keeps the unfused interpreter path
    rep2 = {}
    op2 = compile_pipeline(Retrieve("BM25", k=10) % 10, be, report=rep2)
    assert op2.kind == "cutoff"
    assert isinstance(raise_ir(op2), Cutoff)

    decided = [d["accepted"] for d in
               rep1["fusion_decisions"] + rep2["fusion_decisions"]]
    assert True in decided and False in decided    # both branches exercised

    # and both compiled forms agree with the unoptimised semantics
    for pipe in (Retrieve("BM25", k=200) % 10, Retrieve("BM25", k=10) % 10):
        Ro = pipe.transform(small_ir["Q"], backend=be, optimize=True)
        Ru = pipe.transform(small_ir["Q"], backend=be, optimize=False)
        np.testing.assert_array_equal(np.asarray(Ro["docids"]),
                                      np.asarray(Ru["docids"]))


def test_fused_topk_lands_after_cutoff_hop(small_ir):
    """(Retrieve >> SDM) % K on a fused-capable backend: the cutoff hops the
    Q -> Q stage, then lowers onto the kernel path."""
    be = _fused_backend(small_ir, default_k=200)
    opt = optimize_pipeline((Retrieve("BM25", k=200) >> SDMRewrite()) % 10, be)
    assert isinstance(opt, Then)
    assert isinstance(opt.children[0], FusedTopKRetrieve)
    assert opt.children[0].params["k"] == 10


def test_fused_fat_retrieve_matches_fat_retrieve(small_ir):
    """The fused_scoring-kernel fat stage is feature/rank-equivalent to
    FatRetrieve at the same depth."""
    env = small_ir
    be = _fused_backend(env)
    fat = FatRetrieve(model="BM25", features=("QL", "TF_IDF"), k=15)
    fus = FusedFatRetrieve(model="BM25", features=("QL", "TF_IDF"), k=15)
    Ra = fat.transform(env["Q"], backend=be, optimize=False)
    Rb = fus.transform(env["Q"], backend=be, optimize=False)
    np.testing.assert_array_equal(np.asarray(Ra["docids"]),
                                  np.asarray(Rb["docids"]))
    np.testing.assert_allclose(np.asarray(Ra["scores"]),
                               np.asarray(Rb["scores"]), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(Ra["features"]),
                               np.asarray(Rb["features"]), atol=1e-3)


# ---------------------------------------------------------------------------
# schema validation + cross-pipeline CSE + explain
# ---------------------------------------------------------------------------

def test_cutoff_over_pure_query_rewrite_is_schema_error(small_ir):
    with pytest.raises(SchemaError):
        optimize_pipeline(SDMRewrite() % 5, small_ir["backend"])


def test_plan_cse_shares_prefix_op_instances(small_ir):
    """The planner's shared CSE table interns separately-built equal
    prefixes to ONE op instance — the trie keys on literally shared ops."""
    from repro.core import DenseRerank
    env = small_ir
    p1 = Retrieve("BM25", k=20) >> DenseRerank(alpha=0.5)
    p2 = Retrieve("BM25", k=20) >> DenseRerank(alpha=0.7)
    plan = ExperimentPlan([p1, p2], env["backend"], optimize=True)
    assert plan.chains[0][0] is plan.chains[1][0]
    ctx = Context(env["backend"])
    plan.execute(env["Q"], ctx=ctx)
    assert ctx.exec_counts[plan.chains[0][0].key()] == 1


def test_explain_renders_passes_and_schemas(small_ir):
    be = _fused_backend(small_ir, default_k=200)
    text = (Retrieve("BM25") % 10).explain(be)
    assert "lowered IR" in text
    assert "after fusion" in text
    assert "[R, k=10]" in text
    assert "fusion gate" in text
