"""Evaluation measures vs hand-computed values (trec_eval semantics)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import measures as M


def _R(docids):
    d = np.asarray(docids, np.int32)
    return {"qid": jnp.arange(d.shape[0], dtype=jnp.int32),
            "docids": jnp.asarray(d),
            "scores": jnp.asarray(-np.arange(d.shape[1], dtype=np.float32))}


def test_map_hand_computed():
    # q0: rel docs {1, 3}; ranking [1, 2, 3] -> AP = (1/1 + 2/3)/2
    R = _R([[1, 2, 3]])
    qrels = {0: {1: 1, 3: 1}}
    out = M.compute_measures(R, qrels, ["map"])
    assert abs(out["map"] - (1.0 + 2 / 3) / 2) < 1e-6


def test_precision_recall_rr():
    R = _R([[9, 1, 2, 7]])
    qrels = {0: {1: 1, 7: 2, 55: 1}}
    out = M.compute_measures(R, qrels, ["P_2", "P_4", "recall_4",
                                        "recip_rank", "num_rel_ret"])
    assert abs(out["P_2"] - 0.5) < 1e-6
    assert abs(out["P_4"] - 0.5) < 1e-6
    assert abs(out["recall_4"] - 2 / 3) < 1e-6
    assert abs(out["recip_rank"] - 0.5) < 1e-6
    assert out["num_rel_ret"] == 2.0


def test_ndcg_hand_computed():
    # graded: ranking grades [2, 0, 1]; idcg over [2, 1, 0]
    R = _R([[5, 6, 7]])
    qrels = {0: {5: 2, 7: 1}}
    out = M.compute_measures(R, qrels, ["ndcg_cut_3"])
    dcg = (2 ** 2 - 1) / np.log2(2) + 0 + (2 ** 1 - 1) / np.log2(4)
    idcg = (2 ** 2 - 1) / np.log2(2) + (2 ** 1 - 1) / np.log2(3)
    assert abs(out["ndcg_cut_3"] - dcg / idcg) < 1e-6


def test_perfect_and_empty_rankings():
    R = _R([[1, 2], [8, 9]])
    qrels = {0: {1: 1, 2: 1}, 1: {3: 1}}
    out = M.compute_measures(R, qrels, ["map", "ndcg_cut_2"])
    assert abs(out["map"] - 0.5) < 1e-6        # q0 perfect, q1 zero
    assert abs(out["ndcg_cut_2"] - 0.5) < 1e-6


def test_padding_ignored():
    R = _R([[1, -1, -1]])
    qrels = {0: {1: 1}}
    out = M.compute_measures(R, qrels, ["map", "P_3"])
    assert abs(out["map"] - 1.0) < 1e-6
    assert abs(out["P_3"] - 1 / 3) < 1e-6
