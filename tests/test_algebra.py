"""Operator algebra semantics (paper Tables 1-2) + rewrite preservation.

Property tests (hypothesis) assert the system invariants:
  * rewriting preserves result semantics (the paper's core equivalence claim)
  * cutoff/scale/linear laws
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:      # property tests skip; fallbacks below run
    HAVE_HYPOTHESIS = False

from repro.core import (Extract, FatRetrieve, MultiRetrieve, PrunedRetrieve,
                        Retrieve, compile_pipeline, raise_ir)
from repro.core.transformer import Cutoff, Linear, Then


def optimize(pipe, backend):
    """Compile through the single optimization entry point; raise back to
    a Transformer tree for the structural assertions below."""
    return raise_ir(compile_pipeline(pipe, backend))


def run(p, env, optimize=False):
    return p.transform(env["Q"], backend=env["backend"], optimize=optimize)


def docsets(R, k=None):
    d = np.asarray(R["docids"])
    if k:
        d = d[:, :k]
    return [set(int(x) for x in row if x >= 0) for row in d]


# ---------------------------------------------------------------------------
# operator semantics
# ---------------------------------------------------------------------------

def test_cutoff_truncates_sorted(small_ir):
    R = run(Retrieve("BM25", k=30) % 10, small_ir)
    s = np.asarray(R["scores"])
    assert s.shape[1] == 10
    assert (np.diff(s, axis=1) <= 1e-6).all()


def test_scale_scales_scores_only(small_ir):
    R1 = run(Retrieve("BM25", k=20), small_ir)
    R2 = run(2.5 * Retrieve("BM25", k=20), small_ir)
    assert (np.asarray(R1["docids"]) == np.asarray(R2["docids"])).all()
    np.testing.assert_allclose(np.asarray(R2["scores"]),
                               2.5 * np.asarray(R1["scores"]), rtol=1e-5)


def test_linear_is_combsum(small_ir):
    """+ must equal per-doc weighted score sums over the union."""
    a, b = Retrieve("BM25", k=25), Retrieve("QL", k=25)
    Ra, Rb, Rsum = run(a, small_ir), run(b, small_ir), \
        run(0.5 * a + 2.0 * b, small_ir, optimize=False)
    for q in range(len(Rsum["qid"])):
        expect = {}
        for d, s in zip(np.asarray(Ra["docids"])[q], np.asarray(Ra["scores"])[q]):
            if d >= 0:
                expect[int(d)] = expect.get(int(d), 0) + 0.5 * float(s)
        for d, s in zip(np.asarray(Rb["docids"])[q], np.asarray(Rb["scores"])[q]):
            if d >= 0:
                expect[int(d)] = expect.get(int(d), 0) + 2.0 * float(s)
        got = {int(d): float(s) for d, s in
               zip(np.asarray(Rsum["docids"])[q], np.asarray(Rsum["scores"])[q])
               if d >= 0}
        top = sorted(expect.items(), key=lambda kv: -kv[1])[:len(got)]
        for d, s in top:
            assert d in got
            np.testing.assert_allclose(got[d], s, rtol=1e-4, atol=1e-5)


def test_union_intersect(small_ir):
    a, b = Retrieve("BM25", k=15), Retrieve("QL", k=15)
    Ra, Rb = run(a, small_ir), run(b, small_ir)
    Ru = run(a | b, small_ir)
    Ri = run(a & b, small_ir)
    for q in range(len(Ru["qid"])):
        sa, sb = docsets(Ra)[q], docsets(Rb)[q]
        assert docsets(Ru)[q] == sa | sb
        assert docsets(Ri)[q] == sa & sb


def test_concat_appends_below(small_ir):
    a, b = Retrieve("BM25", k=10), Retrieve("QL", k=20)
    Rc = run(a ^ b, small_ir)
    Ra = run(a, small_ir)
    d = np.asarray(Rc["docids"])
    s = np.asarray(Rc["scores"])
    da = np.asarray(Ra["docids"])
    for q in range(d.shape[0]):
        # R1 docs first, in order, with original scores on top
        assert (d[q, :10] == da[q]).all()
        # appended part strictly below R1's minimum
        valid = np.isfinite(s[q, 10:])
        if valid.any():
            assert s[q, 10:][valid].max() < s[q, :10].min()
        # no duplicates
        live = d[q][d[q] >= 0]
        assert len(live) == len(set(live.tolist()))


def test_feature_union_columns(small_ir):
    p = Retrieve("BM25", k=15) >> (Extract("QL") ** Extract("TF_IDF") **
                                   Extract("DPH"))
    R = run(p, small_ir)
    assert R["features"].shape == (len(R["qid"]), 15, 3)
    assert np.isfinite(np.asarray(R["features"])).all()


# ---------------------------------------------------------------------------
# rewrite rules preserve semantics
# ---------------------------------------------------------------------------

def test_cutoff_pushdown_structure(small_ir):
    opt = optimize(Retrieve("BM25") % 10, small_ir["backend"])
    assert isinstance(opt, PrunedRetrieve)
    assert opt.params["k"] == 10


def test_cutoff_pushdown_preserves_topk(small_ir):
    base = run(Retrieve("BM25") % 10, small_ir, optimize=False)
    opt = run(Retrieve("BM25") % 10, small_ir, optimize=True)
    # approximate block-max pruning: require ≥90% overlap, exact scores on hits
    for sa, sb in zip(docsets(base), docsets(opt)):
        assert len(sa & sb) >= 9


def test_fat_fusion_exact(small_ir):
    pipe = Retrieve("BM25", k=20) >> (Extract("QL") ** Extract("TF_IDF"))
    opt = optimize(pipe, small_ir["backend"])
    assert isinstance(opt, FatRetrieve)
    Ra, Rb = run(pipe, small_ir, optimize=False), run(opt, small_ir, optimize=False)
    assert (np.asarray(Ra["docids"]) == np.asarray(Rb["docids"])).all()
    np.testing.assert_allclose(np.asarray(Ra["features"]),
                               np.asarray(Rb["features"]), atol=1e-4)


def test_linear_fusion_exact(small_ir):
    pipe = 0.6 * Retrieve("BM25", k=20) + 0.4 * Retrieve("DPH", k=20)
    opt = optimize(pipe, small_ir["backend"])
    assert isinstance(opt, MultiRetrieve)
    Ra = run(pipe, small_ir, optimize=False)
    Rb = run(opt, small_ir, optimize=False)
    # same union-top-k up to tie ordering: compare score-aligned doc sets
    for q in range(len(Ra["qid"])):
        sa = docsets(Ra, 10)[q]
        sb = docsets(Rb, 10)[q]
        assert len(sa & sb) >= 9


def _check_rewrite_laws(env, k1, k2, alpha):
    be = env["backend"]
    # cutoff merge law
    p = (Retrieve("BM25", k=30) % k1) % k2
    opt = optimize(p, be)
    ks = min(k1, k2)
    R = run(opt, env, optimize=False)
    assert R["docids"].shape[1] == ks
    # scale folding: alpha*(alpha*T) == alpha^2 * T structurally
    q = alpha * (alpha * Retrieve("BM25", k=5))
    assert abs(q.params["alpha"] - alpha * alpha) < 1e-6


def _check_linear_commutative(env, order):
    pipes = sum(w * Retrieve(m, k=10) for m, w in order)
    R = run(pipes, env, optimize=True)
    ref = sum(w * Retrieve(m, k=10)
              for m, w in [("BM25", 0.5), ("QL", 1.5), ("TF_IDF", 1.0)])
    Rr = run(ref, env, optimize=True)
    for q in range(len(R["qid"])):
        assert docsets(R, 5)[q] == docsets(Rr, 5)[q]


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(k1=st.sampled_from([3, 8, 20]), k2=st.sampled_from([5, 12]),
           alpha=st.floats(0.1, 4.0))
    def test_rewrite_laws(small_ir, k1, k2, alpha):
        _check_rewrite_laws(small_ir, k1, k2, alpha)

    @settings(max_examples=5, deadline=None)
    @given(st.permutations([("BM25", 0.5), ("QL", 1.5), ("TF_IDF", 1.0)]))
    def test_linear_commutative(small_ir, order):
        """+ is commutative: any permutation yields the same fused result."""
        _check_linear_commutative(small_ir, order)


# deterministic fallbacks: the same laws on fixed cases, so coverage does
# not silently vanish when hypothesis is unavailable
@pytest.mark.parametrize("k1,k2,alpha", [(3, 12, 0.7), (20, 5, 2.5),
                                         (8, 5, 1.0)])
def test_rewrite_laws_fixed(small_ir, k1, k2, alpha):
    _check_rewrite_laws(small_ir, k1, k2, alpha)


def test_linear_commutative_fixed(small_ir):
    _check_linear_commutative(
        small_ir, [("TF_IDF", 1.0), ("BM25", 0.5), ("QL", 1.5)])
