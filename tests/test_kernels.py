"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.dense_scoring.ops import streaming_dense_topk
from repro.kernels.dense_scoring.ref import dense_topk_ref
from repro.kernels.pq_scoring.ops import streaming_pq_topk
from repro.kernels.pq_scoring.ref import pq_topk_ref
from repro.kernels.fused_scoring.ops import fused_scoring
from repro.kernels.fused_scoring.ref import fused_scoring_ref
from repro.kernels.topk.ops import streaming_topk
from repro.kernels.topk.topk import streaming_topk_pallas

STATS = {"n_docs": 8000.0, "avg_doclen": 200.0, "total_terms": 1.6e6}


@pytest.mark.parametrize("n", [512, 2048, 5000])
@pytest.mark.parametrize("models", [("BM25",), ("BM25", "QL", "TF_IDF"),
                                    ("BM25", "TF_IDF", "QL", "DPH", "Coord")])
def test_fused_scoring_sweep(n, models):
    rng = np.random.default_rng(n)
    tf = jnp.asarray(rng.integers(0, 30, n), jnp.int32)
    dl = jnp.asarray(rng.integers(20, 800, n), jnp.int32)
    df = jnp.asarray(rng.integers(1, 4000, n), jnp.int32)
    cf = jnp.asarray(rng.integers(1, 30000, n), jnp.int32)
    a = fused_scoring(tf, dl, df, cf, models=models, stats=STATS,
                      impl="pallas", interpret=True)
    b = fused_scoring_ref(tf, dl, df, cf, models=models,
                          n_docs=STATS["n_docs"], avg_dl=STATS["avg_doclen"],
                          total_terms=STATS["total_terms"])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=1e-5)


@pytest.mark.parametrize("n,k,block", [(4096, 10, 1024), (8192, 32, 2048),
                                       (4096, 128, 4096), (20000, 7, 1024)])
def test_streaming_topk_sweep(n, k, block):
    rng = np.random.default_rng(n + k)
    scores = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    v1, i1 = streaming_topk(scores, k=k, block=block, impl="pallas",
                            interpret=True)
    v2, i2 = jax.lax.top_k(scores, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
    assert set(np.asarray(i1).tolist()) == set(np.asarray(i2).tolist())


@pytest.mark.parametrize("n,dim,k,block,with_base",
                         [(2048, 64, 10, 1024, False),
                          (5000, 64, 32, 1024, True),
                          (700, 32, 16, 512, True),
                          (4096, 128, 128, 2048, False)])
def test_streaming_dense_topk_sweep(n, dim, k, block, with_base):
    rng = np.random.default_rng(n + k)
    emb = jnp.asarray(rng.standard_normal((n, dim)).astype(np.float32))
    q = jnp.asarray(rng.standard_normal(dim).astype(np.float32))
    base = (jnp.asarray(rng.standard_normal(n).astype(np.float32))
            if with_base else None)
    v1, i1 = streaming_dense_topk(emb, q, base, k=k, block=block,
                                  impl="pallas", interpret=True)
    v2, i2 = dense_topk_ref(emb, q, base, k=k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5,
                               atol=1e-5)
    assert set(np.asarray(i1).tolist()) == set(np.asarray(i2).tolist())


@pytest.mark.parametrize("n,m,k,block,with_base",
                         [(2048, 8, 10, 512, False),
                          (5000, 8, 32, 512, True),
                          (700, 4, 16, 256, True),
                          (4096, 16, 128, 1024, False)])
def test_streaming_pq_topk_sweep(n, m, k, block, with_base):
    rng = np.random.default_rng(n + m + k)
    codes = jnp.asarray(rng.integers(0, 256, (n, m)).astype(np.uint8))
    table = jnp.asarray(rng.standard_normal((m, 256)).astype(np.float32))
    base = (jnp.asarray(rng.standard_normal(n).astype(np.float32))
            if with_base else None)
    v1, i1 = streaming_pq_topk(codes, table, base, k=k, block=block,
                               impl="pallas", interpret=True)
    v2, i2 = pq_topk_ref(codes, table, base, k=k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5,
                               atol=1e-5)
    # the kernel's lexsort finish orders equal-value survivors by lowest
    # index (lax.top_k's rule), so with distinct scores indices match
    # position-for-position
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_streaming_pq_topk_duplicate_codes():
    # every doc in {0,1} code space: massive score ties.  Like the other
    # streaming kernels, ties deeper than k admit any valid top-k set —
    # the contract is equal top-k *values* and every returned index
    # actually scoring its reported value
    rng = np.random.default_rng(7)
    codes = jnp.asarray(rng.integers(0, 2, (3000, 8)).astype(np.uint8))
    table = jnp.asarray(rng.standard_normal((8, 256)).astype(np.float32))
    v1, i1 = streaming_pq_topk(codes, table, None, k=16, block=512,
                               impl="pallas", interpret=True)
    v2, _ = pq_topk_ref(codes, table, None, k=16)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5,
                               atol=1e-5)
    full = np.asarray(table)[np.arange(8), np.asarray(codes)].sum(axis=1)
    np.testing.assert_allclose(full[np.asarray(i1)], np.asarray(v1),
                               rtol=1e-5, atol=1e-5)


def test_streaming_topk_duplicate_values():
    scores = jnp.asarray(np.array([1.0, 3.0, 3.0, 3.0, 0.5] * 300, np.float32))
    v1, _ = streaming_topk(scores, k=5, block=500, impl="pallas", interpret=True)
    assert (np.asarray(v1) == 3.0).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,HKV,D,bq,bkv",
                         [(1, 128, 2, 2, 64, 64, 64),     # MHA
                          (2, 256, 4, 2, 64, 128, 64),    # GQA
                          (1, 256, 8, 1, 128, 64, 128)])  # MQA
def test_flash_attention_sweep(dtype, B, S, H, HKV, D, bq, bkv):
    rng = np.random.default_rng(S + H)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, HKV, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, HKV, D)), dtype)
    o1 = flash_attention_pallas(q, k, v, causal=True, bq=bq, bkv=bkv,
                                interpret=True)
    o2 = flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-6
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=tol)


@pytest.mark.parametrize("chunk", [32, 128])
def test_flash_attention_chunked(chunk):
    rng = np.random.default_rng(chunk)
    q = jnp.asarray(rng.standard_normal((1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    o1 = flash_attention_pallas(q, k, v, causal=True, chunk=chunk,
                                bq=64, bkv=64, interpret=True)
    o2 = flash_attention_ref(q, k, v, causal=True, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-6)


def test_flash_vjp_matches_naive_grads():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 64, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 64, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 64, 2, 32)), jnp.float32)

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, impl="remat_ref") ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(flash_attention_ref(q, k, v) ** 2)

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
