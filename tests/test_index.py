"""Inverted/forward index structural invariants + gather correctness."""
import numpy as np
import jax.numpy as jnp

from repro.index.inverted import BLOCK, gather_postings


def test_index_invariants(small_ir):
    idx = small_ir["index"]
    corpus = small_ir["corpus"]
    term_start = np.asarray(idx.term_start)
    doc_ids = np.asarray(idx.doc_ids)
    tfs = np.asarray(idx.tfs)
    df = np.asarray(idx.df)
    # CSR offsets monotone, block-aligned
    assert (np.diff(term_start) >= 0).all()
    assert (np.diff(term_start) % BLOCK == 0).all()
    # df equals live postings per term
    for t in np.random.default_rng(0).integers(0, idx.vocab, 50):
        s, e = term_start[t], term_start[t + 1]
        live = (doc_ids[s:e] >= 0).sum()
        assert live == df[t], t
        # postings sorted by docid (within live region)
        d = doc_ids[s:s + df[t]]
        assert (np.diff(d) > 0).all()
    # total collection size consistent
    assert int(np.asarray(idx.cf).sum()) == idx.total_terms
    assert idx.total_terms == len(corpus.doc_terms)


def test_forward_inverted_transpose(small_ir):
    """fwd(d) must contain (t, tf) iff inverted(t) contains (d, tf)."""
    idx = small_ir["index"]
    fwd_start = np.asarray(idx.fwd_start)
    fwd_terms = np.asarray(idx.fwd_terms)
    fwd_tfs = np.asarray(idx.fwd_tfs)
    term_start = np.asarray(idx.term_start)
    doc_ids = np.asarray(idx.doc_ids)
    tfs = np.asarray(idx.tfs)
    rng = np.random.default_rng(1)
    for d in rng.integers(0, idx.n_docs, 20):
        s, e = fwd_start[d], fwd_start[d + 1]
        for t, tf in list(zip(fwd_terms[s:e], fwd_tfs[s:e]))[:10]:
            ps, pe = term_start[t], term_start[t + 1]
            row = doc_ids[ps:pe]
            j = np.searchsorted(row[row >= 0], d)
            assert row[j] == d
            assert tfs[ps + j] == tf


def test_gather_postings_matches_numpy(small_ir):
    idx = small_ir["index"]
    terms = jnp.asarray([5, 17, -1, 100], jnp.int32)
    out = gather_postings(idx, terms, max_postings=small_ir["backend"].max_postings)
    term_start = np.asarray(idx.term_start)
    doc_ids = np.asarray(idx.doc_ids)
    df = np.asarray(idx.df)
    for i, t in enumerate([5, 17, -1, 100]):
        if t < 0:
            assert not bool(np.asarray(out["mask"])[i].any())
            continue
        got = np.asarray(out["doc_ids"])[i][np.asarray(out["mask"])[i]]
        want = doc_ids[term_start[t]:term_start[t] + df[t]]
        assert (got == want).all()


def test_dense_index_unit_norm(small_ir):
    emb = np.asarray(small_ir["backend"].dense.emb)
    norms = np.linalg.norm(emb, axis=1)
    assert np.all(norms < 1.001)
    assert (norms > 0.99).mean() > 0.95
