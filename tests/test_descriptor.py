"""Backend descriptors, calibrated cost model, autotune + tuning-profile
persistence (the measurement-driven compiler layer).

Covers the ISSUE-6 acceptance property explicitly: compiling against a
backend whose descriptor carries a *persisted* TuningProfile performs zero
probe measurements and zero gate-candidate compiles (decision-record
counters), plus profile corruption recovery and backend-digest
invalidation."""
import dataclasses
import json

import numpy as np
import pytest

from repro.analysis.hlo_cost import fit_peaks
from repro.core import BackendDescriptor, JaxBackend, Retrieve, TuningProfile
from repro.core.descriptor import as_descriptor
from repro.core.passes import compile_pipeline, explain_pipeline
from repro.index import build_index, synthesize_corpus

#: fusion-visible capability set (pruned_topk off: the pushdown rewrite
#: would otherwise consume the cutoff before the gate ever sees it)
FUSE_CAPS = frozenset({"fat", "fused_topk", "fused_scoring", "multi_model"})


@pytest.fixture(scope="module")
def env():
    corpus = synthesize_corpus(n_docs=600, vocab=2500, mean_len=60, seed=11)
    return {"index": build_index(corpus)}


def _backend(env, profile=None, *, autotune=True, band=10.0, default_k=50):
    desc = BackendDescriptor.default(FUSE_CAPS).with_profile(profile)
    if autotune:
        desc = desc.with_autotune(True, band=band, probe_queries=2,
                                  probe_repeats=1)
    return JaxBackend(env["index"], default_k=default_k, descriptor=desc)


def _compile(backend, pipe=None):
    rep = {}
    op = compile_pipeline(pipe if pipe is not None
                          else Retrieve("BM25", k=50) % 10,
                          backend, report=rep)
    return op, rep


# ---------------------------------------------------------------------------
# descriptor basics
# ---------------------------------------------------------------------------

def test_default_descriptor_fields():
    d = BackendDescriptor.default()
    assert d.supports("fused_topk") and not d.supports("nope")
    assert d.native_limit("topk") is not None
    assert d.kernel_native("topk", d.native_limit("topk"))
    assert not d.kernel_native("topk", d.native_limit("topk") + 1)
    assert d.kernel_native("fat", 10 ** 9)     # no ceiling for fat
    assert d.host and len(d.peak_digest) == 16


def test_peak_digest_tracks_calibration():
    d = BackendDescriptor.default()
    d2 = d.calibrated({"peak_flops_per_s": 2.0e13,
                       "peak_bytes_per_s": 4.0e11})
    assert d2.peak_flops_per_s == 2.0e13
    assert d.peak_digest != d2.peak_digest


def test_capabilities_kwarg_removed(env):
    """The pre-descriptor ``capabilities=`` ctor kwarg finished its
    deprecation cycle: it now fails like any unknown kwarg, and the
    descriptor is the only capability surface."""
    with pytest.raises(TypeError):
        JaxBackend(env["index"], capabilities=frozenset({"fat"}))
    be = JaxBackend(env["index"],
                    descriptor=BackendDescriptor.default(frozenset({"fat"})))
    assert be.capabilities == frozenset({"fat"})     # read-only alias stays
    assert be.descriptor.capabilities == frozenset({"fat"})
    assert as_descriptor(be) is be.descriptor


# ---------------------------------------------------------------------------
# satellite bugfix: estimate cache scoped by host/peak digest
# ---------------------------------------------------------------------------

def test_estimate_cache_scoped_by_peak_digest(env):
    be = _backend(env, autotune=False)
    _, rep1 = _compile(be)
    assert rep1["tuning"]["gate_estimates"] > 0
    assert set(be._cost_estimates) == {be.descriptor.peak_digest}
    # same backend re-priced under different peak constants: the cached
    # estimates must NOT answer — a fresh scope appears and the candidates
    # are re-priced
    be.descriptor = be.descriptor.calibrated(
        {"peak_flops_per_s": 3.3e13, "peak_bytes_per_s": 1.1e11})
    _, rep2 = _compile(be)
    assert rep2["tuning"]["gate_estimates"] > 0
    assert len(be._cost_estimates) == 2
    # ...and the old scope still answers for the old descriptor
    be.descriptor = _backend(env, autotune=False).descriptor
    _, rep3 = _compile(be)
    assert rep3["tuning"]["gate_estimates"] == 0


# ---------------------------------------------------------------------------
# tuning-profile persistence (tentpole acceptance)
# ---------------------------------------------------------------------------

def test_profile_roundtrip_zero_probe_measurements(env, tmp_path):
    path = tmp_path / "profile.json"
    be = _backend(env, TuningProfile(path))
    _, cold = _compile(be)
    assert cold["tuning"]["probe_measurements"] > 0
    assert cold["tuning"]["gate_estimates"] > 0
    assert path.exists()
    # fresh backend + fresh profile object loading the persisted file:
    # the decision replays with ZERO candidate compiles and ZERO probes
    be2 = _backend(env, TuningProfile(path))
    _, warm = _compile(be2)
    assert warm["tuning"]["probe_measurements"] == 0
    assert warm["tuning"]["gate_estimates"] == 0
    assert warm["tuning"]["profile_hits"] > 0
    assert warm["tuning"]["profile_misses"] == 0
    # the replayed decision is the persisted one, marked as such
    srcs = [d["source"] for d in warm["fusion_decisions"]]
    assert srcs and all(s == "profile" for s in srcs)
    accepted = [d["accepted"] for d in cold["fusion_decisions"]]
    assert [d["accepted"] for d in warm["fusion_decisions"]] == accepted


def test_profile_corrupt_file_recovery(tmp_path):
    path = tmp_path / "profile.json"
    path.write_text('{"version": 1, "entries": {"x": ')   # truncated
    prof = TuningProfile(path)
    assert prof.entries == {} and not path.exists()
    # wrong version: also recovered (stale schema never half-parses)
    path.write_text(json.dumps({"version": 999, "entries": {}}))
    assert TuningProfile(path).entries == {}
    # non-dict entries
    path.write_text(json.dumps({"version": 1, "entries": [1, 2]}))
    assert TuningProfile(path).entries == {}


def test_profile_save_roundtrips_entries(tmp_path):
    path = tmp_path / "p.json"
    prof = TuningProfile(path)
    prof.record("digest", ("topk", ("f",), ("u",)), 8,
                {"accepted": True, "source": "measured"})
    assert prof.dirty
    prof.save()
    assert not prof.dirty and path.exists()
    again = TuningProfile(path)
    hit = again.lookup("digest", ("topk", ("f",), ("u",)), 8)
    assert hit == {"accepted": True, "source": "measured"}
    assert again.lookup("digest", ("other",), 8) is None
    assert again.hits == 1 and again.misses == 1


def test_profile_invalidated_by_backend_digest_change(env, tmp_path):
    path = tmp_path / "profile.json"
    _compile(_backend(env, TuningProfile(path)))
    # different default_k -> different backend content digest -> the
    # persisted entries must miss and the gate re-tunes
    be2 = _backend(env, TuningProfile(path), default_k=40)
    _, rep = _compile(be2, Retrieve("BM25", k=40) % 10)
    assert rep["tuning"]["profile_hits"] == 0
    assert rep["tuning"]["profile_misses"] > 0
    assert rep["tuning"]["gate_estimates"] > 0


# ---------------------------------------------------------------------------
# autotune policy
# ---------------------------------------------------------------------------

def test_autotune_band_zero_measures_nothing(env):
    be = _backend(env, band=0.0)
    _, rep = _compile(be)
    assert rep["tuning"]["probe_measurements"] == 0
    assert all(d["source"] == "estimate" for d in rep["fusion_decisions"])


def test_autotune_wide_band_measures_and_records(env):
    be = _backend(env, band=10.0)
    _, rep = _compile(be)
    assert rep["tuning"]["probe_measurements"] > 0
    d = rep["fusion_decisions"][0]
    assert d["source"] == "measured"
    assert d["fused_measured_s"] > 0 and d["unfused_measured_s"] > 0
    assert d["accepted"] == (d["fused_measured_s"] < d["unfused_measured_s"])
    # HLO counts ride along for calibration
    assert d["fused_flops"] > 0 and d["unfused_bytes"] > 0


def test_mixed_k_linear_fusion_is_measured_only(env):
    pipe = 0.5 * Retrieve("BM25", k=30) + 0.5 * Retrieve("QL", k=50)
    # static gate: mixed-k must NOT fuse (semantics-affecting)
    op_static, rep_static = _compile(_backend(env, autotune=False), pipe)
    assert op_static.kind == "linear"
    assert all(d["pattern"] != "multi_mixed"
               for d in rep_static["fusion_decisions"])
    # autotune: taken only on a measured win, at k = max(k_i)
    op, rep = _compile(_backend(env), pipe)
    ds = [d for d in rep["fusion_decisions"] if d["pattern"] == "multi_mixed"]
    assert len(ds) == 1 and ds[0]["source"] == "measured"
    if ds[0]["accepted"]:
        assert op.kind == "multi_retrieve" and op.params["k"] == 50
    else:
        assert op.kind == "linear"


def test_explain_shows_measured_vs_predicted(env):
    text = explain_pipeline(Retrieve("BM25", k=50) % 10, _backend(env))
    assert "fusion gate" in text
    assert "predicted" in text and "measured" in text


# ---------------------------------------------------------------------------
# auto-refit: profile-carried calibration applied by with_profile
# ---------------------------------------------------------------------------

FIT = {"peak_flops_per_s": 2.0e13, "peak_bytes_per_s": 4.0e11,
       "gamma": 50.0, "n_records": 6, "rms_log_ratio_error": 0.01}


def test_with_profile_auto_refits_from_calibration(tmp_path):
    path = tmp_path / "p.json"
    prof = TuningProfile(path)
    prof.note_calibration(FIT)
    prof.save()
    # a fresh descriptor attaching the persisted profile re-prices its
    # roofline peaks from the stored fit, once
    prof2 = TuningProfile(path)
    d = BackendDescriptor.default().with_profile(prof2)
    assert d.peak_flops_per_s == FIT["peak_flops_per_s"]
    assert d.peak_bytes_per_s == FIT["peak_bytes_per_s"]
    assert prof2.pending_fit(d.peak_digest) is None    # marked applied
    # a second attach of the same (marked) profile is a no-op refit
    d2 = BackendDescriptor.default().with_profile(prof2)
    assert d2.peak_digest == d.peak_digest
    # the applied marker survives persistence
    prof2.save()
    prof3 = TuningProfile(path)
    assert prof3.pending_fit(d.peak_digest) is None
    assert prof3.info()["calibrated"]


def test_with_profile_auto_refit_opt_out():
    prof = TuningProfile(path=None)
    prof.note_calibration(FIT)
    d = BackendDescriptor.default().with_profile(prof, auto_refit=False)
    assert d.peak_flops_per_s != FIT["peak_flops_per_s"]
    # the fit stays pending for a future auto-refit attach
    assert prof.pending_fit(d.peak_digest) == {
        k: float(v) for k, v in FIT.items()}


def test_note_calibration_ignores_malformed_fit():
    prof = TuningProfile(path=None)
    prof.note_calibration(None)
    prof.note_calibration({"peak_flops_per_s": 1.0})   # missing bytes peak
    assert prof.calibration is None and not prof.dirty


# ---------------------------------------------------------------------------
# calibration fit
# ---------------------------------------------------------------------------

def test_fit_peaks_recovers_synthetic_roofline():
    g_true, pf_true = 100.0, 2.0e13
    rng = np.random.default_rng(0)
    recs = []
    for _ in range(6):
        rec = {}
        for side in ("unfused", "fused"):
            F = float(rng.uniform(1e6, 1e9))
            B = float(rng.uniform(1e5, 1e8))
            rec[side] = {"flops": F, "bytes": B,
                         "measured_s": (F + g_true * B) / pf_true}
        recs.append(rec)
    fit = fit_peaks(recs)
    assert fit is not None and fit["n_records"] == 6
    assert abs(np.log10(fit["gamma"] / g_true)) < 1e-6   # grid hits 100
    assert abs(fit["peak_flops_per_s"] / pf_true - 1) < 1e-6
    assert fit["rms_log_ratio_error"] < 1e-9


def test_fit_peaks_rejects_unusable_records():
    assert fit_peaks([]) is None
    assert fit_peaks([{"unfused": {"flops": 0, "bytes": 1,
                                   "measured_s": 1},
                       "fused": {"flops": 1, "bytes": 1,
                                 "measured_s": 1}}]) is None


# ---------------------------------------------------------------------------
# server restart: warm profile skips tuning at compile time
# ---------------------------------------------------------------------------

def test_server_warmup_persists_and_restart_is_profile_warm(env, tmp_path):
    from repro.core.data import make_queries
    from repro.serve.server import PipelineServer

    path = tmp_path / "serve_profile.json"
    pipe = Retrieve("BM25", k=50) % 10
    srv = PipelineServer(pipe, _backend(env, TuningProfile(path)))
    assert srv.compile_report["tuning"]["probe_measurements"] > 0
    terms = np.zeros((1, 3), np.int32)
    weights = np.ones((1, 3), np.float32)
    info = srv.warmup(make_queries(terms, weights, np.array([0])))
    assert path.exists()
    assert info["tuning_profile"]["entries"] > 0
    # "restart": a fresh server process compiles the same pipeline against
    # the persisted profile with zero probes and zero gate compiles
    srv2 = PipelineServer(pipe, _backend(env, TuningProfile(path)))
    t = srv2.compile_report["tuning"]
    assert t["probe_measurements"] == 0 and t["gate_estimates"] == 0
    assert t["profile_hits"] > 0
    assert srv2.stats()["tuning_profile"]["hits"] > 0
