"""Serving-layer tests: continuous batcher vs sequential reference decode."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models import transformer_lm as tlm
from repro.serve.batching import ContinuousBatcher, Request


def _tiny_cfg():
    return tlm.LMConfig(name="tiny", n_layers=2, d_model=32, n_q=4, n_kv=2,
                        d_head=8, d_ff=64, vocab=128, remat=False)


def _reference_generate(cfg, params, prompt: np.ndarray, n_new: int):
    """Sequential greedy decode via prefill + decode_step."""
    toks = jnp.asarray(prompt[None, :], jnp.int32)
    cache = tlm.init_kv_cache(cfg, 1, 64)
    logits, cache = tlm.prefill(cfg, params, toks, cache)
    out = [int(jnp.argmax(logits))]
    pos = prompt.shape[0]
    for _ in range(n_new - 1):
        nxt = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = tlm.decode_step(cfg, params, nxt, cache, jnp.int32(pos))
        out.append(int(jnp.argmax(logits)))
        pos += 1
    return out


def test_continuous_batcher_matches_sequential():
    cfg = _tiny_cfg()
    params = tlm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    # fixed prompt length: one prefill compilation; 4 prompts over 2 slots
    # still exercises slot reuse/admission
    prompts = [rng.integers(0, cfg.vocab, 6, dtype=np.int32)
               for _ in range(4)]

    batcher = ContinuousBatcher(cfg, params, slots=2, max_len=64)
    for i, p in enumerate(prompts):
        batcher.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    done = {r.rid: r.generated for r in batcher.run_to_completion()}
    assert len(done) == 4

    for i, p in enumerate(prompts):
        ref = _reference_generate(cfg, params, p, 5)
        assert done[i] == ref, (i, done[i], ref)


def test_batcher_handles_more_requests_than_slots():
    cfg = _tiny_cfg()
    params = tlm.init_params(cfg, jax.random.key(1))
    batcher = ContinuousBatcher(cfg, params, slots=2, max_len=32)
    for i in range(5):
        batcher.submit(Request(rid=i, prompt=np.arange(4, dtype=np.int32) + i,
                               max_new_tokens=3))
    done = batcher.run_to_completion()
    assert len(done) == 5
    assert all(len(r.generated) == 3 for r in done)
