"""Training-substrate tests: checkpoint integrity, fault-tolerant replay,
straggler policy, gradient compression, elastic meshing, optimizer."""
import json
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train import checkpoint as ckpt
from repro.train import compression, data as data_lib, optimizer as opt_lib
from repro.train.fault import ElasticMesh, StepGuard, StragglerMonitor


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.float32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 3, t)
    out = ckpt.restore(tmp_path, 3, jax.tree.map(np.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.latest_step(tmp_path) == 3


def test_checkpoint_detects_corruption(tmp_path):
    t = _tree()
    d = ckpt.save(tmp_path, 1, t)
    # flip a byte in one shard
    target = next(d.glob("a.npy"))
    raw = bytearray(target.read_bytes())
    raw[-1] ^= 0xFF
    target.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        ckpt.restore(tmp_path, 1, t)


def test_async_checkpointer_gc(tmp_path):
    c = ckpt.AsyncCheckpointer(tmp_path, keep=2)
    for s in [1, 2, 3, 4]:
        c.save_async(s, _tree())
    c.wait()
    steps = sorted(p.name for p in Path(tmp_path).glob("step_*"))
    assert steps == ["step_00000003", "step_00000004"]


def test_stepguard_replays_after_failure(tmp_path):
    """Inject a failure mid-run; the guard must restore and replay the SAME
    batches (determinism contract)."""
    state = {"x": jnp.zeros(()), "seen": jnp.zeros((), jnp.int32)}
    pipeline = data_lib.DataPipeline(
        lambda step, shard=0, n=1: {"v": np.float32(step)})
    fail_at = {"n": 7, "armed": True}

    def step_fn(state, batch):
        if fail_at["armed"] and float(batch["v"]) == fail_at["n"]:
            fail_at["armed"] = False
            raise RuntimeError("injected node failure")
        return ({"x": state["x"] + batch["v"],
                 "seen": state["seen"] + 1}, {"v": batch["v"]})

    guard = StepGuard(tmp_path, ckpt_every=2, max_retries=2)
    state, _, step = guard.run(state, pipeline.iter_from, step_fn, 10)
    assert step == 10
    assert guard.replays == 1
    # sum over steps 0..9 exactly once each
    assert float(state["x"]) == sum(range(10))


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(4, threshold=1.5, grace_steps=3)
    for _ in range(5):
        flagged = mon.record(np.array([1.0, 1.0, 1.0, 2.5]))
    assert flagged == [3]
    # recovered host resets strikes
    mon2 = StragglerMonitor(2, threshold=1.5, grace_steps=3)
    mon2.record(np.array([1.0, 2.5]))
    mon2.record(np.array([1.0, 1.0]))
    assert mon2.strikes[1] == 0


def test_elastic_mesh_plan():
    em = ElasticMesh(model_degree=16)
    plan = em.rescale_plan(old_data_degree=16, new_data_degree=12,
                           global_batch=256, n_micro=4)
    # global batch preserved up to rounding; per-shard divisible by micro
    assert plan["achieved_global_batch"] >= 256
    assert plan["per_shard_batch"] % plan["n_micro"] == 0
    assert plan["n_micro"] >= 4        # grad-accum raised as DP shrank
    # clean halving keeps batch exact
    plan2 = em.rescale_plan(16, 8, 256, 4)
    assert plan2["achieved_global_batch"] == 256
    from repro.train.fault import feasible_mesh_shape
    assert feasible_mesh_shape(255, 16) == (15, 16)
    with pytest.raises(RuntimeError):
        feasible_mesh_shape(15, 16)


@pytest.mark.parametrize("scheme", ["int8", "topk"])
def test_compression_error_feedback_converges(scheme):
    """With error feedback, the accumulated compressed signal tracks the true
    gradient sum (unbiasedness over time)."""
    ef = compression.ErrorFeedback(scheme, k_frac=0.25)
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal(64).astype(np.float32))}
    res = ef.init(g)
    total_out = jnp.zeros(64)
    for _ in range(30):
        out, res = ef.compress_decompress(g, res)
        total_out = total_out + out["w"]
    err = np.abs(np.asarray(total_out) / 30 - np.asarray(g["w"])).max()
    # int8 is near-unbiased per step; topk carries an O(residual/T) lag
    assert err < (0.05 if scheme == "int8" else 0.15)
    comp, raw = ef.wire_bytes(g)
    assert comp < raw


def test_adamw_descends_quadratic():
    cfg = opt_lib.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                              total_steps=100, schedule="constant")
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt_lib.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt_lib.update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5
    assert int(state["step"]) == 60


def test_data_pipeline_deterministic_replay():
    fn = data_lib.lm_batch_fn(vocab=100, batch=4, seq=8)
    p = data_lib.DataPipeline(fn)
    it1 = p.iter_from(5)
    a = next(it1)
    it2 = p.iter_from(5)
    b = next(it2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
