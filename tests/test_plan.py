"""Planner subsystem: exactly-once shared prefixes, sound cache tokens,
plan-vs-sequential equality, MRT decomposition, artifact cache."""
import gc

import numpy as np
import pytest

from repro.core import (DenseRerank, Experiment, Extract, ExperimentPlan,
                        ArtifactCache, Retrieve, RM3Expand, SDMRewrite)
from repro.core.compiler import Context, content_token
from repro.core.data import make_queries
from repro.core.transformer import Generic


def _counting_probe():
    calls = {"n": 0}

    def fn(Q, R):
        calls["n"] += 1
        return Q, R

    return Generic(fn=fn), calls


# ---------------------------------------------------------------------------
# exactly-once shared-prefix execution
# ---------------------------------------------------------------------------

def test_shared_prefix_executes_exactly_once(small_ir):
    """BM25 >> A and BM25 >> B must run BM25 (and the probe) once."""
    env = small_ir
    probe, calls = _counting_probe()
    base = Retrieve("BM25", k=10) >> probe
    p1 = base >> Extract("QL")
    p2 = base >> Extract("TF_IDF")
    ctx = Context(env["backend"])
    plan = ExperimentPlan([p1, p2], env["backend"], optimize=False)
    plan.execute(env["Q"], ctx=ctx)
    assert calls["n"] == 1
    # Retrieve executed once despite 2 pipelines requesting it
    ret_key = Retrieve("BM25", k=10).key()
    assert ctx.exec_counts[ret_key] == 1
    assert plan.n_stage_executions == 4       # BM25, probe, 2x Extract
    assert plan.n_stage_requests == 6


def test_plan_trie_shares_structurally_equal_stages(small_ir):
    """Sharing keys off canonical stage keys, not object identity: separately
    constructed Retrieve("BM25") nodes land on one trie node."""
    env = small_ir
    p1 = Retrieve("BM25", k=10) >> Extract("QL")
    p2 = Retrieve("BM25", k=10) >> Extract("TF_IDF")
    ctx = Context(env["backend"])
    plan = ExperimentPlan([p1, p2], env["backend"], optimize=False)
    plan.execute(env["Q"], ctx=ctx)
    assert ctx.exec_counts[Retrieve("BM25", k=10).key()] == 1


def test_three_way_trie_fanout(small_ir):
    env = small_ir
    pipes = [Retrieve("BM25", k=20) % 5,
             Retrieve("BM25", k=20) >> DenseRerank(alpha=0.5),
             Retrieve("BM25", k=20) >> Extract("QL")]
    ctx = Context(env["backend"])
    plan = ExperimentPlan(pipes, env["backend"], optimize=False)
    res = plan.execute(env["Q"], ctx=ctx)
    assert len(res) == 3
    assert ctx.exec_counts[Retrieve("BM25", k=20).key()] == 1


# ---------------------------------------------------------------------------
# cache-token soundness
# ---------------------------------------------------------------------------

def test_tokens_are_content_addressed(small_ir):
    """Same content in fresh arrays -> same token; different content ->
    different token.  (The old id()-keyed scheme gave neither guarantee.)"""
    ctx = Context(small_ir["backend"])
    terms = np.array([[1, 2, 3]], np.int32)
    Q1 = make_queries(terms)
    Q2 = make_queries(terms.copy())
    Q3 = make_queries(np.array([[4, 5, 6]], np.int32))
    assert ctx.source_token(Q1, None) == ctx.source_token(Q2, None)
    assert ctx.source_token(Q1, None) != ctx.source_token(Q3, None)


def test_memo_survives_gc_pressure(small_ir):
    """A shared Context must stay correct when query arrays are collected
    and their ids recycled by new arrays with different content."""
    env = small_ir
    be = env["backend"]
    ctx = Context(be)
    pipe = Retrieve("BM25", k=10)
    terms = np.asarray(env["Q"]["terms"])[:, :3]

    Q1 = make_queries(terms)
    R1 = pipe.transform(Q1, backend=be, optimize=False, ctx=ctx)
    R1_docs = np.asarray(R1["docids"]).copy()
    del Q1, R1
    gc.collect()
    # churn allocations so CPython recycles the freed object ids
    decoys = [make_queries(np.roll(terms, s, axis=1)) for s in range(1, 4)]
    Q2 = make_queries(terms[::-1].copy())        # different content
    R2 = pipe.transform(Q2, backend=be, optimize=False, ctx=ctx)
    ref = pipe.transform(Q2, backend=be, optimize=False, ctx=Context(be))
    np.testing.assert_array_equal(np.asarray(R2["docids"]),
                                  np.asarray(ref["docids"]))
    # and re-presenting the original content still hits the memo
    n0 = ctx.exec_counts[pipe.key()]
    Q1b = make_queries(terms.copy())
    R1b = pipe.transform(Q1b, backend=be, optimize=False, ctx=ctx)
    np.testing.assert_array_equal(np.asarray(R1b["docids"]), R1_docs)
    assert ctx.exec_counts[pipe.key()] == n0     # memo hit, no re-execution


# ---------------------------------------------------------------------------
# plan vs sequential equality (the test_system pipelines)
# ---------------------------------------------------------------------------

def test_plan_matches_sequential_results(small_ir):
    env = small_ir
    pipes = [
        Retrieve("BM25", k=30),
        Retrieve("QL", k=30),
        Retrieve("BM25", k=30) >> RM3Expand(fb_terms=5, fb_docs=5)
        >> Retrieve("BM25", k=30),
        SDMRewrite() >> Retrieve("BM25", k=10),
        Retrieve("BM25", k=20) >> DenseRerank(alpha=0.5),
    ]
    for optimize in (False, True):
        planned = Experiment(pipes, env["Q"], env["topics"].qrels, ["map"],
                             backend=env["backend"], optimize=optimize,
                             plan=True)
        seq = Experiment(pipes, env["Q"], env["topics"].qrels, ["map"],
                         backend=env["backend"], optimize=optimize,
                         plan=False)
        for Rp, Rs in zip(planned["results"], seq["results"]):
            np.testing.assert_array_equal(np.asarray(Rp["docids"]),
                                          np.asarray(Rs["docids"]))
            np.testing.assert_allclose(np.asarray(Rp["scores"]),
                                       np.asarray(Rs["scores"]), rtol=1e-6)
        for rp, rs in zip(planned["table"], seq["table"]):
            np.testing.assert_allclose(rp["map"], rs["map"], rtol=1e-6)


# ---------------------------------------------------------------------------
# MRT decomposition
# ---------------------------------------------------------------------------

def test_mrt_decomposes_compile_and_steady(small_ir):
    env = small_ir
    res = Experiment([Retrieve("BM25", k=30), Retrieve("QL", k=30)],
                     env["Q"], env["topics"].qrels, ["map"],
                     backend=env["backend"], measure_time=True)
    for row in res["table"]:
        assert row["mrt_ms"] > 0
        assert row["compile_ms"] >= 0
        assert 0 < row["mrt_shared_ms"] <= row["mrt_ms"] + 1e-9
    st = res["stage_table"]
    assert all(r["steady_ms"] is not None for r in st)
    # stage attribution covers both pipelines
    assert {r["n_pipelines"] for r in st} == {1}


def test_mrt_shared_amortises(small_ir):
    """With a shared prefix, amortised MRT must be below full-path MRT."""
    env = small_ir
    base = Retrieve("BM25", k=20)
    res = Experiment([base >> Extract("QL"), base >> Extract("TF_IDF")],
                     env["Q"], env["topics"].qrels, ["map"],
                     backend=env["backend"], optimize=False,
                     measure_time=True)
    for row in res["table"]:
        assert row["mrt_shared_ms"] < row["mrt_ms"]


# ---------------------------------------------------------------------------
# on-disk artifact cache
# ---------------------------------------------------------------------------

def test_artifact_cache_roundtrip(small_ir, tmp_path):
    env = small_ir
    pipes = [Retrieve("BM25", k=20) >> Extract("QL"),
             Retrieve("BM25", k=20) >> Extract("TF_IDF")]
    cache = ArtifactCache(tmp_path / "artifacts")
    r1 = Experiment(pipes, env["Q"], env["topics"].qrels, ["map"],
                    backend=env["backend"], optimize=False,
                    artifact_cache=cache)
    assert cache.hits == 0 and cache.misses > 0
    # second run: every persistable stage comes from disk, nothing executes
    cache2 = ArtifactCache(tmp_path / "artifacts")
    ctx = Context(env["backend"])
    plan = ExperimentPlan(pipes, env["backend"], optimize=False)
    res2 = plan.execute(env["Q"], ctx=ctx, cache=cache2)
    assert cache2.hits == plan.n_stage_executions
    assert not ctx.exec_counts                      # zero stage executions
    for Ra, Rb in zip(r1["results"], res2):
        np.testing.assert_array_equal(np.asarray(Ra["docids"]),
                                      np.asarray(Rb["docids"]))
        np.testing.assert_allclose(np.asarray(Ra["features"]),
                                   np.asarray(Rb["features"]), rtol=1e-6)


def test_artifact_cache_keys_on_query_content(small_ir, tmp_path):
    """A different query set must miss the cache, not alias."""
    env = small_ir
    pipe = [Retrieve("BM25", k=10)]
    cache = ArtifactCache(tmp_path / "a")
    Experiment(pipe, env["Q"], env["topics"].qrels, ["map"],
               backend=env["backend"], artifact_cache=cache)
    other = make_queries(np.asarray(env["Q"]["terms"])[:4])
    plan = ExperimentPlan(pipe, env["backend"])
    res = plan.execute(other, ctx=Context(env["backend"]), cache=cache)
    assert cache.hits == 0                           # no false sharing
    assert np.asarray(res[0]["docids"]).shape[0] == 4


def test_duplicate_pipelines_share_one_leaf(small_ir):
    """Experiment([p, p]) must fill a result for both rows, not None."""
    env = small_ir
    p = Retrieve("BM25", k=15)
    res = Experiment([p, p], env["Q"], env["topics"].qrels, ["map"],
                     backend=env["backend"])
    assert res["plan"].n_stage_executions == 1
    assert all(r is not None for r in res["results"])
    np.testing.assert_array_equal(np.asarray(res["results"][0]["docids"]),
                                  np.asarray(res["results"][1]["docids"]))


def test_artifact_cache_keys_on_backend_config(small_ir, tmp_path):
    """Retrieve(k=None) resolves k from backend.default_k at run time; two
    backends over the same index but different default_k must not share
    artifacts."""
    from repro.core.compiler import JaxBackend
    env = small_ir
    cache = ArtifactCache(tmp_path / "b")
    pipe = [Retrieve("BM25")]
    be40 = JaxBackend(env["index"], default_k=40, query_chunk=4,
                      dense=env["backend"].dense)
    be20 = JaxBackend(env["index"], default_k=20, query_chunk=4,
                      dense=env["backend"].dense)
    r1 = ExperimentPlan(pipe, be40).execute(env["Q"], cache=cache)
    r2 = ExperimentPlan(pipe, be20).execute(env["Q"], cache=cache)
    assert cache.hits == 0                       # no cross-config aliasing
    assert np.asarray(r1[0]["docids"]).shape[1] == 40
    assert np.asarray(r2[0]["docids"]).shape[1] == 20


def test_stateful_and_object_stages_never_persisted(small_ir, tmp_path):
    """Stages keyed by process-local state must not be written to disk."""
    env = small_ir
    probe, _ = _counting_probe()
    pipes = [Retrieve("BM25", k=10) >> probe]
    cache = ArtifactCache(tmp_path / "c")
    plan = ExperimentPlan(pipes, env["backend"], optimize=False)
    plan.execute(env["Q"], ctx=Context(env["backend"]), cache=cache)
    files = list((tmp_path / "c").glob("*.npz"))
    assert len(files) == 1       # the Retrieve prefix only, not the Generic
