"""Dense second-stage retrieval subsystem: fused-vs-unfused equivalence,
IVF recall against brute force, the cost gate's both branches, IR round-trip
key preservation for the dense ops, and engine==sequential equality."""
import numpy as np
import pytest

from repro.core import (DenseRerank, DenseRetrieve, FusedDenseRerank,
                        FusedDenseRetrieve, JaxBackend, Retrieve,
                        compile_pipeline, lower, raise_ir)
from repro.core.transformer import Cutoff
from repro.index.dense import (build_ivf_index, dense_retrieve_exact,
                               ivf_retrieve_topk)


def _dense_backend(env, default_k=60, extra=(), **kw):
    """Kernel-lowering-capable backend without dynamic pruning (keeps the
    sparse first stage exact, so dense equivalences are exact too)."""
    caps = frozenset({"fat", "fused_dense", "dense_topk"}) | set(extra)
    return JaxBackend(env["index"], default_k=default_k,
                      dense=env["backend"].dense, capabilities=caps, **kw)


# ---------------------------------------------------------------------------
# FusedDenseRerank == unfused retrieve >> dense_rerank % K (exact mode)
# ---------------------------------------------------------------------------

def test_fused_dense_rerank_matches_unfused(small_ir):
    be = _dense_backend(small_ir)
    pipe = (Retrieve("BM25", k=200) >> DenseRerank(alpha=0.3)) % 10
    rep = {}
    op = compile_pipeline(pipe, be, report=rep)
    assert op.kind == "fused_dense_rerank"
    assert isinstance(raise_ir(op), FusedDenseRerank)
    assert op.params == {"model": "BM25", "k_in": 200, "k": 10,
                         "alpha": 0.3}
    assert any(d["pattern"] == "dense_rerank" and d["accepted"]
               for d in rep["fusion_decisions"])
    Ro = pipe.transform(small_ir["Q"], backend=be, optimize=True)
    Ru = pipe.transform(small_ir["Q"], backend=be, optimize=False)
    np.testing.assert_array_equal(np.asarray(Ro["docids"]),
                                  np.asarray(Ru["docids"]))
    np.testing.assert_allclose(np.asarray(Ro["scores"]),
                               np.asarray(Ru["scores"]), rtol=1e-4,
                               atol=1e-5)


def test_dense_rerank_fusion_needs_capability(small_ir):
    """Without ``fused_dense`` the chain stays interpreted (and still agrees
    with itself under optimisation)."""
    be = JaxBackend(small_ir["index"], default_k=60,
                    dense=small_ir["backend"].dense,
                    capabilities=frozenset({"fat"}))
    pipe = (Retrieve("BM25", k=200) >> DenseRerank(alpha=0.3)) % 10
    op = compile_pipeline(pipe, be)
    assert "fused_dense_rerank" not in _kinds(op)


def _kinds(op):
    out = [op.kind]
    for i in op.inputs:
        out.extend(_kinds(i))
    return out


# ---------------------------------------------------------------------------
# cost gate: both branches for the dense candidate-generation pattern
# ---------------------------------------------------------------------------

def test_dense_retrieve_gate_fuses_and_falls_back(small_ir):
    be = _dense_backend(small_ir, default_k=200)

    # deep dense retrieve + shallow cutoff: fused strictly cheaper
    rep1 = {}
    op1 = compile_pipeline(DenseRetrieve(k=200, nprobe=8) % 10, be,
                           report=rep1)
    assert op1.kind == "fused_dense_retrieve"
    assert isinstance(raise_ir(op1), FusedDenseRetrieve)

    # cutoff at the retrieve depth: the estimates tie and the gate keeps
    # the unfused interpreter path
    rep2 = {}
    op2 = compile_pipeline(DenseRetrieve(k=10, nprobe=8) % 10, be,
                           report=rep2)
    assert op2.kind == "cutoff"
    assert isinstance(raise_ir(op2), Cutoff)

    decided = [d["accepted"] for d in
               rep1["fusion_decisions"] + rep2["fusion_decisions"]]
    assert True in decided and False in decided

    for pipe in (DenseRetrieve(k=200, nprobe=8) % 10,
                 DenseRetrieve(k=10, nprobe=8) % 10,
                 DenseRetrieve(k=200, nprobe=0) % 10):
        Ro = pipe.transform(small_ir["Q"], backend=be, optimize=True)
        Ru = pipe.transform(small_ir["Q"], backend=be, optimize=False)
        np.testing.assert_array_equal(np.asarray(Ro["docids"]),
                                      np.asarray(Ru["docids"]))
        np.testing.assert_allclose(np.asarray(Ro["scores"]),
                                   np.asarray(Ru["scores"]), rtol=1e-5,
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# IVF recall vs brute force
# ---------------------------------------------------------------------------

def _recall(ivf_docs, brute_docs, k):
    hits = [len(set(a[a >= 0].tolist()) & set(b[b >= 0].tolist())) / k
            for a, b in zip(np.asarray(ivf_docs), np.asarray(brute_docs))]
    return float(np.mean(hits))


def test_ivf_recall_vs_brute_force(small_ir):
    be = small_ir["backend"]
    ivf = build_ivf_index(be.dense, n_lists=16, seed=0)
    qvecs = np.asarray(be.embed_queries(small_ir["Q"]))
    k = 10
    brute, full, half = [], [], []
    for qv in qvecs:
        brute.append(np.asarray(
            dense_retrieve_exact(be.dense, qv, k=k)[0]))
        full.append(np.asarray(
            ivf_retrieve_topk(ivf, qv, k=k, nprobe=ivf.n_lists)[0]))
        half.append(np.asarray(
            ivf_retrieve_topk(ivf, qv, k=k, nprobe=ivf.n_lists // 2)[0]))
    # probing every list scores every document: recall is exactly 1
    assert _recall(full, brute, k) >= 0.999
    # a half-width probe keeps most of the true top-k (loose floor: the
    # quantiser would have to be adversarially bad to miss half)
    assert _recall(half, brute, k) >= 0.5


def test_ivf_lists_partition_documents(small_ir):
    ivf = build_ivf_index(small_ir["backend"].dense, n_lists=16, seed=0)
    starts = np.asarray(ivf.list_start)
    assert starts[0] == 0 and starts[-1] == small_ir["index"].n_docs
    assert (np.diff(starts) >= 0).all()
    assert int(np.diff(starts).max()) == ivf.max_list_len
    assert sorted(np.asarray(ivf.doc_ids).tolist()) == \
        list(range(small_ir["index"].n_docs))


# ---------------------------------------------------------------------------
# IR round trip preserves key() for the dense ops
# ---------------------------------------------------------------------------

def _dense_pipelines():
    return [
        DenseRetrieve(k=20, nprobe=4),
        DenseRetrieve(k=30, nprobe=0) % 5,
        (Retrieve("BM25", k=30) >> DenseRerank(alpha=0.2)) % 10,
        FusedDenseRetrieve(k=5, nprobe=2),
        FusedDenseRerank(model="BM25", k_in=30, k=5, alpha=0.1),
    ]


@pytest.mark.parametrize("i", range(5))
def test_dense_lower_raise_preserves_key(i):
    pipe = _dense_pipelines()[i]
    op = lower(pipe)
    assert op.key() == pipe.key()
    raised = raise_ir(op)
    assert raised is pipe
    assert raised.key() == pipe.key()


# ---------------------------------------------------------------------------
# engine == sequential for dense pipelines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_pipe", [
    lambda: (Retrieve("BM25", k=60) >> DenseRerank(alpha=0.3)) % 10,
    lambda: DenseRetrieve(k=20, nprobe=4),
], ids=["fused_dense_rerank", "dense_retrieve"])
def test_dense_engine_matches_sequential(small_ir, make_pipe):
    env = small_ir
    ivf = build_ivf_index(env["backend"].dense, n_lists=16, seed=0)
    be_seq = _dense_backend(env, sharded=False, ivf=ivf)
    be_eng = _dense_backend(env, ivf=ivf)
    assert be_eng.engine is not None
    pipe = make_pipe()
    Rs = pipe.transform(env["Q"], backend=be_seq, optimize=True)
    Re = pipe.transform(env["Q"], backend=be_eng, optimize=True)
    np.testing.assert_array_equal(np.asarray(Rs["docids"]),
                                  np.asarray(Re["docids"]))
    np.testing.assert_allclose(np.asarray(Rs["scores"]),
                               np.asarray(Re["scores"]), rtol=1e-5,
                               atol=1e-6)
