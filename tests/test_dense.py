"""Dense second-stage retrieval subsystem: fused-vs-unfused equivalence,
IVF recall against brute force, the cost gate's both branches, IR round-trip
key preservation for the dense ops, and engine==sequential equality."""
import numpy as np
import pytest

from repro.core import (BackendDescriptor, DenseRerank, DenseRetrieve,
                        FusedDenseRerank, FusedDenseRetrieve, JaxBackend,
                        Retrieve, ShardedQueryEngine, compile_pipeline,
                        lower, raise_ir)
from repro.core.transformer import Cutoff
from repro.index.dense import (build_ivf_index, build_ivfpq_index,
                               build_pq_codebook, dense_retrieve_exact,
                               ivf_retrieve_topk, ivfpq_retrieve_topk,
                               ivfpq_retrieve_topk_fused, pq_decode,
                               pq_encode, pq_store_bytes, shard_dense_index,
                               sharded_dense_topk)


def _dense_backend(env, default_k=60, extra=(), **kw):
    """Kernel-lowering-capable backend without dynamic pruning (keeps the
    sparse first stage exact, so dense equivalences are exact too)."""
    caps = frozenset({"fat", "fused_dense", "dense_topk"}) | set(extra)
    return JaxBackend(env["index"], default_k=default_k,
                      dense=env["backend"].dense,
                      descriptor=BackendDescriptor.default(caps), **kw)


# ---------------------------------------------------------------------------
# FusedDenseRerank == unfused retrieve >> dense_rerank % K (exact mode)
# ---------------------------------------------------------------------------

def test_fused_dense_rerank_matches_unfused(small_ir):
    be = _dense_backend(small_ir)
    pipe = (Retrieve("BM25", k=200) >> DenseRerank(alpha=0.3)) % 10
    rep = {}
    op = compile_pipeline(pipe, be, report=rep)
    assert op.kind == "fused_dense_rerank"
    assert isinstance(raise_ir(op), FusedDenseRerank)
    assert op.params == {"model": "BM25", "k_in": 200, "k": 10,
                         "alpha": 0.3}
    assert any(d["pattern"] == "dense_rerank" and d["accepted"]
               for d in rep["fusion_decisions"])
    Ro = pipe.transform(small_ir["Q"], backend=be, optimize=True)
    Ru = pipe.transform(small_ir["Q"], backend=be, optimize=False)
    np.testing.assert_array_equal(np.asarray(Ro["docids"]),
                                  np.asarray(Ru["docids"]))
    np.testing.assert_allclose(np.asarray(Ro["scores"]),
                               np.asarray(Ru["scores"]), rtol=1e-4,
                               atol=1e-5)


def test_dense_rerank_fusion_needs_capability(small_ir):
    """Without ``fused_dense`` the chain stays interpreted (and still agrees
    with itself under optimisation)."""
    be = JaxBackend(small_ir["index"], default_k=60,
                    dense=small_ir["backend"].dense,
                    descriptor=BackendDescriptor.default(frozenset({"fat"})))
    pipe = (Retrieve("BM25", k=200) >> DenseRerank(alpha=0.3)) % 10
    op = compile_pipeline(pipe, be)
    assert "fused_dense_rerank" not in _kinds(op)


def _kinds(op):
    out = [op.kind]
    for i in op.inputs:
        out.extend(_kinds(i))
    return out


# ---------------------------------------------------------------------------
# cost gate: both branches for the dense candidate-generation pattern
# ---------------------------------------------------------------------------

def test_dense_retrieve_gate_fuses_and_falls_back(small_ir):
    be = _dense_backend(small_ir, default_k=200)

    # deep dense retrieve + shallow cutoff: fused strictly cheaper
    rep1 = {}
    op1 = compile_pipeline(DenseRetrieve(k=200, nprobe=8) % 10, be,
                           report=rep1)
    assert op1.kind == "fused_dense_retrieve"
    assert isinstance(raise_ir(op1), FusedDenseRetrieve)

    # cutoff at the retrieve depth: the estimates tie and the gate keeps
    # the unfused interpreter path
    rep2 = {}
    op2 = compile_pipeline(DenseRetrieve(k=10, nprobe=8) % 10, be,
                           report=rep2)
    assert op2.kind == "cutoff"
    assert isinstance(raise_ir(op2), Cutoff)

    decided = [d["accepted"] for d in
               rep1["fusion_decisions"] + rep2["fusion_decisions"]]
    assert True in decided and False in decided

    for pipe in (DenseRetrieve(k=200, nprobe=8) % 10,
                 DenseRetrieve(k=10, nprobe=8) % 10,
                 DenseRetrieve(k=200, nprobe=0) % 10):
        Ro = pipe.transform(small_ir["Q"], backend=be, optimize=True)
        Ru = pipe.transform(small_ir["Q"], backend=be, optimize=False)
        np.testing.assert_array_equal(np.asarray(Ro["docids"]),
                                      np.asarray(Ru["docids"]))
        np.testing.assert_allclose(np.asarray(Ro["scores"]),
                                   np.asarray(Ru["scores"]), rtol=1e-5,
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# IVF recall vs brute force
# ---------------------------------------------------------------------------

def _recall(ivf_docs, brute_docs, k):
    hits = [len(set(a[a >= 0].tolist()) & set(b[b >= 0].tolist())) / k
            for a, b in zip(np.asarray(ivf_docs), np.asarray(brute_docs))]
    return float(np.mean(hits))


def test_ivf_recall_vs_brute_force(small_ir):
    be = small_ir["backend"]
    ivf = build_ivf_index(be.dense, n_lists=16, seed=0)
    qvecs = np.asarray(be.embed_queries(small_ir["Q"]))
    k = 10
    brute, full, half = [], [], []
    for qv in qvecs:
        brute.append(np.asarray(
            dense_retrieve_exact(be.dense, qv, k=k)[0]))
        full.append(np.asarray(
            ivf_retrieve_topk(ivf, qv, k=k, nprobe=ivf.n_lists)[0]))
        half.append(np.asarray(
            ivf_retrieve_topk(ivf, qv, k=k, nprobe=ivf.n_lists // 2)[0]))
    # probing every list scores every document: recall is exactly 1
    assert _recall(full, brute, k) >= 0.999
    # a half-width probe keeps most of the true top-k (loose floor: the
    # quantiser would have to be adversarially bad to miss half)
    assert _recall(half, brute, k) >= 0.5


def test_ivf_lists_partition_documents(small_ir):
    ivf = build_ivf_index(small_ir["backend"].dense, n_lists=16, seed=0)
    starts = np.asarray(ivf.list_start)
    assert starts[0] == 0 and starts[-1] == small_ir["index"].n_docs
    assert (np.diff(starts) >= 0).all()
    assert int(np.diff(starts).max()) == ivf.max_list_len
    assert sorted(np.asarray(ivf.doc_ids).tolist()) == \
        list(range(small_ir["index"].n_docs))


# ---------------------------------------------------------------------------
# IVF-PQ: reconstruction, ADC-vs-float parity, gate, doc-axis sharding
# ---------------------------------------------------------------------------

def test_pq_reconstruction_error_decreases_with_m(small_ir):
    """More subspaces -> finer quantisation -> lower reconstruction MSE
    (each subspace clusters a shorter slice with the same 256 codewords)."""
    emb = small_ir["backend"].dense.emb
    mses = []
    for m in (2, 4, 8, 16):
        cb = build_pq_codebook(emb, m=m, iters=8, seed=0)
        rec = np.asarray(pq_decode(cb, pq_encode(cb, emb)))
        mses.append(float(np.mean((np.asarray(emb) - rec) ** 2)))
    assert all(a > b for a, b in zip(mses, mses[1:])), mses


def test_ivfpq_adc_parity_and_recall(small_ir):
    """Two-level search contract: returned scores are *exact* float scores
    of the returned docs (the ADC stage only shortlists), full-probe
    recall@k clears the acceptance floor, and the fused kernel path is
    bit-identical to the unfused reference path."""
    be = small_ir["backend"]
    pqi = build_ivfpq_index(be.dense, n_lists=16, seed=0, m=8)
    emb = np.asarray(be.dense.emb)
    qvecs = np.asarray(be.embed_queries(small_ir["Q"]))
    k = 10
    recalls = []
    for qv in qvecs:
        docs, vals = ivfpq_retrieve_topk(pqi, qv, k=k, nprobe=pqi.n_lists)
        docs, vals = np.asarray(docs), np.asarray(vals)
        # ADC-vs-float parity: the final-K scores ARE the float scores
        np.testing.assert_allclose(vals, emb[docs] @ qv, rtol=1e-5,
                                   atol=1e-5)
        df, vf = ivfpq_retrieve_topk_fused(pqi, qv, k=k, nprobe=pqi.n_lists)
        np.testing.assert_array_equal(np.asarray(df), docs)
        np.testing.assert_array_equal(np.asarray(vf), vals)
        brute = np.asarray(dense_retrieve_exact(be.dense, qv, k=k)[0])
        recalls.append(len(set(docs.tolist()) & set(brute.tolist())) / k)
    assert float(np.mean(recalls)) >= 0.8, recalls


def test_ivfpq_store_compresses_4x(small_ir):
    be = small_ir["backend"]
    pqi = build_ivfpq_index(be.dense, n_lists=16, seed=0, m=8)
    flat = be.dense.emb.size * be.dense.emb.dtype.itemsize
    assert pq_store_bytes(pqi) * 4 <= flat


def test_ivf_keep_flat_false_drops_float_copy(small_ir):
    be = small_ir["backend"]
    ivf = build_ivf_index(be.dense, n_lists=16, seed=0, keep_flat=False)
    assert ivf.emb is None
    with pytest.raises(ValueError):
        ivf_retrieve_topk(ivf, np.zeros(be.dense.dim, np.float32), k=5,
                          nprobe=4)
    # the PQ index built over the skeleton shares the doc-order float
    # store by reference (no list-ordered duplicate is ever materialised)
    pqi = build_ivfpq_index(be.dense, n_lists=16, seed=0, m=8, ivf=ivf)
    assert pqi.emb is be.dense.emb


def test_pq_gate_both_branches(small_ir):
    """The pq_topk cost gate takes the fused kernel lowering for a deep
    retrieve + shallow cutoff and keeps the unfused chain when the
    estimates tie — and the fused rewrite is exact either way."""
    be = _dense_backend(small_ir, default_k=200, extra={"pq_topk"}, pq_m=8)

    rep1 = {}
    op1 = compile_pipeline(DenseRetrieve(k=200, nprobe=8, pq=True) % 10, be,
                           report=rep1)
    assert op1.kind == "fused_dense_retrieve"
    assert op1.params["pq"] is True
    assert op1.params["pq_shortlist"] is not None

    rep2 = {}
    op2 = compile_pipeline(DenseRetrieve(k=10, nprobe=8, pq=True) % 10, be,
                           report=rep2)
    assert op2.kind == "cutoff"

    pq_ds = [d for d in rep1["fusion_decisions"] + rep2["fusion_decisions"]
             if d["pattern"] == "pq_topk"]
    assert [d["accepted"] for d in pq_ds] == [True, False]

    for pipe in (DenseRetrieve(k=200, nprobe=8, pq=True) % 10,
                 DenseRetrieve(k=10, nprobe=8, pq=True) % 10):
        Ro = pipe.transform(small_ir["Q"], backend=be, optimize=True)
        Ru = pipe.transform(small_ir["Q"], backend=be, optimize=False)
        np.testing.assert_array_equal(np.asarray(Ro["docids"]),
                                      np.asarray(Ru["docids"]))
        np.testing.assert_allclose(np.asarray(Ro["scores"]),
                                   np.asarray(Ru["scores"]), rtol=1e-5,
                                   atol=1e-6)


def test_pq_fusion_needs_capability(small_ir):
    """Without ``pq_topk`` the pq chain stays interpreted even though
    ``dense_topk`` is on."""
    be = _dense_backend(small_ir, default_k=200, pq_m=8)
    op = compile_pipeline(DenseRetrieve(k=200, nprobe=8, pq=True) % 10, be)
    assert "fused_dense_retrieve" not in _kinds(op)


def test_nprobe_autotune_measures_then_replays(small_ir):
    """AutotunePass probes the nprobe candidates (wall-clock + overlap
    band) on the first compile and replays the persisted choice with zero
    probe measurements on the second."""
    from repro.core import BackendDescriptor, TuningProfile

    caps = frozenset({"fat", "fused_dense", "dense_topk", "pq_topk"})
    desc = (BackendDescriptor.default(caps)
            .with_autotune(True, probe_queries=2, probe_repeats=1)
            .with_profile(TuningProfile(path=None)))
    be = JaxBackend(small_ir["index"], default_k=200,
                    dense=small_ir["backend"].dense, descriptor=desc,
                    pq_m=8)
    pipe = DenseRetrieve(k=200, nprobe=8, pq=True) % 10
    rep1 = {}
    op1 = compile_pipeline(pipe, be, report=rep1)
    knobs = [d for d in rep1["fusion_decisions"] if d.get("knob") == "nprobe"]
    if not knobs:        # the gate kept the unfused chain: nothing to tune
        pytest.skip("pq fusion not taken on this host; no knob to tune")
    d = knobs[0]
    assert d["source"] == "measured"
    assert d["chosen"] in d["candidates"]
    assert set(d["overlap_at_k"]) == {str(c) for c in d["candidates"]}
    assert op1.params["nprobe"] == d["chosen"]
    rep2 = {}
    op2 = compile_pipeline(pipe, be, report=rep2)
    assert op2.params == op1.params
    knobs2 = [d2 for d2 in rep2["fusion_decisions"]
              if d2.get("knob") == "nprobe"]
    assert knobs2 and knobs2[0]["source"] == "profile"
    assert rep2["tuning"]["probe_measurements"] == 0


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_doc_shard_merge_matches_single_shard_oracle(small_ir, n_shards):
    """Per-shard top-k + cross-shard merge through the engine is
    bit-identical to the single-shard run (and the traced lax merge
    agrees)."""
    import jax
    import jax.numpy as jnp

    from repro.core.engine import StageProgram
    from repro.launch.mesh import make_query_mesh

    be = small_ir["backend"]
    dense = be.dense
    qvecs = be.embed_queries(small_ir["Q"])
    k = 10
    eng = ShardedQueryEngine(mesh=make_query_mesh(doc_shards=1))

    def progs_for(s):
        out = []
        for shard, off in shard_dense_index(dense, s):
            ks = min(k, int(shard.emb.shape[0]))
            fn = (lambda sh, o, kk: (lambda qv: (
                (lambda dv: (dv[0] + jnp.int32(o), dv[1]))(
                    dense_retrieve_exact(sh, qv, k=kk)))))(shard, off, ks)
            out.append(StageProgram(key=("t_shard", s, off), fn=fn))
        return out

    oracle = eng.run_doc_sharded(progs_for(1), None, qvecs, k=k)
    docs, vals = eng.run_doc_sharded(progs_for(n_shards), None, qvecs, k=k)
    np.testing.assert_array_equal(docs, oracle[0])
    np.testing.assert_array_equal(vals, oracle[1])

    shards = shard_dense_index(dense, n_shards)
    dt, vt = jax.jit(jax.vmap(
        lambda q: sharded_dense_topk(shards, q, k=k)))(qvecs)
    np.testing.assert_array_equal(np.asarray(dt), oracle[0])
    np.testing.assert_array_equal(np.asarray(vt), oracle[1])


# ---------------------------------------------------------------------------
# IR round trip preserves key() for the dense ops
# ---------------------------------------------------------------------------

def _dense_pipelines():
    return [
        DenseRetrieve(k=20, nprobe=4),
        DenseRetrieve(k=30, nprobe=0) % 5,
        (Retrieve("BM25", k=30) >> DenseRerank(alpha=0.2)) % 10,
        FusedDenseRetrieve(k=5, nprobe=2),
        FusedDenseRerank(model="BM25", k_in=30, k=5, alpha=0.1),
    ]


@pytest.mark.parametrize("i", range(5))
def test_dense_lower_raise_preserves_key(i):
    pipe = _dense_pipelines()[i]
    op = lower(pipe)
    assert op.key() == pipe.key()
    raised = raise_ir(op)
    assert raised is pipe
    assert raised.key() == pipe.key()


# ---------------------------------------------------------------------------
# engine == sequential for dense pipelines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_pipe", [
    lambda: (Retrieve("BM25", k=60) >> DenseRerank(alpha=0.3)) % 10,
    lambda: DenseRetrieve(k=20, nprobe=4),
], ids=["fused_dense_rerank", "dense_retrieve"])
def test_dense_engine_matches_sequential(small_ir, make_pipe):
    env = small_ir
    ivf = build_ivf_index(env["backend"].dense, n_lists=16, seed=0)
    be_seq = _dense_backend(env, sharded=False, ivf=ivf)
    be_eng = _dense_backend(env, ivf=ivf)
    assert be_eng.engine is not None
    pipe = make_pipe()
    Rs = pipe.transform(env["Q"], backend=be_seq, optimize=True)
    Re = pipe.transform(env["Q"], backend=be_eng, optimize=True)
    np.testing.assert_array_equal(np.asarray(Rs["docids"]),
                                  np.asarray(Re["docids"]))
    np.testing.assert_allclose(np.asarray(Rs["scores"]),
                               np.asarray(Re["scores"]), rtol=1e-5,
                               atol=1e-6)
