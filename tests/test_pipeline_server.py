"""Online serving subsystem: PipelineServer parity vs the offline paths,
zero steady-state recompilation, stage-cache prefix reuse, admission
control, deadlines, and the micro-batching scheduler's closure policy."""
import time

import numpy as np
import pytest

from repro.core import (DenseRerank, ExperimentPlan, Extract, JaxBackend,
                        Retrieve)
from repro.core.compiler import Context
from repro.core.data import make_queries
from repro.serve import (MicroBatchScheduler, PipelineServer, RequestTimeout,
                         RequestTrace, ServeConfig, ServeRequest,
                         ServerOverloaded, StageResultCache)


def _row(Q, i):
    return {k: np.asarray(v)[i:i + 1] for k, v in Q.items()}


def _seq_backend(env):
    return JaxBackend(env["index"], default_k=60, query_chunk=4,
                      dense=env["backend"].dense, sharded=False)


def _replay_rows(server, Q, order):
    reqs = [server.submit_one(_row(Q, i)) for i in order]
    server.pump()
    return [r.wait(30) for r in reqs]


# ---------------------------------------------------------------------------
# serving parity: replayed single queries == plan.execute / sequential
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pipe_fn,name", [
    (lambda: Retrieve("BM25") % 10, "sparse_topk"),
    (lambda: (Retrieve("BM25", k=30) >> DenseRerank(alpha=0.3)) % 10,
     "dense_rerank"),
])
def test_server_matches_offline_paths(small_ir, pipe_fn, name):
    env = small_ir
    pipe = pipe_fn()
    server = PipelineServer(pipe, env["backend"])
    nq = int(np.asarray(env["Q"]["qid"]).shape[0])
    results = _replay_rows(server, env["Q"], range(nq))
    got_d = np.concatenate([np.asarray(r["docids"]) for r in results], 0)
    got_s = np.concatenate([np.asarray(r["scores"]) for r in results], 0)
    # vs the sequential engine (the seed execution path)
    ref = pipe.transform(env["Q"], backend=_seq_backend(env), optimize=False)
    np.testing.assert_array_equal(got_d, np.asarray(ref["docids"]))
    np.testing.assert_allclose(got_s, np.asarray(ref["scores"]), rtol=1e-6)
    # vs the experiment plan on the server's own (sharded) backend
    plan = ExperimentPlan([pipe], env["backend"])
    [rp] = plan.execute(env["Q"], ctx=Context(env["backend"]), record=None)
    np.testing.assert_array_equal(got_d, np.asarray(rp["docids"]))
    # qids must be the requester's, not a cache donor's
    assert [int(np.asarray(r["qid"])[0]) for r in results] == list(range(nq))


def test_server_burst_submit_and_out_of_order_replay(small_ir):
    env = small_ir
    pipe = Retrieve("BM25", k=20) >> Extract("QL")
    server = PipelineServer(pipe, env["backend"])
    order = [3, 0, 7, 1, 1, 6]
    results = _replay_rows(server, env["Q"], order)
    ref = pipe.transform(env["Q"], backend=_seq_backend(env), optimize=False)
    for i, r in zip(order, results):
        np.testing.assert_array_equal(np.asarray(r["docids"])[0],
                                      np.asarray(ref["docids"])[i])
        np.testing.assert_allclose(np.asarray(r["features"])[0],
                                   np.asarray(ref["features"])[i], rtol=1e-6)
    # burst: one submit call with several rows returns a request list
    reqs = server.submit({k: np.asarray(v)[:3] for k, v in env["Q"].items()})
    assert isinstance(reqs, list) and len(reqs) == 3
    server.pump()
    for i, rq in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(rq.wait(30)["docids"])[0],
                                      np.asarray(ref["docids"])[i])


# ---------------------------------------------------------------------------
# steady state never recompiles
# ---------------------------------------------------------------------------

def test_no_recompiles_after_warmup_across_100_requests(small_ir):
    env = small_ir
    be = JaxBackend(env["index"], default_k=60, query_chunk=4,
                    dense=env["backend"].dense)
    server = PipelineServer(Retrieve("BM25") % 10, be,
                            ServeConfig.default(cache_entries=0))
    server.warmup(env["Q"])
    for rep in range(13):                           # 13 * 8 = 104 requests
        server.submit(env["Q"])
        server.pump()
    s = server.stats()
    assert s["served"] >= 100
    assert s["recompiles_since_warmup"] == 0
    assert s["engine"]["max_compiles_per_stage"] <= len(be.engine.ladder)


# ---------------------------------------------------------------------------
# stage-keyed result cache
# ---------------------------------------------------------------------------

def test_repeated_query_full_cache_hit(small_ir):
    env = small_ir
    server = PipelineServer(Retrieve("BM25") % 10, env["backend"])
    r1 = server.submit_one(_row(env["Q"], 0))
    server.pump()
    first = r1.wait(30)
    r2 = server.submit_one(_row(env["Q"], 0))
    server.pump()
    second = r2.wait(30)
    assert r2.trace.cache_hit_depth == r2.trace.chain_len
    np.testing.assert_array_equal(np.asarray(first["docids"]),
                                  np.asarray(second["docids"]))
    assert server.stats()["stage_cache"]["hits"] >= 1


def test_shared_cache_resumes_prefix_across_servers(small_ir):
    """Two pipelines sharing a retrieval prefix: the second server resumes
    mid-chain from entries the first one wrote — the online mirror of the
    plan trie's shared-prefix execution."""
    env = small_ir
    shared = StageResultCache(1024)
    s1 = PipelineServer(Retrieve("BM25", k=20) >> Extract("QL"),
                        env["backend"], ServeConfig.default(optimize=False),
                        cache=shared)
    assert len(s1.chain) == 2
    _replay_rows(s1, env["Q"], range(4))
    s2 = PipelineServer(Retrieve("BM25", k=20) >> Extract("TF_IDF"),
                        env["backend"], ServeConfig.default(optimize=False),
                        cache=shared)
    req = s2.submit_one(_row(env["Q"], 2))
    server_new = s2.submit_one(_row(env["Q"], 6))       # never seen by s1
    s2.pump()
    out = req.wait(30)
    out_new = server_new.wait(30)
    assert req.trace.cache_hit_depth == 1           # resumed after Retrieve
    assert server_new.trace.cache_hit_depth == 0
    ref = (Retrieve("BM25", k=20) >> Extract("TF_IDF")).transform(
        env["Q"], backend=_seq_backend(env), optimize=False)
    for i, r in ((2, out), (6, out_new)):
        np.testing.assert_array_equal(np.asarray(r["docids"])[0],
                                      np.asarray(ref["docids"])[i])
        np.testing.assert_allclose(np.asarray(r["features"])[0],
                                   np.asarray(ref["features"])[i], rtol=1e-6)
        assert int(np.asarray(r["qid"])[0]) == i    # re-stamped, not donor's
    # the full second pipeline is now cached end-to-end
    again = s2.submit_one(_row(env["Q"], 2))
    s2.pump()
    again.wait(30)
    assert again.trace.cache_hit_depth == 2


def test_stage_cache_lru_bound(small_ir):
    env = small_ir
    server = PipelineServer(Retrieve("BM25") % 10, env["backend"],
                            ServeConfig.default().with_cache(3))
    _replay_rows(server, env["Q"], range(8))
    info = server.stats()["stage_cache"]
    assert info["size"] <= 3
    assert info["evictions"] >= 5


# ---------------------------------------------------------------------------
# admission control + deadlines
# ---------------------------------------------------------------------------

def test_admission_control_rejects_when_queue_full(small_ir):
    env = small_ir
    server = PipelineServer(Retrieve("BM25") % 10, env["backend"],
                            ServeConfig.default().with_queue(2))
    server.submit_one(_row(env["Q"], 0))
    with pytest.raises(ServerOverloaded):
        # burst admission is all-or-nothing: 2 rows into 1 free slot must
        # admit neither (partial admission would execute requests the
        # caller holds no handles to)
        server.submit({k: np.asarray(v)[1:3] for k, v in env["Q"].items()})
    server.submit_one(_row(env["Q"], 1))
    with pytest.raises(ServerOverloaded):
        server.submit_one(_row(env["Q"], 2))
    assert server.stats()["scheduler"]["rejected"] == 3
    server.pump()                                   # queued ones still serve
    assert server.stats()["served"] == 2


def test_expired_request_dropped_not_executed(small_ir):
    env = small_ir
    server = PipelineServer(Retrieve("BM25") % 10, env["backend"],
                            ServeConfig.default().with_deadlines(10))
    req = server.submit_one(_row(env["Q"], 0))
    time.sleep(0.05)
    server.pump()
    with pytest.raises(RequestTimeout):
        req.wait(5)
    assert req.trace.timed_out
    assert server.stats()["timed_out"] == 1


# ---------------------------------------------------------------------------
# scheduler policy (no server, no jax)
# ---------------------------------------------------------------------------

def _mk_req(rid):
    return ServeRequest(rid=rid, Q=None, deadline=None,
                        trace=RequestTrace(rid=rid))


def test_scheduler_fills_batches_under_heavy_load():
    sch = MicroBatchScheduler(ladder=(4, 8), max_wait_ms=1000.0)
    for i in range(19):
        sch.submit(_mk_req(i))
    sizes = []
    while True:
        b = sch.next_batch(drain=True)
        if b is None:
            break
        sizes.append((len(b.requests), b.reason))
    # two full max-bucket batches close immediately; the tail drains
    assert sizes == [(8, "full"), (8, "full"), (3, "drain")]


def test_scheduler_bounds_wait_under_light_load():
    sch = MicroBatchScheduler(ladder=(4, 8), max_wait_ms=10.0)
    assert sch.next_batch() is None
    sch.submit(_mk_req(0))
    assert sch.next_batch() is None                 # younger than max_wait
    t0 = time.monotonic()
    b = sch.next_batch(block=True, timeout=2.0)
    waited = time.monotonic() - t0
    assert b is not None and b.reason == "deadline" and len(b.requests) == 1
    assert waited < 1.0                             # ~max_wait, not timeout


def test_scheduler_bucket_selection_matches_ladder():
    sch = MicroBatchScheduler(ladder=(4, 8, 16))
    assert [sch.select_bucket(n) for n in (1, 4, 5, 9, 16)] == [4, 4, 8, 16, 16]


# ---------------------------------------------------------------------------
# threaded continuous mode
# ---------------------------------------------------------------------------

def test_threaded_server_smoke(small_ir):
    env = small_ir
    server = PipelineServer(Retrieve("BM25") % 10, env["backend"],
                            ServeConfig.default(max_wait_ms=2.0)).start()
    try:
        reqs = []
        for i in range(24):
            reqs.append(server.submit_one(_row(env["Q"], i % 8)))
            time.sleep(0.001)
        outs = [r.wait(60) for r in reqs]
    finally:
        server.stop()
    assert server.last_error is None
    assert server.stats()["served"] == 24
    ref = (Retrieve("BM25") % 10).transform(env["Q"],
                                            backend=_seq_backend(env),
                                            optimize=False)
    for i, out in enumerate(outs):
        np.testing.assert_array_equal(np.asarray(out["docids"])[0],
                                      np.asarray(ref["docids"])[i % 8])
