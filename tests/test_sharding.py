"""Sharding-rule resolution logic (host-only, no devices needed beyond 1)."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import sharding as sh


@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_resolve_drops_nondivisible(mesh11):
    profile = {"q_heads": ("model",), "batch": ("data",)}
    # both divide a 1-sized axis trivially
    spec = sh.resolve_spec((sh.BATCH, sh.Q_HEADS), (4, 12), mesh11, profile)
    assert spec == P("data", "model")


def test_resolve_prefix_fallback():
    profile = {"candidates": ("data", "model")}
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # both axes size 1 -> divides; exercise the prefix logic with fake sizes
    # via pure function: simulate with a mesh of shape (2, 3) using host trick
    spec = sh.resolve_spec(("candidates",), (10,), mesh, profile)
    assert spec == P(("data", "model"))


def test_zero1_spec_extends_free_dim():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    s = sh.zero1_spec(P(None, "model"), (8, 16), mesh)
    assert s == P("data", "model")
    # no free divisible dim -> unchanged
    s2 = sh.zero1_spec(P("data", None), (8, 7), mesh)
    assert s2 == P("data", None)


def test_ax_is_leaf():
    tree = {"w": sh.Ax(None, sh.MLP), "b": sh.Ax(sh.MLP)}
    leaves = jax.tree.leaves(tree)
    assert len(leaves) == 2
    assert all(isinstance(l, sh.Ax) for l in leaves)


def test_dp_axes():
    m1 = jax.make_mesh((1, 1), ("data", "model"))
    assert sh.dp_axes(m1) == ("data",)


def test_profiles_cover_all_families(mesh11):
    # activation axes every profile must place
    for name, fn in sh.PROFILES.items():
        prof = fn(mesh11)
        for axis in [sh.BATCH, sh.KV_SEQ, sh.TABLE_ROWS, sh.EDGES,
                     sh.CANDIDATES]:
            assert axis in prof, (name, axis)
    # weight axes for the weight-sharding profiles ('dp' replicates by design)
    for name in ["tp", "fsdp", "zero3", "light"]:
        prof = sh.PROFILES[name](mesh11)
        for axis in [sh.MLP, sh.VOCAB, sh.EXPERTS]:
            assert axis in prof, (name, axis)
