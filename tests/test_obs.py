"""Observability subsystem: metrics registry semantics and Prometheus
exposition, span tracer nesting + Chrome export, flight-recorder ring,
TraceLog writer/reader thread safety, and the served-burst integration
invariants (nested request spans, cause-tagged compiles, zero compile
events after warmup, stats() parity)."""
import json
import threading

import numpy as np
import pytest

from repro.core import DenseRerank, JaxBackend, Retrieve
from repro.obs import (CounterMap, FlightRecorder, MetricsRegistry,
                       NOOP_SPAN, Tracer)
from repro.serve import PipelineServer, ServeConfig
from repro.serve.request import RequestTrace
from repro.serve.trace import TraceLog, latency_summary


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests", ("outcome",))
    c.inc(labels=("ok",))
    c.inc(2, labels=("ok",))
    c.inc(labels=("err",))
    assert c.value(("ok",)) == 3.0
    assert c.value(("err",)) == 1.0
    g = reg.gauge("depth", "queue depth")
    g.set(7)
    g.add(-2)
    assert g.value() == 5.0
    g.set_fn(lambda: 11.0)
    assert reg.snapshot()["depth"]["series"][""] == 11
    h = reg.histogram("lat_ms", "latency", buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 5.0, 50.0, 500.0):
        h.observe(v)
    st = h.stats()
    assert st["count"] == 4 and st["min"] == 0.5 and st["max"] == 500.0
    assert st["mean"] == pytest.approx(555.5 / 4)


def test_registry_get_or_create_is_idempotent_and_kind_checked():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "x", ("k",))
    b = reg.counter("x_total")
    assert a is b                      # shared components aggregate
    a.inc(labels=("v",))
    assert b.value(("v",)) == 1.0
    with pytest.raises(TypeError):
        reg.gauge("x_total")


def test_render_text_escapes_label_values():
    reg = MetricsRegistry()
    c = reg.counter("esc_total", "escaping", ("q",))
    c.inc(labels=('back\\slash "quoted"\nnewline',))
    text = reg.render_text()
    assert ('esc_total{q="back\\\\slash \\"quoted\\"\\nnewline"} 1'
            in text)
    assert "# TYPE esc_total counter" in text
    assert "# HELP esc_total escaping" in text


def test_histogram_exposition_buckets_are_cumulative():
    reg = MetricsRegistry()
    h = reg.histogram("h_ms", "", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 1.6, 3.0, 99.0):
        h.observe(v)
    lines = reg.render_text().splitlines()
    buckets = [ln for ln in lines if ln.startswith("h_ms_bucket")]
    assert buckets == ['h_ms_bucket{le="1"} 1', 'h_ms_bucket{le="2"} 3',
                       'h_ms_bucket{le="4"} 4', 'h_ms_bucket{le="+Inf"} 5']
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in buckets]
    assert counts == sorted(counts)           # cumulative => monotone
    assert "h_ms_sum" in "\n".join(lines)
    assert 'h_ms_count 5' in lines


def test_empty_registry_renders_empty():
    reg = MetricsRegistry()
    assert reg.render_text() == ""
    assert reg.snapshot() == {}


def test_countermap_is_dict_shaped():
    reg = MetricsRegistry()
    cm = CounterMap(reg.counter("tuning_total", "", ("counter",)),
                    ("hits", "misses"))
    cm["hits"] += 1
    cm["hits"] += 1
    cm["misses"] = 5
    assert dict(cm) == {"hits": 2, "misses": 5}
    with pytest.raises(KeyError):
        cm["unknown"] += 1


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_tracer_nests_via_stack_and_explicit_parent_wins():
    tr = Tracer(enabled=True)
    with tr.span("outer", cat="t") as outer:
        with tr.span("inner", cat="t") as inner:
            assert inner.span_id != outer.span_id
        detached = tr.begin("detached", parent=None)
        detached.end()
    orphan = tr.add_span("retro", 0.0, 0.1, parent=outer.span_id, tid=42)
    recs = {r["name"]: r for r in tr.records()}
    assert recs["inner"]["parent"] == outer.span_id
    assert recs["outer"]["parent"] is None
    assert recs["detached"]["parent"] is None
    assert recs["retro"]["parent"] == outer.span_id and orphan is not None
    assert recs["retro"]["tid"] == 42


def test_tracer_chrome_export_is_valid_and_bounded():
    tr = Tracer(enabled=True, capacity=8)
    for i in range(20):
        with tr.span(f"s{i}", cat="c", i=i):
            pass
    tr.event("mark", cat="c")
    out = json.loads(tr.export_chrome_json())
    assert len(out["traceEvents"]) == 8          # ring bound held
    assert out["otherData"]["dropped_records"] == 13
    for ev in out["traceEvents"]:
        assert {"name", "ph", "ts", "pid", "tid", "args"} <= set(ev)
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0
        else:
            assert ev["ph"] == "i" and ev["s"] == "t"


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    assert tr.span("x") is NOOP_SPAN
    assert tr.begin("x") is NOOP_SPAN
    assert tr.add_span("x", 0.0, 1.0) is None
    assert tr.event("x") is None
    assert len(tr) == 0
    assert tr.export_chrome()["traceEvents"] == []


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_flight_recorder_ring_and_kinds():
    fr = FlightRecorder(capacity=4)
    for i in range(10):
        fr.record("admit", rid=i)
    fr.record("shed_door", rid=99)
    events = fr.dump()
    assert len(events) == 4                       # bounded
    assert fr.n_recorded == 11
    assert events[-1]["kind"] == "shed_door"
    assert all("t" in e for e in events)
    assert fr.kinds() == {"admit": 3, "shed_door": 1}
    assert fr.dump(last=2) == events[-2:]
    fr.clear()
    assert fr.dump() == []


# ---------------------------------------------------------------------------
# TraceLog: registry-backed counters + thread safety
# ---------------------------------------------------------------------------

def _mk_trace(rid, *, tenant="default", lane="default", timed_out=False,
              n_tokens=0):
    tr = RequestTrace(rid=rid, tenant=tenant, lane=lane)
    tr.latency_ms = 1.0 + (rid % 7)
    tr.queue_wait_ms = 0.25
    tr.cache_hit_depth = rid % 3
    tr.timed_out = timed_out
    tr.ttft_ms = 0.5 if n_tokens else 0.0
    tr.n_tokens = n_tokens
    return tr


def test_tracelog_summary_is_registry_view():
    log = TraceLog(capacity=64)
    log.register_tenant("default")
    for i in range(10):
        log.record(_mk_trace(i, n_tokens=4 if i % 2 else 0))
    log.record(_mk_trace(10, timed_out=True))
    log.record_batch(5)
    s = log.summary()
    assert s["served"] == 10 and s["timed_out"] == 1
    assert s["batches"] == 1 and s["max_batch_size"] == 5
    assert s["decode"]["requests"] == 5 and s["decode"]["tokens"] == 20
    # the identical numbers must be visible in the Prometheus exposition
    text = log.metrics.render_text()
    assert 'serve_requests_total{tenant="default",outcome="served"} 10' \
        in text
    assert 'serve_decode_tokens_total 20' in text


def test_tracelog_threaded_writers_vs_readers():
    log = TraceLog(capacity=128)
    log.register_tenant("default")
    stop = threading.Event()
    errors: list[BaseException] = []

    def writer(base):
        i = 0
        try:
            while not stop.is_set():
                log.record(_mk_trace(base + i, n_tokens=i % 3))
                log.record_batch(1 + i % 8)
                log.record_stage("retrieve", 0.5)
                i += 1
        except BaseException as e:      # surfaced below
            errors.append(e)

    def reader():
        last_served = last_batches = -1
        try:
            while not stop.is_set():
                s = log.summary()
                assert s["served"] >= last_served      # monotone counters
                assert s["batches"] >= last_batches
                last_served, last_batches = s["served"], s["batches"]
                log.metrics.snapshot()
                latency_summary([1.0, 2.0])
        except BaseException as e:
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(k * 1_000_000,))
               for k in range(2)]
    threads += [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    import time
    time.sleep(1.0)
    stop.set()
    for t in threads:
        t.join(10)
    assert not errors, errors
    s = log.summary()
    assert s["served"] > 0 and s["batches"] > 0


# ---------------------------------------------------------------------------
# served burst integration: spans nest, compiles are cause-tagged, the
# post-warmup trace carries zero compile events, stats() keeps its shape
# ---------------------------------------------------------------------------

def _row(Q, i):
    return {k: np.asarray(v)[i:i + 1] for k, v in Q.items()}


@pytest.fixture(scope="module")
def obs_server(small_ir):
    env = small_ir
    backend = JaxBackend(env["index"], default_k=60, query_chunk=4,
                         dense=env["backend"].dense)
    cfg = (ServeConfig.default(max_wait_ms=2.0)
           .with_observability(True))
    pipe = (Retrieve("BM25", k=30) >> DenseRerank(alpha=0.3)) % 10
    server = PipelineServer(pipe, backend, cfg)
    server.warmup(env["Q"])
    warm_records = server.tracer.records()
    server.tracer.clear()
    reqs = [server.submit_one(_row(env["Q"], i % 8)) for i in range(12)]
    server.pump()
    for r in reqs:
        r.wait(60)
    return {"server": server, "warm_records": warm_records}


def test_burst_trace_nests_request_children(obs_server):
    out = obs_server["server"].trace_export()
    evs = out["traceEvents"]
    json.loads(json.dumps(out))                  # valid Chrome trace JSON
    ids = {e["args"]["span_id"] for e in evs}
    roots = [e for e in evs if e["name"] == "serve.request"]
    assert len(roots) == 12
    by_parent: dict = {}
    for e in evs:
        by_parent.setdefault(e["args"].get("parent_id"), []).append(e)
    for root in roots:
        kids = by_parent.get(root["args"]["span_id"], [])
        names = {k["name"] for k in kids}
        assert "serve.queue" in names and "serve.batch" in names
        # children nest inside the request's [t_arrival, t_done] window
        t0, t1 = root["ts"], root["ts"] + root["dur"]
        for k in kids:
            assert k["ts"] >= t0 - 1.0
            assert k["ts"] + k.get("dur", 0.0) <= t1 + 1.0
    assert all(e["args"].get("parent_id") in ids or
               e["args"].get("parent_id") is None for e in evs)


def test_warmup_compiles_are_cause_tagged(obs_server):
    compiles = [r for r in obs_server["warm_records"]
                if r["name"] == "engine.jit_compile"]
    assert compiles, "warmup on a fresh backend must jit-compile"
    assert all(r["args"]["cause"] in ("cold_rung", "ladder_miss", "pinned")
               for r in compiles)
    assert {"cold_rung"} <= {r["args"]["cause"] for r in compiles}


def test_no_compile_events_after_warmup(obs_server):
    server = obs_server["server"]
    post = [r for r in server.tracer.records()
            if r["name"] == "engine.jit_compile"]
    assert post == []
    assert server.stats()["recompiles_since_warmup"] == 0


def test_stats_parity_and_registry_backing(obs_server):
    server = obs_server["server"]
    s = server.stats()
    for key in ("pipeline", "chain_len", "config", "scheduler", "served",
                "timed_out", "shed", "errors", "late", "batches",
                "mean_batch_size", "max_batch_size", "cache_hit_depths",
                "lane_served", "pipelines", "latency_ms", "queue_wait_ms",
                "stage_cache", "cross_pipeline_hits", "engine",
                "recompiles_since_warmup", "tuning", "tuning_profile"):
        assert key in s, key
    # field-for-field: the summary dict and the registry agree
    snap = server.metrics_snapshot()
    assert (snap["serve_requests_total"]["series"]
            ["tenant=default,outcome=served"] == s["served"] == 12)
    assert snap["serve_batches_total"]["series"][""] == s["batches"]
    text = server.metrics_text()
    assert "# TYPE serve_requests_total counter" in text
    assert "# TYPE engine_compiles_total counter" in text
    assert "# TYPE sched_requests_total counter" in text
    assert "# TYPE stage_cache_lookups_total counter" in text


def test_flight_recorder_captured_lifecycle(obs_server):
    server = obs_server["server"]
    events = server.flight_record()
    kinds = {e["kind"] for e in events}
    assert "admit" in kinds and "batch_close" in kinds
    admits = [e for e in events if e["kind"] == "admit"]
    assert all("rid" in e and "lane" in e for e in admits)


def test_trace_export_writes_perfetto_file(obs_server, tmp_path):
    path = tmp_path / "trace.json"
    out = obs_server["server"].trace_export(str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk == json.loads(json.dumps(out))
    assert on_disk["displayTimeUnit"] == "ms"


def test_observability_disabled_is_default_and_cheap(small_ir):
    env = small_ir
    server = PipelineServer(Retrieve("BM25") % 10, env["backend"],
                            ServeConfig.default())
    req = server.submit_one(_row(env["Q"], 0))
    server.pump()
    req.wait(30)
    assert not server.tracer.enabled
    assert server.trace_export()["traceEvents"] == []
    assert server.flight_record() == []
    # metrics stay on regardless: stats() is always registry-backed
    assert server.stats()["served"] == 1
    assert "serve_requests_total" in server.metrics_snapshot()
