"""MoE dispatch invariants: scatter vs einsum equivalence, capacity, gating."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:      # property test skips; fallback below runs
    HAVE_HYPOTHESIS = False

from repro.models.moe import MoEConfig, moe_apply, moe_init


def _setup(E, k, d=32, ff=16, dispatch="scatter", cf=1.25, **kw):
    cfg = MoEConfig(n_experts=E, top_k=k, d_ff_expert=ff,
                    capacity_factor=cf, dispatch=dispatch, **kw)
    params = moe_init(jax.random.key(0), d, cfg)
    return cfg, params


@pytest.mark.parametrize("E,k", [(4, 1), (8, 2), (16, 4)])
def test_scatter_einsum_equivalent(E, k):
    """Both dispatch strategies must produce identical outputs when no
    tokens are dropped (generous capacity)."""
    d = 32
    cfg_s, params = _setup(E, k, d, dispatch="scatter", cf=float(E))
    cfg_e = MoEConfig(n_experts=E, top_k=k, d_ff_expert=16,
                      capacity_factor=float(E), dispatch="einsum",
                      group_size=64)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 16, d)),
                    jnp.float32)
    out_s, m_s = moe_apply(params, x, cfg_s)
    out_e, m_e = moe_apply(params, x, cfg_e)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_e),
                               atol=1e-4)


def test_capacity_drops_tokens():
    """With 64 total capacity slots and 256 tokens top-1, some token outputs
    must be zero (dropped), none NaN."""
    d = 16
    cfg, params = _setup(8, 1, d, cf=0.1)
    x = jnp.asarray(np.random.default_rng(2).standard_normal((1, 256, d)),
                    jnp.float32)
    out, _ = moe_apply(params, x, cfg)
    norms = np.linalg.norm(np.asarray(out)[0], axis=-1)
    assert (norms == 0).any()          # dropped tokens
    assert np.isfinite(np.asarray(out)).all()


def test_shared_expert_always_on():
    """With a shared expert, even dropped tokens get nonzero output."""
    d = 16
    cfg = MoEConfig(n_experts=8, top_k=1, d_ff_expert=16, n_shared=1,
                    d_ff_shared=16, capacity_factor=0.25)
    params = moe_init(jax.random.key(3), d, cfg)
    x = jnp.asarray(np.random.default_rng(3).standard_normal((1, 32, d)),
                    jnp.float32)
    out, _ = moe_apply(params, x, cfg)
    norms = np.linalg.norm(np.asarray(out)[0], axis=-1)
    assert (norms > 0).all()


def _check_moe_grads_finite(k, E):
    d = 16
    cfg, params = _setup(E, min(k, E), d)
    x = jnp.asarray(np.random.default_rng(4).standard_normal((1, 8, d)),
                    jnp.float32)

    def loss(p):
        out, metrics = moe_apply(p, x, cfg)
        return jnp.sum(out ** 2) + metrics["moe_aux"] + metrics["moe_z"]

    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


if HAVE_HYPOTHESIS:
    @settings(max_examples=4, deadline=None)
    @given(st.sampled_from([1, 3]), st.sampled_from([4, 8]))
    def test_moe_grads_finite(k, E):
        _check_moe_grads_finite(k, E)


@pytest.mark.parametrize("k,E", [(1, 4), (3, 8)])
def test_moe_grads_finite_fixed(k, E):
    _check_moe_grads_finite(k, E)


def test_aux_loss_penalises_imbalance():
    """A router forced onto one expert must have higher aux loss than a
    uniform router."""
    d, E = 16, 8
    cfg, params = _setup(E, 1, d, aux_loss_weight=1.0)
    x = jnp.asarray(np.random.default_rng(5).standard_normal((1, 64, d)),
                    jnp.float32)
    biased = dict(params)
    biased["router"] = jnp.zeros_like(params["router"]).at[:, 0].set(50.0)
    uniform = dict(params)
    uniform["router"] = jnp.zeros_like(params["router"])
    _, m_biased = moe_apply(biased, x, cfg)
    _, m_uniform = moe_apply(uniform, x, cfg)
    assert float(m_biased["moe_aux"]) > float(m_uniform["moe_aux"]) * 2
