import numpy as np
import pytest

from repro.core.compiler import JaxBackend
from repro.core.data import make_queries
from repro.index import build_index, synthesize_corpus, synthesize_topics


@pytest.fixture(scope="session")
def small_ir():
    """Shared small corpus/index/backend/topics for IR-system tests."""
    corpus = synthesize_corpus(n_docs=3000, vocab=12000, mean_len=100, seed=7)
    topics = synthesize_topics(corpus, n_topics=8, q_len=3, rels_per_topic=12,
                               seed=8)
    index = build_index(corpus)
    backend = JaxBackend(index, default_k=60, query_chunk=4)
    Q = make_queries(np.asarray(topics.terms), np.asarray(topics.weights),
                     np.asarray(topics.qids))
    return {"corpus": corpus, "topics": topics, "index": index,
            "backend": backend, "Q": Q}
