"""Deadline-aware serving policy: EDF packing, shed-before-execute, WFQ
lane fairness, adaptive wait, the multi-tenant shared-cache server, and
the ServeConfig front API (incl. the legacy-kwargs deprecation shim)."""
import time

import numpy as np
import pytest

from repro.common import select_ladder_bucket
from repro.core import Extract, JaxBackend, Retrieve
from repro.serve import (DeadlineUnmeetable, MicroBatchScheduler,
                         MultiPipelineServer, PipelineServer, RequestTimeout,
                         RequestTrace, ServeConfig, ServeRequest,
                         ServerOverloaded, StageResultCache)


def _row(Q, i):
    return {k: np.asarray(v)[i:i + 1] for k, v in Q.items()}


def _seq_backend(env):
    return JaxBackend(env["index"], default_k=60, query_chunk=4,
                      dense=env["backend"].dense, sharded=False)


def _mk_req(rid, deadline=None, lane="default"):
    return ServeRequest(rid=rid, Q=None, deadline=deadline, lane=lane,
                        trace=RequestTrace(rid=rid))


# ---------------------------------------------------------------------------
# EDF packing
# ---------------------------------------------------------------------------

def test_edf_orders_mixed_deadlines():
    """Batch packing is earliest-deadline-first: urgent requests jump the
    arrival order; deadline-free requests ride last, FIFO among
    themselves."""
    sch = MicroBatchScheduler(ladder=(8,), max_wait_ms=1000.0)
    now = time.monotonic()
    sch.submit(_mk_req(0, deadline=now + 5.0))
    sch.submit(_mk_req(1, deadline=None))
    sch.submit(_mk_req(2, deadline=now + 1.0))
    sch.submit(_mk_req(3, deadline=now + 3.0))
    sch.submit(_mk_req(4, deadline=None))
    b = sch.next_batch(drain=True)
    assert [r.rid for r in b.requests] == [2, 3, 0, 1, 4]


def test_edf_fifo_without_deadlines():
    """No deadlines anywhere == the old FIFO behaviour, bit-identical."""
    sch = MicroBatchScheduler(ladder=(4, 8), max_wait_ms=1000.0)
    for i in range(19):
        sch.submit(_mk_req(i))
    sizes, rids = [], []
    while True:
        b = sch.next_batch(drain=True)
        if b is None:
            break
        sizes.append((len(b.requests), b.reason))
        rids.extend(r.rid for r in b.requests)
    assert sizes == [(8, "full"), (8, "full"), (3, "drain")]
    assert rids == list(range(19))


# ---------------------------------------------------------------------------
# shed-before-execute
# ---------------------------------------------------------------------------

def test_shed_rejects_unmeetable_deadline_at_submit():
    sch = MicroBatchScheduler(ladder=(8,))
    sch.note_service_time(0.1)                 # one batch costs 100ms
    now = time.monotonic()
    with pytest.raises(DeadlineUnmeetable):
        sch.submit(_mk_req(0, deadline=now + 0.01))
    # DeadlineUnmeetable IS a ServerOverloaded: existing shed-load handlers
    # keep working
    with pytest.raises(ServerOverloaded):
        sch.submit(_mk_req(1, deadline=now + 0.01))
    assert sch.stats()["shed_submit"] == 2
    assert sch.qsize() == 0                    # never occupied queue space
    # a feasible deadline still admits
    sch.submit(_mk_req(2, deadline=now + 10.0))
    assert sch.qsize() == 1


def test_shed_estimates_queue_wait_ahead():
    """With a backlog, the shed test charges (queued/max_batch) batches of
    queue wait before the request's own batch."""
    sch = MicroBatchScheduler(ladder=(4,))     # max_batch 4
    sch.note_service_time(0.1)
    now = time.monotonic()
    for i in range(8):                         # 2 full batches ahead
        sch.submit(_mk_req(i, deadline=now + 10.0))
    # needs ~(8/4)*0.1 + 0.1 = 300ms; a 150ms deadline cannot survive
    with pytest.raises(DeadlineUnmeetable):
        sch.submit(_mk_req(9, deadline=now + 0.15))
    sch.submit(_mk_req(10, deadline=now + 0.5))    # 500ms can


def test_shed_drops_at_batch_close_without_ladder_slot():
    """A request that became infeasible while queued is shed into
    ``Batch.shed`` at close — the batch back-fills with feasible work
    instead of spending a slot on it."""
    sch = MicroBatchScheduler(ladder=(2,))
    now = time.monotonic()
    # no EWMA yet: everything admits
    sch.submit(_mk_req(0, deadline=now + 0.02))
    sch.submit(_mk_req(1, deadline=now + 30.0))
    sch.submit(_mk_req(2, deadline=now + 30.0))
    sch.note_service_time(0.1)                 # learned between submit/close
    time.sleep(0.03)                           # rid 0's deadline now < S away
    b = sch.next_batch(drain=True)
    assert [r.rid for r in b.shed] == [0]
    assert all(r.trace.shed for r in b.shed)
    assert [r.rid for r in b.requests] == [1, 2]   # back-filled to max_batch
    assert sch.stats()["shed_queue"] == 1


def test_service_estimate_scales_by_bucket():
    """Per-rung estimates: measured rungs are exact, unmeasured rungs
    scale linearly from the nearest measured one."""
    sch = MicroBatchScheduler(ladder=(2, 4, 8))
    sch.note_service_time(0.4, 4)
    assert sch.service_estimate() == pytest.approx(0.4)
    assert sch.service_estimate(3) == pytest.approx(0.4)   # rung 4, measured
    assert sch.service_estimate(1) == pytest.approx(0.2)   # rung 2, scaled
    assert sch.service_estimate(8) == pytest.approx(0.8)   # rung 8, scaled
    sch.note_service_time(0.3, 8)                          # now measured
    assert sch.service_estimate(8) == pytest.approx(0.3)
    assert sch.stats()["slot_ms_ewma"] is not None


def test_bucket_estimate_affine_fit():
    """With two measured rungs the estimate is an affine fit — it carries
    the fixed per-batch dispatch cost instead of scaling it away."""
    sch = MicroBatchScheduler(ladder=(2, 4, 8, 16))
    sch.note_service_time(0.2, 2)
    sch.note_service_time(0.44, 8)
    # fit through (2, 0.2), (8, 0.44): c1 = 0.04/slot, c0 = 0.12 fixed
    assert sch.service_estimate(4) == pytest.approx(0.28, rel=1e-6)
    assert sch.service_estimate(16) == pytest.approx(0.76, rel=1e-6)


def test_deadline_caps_batch_size():
    """A batch never packs past the rung the most urgent taken deadline
    can survive: with S(8) ~ 800ms, a 300ms deadline forces a 2-bucket
    batch even though 8 requests are queued."""
    sch = MicroBatchScheduler(ladder=(2, 4, 8), max_wait_ms=1000.0)
    for _ in range(8):
        sch.note_service_time(0.8, 8)    # 100ms/slot: S(2)=.2 S(4)=.4 S(8)=.8
    now = time.monotonic()
    sch.submit(_mk_req(0, deadline=now + 0.3))
    for i in range(1, 8):
        sch.submit(_mk_req(i, deadline=now + 30.0))
    b = sch.next_batch(drain=True)
    assert [r.rid for r in b.requests] == [0, 1] and not b.shed
    b2 = sch.next_batch(drain=True)      # the loose tail packs freely
    assert len(b2.requests) == 6


def test_no_shedding_before_first_measurement():
    """Until the EWMA has a sample, only already-expired deadlines shed —
    the model never guesses."""
    sch = MicroBatchScheduler(ladder=(8,))
    now = time.monotonic()
    sch.submit(_mk_req(0, deadline=now + 0.001))   # tight but future: admits
    with pytest.raises(DeadlineUnmeetable):
        sch.submit(_mk_req(1, deadline=now - 1.0))  # already expired


# ---------------------------------------------------------------------------
# WFQ lanes
# ---------------------------------------------------------------------------

def test_wfq_lane_weights_share_batch_slots():
    sch = MicroBatchScheduler(ladder=(8,), lanes=(("fg", 3.0), ("bg", 1.0)),
                              default_lane="fg")
    for i in range(16):
        sch.submit(_mk_req(i, lane="fg"))
    for i in range(16, 32):
        sch.submit(_mk_req(i, lane="bg"))
    b = sch.next_batch(drain=True)             # "full": 8 slots
    by_lane = {"fg": 0, "bg": 0}
    for r in b.requests:
        by_lane[r.lane] += 1
    assert by_lane == {"fg": 6, "bg": 2}       # 3:1 weights over 8 slots


def test_wfq_background_cannot_starve_interactive():
    """A standing background backlog must not lock interactive arrivals
    out of the next batch."""
    sch = MicroBatchScheduler(ladder=(4,),
                              lanes=(("interactive", 4.0),
                                     ("background", 1.0)),
                              default_lane="interactive")
    for i in range(100):
        sch.submit(_mk_req(i, lane="background"))
    # background alone drains fine (no starvation the other way either)
    b0 = sch.next_batch(drain=True)
    assert len(b0.requests) == 4
    for i in range(100, 104):
        sch.submit(_mk_req(i, lane="interactive"))
    b1 = sch.next_batch(drain=True)
    lanes = [r.lane for r in b1.requests]
    assert lanes.count("interactive") >= 3     # 4:1 weights over 4 slots


def test_unknown_lane_raises():
    sch = MicroBatchScheduler(ladder=(4,))
    with pytest.raises(KeyError):
        sch.submit(_mk_req(0, lane="nope"))


# ---------------------------------------------------------------------------
# adaptive wait + shared ladder policy
# ---------------------------------------------------------------------------

def test_adaptive_wait_shrinks_below_cap():
    sch = MicroBatchScheduler(ladder=(64,), max_wait_ms=100.0,
                              adaptive_wait=True)
    for i in range(4):                         # back-to-back arrivals
        sch.submit(_mk_req(i))
    st = sch.stats()
    assert st["arrival_gap_ewma_ms"] is not None
    # 60 remaining slots at a ~0ms gap: the batch would fill immediately if
    # traffic kept coming; waiting the full 100ms buys nothing
    assert st["effective_wait_ms"] < 100.0


def test_select_bucket_is_the_shared_ladder_policy(small_ir):
    engine = small_ir["backend"].engine
    sch = MicroBatchScheduler(ladder=engine.ladder)
    for n in range(1, engine.ladder[-1] + 1):
        assert sch.select_bucket(n) == engine.select_bucket(n) \
            == select_ladder_bucket(engine.ladder, n)
    # the engine refuses oversized batches (it chunk-plans them); the
    # scheduler clamps (it reports a bucket for any batch it could close)
    with pytest.raises(ValueError):
        engine.select_bucket(engine.ladder[-1] + 1)
    assert sch.select_bucket(engine.ladder[-1] + 1) == engine.ladder[-1]


# ---------------------------------------------------------------------------
# overload: goodput tracks throughput (server level)
# ---------------------------------------------------------------------------

def test_overload_goodput_tracks_throughput(small_ir):
    """Under a backlog far past capacity with a tight deadline, the server
    sheds infeasible work pre-execution; what it *does* execute lands in
    time, so goodput stays proportional to throughput instead of
    collapsing to ~0."""
    env = small_ir
    cfg = ServeConfig.default(cache_entries=0).with_batching(max_batch=8)
    server = PipelineServer(Retrieve("BM25") % 10, env["backend"], cfg)
    server.warmup(env["Q"])
    # learn the service-time EWMA on real traffic, then pin it high enough
    # that the shed math is timing-independent (the bench exercises the
    # organic version)
    for i in range(8):
        server.submit_one(_row(env["Q"], i), timeout_ms=None)
    server.pump()
    assert server.scheduler.service_estimate() is not None
    for _ in range(16):
        server.scheduler.note_service_time(0.2, 8)
    S = server.scheduler.service_estimate()
    # deadline = 4 batches of headroom; with max_batch=8 the shed test
    # rejects once ~3 batches (24 requests) are already queued ahead
    deadline_ms = 1000.0 * 4.0 * S
    n_shed_submit = 0
    reqs = []
    for i in range(64):
        try:
            reqs.append(server.submit_one(_row(env["Q"], i % 8),
                                          timeout_ms=deadline_ms))
        except DeadlineUnmeetable:
            n_shed_submit += 1
    server.pump()
    stats = server.stats()
    overload_served = stats["served"] - 8
    assert n_shed_submit + stats["shed"] > 0   # overload actually shed
    assert overload_served > 0                 # but work still flowed
    # every request is accounted for: warm 8 + the 64 overload submissions
    assert stats["served"] + stats["timed_out"] + n_shed_submit == 72
    assert stats["scheduler"]["shed_submit"] == n_shed_submit
    # goodput ≈ throughput: whatever the server DID execute arrived in
    # time — overload cost answers, not wasted ladder slots
    assert stats["late"] <= overload_served // 2
    assert stats["recompiles_since_warmup"] == 0


# ---------------------------------------------------------------------------
# multi-tenant serving over one shared cache
# ---------------------------------------------------------------------------

def test_multi_tenant_cross_pipeline_prefix_resume(small_ir):
    """Two pipelines sharing a retrieval prefix on ONE server: tenant B
    resumes mid-chain from entries tenant A wrote into the shared cache,
    and the hit is attributed cross-pipeline."""
    env = small_ir
    cfg = ServeConfig.default(optimize=False)
    server = PipelineServer(Retrieve("BM25", k=20) >> Extract("QL"),
                            env["backend"], cfg, name="ql")
    tname = server.add_pipeline(Retrieve("BM25", k=20) >> Extract("TF_IDF"),
                                name="tfidf")
    assert tname == "tfidf"
    assert server.pipelines() == ["ql", "tfidf"]
    for i in range(4):
        server.submit_one(_row(env["Q"], i))   # default tenant: "ql"
    server.pump()
    req = server.submit_one(_row(env["Q"], 2), pipeline="tfidf")
    fresh = server.submit_one(_row(env["Q"], 6), pipeline="tfidf")
    server.pump()
    out = req.wait(30)
    out_fresh = fresh.wait(30)
    assert req.trace.cache_hit_depth == 1      # resumed after Retrieve
    assert req.trace.cross_prefix_hit
    assert fresh.trace.cache_hit_depth == 0
    ref = (Retrieve("BM25", k=20) >> Extract("TF_IDF")).transform(
        env["Q"], backend=_seq_backend(env), optimize=False)
    for i, r in ((2, out), (6, out_fresh)):
        np.testing.assert_array_equal(np.asarray(r["docids"])[0],
                                      np.asarray(ref["docids"])[i])
        np.testing.assert_allclose(np.asarray(r["features"])[0],
                                   np.asarray(ref["features"])[i], rtol=1e-6)
        assert int(np.asarray(r["qid"])[0]) == i
    s = server.stats()
    assert s["cross_pipeline_hits"] >= 1
    assert s["stage_cache"]["cross_pipeline_hits"] >= 1
    # stats()["pipelines"] is the per-tenant accounting, one entry per
    # attached pipeline
    assert set(s["pipelines"]) == {"ql", "tfidf"}
    assert s["pipelines"]["ql"]["served"] == 4
    assert s["pipelines"]["tfidf"]["served"] == 2
    assert s["pipelines"]["tfidf"]["cross_pipeline_prefix_hits"] == 1
    assert s["pipelines"]["ql"]["cross_pipeline_prefix_hits"] == 0


def test_multi_tenant_zero_recompiles_after_warmup(small_ir):
    env = small_ir
    be = JaxBackend(env["index"], default_k=60, query_chunk=4,
                    dense=env["backend"].dense)
    server = MultiPipelineServer(
        {"topk": Retrieve("BM25") % 10,
         "feats": Retrieve("BM25", k=20) >> Extract("QL")},
        be, ServeConfig.default(cache_entries=0))
    warm = server.warmup(env["Q"])
    assert warm["pipelines"] == ["topk", "feats"]
    for rep in range(4):
        for i in range(8):
            server.submit_one(_row(env["Q"], i),
                              pipeline=("topk", "feats")[i % 2])
        server.pump()
    s = server.stats()
    assert s["served"] == 32
    assert s["recompiles_since_warmup"] == 0
    assert set(s["pipelines"]) == {"topk", "feats"}


def test_add_pipeline_duplicate_name_raises(small_ir):
    env = small_ir
    server = PipelineServer(Retrieve("BM25") % 10, env["backend"])
    with pytest.raises(ValueError):
        server.add_pipeline(Retrieve("BM25") % 20, name="default")
    with pytest.raises(KeyError):
        server.submit_one(_row(env["Q"], 0), pipeline="ghost")


# ---------------------------------------------------------------------------
# ServeConfig front API + deprecation shims
# ---------------------------------------------------------------------------

def test_serve_config_builders_and_validation():
    cfg = (ServeConfig.default(max_wait_ms=4.0)
           .with_queue(128)
           .with_batching(max_batch=16, adaptive_wait=True)
           .with_deadlines(250.0, shed=True, service_ewma_alpha=0.5)
           .with_lanes(("interactive", 4.0), ("background", 1.0))
           .with_cache(512, cache_stages=False)
           .with_tracing(True, capacity=99))
    assert cfg.max_queue == 128 and cfg.max_batch == 16
    assert cfg.adaptive_wait and cfg.shed
    assert cfg.default_timeout_ms == 250.0
    assert cfg.service_ewma_alpha == 0.5
    assert cfg.lane_weights() == {"interactive": 4.0, "background": 1.0}
    assert cfg.default_lane == "interactive"
    assert cfg.cache_entries == 512 and not cfg.cache_stages
    assert cfg.trace_stages and cfg.trace_capacity == 99
    # frozen: builders return new values, never mutate
    base = ServeConfig.default()
    assert base.max_queue == 1024
    with pytest.raises(Exception):
        base.max_queue = 7
    with pytest.raises(ValueError):
        ServeConfig(lanes=())
    with pytest.raises(ValueError):
        ServeConfig(lanes=(("a", 1.0), ("a", 2.0)))
    with pytest.raises(ValueError):
        ServeConfig(lanes=(("a", -1.0),))
    with pytest.raises(ValueError):
        ServeConfig(default_lane="ghost")
    d = cfg.as_dict()
    assert d["lanes"] == [["interactive", 4.0], ["background", 1.0]]


def test_legacy_kwargs_removed(small_ir):
    """The loose-kwarg ctor shim finished its deprecation cycle: every
    policy knob now arrives through ``config=ServeConfig(...)`` and the
    old kwargs fail as plain unknown-keyword TypeErrors."""
    env = small_ir
    for kw in ({"max_queue": 7}, {"max_wait_ms": 3.0},
               {"cache_entries": 11}, {"default_timeout_ms": 90.0}):
        with pytest.raises(TypeError):
            PipelineServer(Retrieve("BM25") % 10, env["backend"], **kw)
    cfg = ServeConfig.default(max_queue=7, max_wait_ms=3.0,
                              cache_entries=11, default_timeout_ms=90.0)
    server = PipelineServer(Retrieve("BM25") % 10, env["backend"], cfg)
    assert server.config.max_queue == 7
    assert server.config.default_timeout_ms == 90.0


# ---------------------------------------------------------------------------
# submit API redesign
# ---------------------------------------------------------------------------

def test_submit_always_returns_plain_list(small_ir):
    """The one-release nq==1 attribute-forwarding proxy is gone: submit
    returns a plain list for every burst size, and request attributes live
    only on the elements (submit_one is the single-request API)."""
    env = small_ir
    server = PipelineServer(Retrieve("BM25") % 10, env["backend"])
    res = server.submit(_row(env["Q"], 0))
    assert type(res) is list and len(res) == 1
    with pytest.raises(AttributeError):
        res.rid                                # no proxy forwarding
    multi = server.submit({k: np.asarray(v)[:3] for k, v in env["Q"].items()})
    assert type(multi) is list and len(multi) == 3
    server.pump()


def test_submit_one_requires_single_row(small_ir):
    env = small_ir
    server = PipelineServer(Retrieve("BM25") % 10, env["backend"])
    with pytest.raises(ValueError, match="submit_one"):
        server.submit_one({k: np.asarray(v)[:3] for k, v in env["Q"].items()})


def test_submit_wait_forwards_timeout_ms(small_ir):
    env = small_ir
    cfg = ServeConfig.default().with_deadlines(shed=False)
    server = PipelineServer(Retrieve("BM25") % 10, env["backend"], cfg)
    # a deadline already in the past expires at batch close -> the
    # synchronous path can now express per-request deadlines
    with pytest.raises(RequestTimeout):
        server.submit_wait(_row(env["Q"], 0), timeout_ms=-50.0)
    # and an explicit None = no deadline still serves
    out = server.submit_wait(_row(env["Q"], 1), timeout_ms=None)
    assert int(np.asarray(out["qid"])[0]) == 1


def test_shared_cache_instance_across_servers_still_works(small_ir):
    """The pre-multi-tenant sharing mode (one cache, several servers) is
    unchanged — writer attribution defaults to each server's tenant
    name."""
    env = small_ir
    shared = StageResultCache(256)
    cfg = ServeConfig.default(optimize=False)
    s1 = PipelineServer(Retrieve("BM25", k=20) >> Extract("QL"),
                        env["backend"], cfg, cache=shared, name="s1")
    s1.submit_one(_row(env["Q"], 3))
    s1.pump()
    s2 = PipelineServer(Retrieve("BM25", k=20) >> Extract("TF_IDF"),
                        env["backend"], cfg, cache=shared, name="s2")
    req = s2.submit_one(_row(env["Q"], 3))
    s2.pump()
    req.wait(30)
    assert req.trace.cache_hit_depth == 1
    assert req.trace.cross_prefix_hit          # writer "s1" != reader "s2"
