"""The ``generate`` leaf: A-schema typing, IR round-trips, rewrite
soundness under optimisation, and token-for-token serving parity between
the continuous-batched decode path and the sequential offline oracle."""
import numpy as np
import pytest

from repro.core import (Generate, Retrieve, DenseRerank, SchemaError,
                        SDMRewrite, compile_pipeline, lower, raise_ir)
from repro.core.compiler import run_pipeline
from repro.core.data import make_queries
from repro.models import transformer_lm as tlm
from repro.serve.config import ServeConfig
from repro.serve.server import PipelineServer


def _tiny_cfg():
    return tlm.LMConfig(name="tiny", n_layers=2, d_model=32, n_q=4, n_kv=2,
                        d_head=8, d_ff=64, vocab=128, remat=False)


@pytest.fixture(scope="module")
def gen_env(small_ir):
    """small_ir backend with a tiny decoder LM registered."""
    be = small_ir["backend"]
    if "tiny" not in be._lms:
        be.register_lm("tiny", _tiny_cfg())
    return small_ir


def _rag(k=8, T=6, P=32, docs=3):
    return (Retrieve("BM25") >> DenseRerank() % k
            >> Generate("tiny", max_new_tokens=T, max_prompt_len=P,
                        prompt_docs=docs))


# ---------------------------------------------------------------------------
# A-schema typing
# ---------------------------------------------------------------------------

def test_generate_over_pure_query_expression_is_schema_error(gen_env):
    with pytest.raises(SchemaError, match="pure Q -> Q"):
        compile_pipeline(SDMRewrite() >> Generate("tiny"),
                         gen_env["backend"])


def test_generate_is_terminal_no_stage_may_consume_a(gen_env):
    be = gen_env["backend"]
    base = Retrieve("BM25", k=20) >> Generate("tiny")
    with pytest.raises(SchemaError, match="terminal"):
        compile_pipeline(base % 5, be)                  # cutoff over A
    with pytest.raises(SchemaError, match="terminal"):
        compile_pipeline(2.0 * base, be)                # scale over A
    with pytest.raises(SchemaError, match="terminal"):
        compile_pipeline(base >> DenseRerank(), be)     # rerank over A
    with pytest.raises(SchemaError):
        compile_pipeline(base | Retrieve("QL", k=20), be)


def test_generate_schema_carries_static_decode_width(gen_env):
    from repro.core.passes import annotate
    op = lower(_rag(k=8, T=6))
    s = annotate(op, gen_env["backend"])[id(op)]
    assert s.out == "A"
    assert s.k == 8          # result depth the prompt reads
    assert s.width == 6      # static decode length (bucket-ladder safe)
    assert s.reads_results


# ---------------------------------------------------------------------------
# IR round-trip + optimisation soundness
# ---------------------------------------------------------------------------

def test_generate_ir_round_trip_preserves_key():
    pipe = _rag()
    assert raise_ir(lower(pipe)).key() == pipe.key()


def test_opt_on_equals_opt_off_with_generate(gen_env):
    env = gen_env
    Q = {k: np.asarray(v)[:4] for k, v in env["Q"].items()}
    A_off = run_pipeline(_rag(), Q, backend=env["backend"], optimize=False)
    A_on = run_pipeline(_rag(), Q, backend=env["backend"], optimize=True)
    np.testing.assert_array_equal(np.asarray(A_off["tokens"]),
                                  np.asarray(A_on["tokens"]))
    np.testing.assert_array_equal(np.asarray(A_off["docids"]),
                                  np.asarray(A_on["docids"]))


def test_fusion_still_fires_beneath_generate(gen_env):
    op = compile_pipeline(_rag(), gen_env["backend"])
    from repro.core import ir
    kinds = [o.kind for o in ir.chain(op)]
    assert kinds[-1] == "generate"
    assert "fused_dense_rerank" in kinds     # rewrite ran under the A leaf


# ---------------------------------------------------------------------------
# served decode == sequential offline oracle, token for token
# ---------------------------------------------------------------------------

def test_served_rag_matches_offline_oracle_token_for_token(gen_env):
    env = gen_env
    server = PipelineServer(_rag(), env["backend"],
                            ServeConfig.default().with_decode(4))
    server.warmup({k: np.asarray(v)[:1] for k, v in env["Q"].items()})
    rows = [{k: np.asarray(v)[j:j + 1] for k, v in env["Q"].items()}
            for j in range(4)]
    reqs = [server.submit_one(r) for r in rows]
    server.pump()
    for row, req in zip(rows, reqs):
        served = req.wait(10.0)
        oracle = run_pipeline(_rag(), row, backend=env["backend"])
        np.testing.assert_array_equal(np.asarray(served["tokens"]),
                                      np.asarray(oracle["tokens"]))
        np.testing.assert_array_equal(np.asarray(served["docids"]),
                                      np.asarray(oracle["docids"]))
        assert req.trace.n_tokens == 6
        assert req.trace.ttft_ms > 0.0


def test_mixed_serving_no_recompiles_after_warmup(gen_env):
    """100+ requests mixing a retrieval-only tenant and a RAG tenant must
    ride warm compiled variants end to end: recompiles_since_warmup == 0,
    decode included (prefill/decode-step are pinned-shape engine
    programs)."""
    env = gen_env
    server = PipelineServer(_rag(), env["backend"],
                            ServeConfig.default().with_decode(4))
    server.add_pipeline(Retrieve("BM25") % 10, name="ret-only")
    server.warmup({k: np.asarray(v)[:1] for k, v in env["Q"].items()})
    rng = np.random.default_rng(3)
    reqs = []
    for i in range(110):
        t = rng.integers(0, 12000, (1, 3))
        Qi = make_queries(t, qids=np.array([1000 + i]))
        pipeline = None if i % 3 else "ret-only"
        reqs.append(server.submit_one(Qi, pipeline=pipeline))
        if i % 7 == 0:
            server.pump()        # interleave: some batches mix mid-decode
    server.pump()
    for req in reqs:
        assert req.wait(10.0) is not None
    st = server.stats()
    assert st["recompiles_since_warmup"] == 0
    assert st["served"] >= 110
    assert st["decode"]["requests"] > 0
    assert st["decode_pools"]["default"]["decode_steps"] > 0
