"""End-to-end behaviour tests: full pipelines, Experiment, fit, caching."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (DenseRerank, Experiment, Extract, LTRRerank, Retrieve,
                        RM3Expand, SDMRewrite, StemRewrite, format_table)
from repro.core.compiler import Context
from repro.core.data import make_queries


def test_experiment_table(small_ir):
    env = small_ir
    res = Experiment(
        [Retrieve("BM25", k=30), Retrieve("QL", k=30)],
        env["Q"], env["topics"].qrels, ["map", "ndcg_cut_10", "P_10"],
        backend=env["backend"], names=["bm25", "ql"], measure_time=True)
    assert len(res["table"]) == 2
    for row in res["table"]:
        assert 0.0 < row["map"] <= 1.0
        assert row["mrt_ms"] > 0
    assert "bm25" in format_table(res["table"])


def test_prf_pipeline_runs_and_changes_ranking(small_ir):
    env = small_ir
    base = Retrieve("BM25", k=30)
    prf = base >> RM3Expand(fb_terms=5, fb_docs=5) >> Retrieve("BM25", k=30)
    Rb = base.transform(env["Q"], backend=env["backend"])
    Rp = prf.transform(env["Q"], backend=env["backend"])
    assert Rb["docids"].shape == Rp["docids"].shape
    # expansion must actually alter at least one query's ranking
    assert (np.asarray(Rb["docids"]) != np.asarray(Rp["docids"])).any()


def test_query_rewriters(small_ir):
    env = small_ir
    for rw in [SDMRewrite(), StemRewrite()]:
        pipe = rw >> Retrieve("BM25", k=10)
        R = pipe.transform(env["Q"], backend=env["backend"])
        assert np.isfinite(np.asarray(R["scores"])[:, 0]).all()


def test_full_listing1_pipeline(small_ir):
    """The paper's Listing 1 shape: PRF >> (features) >> LTR, trained."""
    env = small_ir
    fat = Retrieve("BM25", k=20) >> (Extract("QL") ** Extract("TF_IDF"))
    full = fat >> LTRRerank(n_features=2, epochs=10)
    full.fit(env["Q"], env["topics"].qrels, backend=env["backend"])
    res = Experiment([Retrieve("BM25", k=20), full], env["Q"],
                     env["topics"].qrels, ["map"], backend=env["backend"],
                     names=["bm25", "ltr"])
    assert res["table"][1]["map"] > 0.1


def test_dense_rerank_pipeline(small_ir):
    env = small_ir
    pipe = Retrieve("BM25", k=20) >> DenseRerank(alpha=0.5)
    R = pipe.transform(env["Q"], backend=env["backend"])
    s = np.asarray(R["scores"])
    assert (np.diff(s, axis=1) <= 1e-6).all()   # re-sorted


def test_common_prefix_cache_shared(small_ir):
    """Two pipelines sharing a prefix must execute the prefix once."""
    env = small_ir
    calls = {"n": 0}

    def counting(Q, R):
        calls["n"] += 1
        return Q, R

    from repro.core.transformer import Generic
    probe = Generic(fn=counting)
    base = Retrieve("BM25", k=10) >> probe
    p1 = base >> Extract("QL")
    p2 = base >> Extract("TF_IDF")
    Experiment([p1, p2], env["Q"], env["topics"].qrels, ["map"],
               backend=env["backend"], optimize=False)
    assert calls["n"] == 1   # shared prefix ran once


def test_generic_transformer_from_callable(small_ir):
    env = small_ir

    def boost_scores(Q, R):
        return Q, {**R, "scores": R["scores"] + 1.0}

    pipe = Retrieve("BM25", k=5) >> boost_scores
    R = pipe.transform(env["Q"], backend=env["backend"])
    base = Retrieve("BM25", k=5).transform(env["Q"], backend=env["backend"])
    np.testing.assert_allclose(np.asarray(R["scores"]),
                               np.asarray(base["scores"]) + 1.0, rtol=1e-6)
