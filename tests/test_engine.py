"""Sharded query execution engine: sequential-equivalence, bucket-ladder
recompile bounds, chunk planning, async plan execution, rewrite soundness
under sharded execution."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:      # property tests skip; fallbacks below run
    HAVE_HYPOTHESIS = False

from repro.core import (DenseRerank, Experiment, Extract, ExperimentPlan,
                        JaxBackend, Retrieve, RM3Expand, SDMRewrite,
                        ShardedQueryEngine, default_bucket_ladder)
from repro.core.compiler import Context
from repro.core.data import make_queries


def _seq_backend(env):
    return JaxBackend(env["index"], default_k=60, query_chunk=4,
                      dense=env["backend"].dense, sharded=False)


def _tiled_queries(env, nq):
    terms = np.tile(np.asarray(env["Q"]["terms"]), (nq // 8 + 1, 1))[:nq]
    weights = np.tile(np.asarray(env["Q"]["weights"]), (nq // 8 + 1, 1))[:nq]
    return make_queries(terms, weights)


PIPELINES = [
    Retrieve("BM25", k=20),
    Retrieve("BM25", k=20) >> Extract("QL"),
    Retrieve("BM25", k=20) >> RM3Expand(fb_terms=5, fb_docs=5)
    >> Retrieve("BM25", k=10),
    SDMRewrite() >> Retrieve("QL", k=15),
    Retrieve("BM25", k=20) >> DenseRerank(alpha=0.5),
]


# ---------------------------------------------------------------------------
# engine == sequential path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("i", range(len(PIPELINES)))
def test_engine_matches_sequential(small_ir, i):
    env = small_ir
    pipe = PIPELINES[i]
    Re = pipe.transform(env["Q"], backend=env["backend"], optimize=False)
    Rs = pipe.transform(env["Q"], backend=_seq_backend(env), optimize=False)
    np.testing.assert_array_equal(np.asarray(Re["docids"]),
                                  np.asarray(Rs["docids"]))
    np.testing.assert_allclose(np.asarray(Re["scores"]),
                               np.asarray(Rs["scores"]), rtol=1e-6)


def _check_engine_matches_sequential_at(env, nq):
    """Padding/bucketing must be invisible at every query-set size."""
    Q = _tiled_queries(env, nq)
    pipe = Retrieve("BM25", k=10) >> Extract("QL")
    Re = pipe.transform(Q, backend=env["backend"], optimize=False)
    Rs = pipe.transform(Q, backend=_seq_backend(env), optimize=False)
    assert np.asarray(Re["docids"]).shape[0] == nq
    np.testing.assert_array_equal(np.asarray(Re["docids"]),
                                  np.asarray(Rs["docids"]))
    np.testing.assert_allclose(np.asarray(Re["features"]),
                               np.asarray(Rs["features"]), rtol=1e-6)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=1, max_value=40))
    def test_engine_matches_sequential_any_size(small_ir, nq):
        _check_engine_matches_sequential_at(small_ir, nq)


# deterministic fallbacks: bucket boundaries, tails, multi-chunk sizes
@pytest.mark.parametrize("nq", [1, 7, 8, 9, 32, 33, 40])
def test_engine_matches_sequential_sizes_fixed(small_ir, nq):
    _check_engine_matches_sequential_at(small_ir, nq)


def test_optimized_pipelines_match_under_sharded_execution(small_ir):
    """The paper's core equivalence claim must survive the engine: rewritten
    and unrewritten pipelines agree when executed sharded (exact-equality
    pipelines only — pruning rewrites are approximate by design)."""
    env = small_ir
    be = JaxBackend(env["index"], default_k=60, dense=env["backend"].dense,
                    capabilities=frozenset({"fat", "multi_model"}))
    for pipe in [(Retrieve("BM25", k=30) >> SDMRewrite()) % 10,
                 Retrieve("BM25", k=20) >> Extract("QL") >> Extract("TF_IDF"),
                 (Retrieve("BM25", k=30) >> RM3Expand(fb_docs=5)) % 10]:
        Ro = pipe.transform(env["Q"], backend=be, optimize=True)
        Ru = pipe.transform(env["Q"], backend=_seq_backend(env),
                            optimize=False)
        np.testing.assert_array_equal(np.asarray(Ro["docids"]),
                                      np.asarray(Ru["docids"]))
        np.testing.assert_allclose(np.asarray(Ro["scores"]),
                                   np.asarray(Ru["scores"]), rtol=1e-6)


# ---------------------------------------------------------------------------
# bucket ladder bounds recompilation
# ---------------------------------------------------------------------------

def test_recompiles_bounded_by_ladder(small_ir):
    """Across many distinct query-set sizes, one stage may compile at most
    len(ladder) variants (the seed's loop recompiled per distinct size)."""
    env = small_ir
    be = JaxBackend(env["index"], default_k=60, dense=env["backend"].dense)
    eng = be.engine
    pipe = Retrieve("BM25", k=10)
    for nq in (1, 2, 3, 5, 8, 9, 13, 21, 33, 40, 64, 65):
        pipe.transform(_tiled_queries(env, nq), backend=be, optimize=False)
    assert eng.max_compiles_per_stage() <= len(eng.ladder)
    # and the jit cache is really shared across structurally-equal stages
    pipe2 = Retrieve("BM25", k=10)
    n = eng.max_compiles_per_stage()
    pipe2.transform(_tiled_queries(env, 17), backend=be, optimize=False)
    assert eng.max_compiles_per_stage() == n


def test_chunk_plan_covers_and_buckets(small_ir):
    eng = small_ir["backend"].engine
    for nq in range(1, 3 * eng.ladder[-1] + 2):
        plan = eng.chunk_plan(nq)
        assert sum(n for _, n, _ in plan) == nq
        assert all(b in eng.ladder for _, _, b in plan)
        assert all(n <= b for _, n, b in plan)
        starts = [s for s, _, _ in plan]
        assert starts == sorted(starts)
    with pytest.raises(ValueError):
        eng.chunk_plan(0)


def test_default_ladder_is_device_aligned():
    for nd in (1, 2, 3, 5, 8):
        ladder = default_bucket_ladder(nd)
        assert all(b % nd == 0 for b in ladder)
        assert ladder == tuple(sorted(ladder))


def test_explicit_ladder_honoured():
    eng = ShardedQueryEngine(ladder=(2, 6))
    assert eng.ladder == (2, 6)
    assert eng.chunk_plan(15) == ((0, 6, 6), (6, 6, 6), (12, 3, 6))
    assert eng.chunk_plan(1) == ((0, 1, 2),)


# ---------------------------------------------------------------------------
# plan execution through the engine
# ---------------------------------------------------------------------------

def test_plan_results_identical_with_and_without_engine(small_ir):
    env = small_ir
    be_seq = _seq_backend(env)
    for optimize in (False, True):
        pe = ExperimentPlan(PIPELINES, env["backend"], optimize=optimize)
        ps = ExperimentPlan(PIPELINES, be_seq, optimize=optimize)
        re_ = pe.execute(env["Q"], ctx=Context(env["backend"]), record=None)
        rs = ps.execute(env["Q"], ctx=Context(be_seq))
        for Ra, Rb in zip(re_, rs):
            np.testing.assert_array_equal(np.asarray(Ra["docids"]),
                                          np.asarray(Rb["docids"]))
            np.testing.assert_allclose(np.asarray(Ra["scores"]),
                                       np.asarray(Rb["scores"]), rtol=1e-6)


def test_untimed_plan_skips_barriers_and_stays_correct(small_ir):
    """record=None runs fully async (no per-stage block) yet returns the
    same results as the barriered timed pass."""
    env = small_ir
    plan = ExperimentPlan(PIPELINES[:3], env["backend"], optimize=False)
    r_async = plan.execute(env["Q"], ctx=Context(env["backend"]), record=None)
    r_timed = plan.execute(env["Q"], ctx=Context(env["backend"]),
                           record="cold")
    assert all(n.cold_s is not None for n in plan.nodes())
    for Ra, Rb in zip(r_async, r_timed):
        np.testing.assert_array_equal(np.asarray(Ra["docids"]),
                                      np.asarray(Rb["docids"]))


def test_experiment_through_engine_measures_time(small_ir):
    env = small_ir
    res = Experiment([Retrieve("BM25", k=30), Retrieve("QL", k=30)],
                     env["Q"], env["topics"].qrels, ["map"],
                     backend=env["backend"], measure_time=True)
    for row in res["table"]:
        assert row["mrt_ms"] > 0
        assert row["compile_ms"] >= 0


def test_engine_chunk_cache_reused_across_stages(small_ir):
    """Stage-to-stage handoff must reuse sharded chunk pieces instead of
    re-slicing the concatenated output."""
    env = small_ir
    be = JaxBackend(env["index"], default_k=60, dense=env["backend"].dense)
    (Retrieve("BM25", k=20) >> Extract("QL") >> Extract("TF_IDF")) \
        .transform(env["Q"], backend=be, optimize=False)
    assert be.engine.n_chunk_cache_hits > 0
