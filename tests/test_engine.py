"""Sharded query execution engine: sequential-equivalence, bucket-ladder
recompile bounds, chunk planning, async plan execution, rewrite soundness
under sharded execution."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:      # property tests skip; fallbacks below run
    HAVE_HYPOTHESIS = False

from repro.core import (BackendDescriptor, DenseRerank, Experiment, Extract,
                        ExperimentPlan, FusedTopKRetrieve, JaxBackend,
                        Retrieve, RM3Expand, SDMRewrite, ShardedQueryEngine,
                        default_bucket_ladder)
from repro.core.compiler import Context
from repro.core.data import make_queries
from repro.core.engine import StageProgram


def _seq_backend(env):
    return JaxBackend(env["index"], default_k=60, query_chunk=4,
                      dense=env["backend"].dense, sharded=False)


def _tiled_queries(env, nq):
    terms = np.tile(np.asarray(env["Q"]["terms"]), (nq // 8 + 1, 1))[:nq]
    weights = np.tile(np.asarray(env["Q"]["weights"]), (nq // 8 + 1, 1))[:nq]
    return make_queries(terms, weights)


PIPELINES = [
    Retrieve("BM25", k=20),
    Retrieve("BM25", k=20) >> Extract("QL"),
    Retrieve("BM25", k=20) >> RM3Expand(fb_terms=5, fb_docs=5)
    >> Retrieve("BM25", k=10),
    SDMRewrite() >> Retrieve("QL", k=15),
    Retrieve("BM25", k=20) >> DenseRerank(alpha=0.5),
]


# ---------------------------------------------------------------------------
# engine == sequential path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("i", range(len(PIPELINES)))
def test_engine_matches_sequential(small_ir, i):
    env = small_ir
    pipe = PIPELINES[i]
    Re = pipe.transform(env["Q"], backend=env["backend"], optimize=False)
    Rs = pipe.transform(env["Q"], backend=_seq_backend(env), optimize=False)
    np.testing.assert_array_equal(np.asarray(Re["docids"]),
                                  np.asarray(Rs["docids"]))
    np.testing.assert_allclose(np.asarray(Re["scores"]),
                               np.asarray(Rs["scores"]), rtol=1e-6)


def _check_engine_matches_sequential_at(env, nq):
    """Padding/bucketing must be invisible at every query-set size."""
    Q = _tiled_queries(env, nq)
    pipe = Retrieve("BM25", k=10) >> Extract("QL")
    Re = pipe.transform(Q, backend=env["backend"], optimize=False)
    Rs = pipe.transform(Q, backend=_seq_backend(env), optimize=False)
    assert np.asarray(Re["docids"]).shape[0] == nq
    np.testing.assert_array_equal(np.asarray(Re["docids"]),
                                  np.asarray(Rs["docids"]))
    np.testing.assert_allclose(np.asarray(Re["features"]),
                               np.asarray(Rs["features"]), rtol=1e-6)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=1, max_value=40))
    def test_engine_matches_sequential_any_size(small_ir, nq):
        _check_engine_matches_sequential_at(small_ir, nq)


# deterministic fallbacks: bucket boundaries, tails, multi-chunk sizes
@pytest.mark.parametrize("nq", [1, 7, 8, 9, 32, 33, 40])
def test_engine_matches_sequential_sizes_fixed(small_ir, nq):
    _check_engine_matches_sequential_at(small_ir, nq)


def test_optimized_pipelines_match_under_sharded_execution(small_ir):
    """The paper's core equivalence claim must survive the engine: rewritten
    and unrewritten pipelines agree when executed sharded (exact-equality
    pipelines only — pruning rewrites are approximate by design)."""
    env = small_ir
    be = JaxBackend(env["index"], default_k=60, dense=env["backend"].dense,
                    descriptor=BackendDescriptor.default(
                        frozenset({"fat", "multi_model"})))
    for pipe in [(Retrieve("BM25", k=30) >> SDMRewrite()) % 10,
                 Retrieve("BM25", k=20) >> Extract("QL") >> Extract("TF_IDF"),
                 (Retrieve("BM25", k=30) >> RM3Expand(fb_docs=5)) % 10]:
        Ro = pipe.transform(env["Q"], backend=be, optimize=True)
        Ru = pipe.transform(env["Q"], backend=_seq_backend(env),
                            optimize=False)
        np.testing.assert_array_equal(np.asarray(Ro["docids"]),
                                      np.asarray(Ru["docids"]))
        np.testing.assert_allclose(np.asarray(Ro["scores"]),
                                   np.asarray(Ru["scores"]), rtol=1e-6)


# ---------------------------------------------------------------------------
# bucket ladder bounds recompilation
# ---------------------------------------------------------------------------

def test_recompiles_bounded_by_ladder(small_ir):
    """Across many distinct query-set sizes, one stage may compile at most
    len(ladder) variants (the seed's loop recompiled per distinct size)."""
    env = small_ir
    be = JaxBackend(env["index"], default_k=60, dense=env["backend"].dense)
    eng = be.engine
    pipe = Retrieve("BM25", k=10)
    for nq in (1, 2, 3, 5, 8, 9, 13, 21, 33, 40, 64, 65):
        pipe.transform(_tiled_queries(env, nq), backend=be, optimize=False)
    assert eng.max_compiles_per_stage() <= len(eng.ladder)
    # and the jit cache is really shared across structurally-equal stages
    pipe2 = Retrieve("BM25", k=10)
    n = eng.max_compiles_per_stage()
    pipe2.transform(_tiled_queries(env, 17), backend=be, optimize=False)
    assert eng.max_compiles_per_stage() == n


def test_chunk_plan_covers_and_buckets(small_ir):
    eng = small_ir["backend"].engine
    for nq in range(1, 3 * eng.ladder[-1] + 2):
        plan = eng.chunk_plan(nq)
        assert sum(n for _, n, _ in plan) == nq
        assert all(b in eng.ladder for _, _, b in plan)
        assert all(n <= b for _, n, b in plan)
        starts = [s for s, _, _ in plan]
        assert starts == sorted(starts)
    with pytest.raises(ValueError):
        eng.chunk_plan(0)


def test_default_ladder_is_device_aligned():
    for nd in (1, 2, 3, 5, 8):
        ladder = default_bucket_ladder(nd)
        assert all(b % nd == 0 for b in ladder)
        assert ladder == tuple(sorted(ladder))


def test_explicit_ladder_honoured():
    eng = ShardedQueryEngine(ladder=(2, 6))
    assert eng.ladder == (2, 6)
    assert eng.chunk_plan(15) == ((0, 6, 6), (6, 6, 6), (12, 3, 6))
    assert eng.chunk_plan(1) == ((0, 1, 2),)


# ---------------------------------------------------------------------------
# plan execution through the engine
# ---------------------------------------------------------------------------

def test_plan_results_identical_with_and_without_engine(small_ir):
    env = small_ir
    be_seq = _seq_backend(env)
    for optimize in (False, True):
        pe = ExperimentPlan(PIPELINES, env["backend"], optimize=optimize)
        ps = ExperimentPlan(PIPELINES, be_seq, optimize=optimize)
        re_ = pe.execute(env["Q"], ctx=Context(env["backend"]), record=None)
        rs = ps.execute(env["Q"], ctx=Context(be_seq))
        for Ra, Rb in zip(re_, rs):
            np.testing.assert_array_equal(np.asarray(Ra["docids"]),
                                          np.asarray(Rb["docids"]))
            np.testing.assert_allclose(np.asarray(Ra["scores"]),
                                       np.asarray(Rb["scores"]), rtol=1e-6)


def test_untimed_plan_skips_barriers_and_stays_correct(small_ir):
    """record=None runs fully async (no per-stage block) yet returns the
    same results as the barriered timed pass."""
    env = small_ir
    plan = ExperimentPlan(PIPELINES[:3], env["backend"], optimize=False)
    r_async = plan.execute(env["Q"], ctx=Context(env["backend"]), record=None)
    r_timed = plan.execute(env["Q"], ctx=Context(env["backend"]),
                           record="cold")
    assert all(n.cold_s is not None for n in plan.nodes())
    for Ra, Rb in zip(r_async, r_timed):
        np.testing.assert_array_equal(np.asarray(Ra["docids"]),
                                      np.asarray(Rb["docids"]))


def test_experiment_through_engine_measures_time(small_ir):
    env = small_ir
    res = Experiment([Retrieve("BM25", k=30), Retrieve("QL", k=30)],
                     env["Q"], env["topics"].qrels, ["map"],
                     backend=env["backend"], measure_time=True)
    for row in res["table"]:
        assert row["mrt_ms"] > 0
        assert row["compile_ms"] >= 0


# ---------------------------------------------------------------------------
# bucket-ladder edge cases (parity with the sequential engine throughout)
# ---------------------------------------------------------------------------

def test_empty_query_batch_raises_on_both_paths(small_ir):
    """Neither path can infer output shapes from zero queries; both must
    fail loudly and identically instead of crashing deep in XLA."""
    env = small_ir
    Q0 = make_queries(np.zeros((0, 4), np.int32))
    pipe = Retrieve("BM25", k=10)
    with pytest.raises(ValueError, match="empty query batch"):
        pipe.transform(Q0, backend=env["backend"], optimize=False)
    with pytest.raises(ValueError, match="empty query batch"):
        pipe.transform(Q0, backend=_seq_backend(env), optimize=False)


def _fused_caps_backends(env):
    """Engine + sequential backends with identical capabilities and no
    dynamic pruning, so ``% K`` reaches the fused-topk lowering (gate
    permitting) instead of the RQ1 pushdown on both sides."""
    caps = frozenset({"fat", "multi_model", "fused_topk", "fused_scoring"})
    be = JaxBackend(env["index"], default_k=60, query_chunk=4,
                    dense=env["backend"].dense,
                    descriptor=BackendDescriptor.default(caps))
    be_seq = JaxBackend(env["index"], default_k=60, query_chunk=4,
                        dense=env["backend"].dense,
                        descriptor=BackendDescriptor.default(caps),
                        sharded=False)
    return be, be_seq


def test_single_query_parity_through_fused_topk(small_ir):
    env = small_ir
    be, be_seq = _fused_caps_backends(env)
    Q1 = _tiled_queries(env, 1)
    pipe = Retrieve("BM25") % 10
    Re = pipe.transform(Q1, backend=be, optimize=True)
    Rs = pipe.transform(Q1, backend=be_seq, optimize=True)
    assert np.asarray(Re["docids"]).shape[0] == 1
    np.testing.assert_array_equal(np.asarray(Re["docids"]),
                                  np.asarray(Rs["docids"]))
    np.testing.assert_allclose(np.asarray(Re["scores"]),
                               np.asarray(Rs["scores"]), rtol=1e-6)


def test_batch_exactly_at_every_bucket_boundary(small_ir):
    """nq == a ladder rung must take the exact-fit path (no tail trim) and
    stay identical to the sequential engine."""
    env = small_ir
    be, be_seq = _fused_caps_backends(env)
    eng = be.engine
    pipe = Retrieve("BM25") % 10
    for bucket in eng.ladder:
        Q = _tiled_queries(env, bucket)
        plan = eng.chunk_plan(bucket)
        assert plan[-1][1] == plan[-1][2]        # tail fills its bucket
        Re = pipe.transform(Q, backend=be, optimize=True)
        Rs = pipe.transform(Q, backend=be_seq, optimize=True)
        np.testing.assert_array_equal(np.asarray(Re["docids"]),
                                      np.asarray(Rs["docids"]))


def _tiny_env(n_docs=60):
    from repro.index import build_index, synthesize_corpus, synthesize_topics
    corpus = synthesize_corpus(n_docs=n_docs, vocab=500, mean_len=40, seed=3)
    topics = synthesize_topics(corpus, n_topics=4, q_len=3, rels_per_topic=5,
                               seed=4)
    index = build_index(corpus)
    Q = make_queries(np.asarray(topics.terms), np.asarray(topics.weights),
                     np.asarray(topics.qids))
    return index, Q


def test_k_exceeds_ndocs_through_fused_topk_path(small_ir):
    """k > n_docs clamps to the corpus size on every path (top-k cannot
    return more entries than documents exist) — fused kernel, optimised
    cutoff chain, and the sequential engine all agree."""
    index, Q = _tiny_env(n_docs=60)
    k = 96                                        # > n_docs
    be = JaxBackend(index, default_k=50, query_chunk=4)
    be_seq = JaxBackend(index, default_k=50, query_chunk=4, dense=be.dense,
                        sharded=False)
    ref = Retrieve("BM25", k=k).transform(Q, backend=be_seq, optimize=False)
    assert np.asarray(ref["docids"]).shape[1] == 60
    fused = FusedTopKRetrieve("BM25", k=k).transform(Q, backend=be,
                                                     optimize=False)
    np.testing.assert_array_equal(np.asarray(fused["docids"]),
                                  np.asarray(ref["docids"]))
    np.testing.assert_allclose(np.asarray(fused["scores"]),
                               np.asarray(ref["scores"]), rtol=1e-6)
    # the optimised cutoff chain survives compilation + gating at k > n_docs
    be_nopruning = JaxBackend(index, default_k=50, query_chunk=4,
                              dense=be.dense,
                              descriptor=BackendDescriptor.default(frozenset(
                                  {"fat", "multi_model", "fused_topk"})))
    Ro = (Retrieve("BM25", k=k) % k).transform(Q, backend=be_nopruning,
                                               optimize=True)
    np.testing.assert_array_equal(np.asarray(Ro["docids"]),
                                  np.asarray(ref["docids"]))


# ---------------------------------------------------------------------------
# serving API: bucket selection, single-chunk submission, bounded caches
# ---------------------------------------------------------------------------

def test_select_bucket_and_submit_chunk(small_ir):
    env = small_ir
    eng = ShardedQueryEngine(ladder=(4, 8))
    assert [eng.select_bucket(n) for n in (1, 4, 5, 8)] == [4, 4, 8, 8]
    with pytest.raises(ValueError):
        eng.select_bucket(0)
    with pytest.raises(ValueError):
        eng.select_bucket(9)                      # bigger than the ladder
    Q = _tiled_queries(env, 5)
    prog = StageProgram(key=("t", "sum"), fn=lambda t, w: w.sum())
    out = eng.submit_chunk(prog, Q)               # one padded chunk @ 8
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(Q["weights"]).sum(1), rtol=1e-6)
    assert eng.n_dispatches == 1
    with pytest.raises(ValueError):
        eng.submit_chunk(prog, _tiled_queries(env, 9))


def test_engine_caches_are_lru_bounded_with_cache_info(small_ir):
    env = small_ir
    eng = ShardedQueryEngine(ladder=(2, 4), max_jit_entries=2,
                             max_chunk_entries=2)
    Q = _tiled_queries(env, 4)
    for i in range(4):                            # 4 distinct stage keys
        eng.map_queries(lambda t, w: w.sum() + i, Q, key=("stage", i))
    info = eng.cache_info()
    assert set(info) == {"jit", "chunk"}
    assert info["jit"]["size"] <= 2
    assert info["jit"]["evictions"] >= 2
    assert info["chunk"]["size"] <= 2
    for part in info.values():
        assert {"size", "maxsize", "hits", "misses",
                "evictions"} <= set(part)
    # an evicted stage key recompiles on next use (bounded memory trumps
    # the ladder bound under cache pressure)
    eng.map_queries(lambda t, w: w.sum() + 0, Q, key=("stage", 0))
    assert eng.cache_info()["jit"]["size"] <= 2


def test_engine_chunk_cache_reused_across_stages(small_ir):
    """Stage-to-stage handoff must reuse sharded chunk pieces instead of
    re-slicing the concatenated output."""
    env = small_ir
    be = JaxBackend(env["index"], default_k=60, dense=env["backend"].dense)
    (Retrieve("BM25", k=20) >> Extract("QL") >> Extract("TF_IDF")) \
        .transform(env["Q"], backend=be, optimize=False)
    assert be.engine.n_chunk_cache_hits > 0
