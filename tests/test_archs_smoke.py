"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
same-family config and runs one forward + one train step on CPU, asserting
output shapes and finiteness (no NaNs)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import all_arch_ids, get_arch
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts


def _loss_fn(arch, cfg):
    mod = arch.module
    return lambda params, batch: mod.loss_fn(cfg, params, batch)


@pytest.mark.parametrize("arch_id", all_arch_ids())
def test_arch_smoke_train_step(arch_id):
    arch = get_arch(arch_id)
    cfg, batch_fn = arch.reduced()
    mod = arch.module
    params = mod.init_params(cfg, jax.random.key(0))
    batch = {k: jnp.asarray(v) for k, v in batch_fn().items()}

    # forward
    if arch.family == "lm":
        logits, aux = mod.forward(cfg, params, batch["tokens"])
        assert logits.shape == (*batch["tokens"].shape, cfg.vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    elif arch.family == "gnn":
        logits = mod.forward(cfg, params, batch)
        assert logits.shape[-1] == cfg.n_classes
        assert bool(jnp.isfinite(logits).all())
    else:
        if arch_id == "mind":
            loss0, _ = mod.loss_fn(cfg, params, batch)
            assert bool(jnp.isfinite(loss0))
        else:
            logit = mod.forward(cfg, params, batch)
            assert logit.shape == (batch["label"].shape[0],)
            assert bool(jnp.isfinite(logit).all())

    # one full train step (grad + AdamW update)
    step = jax.jit(ts.make_train_step(_loss_fn(arch, cfg),
                                      opt_lib.AdamWConfig(lr=1e-3,
                                                          total_steps=10)))
    state = ts.init_state(params)
    state2, metrics = step(state, batch)
    for leaf in jax.tree.leaves(state2["params"]):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())
    loss_key = next(iter(metrics))
    assert bool(jnp.isfinite(metrics[loss_key]))
    assert int(state2["opt"]["step"]) == 1


@pytest.mark.parametrize("arch_id", [a for a in all_arch_ids()
                                     if get_arch(a).family == "lm"])
def test_lm_serve_consistency(arch_id):
    """prefill+decode must agree with the training forward pass."""
    from repro.models import transformer_lm as tlm
    arch = get_arch(arch_id)
    cfg, batch_fn = arch.reduced()
    params = tlm.init_params(cfg, jax.random.key(1))
    toks = jnp.asarray(batch_fn()["tokens"][:, :16])
    full, _ = tlm.forward(cfg, params, toks)
    cache = tlm.init_kv_cache(cfg, toks.shape[0], 32)
    lg, cache = tlm.prefill(cfg, params, toks, cache)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(full[:, -1], np.float32),
                               atol=5e-2)
    # decode one token and compare against forward on the extended sequence
    nxt = jnp.argmax(lg, -1, keepdims=True).astype(jnp.int32)
    lg2, _ = tlm.decode_step(cfg, params, nxt, cache, jnp.int32(16))
    full2, _ = tlm.forward(cfg, params, jnp.concatenate([toks, nxt], 1))
    np.testing.assert_allclose(np.asarray(lg2, np.float32),
                               np.asarray(full2[:, -1], np.float32),
                               atol=5e-2)


def test_gnn_shapes_cells_reduced():
    """Exercise each GNN cell kind: full graph, sampled, packed molecules."""
    from repro.models import gnn, sampler
    rng = np.random.default_rng(0)
    G = sampler.random_graph(500, 6, 12, 5)
    ns = sampler.NeighborSampler(G, [4, 3])
    sub = ns.sample(np.arange(8))
    assert sub["x"].shape[0] == 8 + 8 * 4 + 8 * 4 * 3
    cfg = gnn.GATConfig(name="t", d_feat=12, n_classes=5)
    p = gnn.init_params(cfg, jax.random.key(0))
    loss, _ = gnn.loss_fn(cfg, p, {k: jnp.asarray(v) for k, v in sub.items()})
    assert bool(jnp.isfinite(loss))

    mol = sampler.pack_molecule_batch(rng, 4, 10, 20, 12, 3)
    cfgm = gnn.GATConfig(name="t", d_feat=12, n_classes=3, readout="mean")
    pm = gnn.init_params(cfgm, jax.random.key(1))
    out = gnn.forward(cfgm, pm, {k: jnp.asarray(v) for k, v in mol.items()})
    assert out.shape == (4, 3)


def test_retrieval_scoring_paths():
    """recsys retrieval_cand cells: vectorised candidate scoring."""
    from repro.configs.registry import get_arch
    for arch_id in ["dcn-v2", "mind", "autoint", "dien"]:
        arch = get_arch(arch_id)
        cfg, batch_fn = arch.reduced()
        mod = arch.module
        params = mod.init_params(cfg, jax.random.key(2))
        b = {k: jnp.asarray(v[:1]) for k, v in batch_fn().items()}
        b["candidates"] = jnp.arange(64, dtype=jnp.int32)
        scores = mod.retrieval_score(cfg, params, b)
        assert scores.shape == (64,)
        assert bool(jnp.isfinite(scores).all())
